"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp oracles in repro/kernels/ref.py (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

LEVELS = [(8, 8), (4, 4), (4, 4), (2, 2)]
STARTS = [0, 64, 80, 96]
N_PIX = 100


def _point_data(key, b, nq, h, k, dtype):
    ks = jax.random.split(key, 5)
    lvl = jax.random.randint(ks[0], (b, nq, h, k), 0, 4)
    wl = jnp.take(jnp.asarray([w for _, w in LEVELS]), lvl).astype(jnp.int32)
    hl = jnp.take(jnp.asarray([hh for hh, _ in LEVELS]), lvl).astype(jnp.int32)
    st = jnp.take(jnp.asarray(STARTS), lvl).astype(jnp.int32)
    x = jax.random.uniform(ks[1], (b, nq, h, k), minval=-2.0, maxval=10.0
                           ).astype(dtype)
    y = jax.random.uniform(ks[2], (b, nq, h, k), minval=-2.0, maxval=10.0
                           ).astype(dtype)
    p = jax.nn.softmax(jax.random.normal(ks[3], (b, nq, h, k)), axis=-1
                       ).astype(dtype)
    return x, y, st, wl, hl, p


@pytest.mark.parametrize("b,nq,h,k,dh", [
    (1, 16, 1, 4, 8), (2, 37, 3, 16, 32), (1, 128, 8, 16, 32), (2, 5, 2, 64, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_msgs_fused_sweep(b, nq, h, k, dh, dtype):
    key = jax.random.PRNGKey(b * 100 + nq)
    v = jax.random.normal(key, (b, N_PIX, h, dh)).astype(dtype)
    x, y, st, wl, hl, p = _point_data(key, b, nq, h, k, dtype)
    out = ops.msgs_fused(v, x, y, st, wl, hl, p, block_q=16)
    want = ref.msgs_fused_ref(v, x, y, st, wl, hl, p)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_msgs_fused_remap_fwp_compact():
    key = jax.random.PRNGKey(3)
    b, nq, h, k, dh = 2, 33, 2, 8, 16
    n_rows = 40                                  # compacted buffer (+0 row)
    v = jax.random.normal(key, (b, n_rows, h, dh))
    v = v.at[:, -1].set(0.0)                     # sentinel row = zeros
    remap = jax.random.randint(key, (b, N_PIX), 0, n_rows)
    x, y, st, wl, hl, p = _point_data(key, b, nq, h, k, jnp.float32)
    out = ops.msgs_fused(v, x, y, st, wl, hl, p, remap=remap, block_q=16)
    want = ref.msgs_fused_ref(v, x, y, st, wl, hl, p, remap=remap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_msgs_fused_zero_probs_prune_exactly():
    """PAP semantics: zero-probability points contribute exactly nothing."""
    key = jax.random.PRNGKey(4)
    b, nq, h, k, dh = 1, 20, 2, 8, 16
    v = jax.random.normal(key, (b, N_PIX, h, dh))
    x, y, st, wl, hl, p = _point_data(key, b, nq, h, k, jnp.float32)
    mask = jax.random.bernoulli(key, 0.5, p.shape)
    p_masked = jnp.where(mask, p, 0.0)
    out = ops.msgs_fused(v, x, y, st, wl, hl, p_masked, block_q=16)
    want = ref.msgs_fused_ref(v, x, y, st, wl, hl, p_masked)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(70, 90, 50), (128, 128, 128), (33, 257, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    key = jax.random.PRNGKey(m)
    x = jax.random.normal(key, (m, k)).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)).astype(dtype)
    out = ops.matmul(x, w, bm=32, bn=32, bk=32)
    want = ref.matmul_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_matmul_int8_dequant_in_kernel():
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (64, 96))
    w = jax.random.normal(jax.random.fold_in(key, 1), (96, 48))
    s = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0
    wq = jnp.clip(jnp.round(w / s), -128, 127).astype(jnp.int8)
    out = ops.matmul(x, wq, s, bm=32, bn=16, bk=32)
    want = ref.matmul_ref(x, wq, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # and the quantized result approximates the f32 one
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=0.2, atol=0.2)


def test_kernel_matches_unfused_reference():
    """Fusion (C6) must not change semantics: fused kernel == the
    materialize-then-aggregate baseline."""
    key = jax.random.PRNGKey(11)
    b, nq, h, k, dh = 2, 24, 2, 16, 16
    v = jax.random.normal(key, (b, N_PIX, h, dh))
    x, y, st, wl, hl, p = _point_data(key, b, nq, h, k, jnp.float32)
    fused = ops.msgs_fused(v, x, y, st, wl, hl, p, block_q=8)
    unfused = ref.msgs_unfused_ref(v, x, y, st, wl, hl, p)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)
