"""Serve-engine tests: bucket routing (exact fit vs pad-up), admission
rejection, mixed-load bit-parity with the single-shape synchronous
engine, the AOT compile-count spy (zero recompiles after warmup),
starvation reporting, post-processing (top-k decode, callbacks, worker
exception propagation), and streaming session churn accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import msda
from repro.core.msdeform_attn import MSDeformAttnConfig
from repro.serve.buckets import BucketRouter, derive_buckets
from repro.serve.engine import DetrRequest, DetrServeEngine
from repro.serve.postproc import (PostprocWorker, StarvationError,
                                  softmax_np, topk_detections)


def _tiny_cfg():
    from repro.core.detector import DetectorConfig
    from repro.core.encoder import EncoderConfig
    attn = MSDeformAttnConfig(d_model=32, n_heads=2, n_levels=4, n_points=2,
                              fwp_mode="compact", fwp_k=1.0,
                              fwp_capacity=0.6,
                              range_narrow=(8.0, 6.0, 4.0, 3.0))
    return DetectorConfig(
        encoder=EncoderConfig(attn=attn, n_blocks=1, d_ffn=64),
        img_size=32, n_classes=3, backbone_width=8,
        decoder=msda.MSDADecoderConfig(n_layers=2, n_queries=8, d_ffn=32))


def _params(cfg):
    from repro.core.detector import init_detector
    return init_detector(jax.random.PRNGKey(1), cfg)


def _images(n, size, key=2):
    from repro.data.detection import synth_detection_batch
    shapes = tuple((size // k, size // k) for k in (4, 8, 16, 32))
    img, _, _, _ = synth_detection_batch(jax.random.PRNGKey(key), n, size,
                                         shapes)
    return np.asarray(img)


# --------------------------------------------------------------------------
# bucket derivation + routing
# --------------------------------------------------------------------------

def test_bucket_routing_exact_fit_and_pad_up():
    cfg = _tiny_cfg()
    router = BucketRouter(derive_buckets(cfg, (64, 32)))
    assert [b.resolution for b in router.buckets] == [32, 64]
    assert router.route(32, 32).resolution == 32       # exact fit
    assert router.route(20, 30).resolution == 32       # pad up, same bucket
    assert router.route(33, 8).resolution == 64        # one dim overflows
    assert router.route(64, 64).resolution == 64
    assert router.route(65, 10) is None                # oversized
    # per-bucket plans carry the bucket's pyramid
    b32, b64 = router.buckets
    assert b32.level_shapes == ((8, 8), (4, 4), (2, 2), (1, 1))
    assert b64.level_shapes == ((16, 16), (8, 8), (4, 4), (2, 2))
    assert b64.n_in == 4 * b32.n_in
    # derivation is memoized per shape: same plan object on re-derive
    again = derive_buckets(cfg, (32, 64))
    assert again[0].plan is b32.plan and again[1].plan is b64.plan
    # resolutions must divide the pyramid strides
    with pytest.raises(ValueError, match="stride"):
        derive_buckets(cfg, (48,))
    # admission validation
    _, reason = router.admit(np.zeros((1, 8, 8), np.float32))
    assert "(3, H, W)" in reason
    _, reason = router.admit(np.zeros((3, 0, 8), np.float32))
    assert "degenerate" in reason
    _, reason = router.admit(np.zeros((3, 65, 8), np.float32))
    assert "exceeds the largest bucket" in reason
    table = router.table()
    assert [row["resolution"] for row in table] == [32, 64]
    assert all(row["table_kb"] > 0 for row in table)


def test_oversized_request_rejected_not_served():
    cfg = _tiny_cfg()
    engine = DetrServeEngine(cfg, _params(cfg), max_batch=2,
                             resolutions=(32,), pipeline_postproc=False)
    ok_req = DetrRequest(rid=0, image=_images(1, 32)[0])
    big_req = DetrRequest(rid=1, image=np.zeros((3, 48, 48), np.float32))
    assert engine.submit(ok_req) is True
    assert engine.submit(big_req) is False
    assert big_req.error is not None and "48x48" in big_req.error
    done = engine.run_until_drained()
    assert [r.rid for r in done] == [0]
    assert engine.rejected == [big_req] and not big_req.done


# --------------------------------------------------------------------------
# mixed load: bit-parity, compile spy, starvation
# --------------------------------------------------------------------------

def test_same_shape_workload_bit_identical_to_single_bucket_sync():
    """On a same-shape workload the bucketed, pipelined engine must be
    BIT-identical to the single-shape synchronous engine: routing and the
    postproc thread change scheduling, never results."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    imgs = _images(5, 32)
    sync = DetrServeEngine(cfg, params, max_batch=2, resolutions=(32,),
                           pipeline_postproc=False)
    piped = DetrServeEngine(cfg, params, max_batch=2, resolutions=(32, 64),
                            pipeline_postproc=True)
    for eng in (sync, piped):
        for i in range(len(imgs)):
            assert eng.submit(DetrRequest(rid=i, image=imgs[i]))
        eng.run_until_drained()
    by_rid = lambda eng: {r.rid: r for r in eng.finished}
    a, b = by_rid(sync), by_rid(piped)
    assert set(a) == set(b) == set(range(len(imgs)))
    for rid in a:
        assert b[rid].bucket == 32                  # routed, not padded up
        np.testing.assert_array_equal(a[rid].cls_probs, b[rid].cls_probs)
        np.testing.assert_array_equal(a[rid].boxes, b[rid].boxes)
        np.testing.assert_array_equal(a[rid].detections["scores"],
                                      b[rid].detections["scores"])
    piped.close()


def test_aot_buckets_zero_recompiles_under_mixed_load():
    """All compilation happens at engine construction; a mixed-resolution
    load (exact fits, pad-ups, short batches) must never retrace."""
    cfg = _tiny_cfg()
    engine = DetrServeEngine(cfg, _params(cfg), max_batch=2,
                             resolutions=(32, 64))
    assert engine.compile_count == len(engine.buckets) == 2
    # compile_count is a view over the registry counter: one labelled
    # series per bucket, each bumped exactly once at trace time
    m = engine.obs.metrics.get("msda_compiles_total")
    assert m.total() == 2
    assert m.value(bucket="32") == 1 and m.value(bucket="64") == 1
    imgs32, imgs64 = _images(3, 32), _images(2, 64)
    rid = 0
    for im in list(imgs32) + list(imgs64):
        assert engine.submit(DetrRequest(rid=rid, image=im))
        rid += 1
    # pad-up: odd sizes land in the 32/64 buckets
    for h, w in ((20, 28), (40, 64)):
        assert engine.submit(DetrRequest(
            rid=rid, image=imgs64[0][:, :h, :w].copy()))
        rid += 1
    done = engine.run_until_drained()
    assert len(done) == rid
    assert engine.compile_count == 2, "mixed load recompiled"
    assert engine.obs.metrics.get("msda_compiles_total").total() == 2
    assert sorted(r.rid for r in done) == list(range(rid))
    for r in done:
        assert r.cls_probs.shape == (8, cfg.n_classes + 1)
        assert np.all(np.isfinite(r.cls_probs))
    engine.close()


def test_run_until_drained_raises_starvation_report():
    cfg = _tiny_cfg()
    engine = DetrServeEngine(cfg, _params(cfg), max_batch=2,
                             resolutions=(32,), pipeline_postproc=False)
    for i in range(5):
        engine.submit(DetrRequest(rid=i, image=_images(1, 32, key=i)[0]))
    with pytest.raises(StarvationError) as ei:
        engine.run_until_drained(max_steps=1)
    rep = ei.value.report
    assert rep["queued"] == {32: 3} and rep["finished"] == 2
    # nothing was dropped: a follow-up drain completes the backlog
    done = engine.run_until_drained()
    assert sorted(r.rid for r in done) == list(range(5))


# --------------------------------------------------------------------------
# post-processing stage
# --------------------------------------------------------------------------

def test_topk_detections_and_callbacks():
    probs = softmax_np(np.asarray([[9.0, 0.0, -9.0],     # class 0
                                   [0.0, 9.0, -9.0],     # class 1
                                   [-9.0, -9.0, 9.0]]))  # background
    boxes = np.tile(np.asarray([[0.5, 0.5, 0.2, 0.2]]), (3, 1))
    det = topk_detections(probs, boxes, k=2)
    assert list(det["labels"]) == [0, 1]                 # background excluded
    assert det["scores"][0] >= det["scores"][1]
    assert det["boxes"].shape == (2, 4)
    cfg = _tiny_cfg()
    engine = DetrServeEngine(cfg, _params(cfg), max_batch=2,
                             resolutions=(32,), topk=3)
    fired = []
    for i in range(2):
        engine.submit(DetrRequest(rid=i, image=_images(2, 32)[i],
                                  callback=lambda r: fired.append(r.rid)))
    done = engine.run_until_drained()
    assert sorted(fired) == [0, 1]
    for r in done:
        assert len(r.detections["scores"]) == 3
        assert r.t_done >= r.t_submit > 0
    engine.close()


def test_postproc_worker_propagates_exceptions():
    def boom(item):
        raise ValueError("decode failed")
    w = PostprocWorker(boom, pipelined=True)
    w.submit(("x",))
    with pytest.raises(ValueError, match="decode failed"):
        w.drain()
    w.close()


# --------------------------------------------------------------------------
# streaming session churn: no frame dropped, none served twice
# --------------------------------------------------------------------------

def test_streaming_session_churn_accounting():
    from repro.serve.engine import StreamingDetrEngine
    from repro.stream import StreamConfig, drifting_scene
    levels = ((8, 10), (4, 5), (2, 3))
    attn = MSDeformAttnConfig(d_model=32, n_heads=4, fwp_mode="compact",
                              fwp_k=1.0, fwp_capacity=0.6,
                              range_narrow=(4.0, 3.0, 2.0))
    dec = msda.MSDADecoderConfig(n_layers=2, n_queries=8, d_ffn=32)
    key = jax.random.PRNGKey(3)
    d = attn.d_model
    params = {
        "decoder": msda.init_decoder(key, dec, attn),
        "cls_head": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                            (d, 3)) * 0.1,
                     "b": jnp.zeros((3,))},
        "box_head": {"w": jax.random.normal(jax.random.fold_in(key, 2),
                                            (d, 4)) * 0.1,
                     "b": jnp.zeros((4,))},
    }
    engine = StreamingDetrEngine(
        attn, dec, params, levels, max_sessions=2,
        stream_cfg=StreamConfig(tile_rows=1, delta_threshold=1e-4,
                                update_frac=0.9),
        update_fwp=False)
    scene = drifting_scene(3, levels, d, 6, batch=2)
    submitted = {}
    s0 = engine.open_session()
    s1 = engine.open_session()
    for t in range(2):
        engine.submit_frame(s0, scene[t][0])
        engine.submit_frame(s1, scene[t][1])
    submitted[s0], submitted[s1] = 2, 2
    engine.run_until_drained()
    closed = engine.close_session(s1)          # churn: leave mid-load...
    s2 = engine.open_session()                 # ...and a new session joins
    for t in range(2, 4):
        engine.submit_frame(s0, scene[t][0])
        engine.submit_frame(s2, scene[t][1])
    submitted[s0] += 2
    submitted[s2] = 2
    engine.run_until_drained()
    done = {s.sid: s.frames_done for s in engine.sessions.values()}
    done[closed.sid] = closed.frames_done
    assert done == submitted                   # no frame dropped/duplicated
    assert sum(len(s.queue) for s in engine.sessions.values()) == 0
    for sess in list(engine.sessions.values()) + [closed]:
        frames = [r["frame"] for r in sess.results]
        assert frames == list(range(len(frames)))   # each served once
    # a starved drain reports instead of silently returning
    engine.submit_frame(s0, scene[4][0])
    with pytest.raises(StarvationError) as ei:
        engine.run_until_drained(max_steps=0)
    assert ei.value.report["queued"] == {s0: 1}
    engine.run_until_drained()


def test_starvation_error_is_runtime_error_with_report():
    from repro.serve.lm import ServeEngine  # noqa: F401 — import side check
    err = StarvationError({"queued": 3})
    assert isinstance(err, RuntimeError)
    assert err.report["queued"] == 3 and "queued=3" in str(err)
    # the report is stamped (wall clock for logs, perf_counter to line up
    # with span data) unless the caller already supplied the keys
    assert err.report["wall_time"] > 0
    assert err.report["t_monotonic"] > 0


def test_starvation_error_reports_most_starved_age():
    err = StarvationError({"queued": {32: 3, 64: 1},
                           "oldest_age_s": {32: 1.25, 64: 0.5}})
    assert "most-starved request (queue 32) has waited 1.250s" in str(err)


# --------------------------------------------------------------------------
# worker / engine lifecycle (close joins the thread, submit-after-close)
# --------------------------------------------------------------------------

def test_postproc_worker_close_joins_and_rejects_submit():
    seen = []
    w = PostprocWorker(seen.append, pipelined=True)
    for i in range(3):
        w.submit(i)
    w.close()
    # FIFO queue + trailing stop sentinel: close() drained the backlog
    assert seen == [0, 1, 2]
    assert w._thread is None
    with pytest.raises(RuntimeError, match="closed"):
        w.submit(3)
    assert seen == [0, 1, 2]
    w.close()                                     # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        w.submit(4)


def test_postproc_worker_sync_mode_close_rejects_submit():
    seen = []
    w = PostprocWorker(seen.append, pipelined=False)
    w.submit(0)
    w.close()
    with pytest.raises(RuntimeError, match="closed"):
        w.submit(1)
    assert seen == [0]


def test_postproc_worker_context_manager():
    seen = []
    with PostprocWorker(seen.append, pipelined=True) as w:
        w.submit("a")
    assert seen == ["a"] and w._thread is None
    with pytest.raises(RuntimeError, match="closed"):
        w.submit("b")


def test_postproc_worker_submit_after_crash_raises():
    def boom(item):
        raise ValueError("decode failed")
    w = PostprocWorker(boom, pipelined=True)
    w.submit(("x",))
    with pytest.raises(ValueError, match="decode failed"):
        w.drain()
    # the crash surfaces on submit too — never enqueue after a dead loop
    with pytest.raises(ValueError, match="decode failed"):
        w.submit(("y",))
    w.close()


def test_engine_close_joins_worker_thread():
    cfg = _tiny_cfg()
    engine = DetrServeEngine(cfg, _params(cfg), max_batch=2,
                             resolutions=(32,))
    thread = engine._post._thread
    assert thread is not None and thread.is_alive()
    engine.submit(DetrRequest(rid=0, image=_images(1, 32)[0]))
    engine.step()
    engine.close()
    assert not thread.is_alive()                 # daemon joined, not leaked
    assert engine._post._thread is None
    assert [r.rid for r in engine.finished] == [0]   # close drained postproc
    with pytest.raises(RuntimeError, match="closed"):
        engine._post.submit(("dead",))
    engine.close()                               # idempotent


def test_engine_context_manager_closes_worker():
    cfg = _tiny_cfg()
    with DetrServeEngine(cfg, _params(cfg), max_batch=2,
                         resolutions=(32,)) as engine:
        engine.submit(DetrRequest(rid=0, image=_images(1, 32)[0]))
        done = engine.run_until_drained()
        assert [r.rid for r in done] == [0]
    assert engine._post._thread is None


# --------------------------------------------------------------------------
# tuned-budget provenance on the serving surfaces
# --------------------------------------------------------------------------

def test_bucket_table_reports_budget_provenance():
    cfg = _tiny_cfg()
    router = BucketRouter(derive_buckets(cfg, (32,)))
    (row,) = router.table()
    assert row["budget_kb"] > 0
    assert row["budget_source"] in ("static", "measured")


def test_streaming_capacity_estimate_reports_budget_source():
    from repro.msda import plan as plan_lib
    from repro.serve.engine import StreamingDetrEngine
    levels = ((8, 10), (4, 5), (2, 3))
    attn = MSDeformAttnConfig(d_model=32, n_heads=4, fwp_mode="compact",
                              fwp_k=1.0, fwp_capacity=0.6,
                              range_narrow=(4.0, 3.0, 2.0))
    dec = msda.MSDADecoderConfig(n_layers=2, n_queries=8, d_ffn=32)
    key = jax.random.PRNGKey(3)
    d = attn.d_model
    params = {
        "decoder": msda.init_decoder(key, dec, attn),
        "cls_head": {"w": jnp.zeros((d, 3)), "b": jnp.zeros((3,))},
        "box_head": {"w": jnp.zeros((d, 4)), "b": jnp.zeros((4,))},
    }
    engine = StreamingDetrEngine(attn, dec, params, levels, max_sessions=1,
                                 update_fwp=False)
    est = engine.capacity_estimate()
    # the engine's ensure_applied() loaded the committed table, so the
    # default budget is the measured one (static only without a table)
    assert est["budget_source"] == ("measured" if plan_lib.tuned_entry()
                                    else "static")
    assert est["budget_bytes"] == plan_lib.window_staging_budget()
    assert engine.capacity_estimate(budget_bytes=1 << 20)["budget_source"] \
        == "caller"
