"""Substrate tests: optimizer, data pipeline, checkpointing, fault-tolerant
loop (restart determinism + failure injection), serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    latest_step, load_checkpoint, restore_into, save_checkpoint)
from repro.data.tokens import TokenDataConfig, synth_token_batch
from repro.models.common import ModelConfig
from repro.models.registry import get_api
from repro.optim.adamw import OptConfig, adamw_init, adamw_update, lr_at
from repro.optim.compress import dequantize_grad, quantize_grad
from repro.serve.lm import Request, ServeConfig, ServeEngine
from repro.train.loop import (
    FailureInjector, SimulatedNodeFailure, TrainLoopConfig, train_loop)
from repro.train.step import build_train_step, make_train_state

CFG = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256, dtype=jnp.float32,
                  remat=False)
DATA = TokenDataConfig(vocab_size=256, seq_len=32, global_batch=8, seed=3)
OPT = OptConfig(lr=1e-2, warmup_steps=2, total_steps=50, weight_decay=0.0)


def _batch(step):
    return synth_token_batch(DATA, step)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert abs(lrs[99] - 0.1) < 0.05
    assert max(lrs) <= 1.0 + 1e-6


def test_data_pipeline_deterministic_and_sharded():
    b1 = synth_token_batch(DATA, 7)
    b2 = synth_token_batch(DATA, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    shards = [synth_token_batch(DATA, 7, shard_id=i, num_shards=4)["tokens"]
              for i in range(4)]
    assert all(s.shape == (2, 33) for s in shards)
    assert not np.array_equal(shards[0], shards[1])


def test_training_loss_decreases():
    state = make_train_state(jax.random.PRNGKey(0), CFG)
    step = jax.jit(build_train_step(CFG, OPT))
    losses = []
    for i in range(15):
        state, m = step(state, _batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_grad_accumulation_matches_full_batch():
    import dataclasses
    cfg1 = CFG
    cfg4 = dataclasses.replace(CFG, grad_accum=4)
    state1 = make_train_state(jax.random.PRNGKey(0), cfg1)
    state4 = make_train_state(jax.random.PRNGKey(0), cfg4)
    s1 = jax.jit(build_train_step(cfg1, OPT))
    s4 = jax.jit(build_train_step(cfg4, OPT))
    b = _batch(0)
    state1, m1 = s1(state1, b)
    state4, m4 = s4(state4, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5, atol=1e-5)
    for a, c in zip(jax.tree.leaves(state1.params), jax.tree.leaves(state4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=5e-4, atol=5e-4)


def test_checkpoint_roundtrip(tmp_path):
    state = make_train_state(jax.random.PRNGKey(0), CFG)
    save_checkpoint(str(tmp_path), 5, state)
    assert latest_step(str(tmp_path)) == 5
    step, loaded = load_checkpoint(str(tmp_path))
    restored = restore_into(state, loaded)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_injection_and_restart_determinism(tmp_path):
    """Crash at step 7, restart from checkpoint, and land bitwise-identical
    to an uninterrupted run (the checkpoint/restart contract)."""
    loop_cfg = TrainLoopConfig(total_steps=12, ckpt_every=5, log_every=100)
    step_fn = jax.jit(build_train_step(CFG, OPT))

    # uninterrupted reference
    ref_state = make_train_state(jax.random.PRNGKey(0), CFG)
    ref_state, _ = train_loop(ref_state, step_fn, _batch, loop_cfg,
                              ckpt_dir=None, log=lambda s: None)

    ckpt_dir = str(tmp_path / "ckpt")
    state = make_train_state(jax.random.PRNGKey(0), CFG)
    inj = FailureInjector(fail_at_step=7)
    with pytest.raises(SimulatedNodeFailure):
        train_loop(state, step_fn, _batch, loop_cfg, ckpt_dir=ckpt_dir,
                   injector=inj, log=lambda s: None)
    assert latest_step(ckpt_dir) == 5
    # "new node" restarts from scratch state + checkpoint
    state2 = make_train_state(jax.random.PRNGKey(0), CFG)
    state2, _ = train_loop(state2, step_fn, _batch, loop_cfg,
                           ckpt_dir=ckpt_dir, log=lambda s: None)
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(state2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_grad_compression_roundtrip_and_error_feedback():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (128,))
    q, s = quantize_grad(g, bits=8)
    err = g - dequantize_grad(q, s)
    assert float(jnp.abs(err).max()) <= float(s) * 0.5 + 1e-6
    # error feedback: accumulated residual keeps the LONG-RUN average exact
    total_sent = jnp.zeros_like(g)
    residual = jnp.zeros_like(g)
    for _ in range(50):
        q, s = quantize_grad(g + residual, bits=4)
        sent = dequantize_grad(q, s)
        residual = (g + residual) - sent
        total_sent = total_sent + sent
    np.testing.assert_allclose(np.asarray(total_sent / 50), np.asarray(g),
                               atol=float(s))


def test_serve_engine_matches_offline_decode():
    cfg = CFG
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(5) + 1, np.arange(9) + 3, np.arange(3) + 11]
    engine = ServeEngine(cfg, params, ServeConfig(max_batch=2, cache_len=64))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    done = engine.run_until_drained()
    assert len(done) == 3 and all(len(r.output) == 6 for r in reqs)

    # offline greedy reference, one request at a time
    for r in reqs:
        cache = api.init_cache(cfg, 1, 64)
        logits, cache = api.prefill(params, cfg, cache,
                                    {"tokens": jnp.asarray(r.prompt)[None]})
        toks = [int(jnp.argmax(logits, -1)[0])]
        pos = len(r.prompt)
        for _ in range(5):
            logits, cache = api.decode_step(
                params, cfg, cache, jnp.asarray([toks[-1]], jnp.int32),
                jnp.asarray([pos], jnp.int32))
            toks.append(int(jnp.argmax(logits, -1)[0]))
            pos += 1
        assert toks == r.output, (toks, r.output)
