"""Observability subsystem tests: the metrics registry (counters /
gauges / fixed-bucket histograms + Prometheus round-trip), the span
tracer (ring buffer, JSONL sink, cross-thread end, negative-duration
guard), the engine instrumentation contracts (compile counter == bucket
count, latency histogram == completed requests, <1% overhead), the
streaming counters vs ``mgr.report()``, ``MSDAPlan.snapshot()``
consistency, the JSONL/Prometheus validator, and the dashboard
renderer on synthetic events."""
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.obs import (MetricsRegistry, NullRegistry, NullTracer,
                       Observability, Tracer, json_snapshot,
                       parse_prometheus_text, prometheus_text)
from repro.obs.metrics import DEFAULT_BYTES_BUCKETS, default_registry


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_counter_labels_total_and_negative_guard():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "test counter")
    c.inc(bucket="32")
    c.inc(2.0, bucket="64", outcome="completed")
    assert c.value(bucket="32") == 1.0
    # label order is irrelevant (sorted key)
    assert c.value(outcome="completed", bucket="64") == 2.0
    assert c.value(bucket="none") == 0.0
    assert c.total() == 3.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    # get-or-create returns the same object; kind mismatch raises
    assert reg.counter("requests_total") is c
    with pytest.raises(TypeError):
        reg.gauge("requests_total")


def test_gauge_set_inc_dec():
    g = MetricsRegistry().gauge("queue_depth")
    g.set(5, bucket="32")
    g.inc(bucket="32")
    g.dec(2, bucket="32")
    assert g.value(bucket="32") == 4.0


def test_histogram_buckets_quantile_and_counts():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.005, 0.05, 0.5, 5.0):
        h.observe(v, span="device")
    assert h.count(span="device") == 5
    assert h.total_count() == 5
    assert h.sum_value(span="device") == pytest.approx(5.56)
    # bucket-resolution quantiles: upper bound of the holding bucket
    assert h.quantile(0.5, span="device") == 0.1
    assert h.quantile(0.99, span="device") == float("inf")
    assert h.quantile(0.5, span="nope") is None
    (series,) = h.collect()
    assert series["buckets"] == [[0.01, 2], [0.1, 3], [1.0, 4]]  # cumulative
    assert series["count"] == 5
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(1.0, 0.1))


def test_registry_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("c_total").inc(k="v")
    reg.gauge("g").set(2.0)
    reg.histogram("h_seconds", buckets=DEFAULT_BYTES_BUCKETS).observe(2048.0)
    snap = reg.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["c_total"]["values"] == [
        {"labels": {"k": "v"}, "value": 1.0}]
    assert snap["histograms"]["h_seconds"]["bucket_bounds"] == \
        list(DEFAULT_BYTES_BUCKETS)
    # snapshots are JSON-serializable as-is
    json.dumps(snap)


def test_null_registry_and_tracer_are_inert():
    obs = Observability.disabled()
    assert not obs.enabled
    obs.metrics.counter("x_total").inc(a="b")
    obs.metrics.gauge("g").set(1.0)
    obs.metrics.histogram("h").observe(1.0)
    assert obs.metrics.snapshot() == {"counters": {}, "gauges": {},
                                      "histograms": {}}
    sid = obs.tracer.start("queue")
    obs.tracer.end(sid)                     # no-op, never raises
    with obs.tracer.span("device"):
        pass
    assert obs.tracer.span_stats() == {} and obs.tracer.snapshot() == []


def test_default_registry_is_a_process_singleton():
    assert default_registry() is default_registry()
    assert isinstance(default_registry(), MetricsRegistry)


# --------------------------------------------------------------------------
# prometheus export round-trip
# --------------------------------------------------------------------------

def test_prometheus_text_round_trips_through_strict_parser():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(3, bucket="32")
    reg.counter("req_total").inc(bucket="64", outcome="ok")
    reg.gauge("depth", "queue depth").set(7, bucket="32")
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = prometheus_text(reg)
    parsed = parse_prometheus_text(text)
    assert (frozenset({"bucket": "32"}.items()), 3.0) in [
        (frozenset(l.items()), v) for l, v in parsed["req_total"]]
    assert parsed["depth"] == [({"bucket": "32"}, 7.0)]
    # histogram renders cumulative _bucket{le=} + _sum/_count series
    le = {l["le"] if l["le"] == "+Inf" else float(l["le"]): v
          for l, v in parsed["lat_seconds_bucket"]}
    assert le == {0.1: 1.0, 1.0: 2.0, "+Inf": 2.0}
    assert parsed["lat_seconds_count"] == [({}, 2.0)]
    assert parsed["lat_seconds_sum"][0][1] == pytest.approx(0.55)


@pytest.mark.parametrize("bad", [
    "not a metric line at all {",
    'x_total{unterminated="1 3.0',
    "x_total not-a-number",
    "# MALFORMED comment kind",
])
def test_prometheus_parser_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_prometheus_text(bad + "\n")


# --------------------------------------------------------------------------
# tracer
# --------------------------------------------------------------------------

def test_tracer_spans_ring_buffer_and_stats():
    tr = Tracer(capacity=4)
    for i in range(6):
        sid = tr.start("step", rid=i)
        tr.end(sid, items=i)
    assert len(tr.spans) == 4                       # bounded ring
    assert [s.rid for s in tr.spans] == [2, 3, 4, 5]
    st = tr.span_stats()["step"]
    assert st["count"] == 4 and st["p50_ms"] >= 0.0
    assert tr.open_count() == 0
    snap = tr.snapshot(last=2)
    assert len(snap) == 2 and snap[-1]["rid"] == 5


def test_tracer_unknown_end_and_negative_duration_raise():
    tr = Tracer()
    with pytest.raises(KeyError):
        tr.end("t0-999")
    sid = tr.start("queue", t=100.0)
    with pytest.raises(ValueError):
        tr.end(sid, t=99.0)                         # clock went backwards
    # the span survives the refused end and can close properly
    assert tr.open_count() == 1
    sp = tr.end(sid, t=101.5)
    assert sp.duration_s == pytest.approx(1.5)


def test_tracer_cross_thread_end():
    tr = Tracer()
    sid = tr.start("device", rid=7)
    t = threading.Thread(target=lambda: tr.end(sid))
    t.start()
    t.join()
    assert tr.open_count() == 0 and tr.spans[-1].rid == 7


def test_tracer_jsonl_sink_and_validator(tmp_path):
    from repro.obs.validate import validate_jsonl
    path = str(tmp_path / "events.jsonl")
    obs = Observability.create(jsonl_path=path)
    with obs.tracer.span("frame_in", rid="s0", n=2):
        pass
    obs.metrics.counter("frames_total").inc()
    obs.flush_metrics()
    obs.tracer.event("plan", engine="test", plan={"backend": "jnp_gather"})
    obs.close()
    r = validate_jsonl(path)
    assert r["spans"] == 1 and r["names"] == ["frame_in"]
    types = [json.loads(l)["type"] for l in open(path)]
    assert types == ["span_start", "span_end", "metrics", "plan"]


def test_validator_rejects_broken_logs(tmp_path):
    from repro.obs.validate import main, validate_jsonl

    def _check(lines, match):
        p = tmp_path / "bad.jsonl"
        p.write_text("\n".join(json.dumps(e) for e in lines) + "\n")
        with pytest.raises(ValueError, match=match):
            validate_jsonl(str(p))
        assert main(["--jsonl", str(p)]) == 1       # CLI exits nonzero

    start = {"type": "span_start", "span": "a", "name": "q", "t": 1.0}
    _check([start], "never ended")
    _check([{"type": "span_end", "span": "a", "name": "q", "t": 2.0,
             "dur_s": 1.0}], "without matching")
    _check([start, {"type": "span_end", "span": "a", "name": "q", "t": 2.0,
                    "dur_s": -0.5}], "negative/missing duration")
    _check([start, {"type": "span_end", "span": "a", "name": "other",
                    "t": 2.0, "dur_s": 1.0}], "name mismatch")
    _check([start, start], "duplicate span_start")


# --------------------------------------------------------------------------
# instrumented engines
# --------------------------------------------------------------------------

def _tiny_engine():
    from tests.test_serve import _params, _tiny_cfg
    from repro.serve.engine import DetrServeEngine
    cfg = _tiny_cfg()
    return DetrServeEngine(cfg, _params(cfg), max_batch=2,
                           resolutions=(32, 64))


def test_engine_metrics_compile_counter_and_latency_histogram():
    """(i) compile counter == bucket count via the registry, (ii) latency
    histogram total == completed requests under mixed-resolution churn,
    (iii) per-request instrumentation cost < 1% of the measured request
    latency."""
    from tests.test_serve import _images
    from repro.serve.engine import DetrRequest
    engine = _tiny_engine()
    m = engine.obs.metrics
    compiles = m.get("msda_compiles_total")
    assert compiles.total() == len(engine.buckets) == 2
    assert compiles.value(bucket="32") == 1.0
    assert compiles.value(bucket="64") == 1.0

    imgs = list(_images(3, 32)) + list(_images(2, 64)) \
        + [np.asarray(_images(1, 64)[0][:, :40, :48])]      # pad-up route
    rid = 0
    for im in imgs:
        assert engine.submit(DetrRequest(rid=rid, image=im))
        rid += 1
    done = engine.run_until_drained()
    assert len(done) == rid

    # zero retraces under churn, asserted against the registry
    assert compiles.total() == 2
    assert engine.compile_count == 2                        # back-compat view
    lat = m.get("serve_request_latency_seconds")
    assert lat.total_count() == rid
    assert lat.count(bucket="32") == 3 and lat.count(bucket="64") == 3
    req = m.get("serve_requests_total")
    assert req.value(bucket="32", outcome="admitted") == 3
    assert req.value(outcome="completed", bucket="32") == 3
    # every request produced a queue + device + postproc span
    stats = engine.obs.tracer.span_stats()
    assert stats["queue"]["count"] == rid
    assert stats["device"]["count"] >= 1
    assert stats["postproc"]["count"] >= 1

    # (iii) overhead: deterministic per-request instrumentation cost
    # (what the serve path adds per request) vs measured request latency
    mean_req_s = lat.sum_value(bucket="64") / lat.count(bucket="64")
    probe = Observability.create()
    c = probe.metrics.counter("x_total")
    h = probe.metrics.histogram("x_seconds")
    n = 1000
    t0 = time.perf_counter()
    for i in range(n):
        c.inc(bucket="32", outcome="completed")
        for name in ("queue", "device", "postproc"):
            probe.tracer.end(probe.tracer.start(name, rid=i))
        h.observe(1e-3, bucket="32")
        h.observe(1e-3, span="device")
    per_req_s = (time.perf_counter() - t0) / n
    probe.close()
    assert per_req_s < 0.01 * mean_req_s, \
        f"instrumentation {per_req_s*1e6:.1f}us vs request {mean_req_s*1e6:.0f}us"
    engine.close()


def test_engine_rejected_requests_counted():
    from repro.serve.engine import DetrRequest
    engine = _tiny_engine()
    assert not engine.submit(DetrRequest(
        rid=0, image=np.zeros((3, 100, 100), np.float32)))   # oversized
    assert engine.obs.metrics.value("serve_requests_total",
                                    bucket="none", outcome="rejected") == 1.0
    engine.close()


def test_disabled_engine_serves_identically_with_empty_registry():
    from tests.test_serve import _images
    from repro.serve.engine import DetrRequest
    from repro.serve.engine import DetrServeEngine
    from tests.test_serve import _params, _tiny_cfg
    cfg = _tiny_cfg()
    engine = DetrServeEngine(cfg, _params(cfg), max_batch=2,
                             resolutions=(32,), obs=Observability.disabled())
    for i, im in enumerate(_images(2, 32)):
        assert engine.submit(DetrRequest(rid=i, image=im))
    done = engine.run_until_drained()
    assert len(done) == 2 and all(np.isfinite(r.cls_probs).all()
                                  for r in done)
    assert engine.obs.metrics.snapshot()["counters"] == {}
    assert engine.compile_count == 0        # null counter: the view reads 0
    engine.close()


def test_streaming_manager_counters_match_report():
    from tests.test_stream import N_IN, _cfg, _mgr, D
    from repro.stream import StreamConfig
    mgr, plan = _mgr(_cfg(), StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                          update_frac=0.5))
    key = jax.random.PRNGKey(3)
    x0 = jax.random.normal(key, (2, N_IN, D))
    mgr.step(x0)                                     # rebuild (cold)
    mgr.step(x0.at[:, 0:3].add(0.5))                 # incremental
    r = mgr.report()
    m = mgr.obs.metrics
    frames = m.get("stream_frames_total")
    assert frames.total() == r["frames"] == 2
    assert frames.value(mode="rebuild") == r["rebuild_frames"] == 1
    assert frames.value(mode="incremental") == r["incremental_frames"] == 1
    assert m.get("staged_bytes_total").total() == r["staged_bytes_total"]
    assert m.get("stream_rebuilds_total").value(reason="first-frame") == 1
    # trace_counts (the old dict) is now a live view over the registry
    assert mgr.trace_counts == {
        k: int(m.get("msda_traces_total").value(fn=k))
        for k in ("build", "frame", "restage")}
    # scatter/rebuild/diff spans recorded with durations
    stats = mgr.obs.tracer.span_stats()
    assert "diff" in stats and "rebuild" in stats
    assert all(st["total_s"] >= 0 for st in stats.values())


# --------------------------------------------------------------------------
# plan snapshot
# --------------------------------------------------------------------------

def test_plan_snapshot_is_structured_twin_of_describe():
    from repro import msda
    from repro.core.msdeform_attn import MSDeformAttnConfig
    cfg = MSDeformAttnConfig(d_model=32, n_heads=4, fwp_mode="compact",
                             fwp_k=1.0, fwp_capacity=0.6,
                             range_narrow=(4.0, 3.0, 2.0))
    plan = msda.make_plan(cfg, ((8, 10), (4, 5), (2, 3)),
                          backend="jnp_gather", n_queries=16, n_consumers=2)
    snap = plan.snapshot()
    assert snap["backend"] == plan.backend
    assert snap["value_table_bytes"] == plan.value_table_bytes
    assert snap["budget_source"] == plan.budget_source
    assert snap["decode"]["n_consumers"] == 2
    json.dumps(snap)                                 # exporter-safe
    # describe() is a pure formatter over the snapshot: the numbers in
    # the string are the numbers in the dict
    d = plan.describe()
    assert plan.backend in d
    assert f"table={snap['value_table_bytes'] / 1024:.0f}KB" in d


def test_engine_plan_events_logged_per_bucket(tmp_path, monkeypatch):
    from repro.obs.obs import OBS_JSONL_ENV
    path = str(tmp_path / "serve.jsonl")
    monkeypatch.setenv(OBS_JSONL_ENV, path)
    engine = _tiny_engine()                          # obs=None -> env sink
    engine.close()
    plans = [json.loads(l) for l in open(path)
             if json.loads(l)["type"] == "plan"]
    assert sorted(p["bucket"] for p in plans) == [32, 64]
    assert all(p["plan"]["backend"] == plans[0]["plan"]["backend"]
               for p in plans)


# --------------------------------------------------------------------------
# json snapshot + dashboard
# --------------------------------------------------------------------------

def test_json_snapshot_schema(tmp_path):
    from repro.obs import write_json_snapshot
    obs = Observability.create()
    obs.metrics.counter("c_total").inc()
    with obs.tracer.span("device"):
        pass
    snap = json_snapshot(obs.metrics, obs.tracer, extra={"run": "t1"})
    assert snap["schema"] == "repro.obs/v1"
    assert snap["metrics"]["counters"]["c_total"]["values"][0]["value"] == 1.0
    assert snap["spans"]["device"]["count"] == 1
    assert snap["run"] == "t1"
    p = tmp_path / "snap.json"
    write_json_snapshot(str(p), obs.metrics, obs.tracer)
    assert json.loads(p.read_text())["schema"] == "repro.obs/v1"
    obs.close()


def _synthetic_events():
    snap = {"counters": {
        "serve_requests_total": {"help": "", "values": [
            {"labels": {"bucket": "32", "outcome": "completed"},
             "value": 9.0}]},
        "staged_bytes_total": {"help": "", "values": [
            {"labels": {"mode": "incremental"}, "value": 4096.0},
            {"labels": {"mode": "rebuild"}, "value": 65536.0}]},
        "stream_frames_total": {"help": "", "values": [
            {"labels": {"mode": "incremental"}, "value": 8.0},
            {"labels": {"mode": "rebuild"}, "value": 1.0}]},
        "stream_rebuilds_total": {"help": "", "values": [
            {"labels": {"reason": "cold"}, "value": 1.0}]},
    }, "gauges": {
        "serve_queue_depth": {"help": "", "values": [
            {"labels": {"bucket": "32"}, "value": 3.0}]},
    }, "histograms": {}}
    return [
        {"type": "span_start", "span": "a", "name": "device", "t": 1.0},
        {"type": "span_end", "span": "a", "name": "device", "t": 1.02,
         "dur_s": 0.02},
        {"type": "plan", "t": 1.1, "bucket": "32",
         "plan": {"backend": "jnp_gather", "budget_source": "measured",
                  "table_dtype": "float32", "value_table_bytes": 43520}},
        {"type": "metrics", "t": 2.0, "data": snap},
    ]


def test_dashboard_renders_synthetic_events():
    from repro.obs.dashboard import feed_event, new_model, render_dashboard
    model = new_model()
    for ev in _synthetic_events():
        feed_event(model, ev)
    out = render_dashboard(model, width=80)
    assert "requests completed: 9" in out
    assert "bucket    32: ███" in out
    assert "device" in out and "20.00" in out        # 0.02 s span as ms
    assert "incremental:rebuild frames = 8:1" in out
    assert "rebuild reason cold" in out
    assert "backend=jnp_gather" in out and "budget=measured" in out
    # every line fits the box
    assert all(len(l) == 80 for l in out.splitlines())


def test_dashboard_feed_lines_tolerates_torn_tail():
    from repro.obs.dashboard import feed_lines, new_model
    model = new_model()
    lines = [json.dumps(e) for e in _synthetic_events()]
    lines.append('{"type": "span_start", "span": "b", "na')   # torn write
    feed_lines(model, lines)
    assert model["events"] == 4                      # torn line skipped
