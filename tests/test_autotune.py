"""Measured-plan autotuner: persistence round-trip, corrupt-table
fallback, the committed-table CI path, and the plan-memo staleness
regressions (env flips and tuned-table apply/clear mid-process) the
measured table would otherwise trip over."""
import json
import warnings

import numpy as np
import pytest

from repro import msda
from repro.msda import autotune
from repro.msda import plan as plan_lib

CFG = autotune._default_cfg()
LEVELS = autotune.CALIB_LEVELS


def _entry(budget=12 * 2**20, stride=2, frac=0.5, beneficial=True):
    """A structurally valid platform entry with distinctive values."""
    return {"provenance": "measured", "platform": autotune.platform_key(),
            "staging_budget_bytes": int(budget),
            "decode_sweep_beneficial": bool(beneficial),
            "decode_persistent_speedup": 1.0,
            "stream": {"diff_channel_stride": int(stride),
                       "update_frac": float(frac)}}


# --------------------------------------------------------------------------
# Persistence round-trip
# --------------------------------------------------------------------------

def test_round_trip_identical_plan(tmp_path):
    """persist -> reload -> the applied entry and the resolved plan are
    identical to the in-process originals."""
    path = str(tmp_path / "autotune.json")
    entry = _entry(budget=12 * 2**20)
    autotune.save_entry(entry, path)

    loaded = autotune.plan_autotune(measure=False, cache_path=path)
    assert loaded == entry
    plan_a = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan_a.staging_budget_bytes == 12 * 2**20
    assert plan_a.budget_source == "measured"
    assert "budget=measured" in plan_a.describe()

    # clear, reload from disk: bit-identical plan resolution
    plan_lib.apply_tuned_plan_table(None)
    reloaded = autotune.plan_autotune(measure=False, cache_path=path)
    assert reloaded == entry
    plan_b = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan_b == plan_a


def test_save_entry_merges_platforms(tmp_path):
    """Writing one platform's entry never clobbers another's row."""
    path = str(tmp_path / "autotune.json")
    other = dict(_entry(budget=2**20), platform="tpu")
    autotune.save_entry(other, path, platform="tpu")
    autotune.save_entry(_entry(budget=12 * 2**20), path)
    table = autotune.load_table(path)
    assert set(table["platforms"]) == {"tpu", autotune.platform_key()}
    assert table["platforms"]["tpu"]["staging_budget_bytes"] == 2**20


# --------------------------------------------------------------------------
# Corrupted / partial tables fall back to the static formulas
# --------------------------------------------------------------------------

def test_corrupt_table_warns_and_falls_back(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text("{not json at all")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        got = autotune.plan_autotune(measure=False, cache_path=str(path),
                                     warn_missing=False)
    assert got is None
    plan = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan.budget_source == "static"
    assert plan.staging_budget_bytes == plan_lib.DEFAULT_WINDOW_STAGING_BUDGET


def test_wrong_schema_warns_and_falls_back(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps({"schema": 999, "platforms": {}}))
    with pytest.warns(RuntimeWarning, match="schema"):
        assert autotune.load_table(str(path)) is None


def test_partial_entry_warns_and_falls_back(tmp_path):
    """A truncated/hand-mangled entry (missing the stream block) fails
    closed to the static formulas with a warning — never a crash."""
    path = tmp_path / "autotune.json"
    bad = _entry()
    del bad["stream"]
    path.write_text(json.dumps(
        {"schema": autotune.SCHEMA_VERSION,
         "platforms": {autotune.platform_key(): bad}}))
    with pytest.warns(RuntimeWarning, match="partial/invalid"):
        got = autotune.plan_autotune(measure=False, cache_path=str(path),
                                     warn_missing=False)
    assert got is None
    assert plan_lib.tuned_entry() is None


def test_missing_table_is_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert autotune.load_table("/nonexistent/autotune.json") is None


def test_valid_entry_rejects_partials():
    assert autotune.valid_entry(_entry())
    for mutate in (
        lambda e: e.pop("staging_budget_bytes"),
        lambda e: e.update(staging_budget_bytes=0),
        lambda e: e.pop("decode_sweep_beneficial"),
        lambda e: e.pop("stream"),
        lambda e: e["stream"].update(diff_channel_stride=0),
        lambda e: e["stream"].update(update_frac=0.0),
        lambda e: e["stream"].update(update_frac=1.5),
    ):
        e = _entry()
        mutate(e)
        assert not autotune.valid_entry(e), e


# --------------------------------------------------------------------------
# Committed-table CI path (no timing runs)
# --------------------------------------------------------------------------

def test_committed_table_no_measure():
    """The repo's committed results/autotune.json serves this platform
    without any timing: measured provenance end-to-end."""
    entry = autotune.plan_autotune(measure=False)
    assert entry is not None, (
        "no committed autotune entry for platform "
        f"{autotune.platform_key()!r} in results/autotune.json")
    plan = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan.budget_source == "measured"
    assert "budget=measured" in plan.describe()


def test_committed_table_check_cli():
    """The CI leg verbatim: --no-measure --check exits 0 (provenance +
    tuned-vs-static bit-identity)."""
    assert autotune.main(["--no-measure", "--check"]) == 0


def test_ensure_applied_is_load_only_and_once():
    autotune._ENSURE_TRIED = False
    got = autotune.ensure_applied()
    assert got == plan_lib.tuned_entry()
    # second call is a no-op returning the applied entry (or None)
    assert autotune.ensure_applied() == got


def test_ensure_applied_never_raises(tmp_path):
    autotune._ENSURE_TRIED = False
    plan_lib.apply_tuned_plan_table(None)
    bad = tmp_path / "autotune.json"
    bad.write_text("garbage{")
    assert autotune.ensure_applied(cache_path=str(bad)) is None
    assert plan_lib.tuned_entry() is None


# --------------------------------------------------------------------------
# Satellite regression: plan_for memo staleness on mid-process changes
# --------------------------------------------------------------------------

def test_plan_for_env_budget_flip(monkeypatch):
    plan0 = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan0.staging_budget_bytes == \
        plan_lib.DEFAULT_WINDOW_STAGING_BUDGET
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "1024")
    plan1 = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan1.staging_budget_bytes == 1024
    assert plan1 != plan0
    monkeypatch.delenv("REPRO_MSDA_VMEM_BUDGET")
    assert plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6) == plan0


def test_plan_for_env_table_dtype_flip(monkeypatch):
    plan0 = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan0.table_dtype == "float32"
    monkeypatch.setenv("REPRO_MSDA_TABLE_DTYPE", "int8")
    plan1 = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan1.table_dtype == "int8"
    assert plan1 != plan0


def test_plan_for_env_query_order_flip(monkeypatch):
    plan0 = plan_lib.plan_for(CFG, LEVELS, None)
    assert plan0.query_order == "none"
    monkeypatch.setenv("REPRO_MSDA_QUERY_ORDER", "zorder")
    plan1 = plan_lib.plan_for(CFG, LEVELS, None)
    assert plan1.query_order == "zorder"
    assert plan1 != plan0


def test_plan_for_tuned_table_flip():
    """Applying/clearing a tuned table mid-process must never serve a
    stale memoized plan — the measured-table analogue of the env bug."""
    plan0 = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan0.budget_source == "static"
    plan_lib.apply_tuned_plan_table(_entry(budget=24 * 2**20))
    plan1 = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan1.staging_budget_bytes == 24 * 2**20
    assert plan1.budget_source == "measured"
    plan_lib.apply_tuned_plan_table(None)
    assert plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6) == plan0


def test_env_pin_beats_tuned_table(monkeypatch):
    """REPRO_MSDA_VMEM_BUDGET is the documented operator override: it
    wins over an applied measured entry and reports static provenance."""
    plan_lib.apply_tuned_plan_table(_entry(budget=24 * 2**20))
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", str(2 * 2**20))
    plan = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan.staging_budget_bytes == 2 * 2**20
    assert plan.budget_source == "static"


# --------------------------------------------------------------------------
# Tuned knobs reach the streaming config
# --------------------------------------------------------------------------

def test_resolve_stream_config_consumes_tuned_table():
    from repro.stream import StreamConfig, resolve_stream_config
    base = resolve_stream_config(None)
    assert (base.diff_channel_stride, base.update_frac) == (1, 0.25)
    plan_lib.apply_tuned_plan_table(_entry(stride=2, frac=0.5))
    tuned = resolve_stream_config(None)
    assert (tuned.diff_channel_stride, tuned.update_frac) == (2, 0.5)
    # an explicit config always wins untouched
    mine = StreamConfig(diff_channel_stride=4)
    assert resolve_stream_config(mine) is mine
    plan_lib.apply_tuned_plan_table(None)
    again = resolve_stream_config(None)
    assert (again.diff_channel_stride, again.update_frac) == (1, 0.25)


def test_decode_sweep_veto_gates_auto():
    """A measured decode-sweep loss flips the auto policy's decode gate
    to per-layer restaging; numerics are untouched (backend choice only)."""
    plan_yes = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan_yes.backend == "pallas_decode"
    plan_lib.apply_tuned_plan_table(_entry(beneficial=False))
    plan_no = plan_lib.plan_for(CFG, LEVELS, "auto", 64, 6)
    assert plan_no.backend != "pallas_decode"


# --------------------------------------------------------------------------
# Tuned-vs-static bit-identity (tuning changes choice, never numerics)
# --------------------------------------------------------------------------

def test_tuned_vs_static_bit_identity():
    import jax
    import jax.numpy as jnp
    from repro.core import nn
    from repro.core.msdeform_attn import init_msdeform_attn

    plan_lib.apply_tuned_plan_table(_entry(budget=24 * 2**20))
    tuned_plan = msda.make_plan(CFG, LEVELS, backend="auto")
    key = jax.random.PRNGKey(5)
    params = init_msdeform_attn(key, CFG)
    n_in = tuned_plan.n_in
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, n_in, CFG.d_model))
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, n_in, CFG.d_model))
    refs = jnp.broadcast_to(
        nn.reference_points_for_levels(LEVELS)[None], (1, n_in, 2))
    out_tuned, _ = msda.msda_attention(params, tuned_plan, q, refs, x)

    plan_lib.apply_tuned_plan_table(None)
    static_plan = msda.make_plan(CFG, LEVELS, backend=tuned_plan.backend)
    assert static_plan.budget_source == "static"
    out_static, _ = msda.msda_attention(params, static_plan, q, refs, x)
    np.testing.assert_array_equal(np.asarray(out_tuned),
                                  np.asarray(out_static))
