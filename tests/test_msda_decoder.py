"""Decoder + shared-ValueCache tests: build-once spy, cross-attention
backend parity (packed + pad-lane geometries, FWP off/compact), grads
through the decoder stack, and the detector/serving integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import msda
from repro.core import nn
from repro.core.msdeform_attn import MSDeformAttnConfig, init_msdeform_attn
from repro.msda import cache as cache_mod

LEVELS = ((16, 20), (8, 10), (4, 5), (2, 3))
N_IN = sum(h * w for h, w in LEVELS)
B = 2
RANGES = (6.0, 4.0, 3.0, 2.0)
DEC_BACKENDS = ("jnp_gather", "pallas_fused")    # decode-shaped launches


def _geometry(packed: bool):
    """packed: 8 heads x Dh=32 -> 4-head lane groups; pad-lane: Dh=40."""
    d, heads = (256, 8) if packed else (80, 2)
    return MSDeformAttnConfig(d_model=d, n_heads=heads, range_narrow=RANGES)


def _setup(packed: bool, **cfg_kw):
    cfg = dataclasses.replace(_geometry(packed), **cfg_kw)
    key = jax.random.PRNGKey(5 if packed else 7)
    mem = jax.random.normal(key, (B, N_IN, cfg.d_model))
    dcfg = msda.MSDADecoderConfig(n_layers=3, n_queries=20, d_ffn=64)
    dparams = msda.init_decoder(jax.random.fold_in(key, 1), dcfg, cfg)
    state = None
    if cfg.fwp_mode != "off":
        # one raster encoder pass builds the FWP link the cache compacts by
        eparams = init_msdeform_attn(jax.random.fold_in(key, 2), cfg)
        eplan = msda.make_plan(cfg, LEVELS, backend="jnp_gather")
        refs = jnp.broadcast_to(
            nn.reference_points_for_levels(LEVELS)[None], (B, N_IN, 2))
        _, state = msda.msda_attention(eparams, eplan, mem, refs, mem)
        assert state.fwp is not None
    return cfg, dcfg, dparams, mem, state


# --------------------------------------------------------------------------
# decoder cross-attention parity across backends
# --------------------------------------------------------------------------

@pytest.mark.parametrize("packed", (False, True), ids=("padlane", "packed"))
@pytest.mark.parametrize("fwp", ("off", "compact"))
def test_decoder_backend_parity(packed, fwp):
    """The full decoder stack must be numerically identical through the
    jnp_gather oracle and the pallas_fused kernel, in both lane layouts,
    dense and FWP-compacted."""
    kw = {} if fwp == "off" else dict(fwp_mode="compact", fwp_k=1.0,
                                      fwp_capacity=0.6)
    cfg, dcfg, dparams, mem, state = _setup(packed, **kw)
    outs = {}
    for be in DEC_BACKENDS:
        plan = msda.make_plan(cfg, LEVELS, backend=be,
                              n_queries=dcfg.n_queries,
                              n_consumers=dcfg.n_layers)
        if packed:
            assert plan.lane_layout == "pack" and plan.head_pack == 4
        else:
            assert plan.lane_layout == "pad" and plan.head_pack == 1
        h, refs, _ = msda.decoder_apply(dparams, dcfg, plan, mem, state)
        outs[be] = (np.asarray(h), np.asarray(refs))
    np.testing.assert_allclose(outs["pallas_fused"][0],
                               outs["jnp_gather"][0], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["pallas_fused"][1],
                               outs["jnp_gather"][1], rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# build-once spy: ONE value projection serves every decoder layer
# --------------------------------------------------------------------------

class _ProjectionSpy:
    def __init__(self):
        self.calls = 0
        self._real = cache_mod.project_values

    def __call__(self, *args, **kwargs):
        self.calls += 1
        return self._real(*args, **kwargs)


def test_decoder_builds_value_cache_exactly_once(monkeypatch):
    """6 decoder layers, ONE value projection: the shared cache is built
    once and every layer samples it — no re-projection."""
    cfg, _, _, mem, state = _setup(True, fwp_mode="compact", fwp_k=1.0,
                                   fwp_capacity=0.6)
    dcfg = msda.MSDADecoderConfig(n_layers=6, n_queries=20, d_ffn=64)
    dparams = msda.init_decoder(jax.random.PRNGKey(3), dcfg, cfg)
    plan = msda.make_plan(cfg, LEVELS, backend="jnp_gather",
                          n_queries=dcfg.n_queries,
                          n_consumers=dcfg.n_layers)
    spy = _ProjectionSpy()
    monkeypatch.setattr(cache_mod, "project_values", spy)
    h, _, dstate = msda.decoder_apply(dparams, dcfg, plan, mem, state,
                                      collect_stats=True)
    monkeypatch.undo()
    assert spy.calls == 1, f"value projection ran {spy.calls}x for 6 layers"
    assert len(dstate.block_stats) == dcfg.n_layers
    # the cache's geometry contract: per-level slot windows are the level
    # capacities, bounded by the table rows EXCLUDING the sentinel
    from repro.core.fwp import level_capacities
    caps = level_capacities(LEVELS, cfg.fwp_capacity)
    assert dstate.cache.slot_windows == tuple(
        min(int(c), dstate.cache.n_rows - 1) for c in caps)
    assert sum(caps) + 1 == dstate.cache.n_rows
    # every layer sampled the SAME compacted table
    assert dstate.cache is not None
    assert all(int(s["value_rows"]) == dstate.cache.n_rows
               for s in dstate.block_stats)
    assert bool(jnp.all(jnp.isfinite(h)))


def test_decoder_never_densifies_compact_cache(monkeypatch):
    """Under fwp_mode="compact" no decoder layer may densify the shared
    table back to (B, N_in, H, Dh): no 4-D take_along_axis anywhere in
    the decoder forward."""
    cfg, dcfg, dparams, mem, state = _setup(True, fwp_mode="compact",
                                            fwp_k=1.0, fwp_capacity=0.6)
    plan = msda.make_plan(cfg, LEVELS, backend="pallas_fused",
                          n_queries=dcfg.n_queries,
                          n_consumers=dcfg.n_layers)
    ndims = []
    real = jnp.take_along_axis

    def spy(arr, idx, axis=None, **kw):
        ndims.append(arr.ndim)
        return real(arr, idx, axis=axis, **kw)

    monkeypatch.setattr(jnp, "take_along_axis", spy)
    msda.decoder_apply(dparams, dcfg, plan, mem, state)
    monkeypatch.undo()
    assert all(nd != 4 for nd in ndims), ndims


# --------------------------------------------------------------------------
# fwp chain semantics through the decoder
# --------------------------------------------------------------------------

def test_decoder_carries_fwp_link_without_rebuilding():
    """update_fwp=False semantics: the decoder samples a FIXED memory, so
    its state keeps the encoder's FWP link unchanged instead of deriving
    a new mask per layer."""
    cfg, dcfg, dparams, mem, state = _setup(True, fwp_mode="compact",
                                            fwp_k=1.0, fwp_capacity=0.6)
    plan = msda.make_plan(cfg, LEVELS, backend="jnp_gather",
                          n_queries=dcfg.n_queries)
    _, _, dstate = msda.decoder_apply(dparams, dcfg, plan, mem, state)
    assert dstate.fwp is state.fwp                 # same link, not rebuilt
    assert dstate.block_index == dcfg.n_layers


# --------------------------------------------------------------------------
# gradients through the decoder stack
# --------------------------------------------------------------------------

def test_grad_through_decoder_smoke():
    cfg, dcfg, dparams, mem, state = _setup(False, fwp_mode="compact",
                                            fwp_k=1.0, fwp_capacity=0.6)
    plan = msda.make_plan(cfg, LEVELS, backend="jnp_gather",
                          n_queries=dcfg.n_queries)

    def loss(p):
        h, refs, _ = msda.decoder_apply(p, dcfg, plan, mem, state)
        return jnp.mean(jnp.square(h)) + jnp.mean(refs)

    val, grads = jax.value_and_grad(loss)(dparams)
    assert np.isfinite(float(val))
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in leaves)
    # the build-once value projection must receive gradient from ALL layers
    assert float(np.abs(np.asarray(grads["value"]["value_w"])).sum()) > 0
    # and the per-layer sampling weights train too
    assert float(np.abs(np.asarray(
        grads["layers"][0]["cross"]["offs_w"])).sum()) > 0
    # the reference refinement head must be trainable: only the INCOMING
    # refs are detached, the per-layer delta stays live (a fully detached
    # update would freeze the zero-init weights forever)
    for layer in grads["layers"]:
        assert float(np.abs(np.asarray(layer["ref_delta"]["w"])).sum()) > 0


# --------------------------------------------------------------------------
# detector + serving integration
# --------------------------------------------------------------------------

def _tiny_decoder_detector():
    from repro.core.detector import DetectorConfig
    from repro.core.encoder import EncoderConfig
    attn = MSDeformAttnConfig(d_model=32, n_heads=2, n_levels=4, n_points=2,
                              fwp_mode="compact", fwp_k=1.0,
                              fwp_capacity=0.6,
                              range_narrow=(8.0, 6.0, 4.0, 3.0))
    return DetectorConfig(
        encoder=EncoderConfig(attn=attn, n_blocks=2, d_ffn=64),
        img_size=32, n_classes=4, backbone_width=16,
        decoder=msda.MSDADecoderConfig(n_layers=2, n_queries=12, d_ffn=64))


def test_detector_decoder_head_end_to_end():
    from repro.core.detector import (decoder_detection_loss, detector_apply,
                                     init_detector)
    from repro.data.detection import synth_detection_batch
    cfg = _tiny_decoder_detector()
    key = jax.random.PRNGKey(0)
    params = init_detector(key, cfg)
    img, _, _, gt = synth_detection_batch(key, 2, cfg.img_size,
                                          cfg.level_shapes)
    cls, box, aux = jax.jit(
        lambda p, i: detector_apply(p, cfg, i, collect_stats=True))(params, img)
    assert cls.shape == (2, 12, cfg.n_classes + 1)
    assert box.shape == (2, 12, 4)
    assert len(aux["decoder_blocks"]) == 2
    assert bool(jnp.all(jnp.isfinite(cls))) and bool(jnp.all((box >= 0)
                                                             & (box <= 1)))
    (l, _), grads = jax.value_and_grad(decoder_detection_loss, has_aux=True)(
        params, cfg, img, gt["cls"], gt["box"], gt["active"])
    assert np.isfinite(float(l))
    assert all(np.all(np.isfinite(np.asarray(g)))
               for g in jax.tree.leaves(grads))


def test_hungarian_and_greedy_disagree_on_contested_query():
    """Pinned case: two GTs both prefer query 0. Greedy assigns BOTH to
    query 0 (collision); the Hungarian matcher finds the globally optimal
    collision-free assignment with strictly lower total cost."""
    from repro.core import detector as det
    if det._linear_sum_assignment is None:
        pytest.skip("scipy not installed (optional dep)")
    cost = jnp.asarray([[[0.00, 0.10, 5.0],
                         [0.05, 4.00, 5.0]]])          # (1, 2 gts, 3 queries)
    active = jnp.ones((1, 2), bool)
    greedy = np.asarray(det.match_queries(cost, active, matcher="greedy"))
    hung = np.asarray(det.match_queries(cost, active, matcher="hungarian"))
    np.testing.assert_array_equal(greedy, [[0, 0]])    # the collision
    np.testing.assert_array_equal(hung, [[1, 0]])      # optimal, distinct
    # greedy is not even a valid assignment (both gts claim q0); among
    # VALID (injective) assignments the Hungarian one is the optimum
    import itertools
    c = np.asarray(cost[0])
    total = lambda own: c[np.arange(2), list(own)].sum()
    assert len(set(hung[0])) == 2 and len(set(greedy[0])) == 1
    best = min(total(p) for p in itertools.permutations(range(3), 2))
    np.testing.assert_allclose(total(hung[0]), best)
    # auto mode (scipy present) resolves to the Hungarian assignment
    auto = np.asarray(det.match_queries(cost, active))
    np.testing.assert_array_equal(auto, hung)


def test_hungarian_ignores_inactive_gt_rows():
    """An inactive GT whose cost row would win query 0 must not steal it
    from the active GT: inactive rows are flattened to a constant cost."""
    from repro.core import detector as det
    if det._linear_sum_assignment is None:
        pytest.skip("scipy not installed (optional dep)")
    cost = jnp.asarray([[[0.5, 3.0],
                         [0.0, 9.0]]])                 # gt1 wants q0 harder...
    active = jnp.asarray([[True, False]])              # ...but is inactive
    own = np.asarray(det.match_queries(cost, active, matcher="hungarian"))
    assert own[0, 0] == 0                              # active gt keeps q0


def test_decoder_loss_hungarian_end_to_end():
    """decoder_detection_loss with the Hungarian matcher stays jit- and
    grad-compatible (pure_callback under stop_gradient) and finite."""
    from repro.core import detector as det
    from repro.data.detection import synth_detection_batch
    if det._linear_sum_assignment is None:
        pytest.skip("scipy not installed (optional dep)")
    cfg = _tiny_decoder_detector()
    params = det.init_detector(jax.random.PRNGKey(4), cfg)
    img, _, _, gt = synth_detection_batch(jax.random.PRNGKey(5), 2,
                                          cfg.img_size, cfg.level_shapes)
    loss_fn = jax.jit(jax.value_and_grad(
        lambda p: det.decoder_detection_loss(
            p, cfg, img, gt["cls"], gt["box"], gt["active"],
            matcher="hungarian")[0]))
    l, grads = loss_fn(params)
    assert np.isfinite(float(l))
    assert all(np.all(np.isfinite(np.asarray(g)))
               for g in jax.tree.leaves(grads))
    # and the greedy fallback still runs (optional-dep guard path)
    l2, _ = det.decoder_detection_loss(params, cfg, img, gt["cls"],
                                       gt["box"], gt["active"],
                                       matcher="greedy")
    assert np.isfinite(float(l2))


def test_detr_serve_engine_decoder_head():
    from repro.core.detector import init_detector
    from repro.data.detection import synth_detection_batch
    from repro.serve.engine import DetrRequest, DetrServeEngine
    cfg = _tiny_decoder_detector()
    params = init_detector(jax.random.PRNGKey(1), cfg)
    engine = DetrServeEngine(cfg, params, max_batch=2)
    assert "build-once" in engine.describe()
    img, _, _, _ = synth_detection_batch(jax.random.PRNGKey(2), 3,
                                         cfg.img_size, cfg.level_shapes)
    for i in range(3):                    # 3 requests -> 2 steps (pad lane)
        engine.submit(DetrRequest(rid=i, image=np.asarray(img[i])))
    done = engine.run_until_drained()
    assert len(done) == 3 and all(r.done for r in done)
    for r in done:
        assert r.cls_probs.shape == (12, cfg.n_classes + 1)
        assert r.boxes.shape == (12, 4)
        assert np.all(np.isfinite(r.cls_probs)) and np.all(np.isfinite(r.boxes))
