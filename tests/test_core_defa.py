"""DEFA algorithm tests: exactness contracts, pruning invariants, quant
bounds, and hypothesis property tests on the paper's mechanisms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# given/settings/st skip property tests cleanly when hypothesis is absent
from conftest import given, settings, st

from repro.core import fwp as fwp_lib
from repro.core import pap as pap_lib
from repro.core.msdeform_attn import (
    MSDeformAttnConfig, init_msdeform_attn, msdeform_attn_apply,
    msdeform_attn_ref)
from repro.core.quant import fake_quant, quant_scale

LEVELS = ((16, 20), (8, 10), (4, 5), (2, 3))
N_IN = sum(h * w for h, w in LEVELS)
B, NQ, D = 2, 50, 64


@pytest.fixture(scope="module")
def setup():
    cfg = MSDeformAttnConfig(d_model=D, n_heads=4)
    key = jax.random.PRNGKey(0)
    params = init_msdeform_attn(key, cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, NQ, D))
    x = jax.random.normal(k2, (B, N_IN, D))
    refp = jax.random.uniform(k3, (B, NQ, 2))
    out_ref = msdeform_attn_ref(params, cfg, q, refp, x, LEVELS)
    return cfg, params, q, x, refp, out_ref


def _apply(setup_t, **kw):
    cfg, params, q, x, refp, out_ref = setup_t
    cfg2 = dataclasses.replace(cfg, **kw)
    return msdeform_attn_apply(params, cfg2, q, refp, x, LEVELS,
                               collect_stats=True)


def test_defa_apply_equals_oracle_when_off(setup):
    out, _ = _apply(setup)
    np.testing.assert_allclose(np.asarray(out), np.asarray(setup[-1]),
                               rtol=2e-5, atol=2e-5)


def test_pap_topk_full_equals_exact(setup):
    out, _ = _apply(setup, pap_mode="topk", pap_keep=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(setup[-1]),
                               rtol=2e-5, atol=2e-5)


def test_pap_threshold_to_zero_equals_exact(setup):
    out, _ = _apply(setup, pap_mode="threshold", pap_threshold=0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(setup[-1]),
                               rtol=2e-5, atol=2e-5)


def test_pap_monotone_error_in_threshold(setup):
    errs = []
    for thr in (0.01, 0.05, 0.2):
        out, aux = _apply(setup, pap_mode="threshold", pap_threshold=thr)
        errs.append(float(jnp.mean(jnp.abs(out - setup[-1]))))
    assert errs[0] <= errs[1] <= errs[2], errs


def test_pap_topk_matches_threshold_when_covering(setup):
    """topk with K >= survivors == threshold mode (TPU-adapted == faithful)."""
    _, auxt = _apply(setup, pap_mode="threshold", pap_threshold=0.02)
    out_t, _ = _apply(setup, pap_mode="threshold", pap_threshold=0.02)
    # survivors per (q,h) can be anything <= 16; use K=16 with threshold
    cfg, params, q, x, refp, _ = setup
    cfg2 = dataclasses.replace(cfg, pap_mode="topk", pap_keep=16)
    sel_probs = None
    # topk keeps all 16; to mimic threshold also zero small ones:
    probs_sel = pap_lib.pap_topk_select(
        jax.nn.softmax(jnp.einsum("bnd,dhk->bnhk", q, params["attn_w"])
                       + params["attn_b"], axis=-1), 16)
    assert probs_sel.probs.shape[-1] == 16


def test_fwp_mask_equals_compact_when_capacity_covers(setup):
    _, aux_m = _apply(setup, fwp_mode="mask", fwp_k=0.5)
    st_m = aux_m["fwp_state"]
    out_m, _ = msdeform_attn_apply(
        setup[1], dataclasses.replace(setup[0], fwp_mode="mask", fwp_k=0.5),
        setup[2], setup[4], setup[3], LEVELS, fwp_state=st_m)
    _, aux_c = _apply(setup, fwp_mode="compact", fwp_k=0.5, fwp_capacity=1.0)
    out_c, _ = msdeform_attn_apply(
        setup[1], dataclasses.replace(setup[0], fwp_mode="compact", fwp_k=0.5,
                                      fwp_capacity=1.0),
        setup[2], setup[4], setup[3], LEVELS, fwp_state=aux_c["fwp_state"])
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_m),
                               rtol=2e-5, atol=2e-5)


def test_fwp_threshold_monotone_in_k(setup):
    keeps = []
    for k in (0.25, 1.0, 2.0):
        _, aux = _apply(setup, fwp_mode="mask", fwp_k=k)
        keeps.append(float(jnp.mean(aux["fwp_state"].keep_mask)))
    assert keeps[0] >= keeps[1] >= keeps[2], keeps


def test_fwp_frequency_counts_hand_case():
    """One sampling point with all-inbounds corners -> 4 pixels counted once."""
    idx = jnp.asarray([[5, 6, 9, 10]])
    valid = jnp.ones((1, 4))
    freq = fwp_lib.count_frequency(idx, valid, 16)
    assert freq.shape == (1, 16)
    assert float(freq.sum()) == 4.0
    assert float(freq[0, 5]) == 1.0 and float(freq[0, 10]) == 1.0


def test_range_narrow_large_bound_is_identity(setup):
    out, _ = _apply(setup, range_narrow=(1e6, 1e6, 1e6, 1e6))
    np.testing.assert_allclose(np.asarray(out), np.asarray(setup[-1]),
                               rtol=2e-5, atol=2e-5)


def test_range_narrow_bounds_offsets(setup):
    """With a tight bound, all sampled pixels stay within R+1 of reference."""
    cfg, params, q, x, refp, _ = setup
    cfg2 = dataclasses.replace(cfg, range_narrow=(2.0, 2.0, 2.0, 2.0))
    out, aux = msdeform_attn_apply(params, cfg2, q, refp, x, LEVELS,
                                   collect_stats=True)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_int12_close_int8_worse(setup):
    out12, _ = _apply(setup, act_bits=12, weight_bits=12)
    out8, _ = _apply(setup, act_bits=8, weight_bits=8)
    e12 = float(jnp.mean(jnp.abs(out12 - setup[-1])))
    e8 = float(jnp.mean(jnp.abs(out8 - setup[-1])))
    assert e12 < e8, (e12, e8)       # paper: INT8 unacceptable, INT12 fine
    assert e12 < 0.02


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=64),
       st.sampled_from([8, 12]))
def test_fake_quant_error_bound(vals, bits):
    x = jnp.asarray(vals, jnp.float32)
    y = fake_quant(x, bits)
    s = quant_scale(x, bits)
    assert float(jnp.max(jnp.abs(y - x))) <= float(s) * 0.5 + 1e-6


@pytest.mark.parametrize("fwp_mode", ["off", "compact"])
def test_int8_storage_roundtrip_on_cache_value_shapes(fwp_mode):
    """int8-STORAGE parity (the real-bandwidth variant, not fake-quant):
    pack/unpack round-trip on the (B, N_rows, H, Dh) value tables the
    cache actually builds — the dense n_in table and the FWP-compacted
    slot table with its zero sentinel row. Per-channel symmetric int8
    bounds the elementwise error by half a step (s/2)."""
    from repro.core.quant import pack_int8, unpack_int8
    from repro.msda import build_value_cache, make_plan, msda_attention
    from repro.msda.pipeline import MSDAPipelineState

    cfg = MSDeformAttnConfig(d_model=D, n_heads=4, fwp_mode=fwp_mode,
                             fwp_capacity=0.6, fwp_k=1.0)
    key = jax.random.PRNGKey(5)
    params = init_msdeform_attn(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, N_IN, D))
    plan = make_plan(cfg, LEVELS, backend="jnp_gather", n_queries=16)
    state = None
    if fwp_mode == "compact":
        # a real FWP link from one raster pass, so the table is the
        # compacted slot buffer + sentinel the decoder actually samples
        plan_r = make_plan(cfg, LEVELS, backend="jnp_gather")
        q = jax.random.normal(jax.random.fold_in(key, 2), (B, N_IN, D))
        refs = jax.random.uniform(jax.random.fold_in(key, 3), (B, N_IN, 2))
        _, state = msda_attention(params, plan_r, q, refs, x)
    cache = build_value_cache(params, plan, x, state)
    v = cache.v
    assert v.shape[1] == cache.n_rows

    q8, s = pack_int8(v)
    v8 = unpack_int8(q8, s, v.dtype)
    assert q8.dtype == jnp.int8 and v8.shape == v.shape
    # elementwise half-step bound under the per-channel (last-dim) scale
    err = np.asarray(jnp.abs(v8 - v))
    bound = np.asarray(jnp.broadcast_to(s * 0.5, v.shape)) + 1e-6
    assert (err <= bound).all(), float((err - bound).max())
    # aggregate tolerance vs f32 on the real value distribution
    rel = float(jnp.mean(jnp.abs(v8 - v)) / jnp.mean(jnp.abs(v)))
    assert rel < 0.01, rel
    if fwp_mode == "compact":
        # the zero sentinel row must round-trip to EXACT zero (pruned
        # pixels contribute nothing, int8 or not)
        assert not np.asarray(v8[:, -1]).any()
        # and pruned-pixel routing is preserved: sampling the int8
        # round-tripped table through pix2slot changes nothing structural
        assert cache.pix2slot is not None
    # half-step bound also on the storage of a STREAM-updated table:
    # rows written by the incremental path share the same pack contract
    rows = jax.random.normal(jax.random.fold_in(key, 4),
                             (B, 3) + v.shape[2:])
    v_upd = v.at[:, 1:4].set(rows)
    q8u, su = pack_int8(v_upd)
    errs = jnp.abs(unpack_int8(q8u, su, v.dtype) - v_upd)
    assert bool(jnp.all(errs <= su * 0.5 + 1e-6))


@pytest.mark.parametrize("fwp_mode", ["off", "compact"])
def test_int8_table_cache_stores_codes_not_floats(fwp_mode):
    """The end-to-end extension of the storage round-trip above: with
    ``table_dtype="int8"`` the cache itself IS the packed form — ``v``
    holds int8 codes, ``scale`` the frozen (B, 1, H, Dh) f32 per-channel
    scale, and a dense float table is never materialized. The
    dequantized view obeys the same half-step bound against the float
    build, and the compact sentinel row is code 0 exactly. (Full
    sampled-OUTPUT parity across all backends lives in
    tests/test_msda_backends.py.)"""
    import dataclasses as _dc

    from repro.msda import build_value_cache, make_plan, msda_attention
    cfg = MSDeformAttnConfig(d_model=D, n_heads=4, fwp_mode=fwp_mode,
                             fwp_capacity=0.6, fwp_k=1.0)
    key = jax.random.PRNGKey(5)
    params = init_msdeform_attn(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, N_IN, D))
    state = None
    if fwp_mode == "compact":
        plan_r = make_plan(cfg, LEVELS, backend="jnp_gather")
        q = jax.random.normal(jax.random.fold_in(key, 2), (B, N_IN, D))
        refs = jax.random.uniform(jax.random.fold_in(key, 3), (B, N_IN, 2))
        _, state = msda_attention(params, plan_r, q, refs, x)
    plan32 = make_plan(_dc.replace(cfg, table_dtype="float32"), LEVELS,
                       backend="jnp_gather", n_queries=16)
    plan8 = make_plan(_dc.replace(cfg, table_dtype="int8"), LEVELS,
                      backend="jnp_gather", n_queries=16)
    ref = build_value_cache(params, plan32, x, state)
    c8 = build_value_cache(params, plan8, x, state)
    assert ref.scale is None and ref.v.dtype == x.dtype
    assert c8.v.dtype == jnp.int8
    assert c8.scale is not None and c8.scale.shape == (B, 1, 4, D // 4)
    deq = np.asarray(c8.v, np.float32) * np.asarray(c8.scale)
    err = np.abs(deq - np.asarray(ref.v))
    bound = np.broadcast_to(np.asarray(c8.scale) * 0.5, err.shape) + 1e-6
    assert (err <= bound).all(), float((err - bound).max())
    # dtype-aware accounting: the int8 build stages ~4x fewer bytes
    assert c8.table_bytes < ref.table_bytes / 3
    if fwp_mode == "compact":
        assert not np.asarray(c8.v[:, -1]).any()   # sentinel: exact 0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 15))
def test_pap_topk_keep_frac(k):
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(k), (2, 8, 2, 16)))
    sel = pap_lib.pap_topk_select(probs, k)
    assert sel.probs.shape[-1] == k
    np.testing.assert_allclose(float(sel.keep_frac), k / 16, rtol=1e-6)
    # kept probabilities are the k largest
    assert float(sel.probs.min()) >= 0.0


@settings(max_examples=10, deadline=None)
@given(st.floats(0.1, 3.0))
def test_fwp_state_slots_bijective(k):
    """Every surviving pixel maps to a unique compact slot."""
    key = jax.random.PRNGKey(int(k * 100))
    freq = jax.random.randint(key, (1, N_IN), 0, 5).astype(jnp.float32)
    state = fwp_lib.build_fwp_state(freq, LEVELS, k=k, mode="compact",
                                    capacity=1.0)
    p2s = np.asarray(state.pix2slot[0])
    cap = state.keep_idx.shape[1]
    used = p2s[p2s < cap]
    assert len(np.unique(used)) == len(used)      # injective
    # surviving pixels (mask) are exactly those with a slot
    mask = np.asarray(state.keep_mask[0])
    assert ((p2s < cap) == mask).all()
