"""Streaming temporal-reuse tests: tile geometry, incremental-vs-rebuild
parity (including the delta-threshold-0 mode across keep transitions),
frozen-scale quantization, staged-bytes accounting (the >= 2x
drifting-scene criterion), the staged-decode row scatter, and the
StreamingDetrEngine session lifecycle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import msda
from repro.core.msdeform_attn import MSDeformAttnConfig, init_msdeform_attn
from repro.msda.cache import build_value_cache
from repro.msda.pipeline import MSDAPipelineState
from repro.stream import (StreamConfig, TemporalCacheManager, drifting_scene,
                          tile_geometry)

LEVELS = ((8, 10), (4, 5), (2, 3))
N_IN = sum(h * w for h, w in LEVELS)
D = 32


def _cfg(**kw):
    base = dict(d_model=D, n_heads=4, fwp_mode="compact", fwp_k=1.0,
                fwp_capacity=0.6, range_narrow=(4.0, 3.0, 2.0))
    base.update(kw)
    return MSDeformAttnConfig(**base)


def _mgr(cfg, scfg, batch=2, backend="jnp_gather", n_queries=16):
    params = init_msdeform_attn(jax.random.PRNGKey(0), cfg)
    plan = msda.make_plan(cfg, LEVELS, backend=backend,
                          n_queries=n_queries, n_consumers=2)
    vparams = {k: params[k] for k in ("value_w", "value_b")}
    return TemporalCacheManager(plan, vparams, scfg, batch=batch), plan


def _frames(key, batch=2, n=4):
    base = jax.random.normal(key, (batch, N_IN, D))
    return [base + 0.1 * t * jnp.sign(base) for t in range(n)]


def _scratch(mgr, plan, x):
    """Reference: a from-scratch build under the manager's CURRENT keep
    geometry — what a non-streaming deployment would rebuild per frame."""
    return build_value_cache(mgr.params, plan, jnp.asarray(x),
                             MSDAPipelineState(fwp=mgr.fwp))


# --------------------------------------------------------------------------
# tile geometry
# --------------------------------------------------------------------------

def test_tile_geometry_row_aligned_partition():
    geo = tile_geometry(LEVELS, tile_rows=2)
    # tiles partition the flat pixel space, in raster order
    assert geo.n_in == N_IN
    covered = np.zeros(N_IN, bool)
    for t in range(geo.n_tiles):
        lo = geo.tile_pix_start[t]
        hi = lo + geo.tile_pix_count[t]
        assert not covered[lo:hi].any()
        covered[lo:hi] = True
        np.testing.assert_array_equal(geo.tile_of_pixel[lo:hi], t)
        # row alignment: tile extent is a whole number of level rows
        w = LEVELS[geo.tile_level[t]][1]
        assert geo.tile_pix_count[t] % w == 0
    assert covered.all()
    with pytest.raises(ValueError):
        tile_geometry(LEVELS, tile_rows=0)


# --------------------------------------------------------------------------
# incremental parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fwp_mode,backend", [
    ("compact", "jnp_gather"), ("off", "jnp_gather"),
    ("mask", "jnp_gather"), ("compact", "pallas_decode")])
def test_incremental_tile_update_matches_scratch_build(fwp_mode, backend):
    """A localized feature change is scatter-updated into the persistent
    table (and its decode staging) EXACTLY as a from-scratch rebuild of
    the new memory would produce it."""
    cfg = _cfg(fwp_mode=fwp_mode)
    mgr, plan = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                       update_frac=0.5), backend=backend)
    key = jax.random.PRNGKey(1)
    x0 = jax.random.normal(key, (2, N_IN, D))
    mgr.step(x0)
    x1 = x0.at[:, 3:6].add(0.5)                  # one tile of level 0
    cache, st = mgr.step(x1)
    assert st["mode"] == "incremental", st
    assert st["n_dirty"] > 0
    ref = _scratch(mgr, plan, x1)
    np.testing.assert_array_equal(np.asarray(cache.v), np.asarray(ref.v))
    if backend == "pallas_decode":
        assert cache.staged is not None
        np.testing.assert_array_equal(np.asarray(cache.staged.v),
                                      np.asarray(ref.staged.v))


def test_threshold0_parity_across_frames_with_keep_transition():
    """THE acceptance parity: delta-threshold 0 marks every tile changed,
    and across >= 3 consecutive frames — including a keep-mask
    transition — the incremental path's caches match a full per-frame
    rebuild within 1e-5."""
    cfg = _cfg()
    mgr, plan = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=0.0,
                                       update_frac=1.0))
    key = jax.random.PRNGKey(2)
    frames = _frames(key, n=5)
    # structured frequencies whose EMA will flip the warm-start keep set
    freq = jnp.where(jax.random.uniform(jax.random.fold_in(key, 9),
                                        (2, N_IN)) > 0.5, 10.0, 0.0)
    modes, transitions = [], 0
    for t, x in enumerate(frames):
        cache, st = mgr.step(x)
        modes.append(st["mode"])
        transitions += st["keep_transition"]
        ref = _scratch(mgr, plan, x)
        np.testing.assert_allclose(np.asarray(cache.v), np.asarray(ref.v),
                                   atol=1e-5)
        mgr.observe(freq)
    assert transitions >= 1, modes         # the keep set DID transition
    assert modes.count("incremental") >= 3, modes
    # all tiles really were marked changed on the incremental frames
    assert mgr.last_stats["mode"] == "incremental"


def test_over_budget_dirt_falls_back_to_rebuild():
    cfg = _cfg()
    mgr, plan = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                       update_frac=0.05))
    key = jax.random.PRNGKey(3)
    x0 = jax.random.normal(key, (2, N_IN, D))
    mgr.step(x0)
    x1 = x0 + 1.0                                 # everything changes
    cache, st = mgr.step(x1)
    assert st["mode"] == "rebuild" and st["reason"] == "dirty>budget"
    ref = _scratch(mgr, plan, x1)
    np.testing.assert_array_equal(np.asarray(cache.v), np.asarray(ref.v))


def test_subthreshold_drift_accumulates_against_last_projection():
    """The diff reference is the memory as of each tile's last
    re-projection, so repeated sub-threshold drift eventually crosses the
    threshold instead of escaping detection forever."""
    cfg = _cfg(fwp_mode="off")
    thr = 0.5
    mgr, _ = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=thr,
                                    update_frac=1.0))
    key = jax.random.PRNGKey(4)
    x0 = jax.random.normal(key, (2, N_IN, D))
    mgr.step(x0)
    x1 = x0.at[:, 0:3].add(0.3 * thr)             # below threshold
    _, st1 = mgr.step(x1)
    assert st1["mode"] == "incremental" and st1["n_dirty"] == 0
    x2 = x0.at[:, 0:3].add(1.2 * thr)             # cumulative drift crosses
    _, st2 = mgr.step(x2)
    assert st2["n_dirty"] > 0, st2


def test_frozen_scale_quant_keeps_table_grid_stable():
    """With INT12 activations on, incremental updates quantize against
    the scale captured at the last full build: re-projecting unchanged
    rows reproduces the table bit-for-bit (no grid drift)."""
    cfg = _cfg(act_bits=12, weight_bits=12)
    mgr, _ = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=0.0,
                                    update_frac=1.0))
    key = jax.random.PRNGKey(5)
    x0 = jax.random.normal(key, (2, N_IN, D))
    cache0, _ = mgr.step(x0)
    v0 = np.asarray(cache0.v)
    cache1, st = mgr.step(x0)                     # same memory, all "dirty"
    assert st["mode"] == "incremental"
    np.testing.assert_array_equal(np.asarray(cache1.v), v0)


def test_probed_diff_detects_full_width_changes():
    """Channel-strided diffing still catches a real tile change (the
    drifting scene perturbs every channel), and the parity contract is
    unchanged for the rows it updates."""
    cfg = _cfg()
    mgr, plan = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                       update_frac=0.5,
                                       diff_channel_stride=4))
    key = jax.random.PRNGKey(6)
    x0 = jax.random.normal(key, (2, N_IN, D))
    mgr.step(x0)
    x1 = x0.at[:, 3:6].add(0.5)
    cache, st = mgr.step(x1)
    assert st["mode"] == "incremental" and st["n_dirty"] > 0
    ref = _scratch(mgr, plan, x1)
    np.testing.assert_array_equal(np.asarray(cache.v), np.asarray(ref.v))


def test_update_staged_rows_matches_full_restage():
    """Scattering a row subset into the staged decode layout equals
    re-staging the updated table from scratch."""
    from repro.kernels.msgs_decode import (stage_decode_table,
                                           update_staged_rows)
    key = jax.random.PRNGKey(7)
    b, n_rows, h, dh, u = 2, 11, 4, 8, 5
    v = jax.random.normal(key, (b, n_rows, h, dh))
    staged = stage_decode_table(v, head_pack=2)
    idx = jnp.stack([jnp.asarray([0, 3, 4, 7, 10]),
                     jnp.asarray([1, 2, 5, 8, 9])])
    rows = jax.random.normal(jax.random.fold_in(key, 1), (b, u, h, dh))
    bidx = jnp.arange(b)[:, None]
    v2 = v.at[bidx, idx].set(rows)
    got = update_staged_rows(staged, idx, rows)
    want = stage_decode_table(v2, head_pack=2)
    np.testing.assert_array_equal(np.asarray(got.v), np.asarray(want.v))


# --------------------------------------------------------------------------
# staged-bytes accounting — the >= 2x drifting-scene criterion
# --------------------------------------------------------------------------

def test_drifting_scene_bytes_ratio_at_least_2x():
    """The acceptance criterion: on the drifting-scene benchmark the
    incremental updates project/stage >= 2x fewer bytes than per-frame
    rebuilds (same measured path benchmarks/fmap_reuse.py reports)."""
    from benchmarks.fmap_reuse import _stream_staged
    r = _stream_staged(n_frames=32)
    assert r["stream_bytes_ratio"] >= 2.0, r
    assert r["stream_incremental_frames"] > r["stream_rebuild_frames"], r


def test_frame_stats_and_pipeline_state_carry_stream_accounting():
    cfg = _cfg()
    mgr, plan = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                       update_frac=0.5))
    key = jax.random.PRNGKey(8)
    x0 = jax.random.normal(key, (2, N_IN, D))
    _, st = mgr.step(x0)
    assert st["mode"] == "rebuild"
    assert st["staged_bytes"] == st["rebuild_bytes"] == mgr._full_bytes
    _, st = mgr.step(x0.at[:, 0:3].add(0.5))
    assert st["mode"] == "incremental"
    assert st["staged_bytes"] == plan.table_bytes_for_rows(
        mgr.update_rows, with_indirection=False)
    state = mgr.pipeline_state()
    assert state.stream is st and state.fwp is mgr.fwp
    # advance() preserves the frame accounting for every layer's consumer
    assert state.advance(None, None).stream is st
    r = mgr.report()
    assert r["frames"] == 2 and r["rebuild_frames"] == 1
    assert r["staged_bytes_total"] == st["staged_bytes"] + mgr._full_bytes
    # the plan's describe() surfaces the temporal accounting
    plan_s = dataclasses.replace(plan, stream_update_rows=mgr.update_rows)
    assert "stream<=" in plan_s.describe()


# --------------------------------------------------------------------------
# decoder + engine
# --------------------------------------------------------------------------

def _decoder_setup(backend="jnp_gather"):
    cfg = _cfg()
    dec_cfg = msda.MSDADecoderConfig(n_layers=2, n_queries=8, d_ffn=32)
    key = jax.random.PRNGKey(11)
    params = {
        "decoder": msda.init_decoder(key, dec_cfg, cfg),
        "cls_head": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                            (D, 3)) * 0.1,
                     "b": jnp.zeros((3,))},
        "box_head": {"w": jax.random.normal(jax.random.fold_in(key, 2),
                                            (D, 4)) * 0.1,
                     "b": jnp.zeros((4,))},
    }
    return cfg, dec_cfg, params


def test_decoder_apply_accepts_external_cache():
    """decoder_apply(cache=...) must run the stack against the provided
    cache and match the internally built one for identical memory."""
    cfg, dec_cfg, params = _decoder_setup()
    plan = msda.make_plan(cfg, LEVELS, backend="jnp_gather",
                          n_queries=dec_cfg.n_queries,
                          n_consumers=dec_cfg.n_layers)
    key = jax.random.PRNGKey(12)
    memory = jax.random.normal(key, (2, N_IN, D))
    h_int, refs_int, _ = msda.decoder_apply(params["decoder"], dec_cfg,
                                            plan, memory)
    cache = build_value_cache(params["decoder"]["value"], plan, memory)
    h_ext, refs_ext, dstate = msda.decoder_apply(
        params["decoder"], dec_cfg, plan, memory, cache=cache)
    np.testing.assert_allclose(np.asarray(h_int), np.asarray(h_ext),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(refs_int), np.asarray(refs_ext),
                               atol=1e-6)
    assert dstate.cache is cache


def test_streaming_engine_sessions_end_to_end():
    from repro.serve.engine import StreamingDetrEngine
    cfg, dec_cfg, params = _decoder_setup()
    engine = StreamingDetrEngine(
        cfg, dec_cfg, params, LEVELS, max_sessions=2,
        stream_cfg=StreamConfig(tile_rows=1, delta_threshold=1e-4,
                                update_frac=0.5))
    assert "streaming" in engine.describe()
    s0 = engine.open_session()
    s1 = engine.open_session()
    scenes = {s0: drifting_scene(1, LEVELS, D, 4),
              s1: drifting_scene(2, LEVELS, D, 4)}
    for t in range(4):
        for sid in (s0, s1):
            engine.submit_frame(sid, scenes[sid][t][0])
    engine.run_until_drained()
    for sid in (s0, s1):
        sess = engine.close_session(sid)
        assert len(sess.results) == 4
        for res in sess.results:
            assert res["cls_probs"].shape == (dec_cfg.n_queries, 3)
            assert res["boxes"].shape == (dec_cfg.n_queries, 4)
            assert np.isfinite(res["boxes"]).all()
            assert res["stream"]["mode"] in ("rebuild", "incremental",
                                             "partial")
    r = engine.report()
    assert r["frames"] == 4
    assert r["staged_bytes_total"] <= r["rebuild_bytes_total"]
    # freed slots are reusable
    s2 = engine.open_session()
    assert engine.sessions[s2].slot in (0, 1)


# --------------------------------------------------------------------------
# per-level partial restage + slot permutation (cache-local ordering)
# --------------------------------------------------------------------------

def _single_level_transition(mgr, key):
    """Drive the EMA so the keep set flips ONLY inside level 0."""
    freq = jnp.ones((2, N_IN))
    h0w0 = LEVELS[0][0] * LEVELS[0][1]
    flip = jnp.where(jax.random.uniform(key, (2, h0w0)) > 0.5, 10.0, 0.0)
    mgr.observe(freq.at[:, :h0w0].set(flip))


@pytest.mark.parametrize("backend", ("jnp_gather", "pallas_decode"))
def test_partial_restage_matches_scratch_build(backend):
    """A keep transition confined to one level restages ONLY that level's
    contiguous slot range (mode ``partial``), and the resulting cache —
    values, staged decode table AND swapped geometry — is bit-identical
    to a from-scratch build of the frame under the new keep set."""
    cfg = _cfg()
    mgr, plan = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=0.0,
                                       update_frac=1.0), backend=backend)
    key = jax.random.PRNGKey(31)
    x0 = jax.random.normal(key, (2, N_IN, D))
    mgr.step(x0)
    _single_level_transition(mgr, jax.random.fold_in(key, 1))
    assert mgr._geometry_stale
    assert mgr._transition_levels() == (0,)
    x1 = x0 + 0.05 * jnp.sign(x0)
    cache, st = mgr.step(x1)
    assert st["mode"] == "partial" and st["reason"] == "keep-transition"
    assert st["restaged_levels"] == (0,)
    ref = _scratch(mgr, plan, x1)
    np.testing.assert_array_equal(np.asarray(cache.v), np.asarray(ref.v))
    np.testing.assert_array_equal(np.asarray(cache.keep_idx),
                                  np.asarray(ref.keep_idx))
    np.testing.assert_array_equal(np.asarray(cache.pix2slot),
                                  np.asarray(ref.pix2slot))
    if backend == "pallas_decode":
        np.testing.assert_array_equal(np.asarray(cache.staged.v),
                                      np.asarray(ref.staged.v))
        np.testing.assert_array_equal(np.asarray(cache.staged.remap),
                                      np.asarray(ref.staged.remap))
    assert mgr.report()["partial_frames"] == 1
    # accounting: the partial frame staged level 0's slots + the
    # incremental budget, not the whole table's indirection
    assert st["staged_bytes"] == plan.table_bytes_for_rows(
        mgr._slot_offs[1], with_indirection=False) \
        + LEVELS[0][0] * LEVELS[0][1] * 4 + mgr._incr_bytes


def test_whole_geometry_transition_still_rebuilds():
    """When EVERY level's keep set moves, the partial path declines and
    the frame full-rebuilds (same bytes, one build)."""
    cfg = _cfg()
    mgr, _ = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=0.0,
                                    update_frac=1.0))
    key = jax.random.PRNGKey(32)
    x0 = jax.random.normal(key, (2, N_IN, D))
    mgr.step(x0)
    flip = jnp.where(jax.random.uniform(jax.random.fold_in(key, 1),
                                        (2, N_IN)) > 0.5, 10.0, 0.0)
    mgr.observe(flip)
    assert mgr._geometry_stale
    assert mgr._transition_levels() is None
    _, st = mgr.step(x0)
    assert st["mode"] == "rebuild" and st["reason"] == "keep-transition"


def test_permute_slots_is_state_permutation():
    """permute_slots + step(permuted frames) == step(frames) + permute:
    the manager's per-slot state is exchangeable, which is what lets the
    engine place clustering sessions on adjacent slots without touching
    numerics."""
    cfg = _cfg()
    mk = lambda: _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                        update_frac=0.5),
                      backend="pallas_decode")[0]
    key = jax.random.PRNGKey(33)
    x0 = jax.random.normal(key, (2, N_IN, D))
    x1 = x0.at[:, 3:6].add(0.5)
    m_a = mk()
    m_a.step(x0)
    c_a, st_a = m_a.step(x1)
    m_b = mk()
    m_b.step(x0)
    m_b.permute_slots((1, 0))
    c_b, st_b = m_b.step(x1[::-1])
    assert st_a["mode"] == st_b["mode"] == "incremental"
    np.testing.assert_array_equal(np.asarray(c_b.v), np.asarray(c_a.v)[::-1])
    np.testing.assert_array_equal(np.asarray(c_b.staged.v),
                                  np.asarray(c_a.staged.v)[::-1])
    np.testing.assert_array_equal(np.asarray(m_b.x_ref),
                                  np.asarray(m_a.x_ref)[::-1])
    with pytest.raises(ValueError):
        m_b.permute_slots((0, 0))                  # not a permutation
    with pytest.raises(ValueError):
        m_b.permute_slots((0, 1, 2))               # wrong batch


def test_engine_reorder_sessions_never_drops_or_duplicates():
    """reorder_sessions() reassigns sessions to adjacent slots by
    reference-point cluster: the session set and the slot multiset are
    preserved, free slots stay free, and every session keeps serving its
    own stream afterwards."""
    from repro.serve.engine import StreamingDetrEngine
    cfg, dec_cfg, params = _decoder_setup()
    engine = StreamingDetrEngine(
        cfg, dec_cfg, params, LEVELS, max_sessions=3,
        stream_cfg=StreamConfig(tile_rows=1, delta_threshold=1e-4,
                                update_frac=0.5))
    sids = [engine.open_session() for _ in range(3)]
    scenes = {sid: drifting_scene(i + 1, LEVELS, D, 3)
              for i, sid in enumerate(sids)}
    for t in range(2):
        for sid in sids:
            engine.submit_frame(sid, scenes[sid][t][0])
    engine.run_until_drained()
    before = {s.sid: s.slot for s in engine.sessions.values()}
    mapping = engine.reorder_sessions()
    assert set(mapping) == set(before)                       # no session
    #   dropped or invented
    assert sorted(mapping.values()) == sorted(before.values())  # slots
    #   conserved (free slots stay free)
    # slot bookkeeping agrees between sessions dict and mapping
    for sid, slot in mapping.items():
        assert engine.sessions[sid].slot == slot
    # sessions keep serving their own streams post-reorder
    for sid in sids:
        engine.submit_frame(sid, scenes[sid][2][0])
    assert engine.step() == 3
    for sid in sids:
        sess = engine.sessions[sid]
        assert len(sess.results) == 3
        assert np.isfinite(sess.results[-1]["boxes"]).all()
    # closing a moved session frees its CURRENT slot for reuse
    freed = engine.close_session(sids[0]).slot
    s_new = engine.open_session()
    assert engine.sessions[s_new].slot == freed


def test_engine_reorder_noop_cases():
    """Reordering with < 2 placed sessions (or before any frame produced
    a centroid) is the identity."""
    from repro.serve.engine import StreamingDetrEngine
    cfg, dec_cfg, params = _decoder_setup()
    engine = StreamingDetrEngine(cfg, dec_cfg, params, LEVELS,
                                 max_sessions=2)
    assert engine.reorder_sessions() == {}
    s0 = engine.open_session()
    assert engine.reorder_sessions() == {s0: engine.sessions[s0].slot}


# --------------------------------------------------------------------------
# int8 table streaming: frozen scale, dtype guards, mid-stream plan swap
# --------------------------------------------------------------------------

def test_int8_stream_stays_int8_end_to_end():
    """A quantized-table stream never materializes a float table: the
    first-frame rebuild builds codes + frozen per-channel scale, and
    every incremental update scatters int8 codes into BOTH the cache
    table and its staged decode layout under the SAME scale (identical
    frame => bit-stable codes)."""
    cfg = _cfg(table_dtype="int8")
    mgr, plan = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                       update_frac=0.5),
                     backend="pallas_decode")
    assert plan.quantized_table
    key = jax.random.PRNGKey(21)
    x0 = jax.random.normal(key, (2, N_IN, D))
    cache0, st0 = mgr.step(x0)
    assert st0["mode"] == "rebuild"
    assert cache0.v.dtype == jnp.int8
    assert cache0.scale is not None and cache0.scale.dtype == jnp.float32
    assert cache0.staged is not None and cache0.staged.v.dtype == jnp.int8
    s0 = np.asarray(cache0.scale)
    cache1, st1 = mgr.step(x0.at[:, 3:6].add(0.5))
    assert st1["mode"] == "incremental" and st1["n_dirty"] > 0
    assert cache1.v.dtype == jnp.int8
    assert cache1.staged.v.dtype == jnp.int8
    # the scale is FROZEN for the cache's lifetime — updates requantize
    # onto the same grid, they never re-derive it
    np.testing.assert_array_equal(np.asarray(cache1.scale), s0)
    # identical frame: the requantized rows land on identical codes
    cache2, st2 = mgr.step(x0.at[:, 3:6].add(0.5))
    assert st2["mode"] == "incremental"
    np.testing.assert_array_equal(np.asarray(cache2.v), np.asarray(cache1.v))
    assert mgr.report()["table_dtype"] == "int8"


def test_int8_scatter_and_staged_update_reject_dtype_drift():
    """The hard guards behind the end-to-end int8 contract: scattering
    float rows into an int8 table (cache OR staged layout) raises instead
    of silently casting garbage onto the code grid."""
    from repro.kernels.msgs_decode import (stage_decode_table,
                                           update_staged_rows)
    from repro.msda.cache import scatter_table_rows
    cfg = _cfg(table_dtype="int8")
    mgr, _ = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                    update_frac=0.5),
                  backend="pallas_decode")
    key = jax.random.PRNGKey(22)
    cache, _ = mgr.step(jax.random.normal(key, (2, N_IN, D)))
    idx = jnp.zeros((2, 1), jnp.int32)
    f32_rows = jnp.zeros((2, 1) + cache.v.shape[2:], jnp.float32)
    with pytest.raises(TypeError, match="frozen scale"):
        scatter_table_rows(cache.v, idx, f32_rows)
    with pytest.raises(TypeError, match="dtype"):
        update_staged_rows(cache.staged, idx, f32_rows)
    # int8 codes (the quantize-then-scatter path) are accepted
    codes = jnp.zeros_like(f32_rows, jnp.int8)
    assert scatter_table_rows(cache.v, idx, codes).dtype == jnp.int8
    assert update_staged_rows(cache.staged, idx, codes).v.dtype == jnp.int8


def test_mid_stream_plan_swap_forces_full_rebuild():
    """Changing the manager's plan mid-stream (e.g. flipping the table
    dtype f32 -> int8) must force ONE full rebuild that re-derives the
    new layout + scale, then return to steady incremental updates."""
    cfg = _cfg()
    mgr, plan = _mgr(cfg, StreamConfig(tile_rows=2, delta_threshold=1e-6,
                                       update_frac=0.5))
    key = jax.random.PRNGKey(23)
    x0 = jax.random.normal(key, (2, N_IN, D))
    mgr.step(x0)
    cache, st = mgr.step(x0.at[:, 0:3].add(0.5))
    assert st["mode"] == "incremental"
    assert cache.scale is None and cache.v.dtype != jnp.int8
    plan8 = msda.make_plan(dataclasses.replace(cfg, table_dtype="int8"),
                           LEVELS, backend="jnp_gather", n_queries=16,
                           n_consumers=2)
    mgr.plan = plan8
    cache, st = mgr.step(x0.at[:, 0:3].add(0.5))
    assert st["mode"] == "rebuild" and st["reason"] == "plan-change", st
    assert cache.v.dtype == jnp.int8 and cache.scale is not None
    assert mgr.report()["table_dtype"] == "int8"
    # steady state resumes on the new plan — and stays int8
    cache, st = mgr.step(x0.at[:, 0:3].add(0.7))
    assert st["mode"] == "incremental"
    assert cache.v.dtype == jnp.int8


def test_streaming_engine_admission_is_slot_local():
    """Admitting a session mid-stream rebuilds ONLY the joining slot's
    rows — a batch-1 build scattered into the slot — while the running
    session rides the ordinary incremental path (no batch-wide rebuild
    storm), the admitted slot's table exactly matches a from-scratch
    build of its own frame (no stale-slot leakage), and repeated churn
    never retraces any compiled path."""
    from repro.core import fwp as fwp_lib
    from repro.serve.engine import StreamingDetrEngine
    cfg, dec_cfg, params = _decoder_setup()
    engine = StreamingDetrEngine(
        cfg, dec_cfg, params, LEVELS, max_sessions=2,
        stream_cfg=StreamConfig(tile_rows=1, delta_threshold=1e-4,
                                update_frac=0.9),
        update_fwp=False)     # freeze the keep set: isolates admission
    #   from warm-up EMA transitions
    mgr = engine.mgr
    s0 = engine.open_session()
    scene = drifting_scene(3, LEVELS, D, 3)
    engine.submit_frame(s0, scene[0][0])
    engine.step()
    engine.submit_frame(s0, scene[1][0])
    engine.step()
    assert mgr.last_stats["mode"] == "incremental"
    s1 = engine.open_session()                     # mid-stream admission
    engine.submit_frame(s0, scene[2][0])
    engine.submit_frame(s1, scene[0][0])
    engine.step()
    st = mgr.last_stats
    assert st["mode"] == "incremental", st         # no rebuild storm
    assert st["admitted_slots"] == (1,), st
    assert mgr.rebuild_frames == 1                 # only the first frame
    # the admitted slot's rows == a from-scratch build of its own frame
    # under its slot's keep geometry
    f = mgr.fwp
    fwp1 = None if f is None else fwp_lib.FWPState(
        keep_mask=f.keep_mask[1:2],
        keep_idx=None if f.keep_idx is None else f.keep_idx[1:2],
        pix2slot=None if f.pix2slot is None else f.pix2slot[1:2],
        freq=f.freq[1:2])
    ref = build_value_cache(mgr.params, mgr.plan,
                            jnp.asarray(scene[0][0])[None],
                            MSDAPipelineState(fwp=fwp1))
    np.testing.assert_allclose(np.asarray(mgr.cache.v[1]),
                               np.asarray(ref.v[0]), atol=1e-5)
    with pytest.raises(RuntimeError):
        engine.open_session()                      # only 2 slots
    # churn again: close + rejoin retraces NOTHING (the batch-1 build was
    # traced by the first admission) and stays slot-local
    traces = dict(mgr.trace_counts)
    engine.close_session(s1)
    s2 = engine.open_session()
    engine.submit_frame(s2, scene[1][0])
    engine.step()
    assert mgr.trace_counts == traces, (mgr.trace_counts, traces)
    assert mgr.last_stats["admitted_slots"] == (1,)
    assert mgr.last_stats["mode"] == "incremental"
    assert mgr.rebuild_frames == 1