import os
import sys

# Smoke tests and benches run on the single real CPU device. ONLY the
# dry-run (launch/dryrun.py) overrides the device count, never here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# repo root on sys.path so tests can import the benchmarks package
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# hypothesis is an optional dependency (the `test` extra in pyproject.toml).
# Test modules import given/settings/st from here: with hypothesis absent,
# property tests skip cleanly and everything else still runs.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    # CI leg: deterministic, capped example count. Selected with
    # `pytest --hypothesis-profile=ci` (.github/workflows/ci.yml).
    settings.register_profile("ci", max_examples=25, deadline=None,
                              derandomize=True)
except ImportError:
    import pytest  # noqa: E402

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (pip install -e .[test])")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _StubStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StubStrategies()

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _isolate_tuned_plan_table():
    """Serve engines call ``autotune.ensure_applied()`` at construction,
    which applies the committed tuned plan table process-globally — a
    measured staging budget would then leak into every later test's
    ``backend="auto"`` planning. Restore the untuned state (and the
    once-per-process ensure guard) around every test so only tests that
    explicitly opt in see tuned plans."""
    from repro.msda import autotune, plan as plan_lib
    prev_entry = plan_lib.tuned_entry()
    prev_gen = plan_lib.tuned_generation()
    prev_tried = autotune._ENSURE_TRIED
    yield
    if plan_lib.tuned_generation() != prev_gen:
        plan_lib.apply_tuned_plan_table(prev_entry)
    autotune._ENSURE_TRIED = prev_tried
