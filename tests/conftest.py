import os
import sys

# Smoke tests and benches run on the single real CPU device. ONLY the
# dry-run (launch/dryrun.py) overrides the device count, never here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# repo root on sys.path so tests can import the benchmarks package
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
