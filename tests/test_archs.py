"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward + one train step on CPU, asserting output shapes and
no NaNs. (Full configs are exercised via the dry-run only.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, shapes_for
from repro.models.registry import get_api

B, S = 2, 16


def _smoke_batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    logits = api.forward(params, cfg, batch)
    s_total = S + 1 + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_total, cfg.vocab_size), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    (loss, metrics), grads = jax.value_and_grad(
        api.loss_fn, has_aux=True)(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs must carry the exact assigned hyperparameters."""
    expected = {
        "olmoe-1b-7b": dict(n_layers=16, d_model=2048, n_heads=16,
                            n_kv_heads=16, d_ff=1024, vocab_size=50304,
                            n_experts=64, n_experts_active=8),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=32768, vocab_size=131072,
                            n_experts=8, n_experts_active=2),
        "granite-20b": dict(n_layers=52, d_model=6144, n_heads=48,
                            n_kv_heads=1, d_ff=24576, vocab_size=49152),
        "minitron-8b": dict(n_layers=32, d_model=4096, n_heads=32,
                            n_kv_heads=8, d_ff=16384, vocab_size=256000),
        "minitron-4b": dict(n_layers=32, d_model=3072, n_heads=24,
                            n_kv_heads=8, d_ff=9216, vocab_size=256000),
        "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32,
                            n_kv_heads=32, d_ff=11008, vocab_size=102400),
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab_size=50280,
                            ssm_state=128),
        "llava-next-34b": dict(n_layers=60, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=20480, vocab_size=64000),
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6,
                             n_kv_heads=6, d_ff=1536, vocab_size=51865),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16),
    }[arch]
    cfg = get_config(arch)
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_shape_skip_policy():
    assert shapes_for("ssm") == ["train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"]
    assert shapes_for("hybrid")[-1] == "long_500k"
    for fam in ("dense", "moe", "vlm", "encdec"):
        assert "long_500k" not in shapes_for(fam)
    assert SHAPES["long_500k"].kind == "decode"
    assert SHAPES["train_4k"].global_batch == 256


def test_param_counts_plausible():
    """Analytic param counts should land near the archs' nameplate sizes."""
    approx = {
        "grok-1-314b": (314e9, 0.15),
        "granite-20b": (20e9, 0.35),
        "minitron-8b": (8e9, 0.45),   # fat embeddings dominate
        "minitron-4b": (4e9, 0.6),
        "deepseek-7b": (7e9, 0.25),
        "llava-next-34b": (34e9, 0.25),
        "mamba2-130m": (130e6, 0.45),
        "hymba-1.5b": (1.5e9, 0.5),
        "olmoe-1b-7b": (6.9e9, 0.35),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)
