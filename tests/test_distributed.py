"""Distributed-semantics tests on 8 virtual CPU devices (subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8):

  * sharded (DP×TP) train step == single-device train step
  * error-feedback int8 compressed cross-"pod" psum inside shard_map
  * elastic checkpoint restore across different mesh shapes
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=420,
                         cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.models.common import ModelConfig
    from repro.optim.adamw import OptConfig
    from repro.train.step import (build_train_step, make_train_state,
                                  train_state_shardings)
    from repro.data.tokens import TokenDataConfig, synth_token_batch

    assert len(jax.devices()) == 8
    cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, remat=False)
    opt = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10, weight_decay=0.0)
    data = TokenDataConfig(vocab_size=256, seq_len=32, global_batch=8, seed=3)
    batch = synth_token_batch(data, 0)

    # single device
    s0 = make_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(build_train_step(cfg, opt))
    s0, m0 = step(s0, batch)

    # 4-way data x 2-way tensor mesh
    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    s1 = make_train_state(jax.random.PRNGKey(0), cfg)
    with mesh:
        specs = train_state_shardings(cfg, mesh, jax.eval_shape(lambda: s1))
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
        bsh = {"tokens": NamedSharding(mesh, P("data", None))}
        stepd = jax.jit(build_train_step(cfg, opt),
                        in_shardings=(sh, bsh), out_shardings=(sh, None))
        s1, m1 = stepd(s1, batch)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(s0.params), jax.tree.leaves(s1.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
    print("SHARDED==SINGLE OK")
    """)


def test_compressed_psum_shard_map():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim.compress import compressed_psum

    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))   # per-rank rows

    def f(g_local, res):
        out, new_res = compressed_psum(g_local, "pod", bits=8, residual=res)
        return out, new_res

    fm = shard_map(f, mesh=mesh,
                   in_specs=(P(("pod", "data")), P(("pod", "data"))),
                   out_specs=(P(("pod", "data")), P(("pod", "data"))))
    res = jnp.zeros_like(g)
    out, res = fm(g, res)
    # exact mean over the pod axis of the uncompressed input, within int8 tol
    g2 = g.reshape(2, 4, 1, 64)
    want = jnp.broadcast_to(g2.mean(0, keepdims=True), g2.shape).reshape(8, 1, 64)
    scale = jnp.abs(g).max() / 127.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(want)[:, 0],
                               atol=float(scale) * 1.1)
    # error feedback: residual shrinks the NEXT round's error
    out2, res2 = fm(g, res)
    e1 = np.abs(np.asarray(out) - np.asarray(want)[:, 0]).mean()
    e2 = np.abs(np.asarray((out + out2) / 2) - np.asarray(want)[:, 0]).mean()
    assert e2 <= e1 + 1e-7, (e1, e2)
    print("COMPRESSED PSUM OK")
    """)


def test_elastic_checkpoint_reshard():
    _run("""
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint.store import (save_checkpoint, load_checkpoint,
                                        restore_into, reshard)
    from repro.models.common import ModelConfig
    from repro.train.step import make_train_state, train_state_shardings

    cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, remat=False)
    state = make_train_state(jax.random.PRNGKey(0), cfg)

    # save from an 8-device (4x2) mesh
    mesh_a = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    specs_a = train_state_shardings(cfg, mesh_a, jax.eval_shape(lambda: state))
    sh_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s), specs_a,
                        is_leaf=lambda x: isinstance(x, P))
    placed = reshard(state, sh_a)
    d = tempfile.mkdtemp()
    save_checkpoint(d, 3, placed)

    # restore onto a DIFFERENT mesh (2x4) — elastic scaling
    mesh_b = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    specs_b = train_state_shardings(cfg, mesh_b, jax.eval_shape(lambda: state))
    sh_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s), specs_b,
                        is_leaf=lambda x: isinstance(x, P))
    step, loaded = load_checkpoint(d)
    restored = restore_into(state, loaded)
    placed_b = reshard(restored, sh_b)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(placed_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ELASTIC RESHARD OK")
    """)
