"""Model-zoo behaviour tests: loss/grad finiteness and the
forward == prefill+decode consistency contract, for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ModelConfig
from repro.models.registry import get_api

B, S, V = 2, 32, 256


def _toks():
    return jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, V)


CFGS = {
    "dense": (ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=V,
                          dtype=jnp.float32), {}),
    "moe": (ModelConfig(family="moe", n_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=4, d_ff=96, vocab_size=V, n_experts=8,
                        n_experts_active=2, expert_capacity_factor=4.0,
                        dtype=jnp.float32), {}),
    "ssm": (ModelConfig(family="ssm", n_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=4, d_ff=0, vocab_size=V, ssm_state=16,
                        ssm_head_dim=16, ssm_chunk=8, dtype=jnp.float32), {}),
    "hybrid": (ModelConfig(family="hybrid", n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=2, d_ff=128, vocab_size=V, ssm_state=8,
                           ssm_head_dim=16, ssm_chunk=8, attn_window=8,
                           global_every=2, dtype=jnp.float32), {}),
    "vlm": (ModelConfig(family="vlm", n_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=2, d_ff=128, vocab_size=V, n_img_tokens=8,
                        dtype=jnp.float32),
            {"img_embeds": jax.random.normal(jax.random.PRNGKey(2), (B, 8, 64))}),
    "encdec": (ModelConfig(family="encdec", n_layers=2, n_enc_layers=2,
                           d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                           vocab_size=V, enc_seq_len=16, dtype=jnp.float32),
               {"frames": jax.random.normal(jax.random.PRNGKey(3), (B, 16, 64))}),
}


@pytest.mark.parametrize("family", list(CFGS))
def test_loss_and_grads_finite(family):
    cfg, extras = CFGS[family]
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": _toks(), **extras}
    (loss, metrics), grads = jax.value_and_grad(
        api.loss_fn, has_aux=True)(params, cfg, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(loss)), float(loss)
    assert bool(jnp.isfinite(gnorm)), float(gnorm)
    assert float(loss) > 0.0


@pytest.mark.parametrize("family", list(CFGS))
def test_prefill_decode_matches_forward(family):
    cfg, extras = CFGS[family]
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = _toks()
    batch = {"tokens": toks, **extras}
    logits_full = api.forward(params, cfg, batch)          # (B, S_total, V)

    cache = api.init_cache(cfg, B, cache_len=S + cfg.n_img_tokens + 8)
    pre = dict(batch)
    pre["tokens"] = toks[:, :S]
    lp, cache = api.prefill(params, cfg, cache, pre)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_full[:, -2]),
                               rtol=2e-4, atol=2e-4)
    pos = jnp.asarray(S + cfg.n_img_tokens, jnp.int32)     # img tokens prepended
    ld, cache = api.decode_step(params, cfg, cache, toks[:, S], pos)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_causality_dense():
    cfg, _ = CFGS["dense"]
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = _toks()
    l_full = api.forward(params, cfg, {"tokens": toks})
    l_pre = api.forward(params, cfg, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(np.asarray(l_full[:, :S]), np.asarray(l_pre),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_limits_context():
    """With window w, logits at position i must not depend on tokens < i-w."""
    cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=V, attn_window=4,
                      dtype=jnp.float32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    t1 = _toks()
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % V)              # mutate a far-past token
    l1 = api.forward(params, cfg, {"tokens": t1})
    l2 = api.forward(params, cfg, {"tokens": t2})
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(l1[:, 1]) - np.asarray(l2[:, 1])).max() > 1e-4


def test_blockwise_attention_matches_dense():
    from repro.models import layers as L
    cfg = ModelConfig(family="dense", d_model=64, n_heads=4, n_kv_heads=4,
                      attn_chunk=8, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 4, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 4, 16))
    pos = jnp.arange(64)
    for win in (0, 16):
        w = jnp.asarray(win, jnp.int32)
        d = L._attn_dense(q, k, v, pos, pos, w)
        b = L._attn_blockwise(q, k, v, w, chunk=8)
        np.testing.assert_allclose(np.asarray(b), np.asarray(d),
                                   rtol=2e-5, atol=2e-5)


def test_ssd_chunk_invariance():
    """The chunked SSD algorithm must be invariant to chunk size."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    cfg1 = ModelConfig(family="ssm", d_model=32, ssm_state=8, ssm_head_dim=8,
                       ssm_chunk=4, dtype=jnp.float32)
    cfg2 = ModelConfig(family="ssm", d_model=32, ssm_state=8, ssm_head_dim=8,
                       ssm_chunk=16, dtype=jnp.float32)
    p = L.ssd_init(key, cfg1)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, 32))
    y1 = L.ssd_forward(p, cfg1, x)
    y2 = L.ssd_forward(p, cfg2, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_counted():
    """With tight capacity the layer must still run and drop gracefully."""
    from repro.models import layers as L
    cfg = ModelConfig(family="moe", d_model=32, d_ff=64, n_experts=4,
                      n_experts_active=2, expert_capacity_factor=0.5,
                      dtype=jnp.float32)
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = L.moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
