"""Property-based invariants of the FWP compact-table geometry.

The whole compact execution path (windowed slot windows, decode staging,
pix2slot corner remap) leans on three structural guarantees of
``build_fwp_state(mode="compact")``:

  1. **raster order** — within each level, the compact slots are sorted
     by pixel index (and level segments are concatenated in level order),
     so the full ``keep_idx`` row is strictly increasing: a spatial pixel
     window maps to ONE contiguous slot range.
  2. **slot windows** — the slot range of any pixel window ``[lo, hi)``
     is exactly ``searchsorted(keep_idx, lo) .. searchsorted(keep_idx,
     hi)`` and never holds more than ``min(window_pixels, cap_l)`` slots
     — the static bound the windowed kernel stages by.
  3. **pix2slot round-trip** — ``pix2slot[keep_idx[s]] == s`` for every
     surviving slot; every non-sentinel ``pix2slot`` entry points back at
     its own pixel; pruned pixels hit the zero-sentinel row.

Each invariant runs as a hypothesis property (when installed — the
``test`` extra) AND as a fixed-seed sweep that always runs, so the
invariants stay exercised in hypothesis-free environments.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# given/settings/st skip property tests cleanly when hypothesis is absent
from conftest import given, settings, st

from repro.core.fwp import (_per_level_threshold, build_fwp_state,
                            build_fwp_state_hysteresis, level_capacities,
                            level_starts)

LEVEL_POOL = (
    ((8, 10), (4, 5), (2, 3)),
    ((16, 20), (8, 10), (4, 5), (2, 3)),
    ((5, 7), (3, 3)),
    ((2, 3),),
)


def _state_for(seed: int, level_shapes, capacity: float, k: float,
               batch: int = 2):
    """Random frequency field (with exact zeros, like real FWP counts)
    -> compact FWPState."""
    _, n_in = level_starts(level_shapes)
    key = jax.random.PRNGKey(seed)
    freq = jax.random.uniform(key, (batch, n_in), maxval=10.0)
    alive = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.7,
                                 (batch, n_in))
    freq = freq * alive.astype(jnp.float32)
    return build_fwp_state(freq, level_shapes, k=k, mode="compact",
                           capacity=capacity)


# --------------------------------------------------------------------------
# invariant checkers (shared by the hypothesis and fixed-seed entries)
# --------------------------------------------------------------------------

def _check_raster_order(state, level_shapes, capacity):
    """Slots are raster-ordered per level and level-segmented, so the
    full keep_idx row is strictly increasing and each level's slots stay
    inside that level's flat pixel range."""
    starts, _ = level_starts(level_shapes)
    caps = level_capacities(level_shapes, capacity)
    ki = np.asarray(state.keep_idx)
    assert ki.shape[1] == sum(caps)
    # strictly increasing across the whole row (level segments ordered)
    assert (np.diff(ki, axis=1) > 0).all(), "keep_idx not raster-ordered"
    off = 0
    for (h, w), s, c in zip(level_shapes, starts, caps):
        seg = ki[:, off:off + c]
        assert (seg >= s).all() and (seg < s + h * w).all(), \
            f"level slots escape the level range (start={s}, n={h*w})"
        off += c


def _check_pix2slot_roundtrip(state):
    """pix2slot and keep_idx are inverse maps on the surviving slots;
    everything else lands on the sentinel."""
    ki = np.asarray(state.keep_idx)
    p2s = np.asarray(state.pix2slot)
    mask = np.asarray(state.keep_mask)
    b, cap_total = ki.shape
    sentinel = cap_total
    surviving = np.take_along_axis(mask, ki, axis=1)          # (B, cap)
    for bi in range(b):
        # surviving slot s -> its pixel -> back to s
        s_idx = np.nonzero(surviving[bi])[0]
        np.testing.assert_array_equal(p2s[bi, ki[bi, s_idx]], s_idx)
        # every non-sentinel entry points back at its own pixel AND that
        # pixel survived the threshold
        pix = np.nonzero(p2s[bi] != sentinel)[0]
        slots = p2s[bi, pix]
        np.testing.assert_array_equal(ki[bi, slots], pix)
        assert mask[bi, pix].all()
        # pruned pixels (below threshold) always hit the sentinel
        assert (p2s[bi, ~mask[bi]] == sentinel).all()


def _check_slot_windows(state, level_shapes, capacity, seed: int):
    """searchsorted(keep_idx)-derived slot windows of random pixel
    windows: the window is exactly the contiguous [s0, s1) slot range
    and covers at most min(window_pixels, cap_l) slots — and the
    kernel's clipped static window keeps every kept slot addressable."""
    starts, _ = level_starts(level_shapes)
    caps = level_capacities(level_shapes, capacity)
    ki = np.asarray(state.keep_idx)
    b, n_rows_nosent = ki.shape
    rng = np.random.default_rng(seed)
    for li, ((h, w), s, cap_l) in enumerate(zip(level_shapes, starts, caps)):
        n_l = h * w
        for _ in range(4):
            # random row-aligned pixel window inside level li (the kernel
            # windows whole rows: wp = n_rows * w)
            r0 = int(rng.integers(0, h))
            r1 = int(rng.integers(r0, h)) + 1
            lo = s + r0 * w
            hi = s + r1 * w
            wp = hi - lo
            for bi in range(b):
                s0 = int(np.searchsorted(ki[bi], lo))
                s1 = int(np.searchsorted(ki[bi], hi))
                in_window = ((ki[bi] >= lo) & (ki[bi] < hi))
                # the slot range is exactly the window's kept pixels...
                assert in_window.sum() == s1 - s0
                if s1 > s0:
                    assert in_window[s0:s1].all()
                # ...and never exceeds the static staging bound
                wext = min(wp, cap_l)
                assert s1 - s0 <= wext, (s1 - s0, wext)
                # kernel clipping: start = clip(s0, 0, n_rows - wext)
                # (n_rows includes the sentinel) only ever moves the
                # start DOWN, keeping every kept slot covered
                start_clipped = min(s0, (n_rows_nosent + 1) - wext)
                assert start_clipped <= s0
                assert s1 <= start_clipped + wext


def _check_all(seed, level_shapes, capacity, k):
    state = _state_for(seed, level_shapes, capacity, k)
    _check_raster_order(state, level_shapes, capacity)
    _check_pix2slot_roundtrip(state)
    _check_slot_windows(state, level_shapes, capacity, seed)


# --------------------------------------------------------------------------
# hypothesis properties (skip cleanly when hypothesis is absent)
# --------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**16), st.integers(0, len(LEVEL_POOL) - 1),
       st.floats(0.1, 1.0), st.floats(0.0, 2.0))
def test_fwp_compact_invariants_property(seed, pool_idx, capacity, k):
    _check_all(seed, LEVEL_POOL[pool_idx], capacity, k)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**16), st.floats(0.1, 1.0))
def test_fwp_slot_windows_cover_all_kept_pixels_property(seed, capacity):
    """Dedicated window property on the DETR-ish 4-level pyramid: every
    kept pixel of every row-aligned window is reachable through the
    searchsorted slot window (what the windowed kernel's no-densify
    execution relies on)."""
    level_shapes = LEVEL_POOL[1]
    state = _state_for(seed, level_shapes, capacity, k=1.0)
    _check_slot_windows(state, level_shapes, capacity, seed)


# --------------------------------------------------------------------------
# fixed-seed fallback — ALWAYS runs, hypothesis or not
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pool_idx", range(len(LEVEL_POOL)))
def test_fwp_compact_invariants_fixed_seeds(pool_idx):
    """Seeded sweep of the same invariants: keeps the geometry contract
    exercised when hypothesis isn't installed (it is only the `test`
    extra), and pins a deterministic regression surface either way."""
    for seed in range(5):
        for capacity in (0.25, 0.6, 1.0):
            _check_all(seed, LEVEL_POOL[pool_idx], capacity, k=1.0)


# --------------------------------------------------------------------------
# temporal hysteresis (streaming FWP): bounded drift => bounded churn
# --------------------------------------------------------------------------

def _hyst_chain_check(seed: int, level_shapes, capacity: float,
                      k_exit: float, band: float, drift: float,
                      n_frames: int = 4, batch: int = 2):
    """Drive a bounded-drift score sequence through the hysteresis build
    and check, at every transition:

      1. the compact geometry invariants hold for every state (raster
         order per level, pix2slot round-trip, slot windows) — surviving
         slots keep raster order across frames by construction;
      2. the churn CERTIFICATE: a pixel can only change keep-state when
         its previous score was within ``(1+k)·drift`` of the
         corresponding threshold — bounded score drift implies bounded
         keep churn;
      3. incumbent retention: every previous slot-holder that is still
         kept retains a slot, so ``keep_idx`` churn is bounded by mask
         churn plus capacity-cropped survivors:
         ``|K_prev Δ K_new| <= 2·(entered + cropped_kept_prev)``.
    """
    k_enter = k_exit + band
    _, n_in = level_starts(level_shapes)
    key = jax.random.PRNGKey(seed)
    ema = jax.random.uniform(key, (batch, n_in), maxval=10.0)
    build = lambda e, prev: build_fwp_state_hysteresis(
        e, level_shapes, k_enter=k_enter, k_exit=k_exit, mode="compact",
        capacity=capacity, prev=prev)
    state = build(ema, None)
    _check_raster_order(state, level_shapes, capacity)
    _check_pix2slot_roundtrip(state)
    caps = level_capacities(level_shapes, capacity)
    starts, _ = level_starts(level_shapes)
    for t in range(n_frames):
        step = jax.random.uniform(jax.random.fold_in(key, t + 1),
                                  (batch, n_in), minval=-drift, maxval=drift)
        ema2 = jnp.maximum(ema + step, 0.0)       # clip only shrinks drift
        new = build(ema2, state)
        _check_raster_order(new, level_shapes, capacity)
        _check_pix2slot_roundtrip(new)
        _check_slot_windows(new, level_shapes, capacity, seed + t)

        pm = np.asarray(state.keep_mask)
        nm = np.asarray(new.keep_mask)
        e_prev = np.asarray(ema)
        t_hi = np.asarray(_per_level_threshold(ema, level_shapes, k_enter))
        t_lo = np.asarray(_per_level_threshold(ema, level_shapes, k_exit))
        eps = 1e-4 * (np.max(e_prev) + 1.0)
        entered = ~pm & nm
        exited = pm & ~nm
        # certificate 2: churn only within the drift margin of a threshold
        m_in = (1.0 + k_enter) * drift + eps
        m_out = (1.0 + k_exit) * drift + eps
        assert (e_prev[entered] >= (t_hi[entered] - m_in)).all()
        assert (e_prev[entered] < t_hi[entered] + eps).all()
        assert (e_prev[exited] < (t_lo[exited] + m_out)).all()
        assert (e_prev[exited] >= t_lo[exited] - eps).all()
        # certificate 3: kept incumbents retain slots; keep_idx churn is
        # bounded by mask churn + capacity-cropped survivors
        ki_p = np.asarray(state.keep_idx)
        ki_n = np.asarray(new.keep_idx)
        for b in range(batch):
            held = set(ki_p[b].tolist())
            kept_incumbents = [p for p in ki_p[b].tolist() if nm[b, p]]
            new_set = set(ki_n[b].tolist())
            assert set(kept_incumbents) <= new_set
            off = 0
            for (h, w), s, c in zip(level_shapes, starts, caps):
                lvl = slice(int(s), int(s) + h * w)
                sym = len(set(ki_p[b, off:off + c].tolist())
                          ^ set(ki_n[b, off:off + c].tolist()))
                ent_l = int(entered[b, lvl].sum())
                crop_prev = max(0, int(pm[b, lvl].sum()) - c)
                assert sym <= 2 * (ent_l + crop_prev), \
                    (sym, ent_l, crop_prev)
                off += c
        state, ema = new, ema2


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**16), st.integers(0, len(LEVEL_POOL) - 1),
       st.floats(0.2, 1.0), st.floats(0.2, 1.2), st.floats(0.05, 0.8),
       st.floats(0.0, 0.5))
def test_fwp_hysteresis_bounded_churn_property(seed, pool_idx, capacity,
                                               k_exit, band, drift):
    _hyst_chain_check(seed, LEVEL_POOL[pool_idx], capacity, k_exit, band,
                      drift)


@pytest.mark.parametrize("pool_idx", range(len(LEVEL_POOL)))
def test_fwp_hysteresis_bounded_churn_fixed_seeds(pool_idx):
    """Seeded sweep of the hysteresis churn certificates — always runs,
    hypothesis or not."""
    for seed in range(3):
        for capacity in (0.3, 0.6, 1.0):
            for drift in (0.05, 0.4):
                _hyst_chain_check(seed, LEVEL_POOL[pool_idx], capacity,
                                  k_exit=0.8, band=0.5, drift=drift)


def test_fwp_hysteresis_zero_drift_is_a_fixpoint():
    """Same scores + hysteresis => zero churn: the keep set, slot order
    and routing are all bit-stable (what keeps the streaming cache's
    slot geometry fixed between real signal changes)."""
    level_shapes = LEVEL_POOL[1]
    _, n_in = level_starts(level_shapes)
    ema = jax.random.uniform(jax.random.PRNGKey(3), (2, n_in), maxval=5.0)
    s1 = build_fwp_state_hysteresis(ema, level_shapes, k_enter=1.25,
                                    k_exit=0.75, mode="compact",
                                    capacity=0.6, prev=None)
    s2 = build_fwp_state_hysteresis(ema, level_shapes, k_enter=1.25,
                                    k_exit=0.75, mode="compact",
                                    capacity=0.6, prev=s1)
    np.testing.assert_array_equal(np.asarray(s1.keep_mask),
                                  np.asarray(s2.keep_mask))
    np.testing.assert_array_equal(np.asarray(s1.keep_idx),
                                  np.asarray(s2.keep_idx))
    np.testing.assert_array_equal(np.asarray(s1.pix2slot),
                                  np.asarray(s2.pix2slot))


def test_fwp_hysteresis_sticks_inside_the_band():
    """A pixel between the exit and enter thresholds keeps its previous
    decision — the defining hysteresis property — and k_enter < k_exit
    is rejected."""
    level_shapes = ((2, 3),)
    # six pixels, means chosen so thresholds are easy to place
    ema0 = jnp.asarray([[10.0, 0.0, 5.0, 5.0, 5.0, 5.0]])
    st0 = build_fwp_state_hysteresis(ema0, level_shapes, k_enter=1.4,
                                     k_exit=0.6, mode="mask",
                                     capacity=1.0, prev=None)
    m0 = np.asarray(st0.keep_mask)[0]
    assert m0[0] and not m0[1]                   # clear keep / clear prune
    # drift everyone INTO the band: decisions must stick
    ema1 = jnp.asarray([[5.5, 4.5, 5.0, 5.0, 5.0, 5.0]])
    st1 = build_fwp_state_hysteresis(ema1, level_shapes, k_enter=1.4,
                                     k_exit=0.6, mode="mask",
                                     capacity=1.0, prev=st0)
    m1 = np.asarray(st1.keep_mask)[0]
    assert m1[0] and not m1[1]                   # sticky inside the band
    np.testing.assert_array_equal(m1[2:], m0[2:])
    with pytest.raises(ValueError):
        build_fwp_state_hysteresis(ema1, level_shapes, k_enter=0.5,
                                   k_exit=0.9, mode="mask", capacity=1.0)


def test_fwp_compact_invariants_threshold_extremes():
    """k=0 keeps every pixel (capacity permitting); a huge k prunes all:
    the geometry invariants must hold at both extremes."""
    level_shapes = LEVEL_POOL[0]
    for k in (0.0, 100.0):
        _check_all(7, level_shapes, 0.6, k)
    # k=0, full capacity: every pixel survives and round-trips
    state = _state_for(11, level_shapes, 1.0, 0.0)
    assert bool(np.asarray(state.keep_mask).all())
    p2s = np.asarray(state.pix2slot)
    assert (p2s != state.keep_idx.shape[1]).all()   # no sentinel hits
