"""Property-based invariants of the FWP compact-table geometry.

The whole compact execution path (windowed slot windows, decode staging,
pix2slot corner remap) leans on three structural guarantees of
``build_fwp_state(mode="compact")``:

  1. **raster order** — within each level, the compact slots are sorted
     by pixel index (and level segments are concatenated in level order),
     so the full ``keep_idx`` row is strictly increasing: a spatial pixel
     window maps to ONE contiguous slot range.
  2. **slot windows** — the slot range of any pixel window ``[lo, hi)``
     is exactly ``searchsorted(keep_idx, lo) .. searchsorted(keep_idx,
     hi)`` and never holds more than ``min(window_pixels, cap_l)`` slots
     — the static bound the windowed kernel stages by.
  3. **pix2slot round-trip** — ``pix2slot[keep_idx[s]] == s`` for every
     surviving slot; every non-sentinel ``pix2slot`` entry points back at
     its own pixel; pruned pixels hit the zero-sentinel row.

Each invariant runs as a hypothesis property (when installed — the
``test`` extra) AND as a fixed-seed sweep that always runs, so the
invariants stay exercised in hypothesis-free environments.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# given/settings/st skip property tests cleanly when hypothesis is absent
from conftest import given, settings, st

from repro.core.fwp import (build_fwp_state, level_capacities, level_starts)

LEVEL_POOL = (
    ((8, 10), (4, 5), (2, 3)),
    ((16, 20), (8, 10), (4, 5), (2, 3)),
    ((5, 7), (3, 3)),
    ((2, 3),),
)


def _state_for(seed: int, level_shapes, capacity: float, k: float,
               batch: int = 2):
    """Random frequency field (with exact zeros, like real FWP counts)
    -> compact FWPState."""
    _, n_in = level_starts(level_shapes)
    key = jax.random.PRNGKey(seed)
    freq = jax.random.uniform(key, (batch, n_in), maxval=10.0)
    alive = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.7,
                                 (batch, n_in))
    freq = freq * alive.astype(jnp.float32)
    return build_fwp_state(freq, level_shapes, k=k, mode="compact",
                           capacity=capacity)


# --------------------------------------------------------------------------
# invariant checkers (shared by the hypothesis and fixed-seed entries)
# --------------------------------------------------------------------------

def _check_raster_order(state, level_shapes, capacity):
    """Slots are raster-ordered per level and level-segmented, so the
    full keep_idx row is strictly increasing and each level's slots stay
    inside that level's flat pixel range."""
    starts, _ = level_starts(level_shapes)
    caps = level_capacities(level_shapes, capacity)
    ki = np.asarray(state.keep_idx)
    assert ki.shape[1] == sum(caps)
    # strictly increasing across the whole row (level segments ordered)
    assert (np.diff(ki, axis=1) > 0).all(), "keep_idx not raster-ordered"
    off = 0
    for (h, w), s, c in zip(level_shapes, starts, caps):
        seg = ki[:, off:off + c]
        assert (seg >= s).all() and (seg < s + h * w).all(), \
            f"level slots escape the level range (start={s}, n={h*w})"
        off += c


def _check_pix2slot_roundtrip(state):
    """pix2slot and keep_idx are inverse maps on the surviving slots;
    everything else lands on the sentinel."""
    ki = np.asarray(state.keep_idx)
    p2s = np.asarray(state.pix2slot)
    mask = np.asarray(state.keep_mask)
    b, cap_total = ki.shape
    sentinel = cap_total
    surviving = np.take_along_axis(mask, ki, axis=1)          # (B, cap)
    for bi in range(b):
        # surviving slot s -> its pixel -> back to s
        s_idx = np.nonzero(surviving[bi])[0]
        np.testing.assert_array_equal(p2s[bi, ki[bi, s_idx]], s_idx)
        # every non-sentinel entry points back at its own pixel AND that
        # pixel survived the threshold
        pix = np.nonzero(p2s[bi] != sentinel)[0]
        slots = p2s[bi, pix]
        np.testing.assert_array_equal(ki[bi, slots], pix)
        assert mask[bi, pix].all()
        # pruned pixels (below threshold) always hit the sentinel
        assert (p2s[bi, ~mask[bi]] == sentinel).all()


def _check_slot_windows(state, level_shapes, capacity, seed: int):
    """searchsorted(keep_idx)-derived slot windows of random pixel
    windows: the window is exactly the contiguous [s0, s1) slot range
    and covers at most min(window_pixels, cap_l) slots — and the
    kernel's clipped static window keeps every kept slot addressable."""
    starts, _ = level_starts(level_shapes)
    caps = level_capacities(level_shapes, capacity)
    ki = np.asarray(state.keep_idx)
    b, n_rows_nosent = ki.shape
    rng = np.random.default_rng(seed)
    for li, ((h, w), s, cap_l) in enumerate(zip(level_shapes, starts, caps)):
        n_l = h * w
        for _ in range(4):
            # random row-aligned pixel window inside level li (the kernel
            # windows whole rows: wp = n_rows * w)
            r0 = int(rng.integers(0, h))
            r1 = int(rng.integers(r0, h)) + 1
            lo = s + r0 * w
            hi = s + r1 * w
            wp = hi - lo
            for bi in range(b):
                s0 = int(np.searchsorted(ki[bi], lo))
                s1 = int(np.searchsorted(ki[bi], hi))
                in_window = ((ki[bi] >= lo) & (ki[bi] < hi))
                # the slot range is exactly the window's kept pixels...
                assert in_window.sum() == s1 - s0
                if s1 > s0:
                    assert in_window[s0:s1].all()
                # ...and never exceeds the static staging bound
                wext = min(wp, cap_l)
                assert s1 - s0 <= wext, (s1 - s0, wext)
                # kernel clipping: start = clip(s0, 0, n_rows - wext)
                # (n_rows includes the sentinel) only ever moves the
                # start DOWN, keeping every kept slot covered
                start_clipped = min(s0, (n_rows_nosent + 1) - wext)
                assert start_clipped <= s0
                assert s1 <= start_clipped + wext


def _check_all(seed, level_shapes, capacity, k):
    state = _state_for(seed, level_shapes, capacity, k)
    _check_raster_order(state, level_shapes, capacity)
    _check_pix2slot_roundtrip(state)
    _check_slot_windows(state, level_shapes, capacity, seed)


# --------------------------------------------------------------------------
# hypothesis properties (skip cleanly when hypothesis is absent)
# --------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**16), st.integers(0, len(LEVEL_POOL) - 1),
       st.floats(0.1, 1.0), st.floats(0.0, 2.0))
def test_fwp_compact_invariants_property(seed, pool_idx, capacity, k):
    _check_all(seed, LEVEL_POOL[pool_idx], capacity, k)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**16), st.floats(0.1, 1.0))
def test_fwp_slot_windows_cover_all_kept_pixels_property(seed, capacity):
    """Dedicated window property on the DETR-ish 4-level pyramid: every
    kept pixel of every row-aligned window is reachable through the
    searchsorted slot window (what the windowed kernel's no-densify
    execution relies on)."""
    level_shapes = LEVEL_POOL[1]
    state = _state_for(seed, level_shapes, capacity, k=1.0)
    _check_slot_windows(state, level_shapes, capacity, seed)


# --------------------------------------------------------------------------
# fixed-seed fallback — ALWAYS runs, hypothesis or not
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pool_idx", range(len(LEVEL_POOL)))
def test_fwp_compact_invariants_fixed_seeds(pool_idx):
    """Seeded sweep of the same invariants: keeps the geometry contract
    exercised when hypothesis isn't installed (it is only the `test`
    extra), and pins a deterministic regression surface either way."""
    for seed in range(5):
        for capacity in (0.25, 0.6, 1.0):
            _check_all(seed, LEVEL_POOL[pool_idx], capacity, k=1.0)


def test_fwp_compact_invariants_threshold_extremes():
    """k=0 keeps every pixel (capacity permitting); a huge k prunes all:
    the geometry invariants must hold at both extremes."""
    level_shapes = LEVEL_POOL[0]
    for k in (0.0, 100.0):
        _check_all(7, level_shapes, 0.6, k)
    # k=0, full capacity: every pixel survives and round-trips
    state = _state_for(11, level_shapes, 1.0, 0.0)
    assert bool(np.asarray(state.keep_mask).all())
    p2s = np.asarray(state.pix2slot)
    assert (p2s != state.keep_idx.shape[1]).all()   # no sentinel hits
