"""flash-decode kernel validation: shape/dtype sweep + ring-buffer masks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,hq,hkv,dh,w", [
    (2, 8, 2, 32, 100), (1, 4, 4, 64, 513), (3, 25, 5, 16, 64), (2, 48, 8, 32, 257),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(b, hq, hkv, dh, w, dtype):
    key = jax.random.PRNGKey(b * 7 + w)
    q = jax.random.normal(key, (b, hq, dh)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, w, hkv, dh)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, w, hkv, dh)).astype(dtype)
    valid = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.7, (b, w))
    valid = valid.at[:, 0].set(True)                   # at least one slot
    out = ops.flash_decode(q, k, v, valid, chunk=64)
    want = ref.flash_decode_ref(q, k, v, valid)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_decode_matches_model_attn_decode():
    """Kernel == the model's decode-attention math on a ring-buffer cache."""
    from repro.models import layers as L
    from repro.models.common import ModelConfig
    cfg = ModelConfig(family="dense", d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=64, dtype=jnp.float32)
    p = L.attn_init(jax.random.PRNGKey(0), cfg)
    cache = L.attn_cache_init(cfg, 2, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 64))
    pos = jnp.arange(5)
    win = jnp.asarray(0, jnp.int32)
    _, cache = L.attn_prefill(p, cfg, x, pos, cache, win)
    x1 = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 64))
    posv = jnp.asarray([5, 5], jnp.int32)
    y_model, cache2 = L.attn_decode(p, cfg, x1, cache, posv, win)

    # rebuild the same computation with the kernel
    q = jnp.einsum("bsd,dhk->bshk", x1, p["wq"])[:, 0]
    knew = jnp.einsum("bsd,dhk->bshk", x1, p["wk"])
    vnew = jnp.einsum("bsd,dhk->bshk", x1, p["wv"])
    from repro.models.common import rope
    q = rope(q[:, None], posv[:, None], cfg.rope_theta)[:, 0]
    knew = rope(knew, posv[:, None], cfg.rope_theta)
    kc = cache["k"].at[:, 5].set(knew[:, 0])
    vc = cache["v"].at[:, 5].set(vnew[:, 0])
    kpos = cache["kpos"].at[:, 5].set(posv)
    valid = kpos <= posv[:, None]
    out = ops.flash_decode(q, kc, vc, valid, chunk=16)
    y_kernel = jnp.einsum("bhk,hkd->bd", out.astype(jnp.float32),
                          p["wo"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model[:, 0]),
                               rtol=2e-4, atol=2e-4)
