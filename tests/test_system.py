"""End-to-end behaviour tests for the paper's system: the full DEFA
pipeline (backbone -> encoder with block-chained FWP -> heads) trains,
prunes, and serves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detector import (
    DetectorConfig, detection_loss, detector_apply, init_detector)
from repro.core.encoder import EncoderConfig
from repro.core.msdeform_attn import MSDeformAttnConfig
from repro.data.detection import synth_detection_batch
from repro.optim.adamw import OptConfig, adamw_init, adamw_update


def _tiny_cfg(**attn_kw):
    attn = MSDeformAttnConfig(d_model=32, n_heads=2, n_levels=4, n_points=2,
                              **attn_kw)
    return DetectorConfig(encoder=EncoderConfig(attn=attn, n_blocks=2,
                                                d_ffn=64),
                          img_size=32, n_classes=4, backbone_width=16)


def test_detector_trains_and_defa_preserves_function():
    cfg = _tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = init_detector(key, cfg)
    opt = adamw_init(params)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=3, total_steps=20,
                        weight_decay=0.0)

    @jax.jit
    def step(params, opt, img, tc, tb):
        (loss, _), grads = jax.value_and_grad(
            detection_loss, has_aux=True)(params, cfg, img, tc, tb)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    losses = []
    for i in range(20):
        img, tc, tb, _ = synth_detection_batch(
            jax.random.fold_in(key, i), 4, cfg.img_size, cfg.level_shapes)
        params, opt, loss = step(params, opt, img, tc, tb)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses   # system learns

    # DEFA pruning on the trained system: outputs stay close to exact
    img, _, _, _ = synth_detection_batch(key, 4, cfg.img_size,
                                         cfg.level_shapes)
    cls0, box0, _ = detector_apply(params, cfg, img)
    defa = _tiny_cfg(pap_mode="threshold", pap_threshold=0.02,
                     range_narrow=(8.0, 6.0, 4.0, 3.0),
                     act_bits=12, weight_bits=12)
    cls1, box1, aux = detector_apply(params, defa, img, collect_stats=True)
    assert bool(jnp.all(jnp.isfinite(cls1)))
    # class DECISIONS should mostly survive pruning
    agree = float(jnp.mean((jnp.argmax(cls0, -1) == jnp.argmax(cls1, -1))
                           .astype(jnp.float32)))
    assert agree > 0.9, agree
    # PAP actually pruned something on a trained model
    kept = float(np.mean([float(b["point_alive_frac"]) for b in aux["blocks"]]))
    assert kept < 0.99


def test_fwp_chain_reduces_value_rows():
    """Block k's mask must shrink block k+1's compacted value buffer."""
    cfg = _tiny_cfg(fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6)
    key = jax.random.PRNGKey(1)
    params = init_detector(key, cfg)
    img, _, _, _ = synth_detection_batch(key, 2, cfg.img_size,
                                         cfg.level_shapes)
    _, _, aux = detector_apply(params, cfg, img, collect_stats=True)
    n_in = sum(h * w for h, w in cfg.level_shapes)
    # block 0 runs unpruned; block 1 consumed the compact keep-list
    assert aux["blocks"][1]["value_rows"] < n_in
    assert 0.0 < float(aux["blocks"][0]["fwp_keep_frac"]) < 1.0


def test_pallas_impl_inside_full_system():
    cfg = _tiny_cfg(impl="pallas", pap_mode="topk", pap_keep=4,
                    range_narrow=(8.0, 6.0, 4.0, 3.0))
    cfg_jnp = _tiny_cfg(impl="jnp", pap_mode="topk", pap_keep=4,
                        range_narrow=(8.0, 6.0, 4.0, 3.0))
    key = jax.random.PRNGKey(2)
    params = init_detector(key, cfg)
    img, _, _, _ = synth_detection_batch(key, 2, cfg.img_size,
                                         cfg.level_shapes)
    cls_k, box_k, _ = detector_apply(params, cfg, img)
    cls_j, box_j, _ = detector_apply(params, cfg_jnp, img)
    np.testing.assert_allclose(np.asarray(cls_k), np.asarray(cls_j),
                               rtol=2e-4, atol=2e-4)
