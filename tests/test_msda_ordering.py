"""Cache-local query ordering (repro/msda/ordering.py) tests.

The contract under test: ordering is a PURE permutation — permute the
queries by reference point, sample, invert the permutation on the output
— so the attention result is BIT-IDENTICAL to the unordered run for
every backend that permutes (jnp_gather, pallas_fused, pallas_decode),
and the raster-only windowed kernel is gated to the identity path
(its per-tile windows derive from raster query position). Plus the
policy plumbing: config field / env-var resolution, the plan's measured
per-tile window accounting, and the monotone key/permutation math as a
hypothesis property with fixed-seed fallbacks.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro import msda
from repro.core import nn
from repro.core.msdeform_attn import MSDeformAttnConfig, init_msdeform_attn
from repro.msda import ordering

LEVELS = ((16, 20), (8, 10), (4, 5), (2, 3))
N_IN = sum(h * w for h, w in LEVELS)
B, D = 1, 64
N_DEC_Q = 40
RANGES = (6.0, 4.0, 3.0, 2.0)
# backends that actually permute (not raster_only, see the module doc)
PERMUTING_BACKENDS = ("jnp_gather", "pallas_fused", "pallas_decode")


@pytest.fixture(scope="module")
def setup():
    cfg = MSDeformAttnConfig(d_model=D, n_heads=2, range_narrow=RANGES)
    key = jax.random.PRNGKey(3)
    params = init_msdeform_attn(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, N_IN, D))
    dq = jax.random.normal(jax.random.fold_in(key, 2), (B, N_DEC_Q, D))
    drefs = jax.random.uniform(jax.random.fold_in(key, 3), (B, N_DEC_Q, 2),
                               minval=0.05, maxval=0.95)
    return cfg, params, dq, drefs, x


def _fwp_state(cfg, params, x):
    q = jax.random.normal(jax.random.PRNGKey(5), (B, N_IN, D))
    refs = jnp.broadcast_to(
        nn.reference_points_for_levels(LEVELS)[None], (B, N_IN, 2))
    plan = msda.make_plan(cfg, LEVELS, backend="jnp_gather")
    _, state = msda.msda_attention(params, plan, q, refs, x)
    return state


# --------------------------------------------------------------------------
# permutation math: hypothesis property + fixed-seed fallback
# --------------------------------------------------------------------------

def _check_permutation(seed: int, n: int, method: str):
    refs = jax.random.uniform(jax.random.PRNGKey(seed), (2, n, 2))
    perm, inv = ordering.query_permutation(refs, LEVELS, method)
    p, i = np.asarray(perm), np.asarray(inv)
    for b in range(p.shape[0]):
        # a true permutation of range(n), and inv really inverts it
        assert sorted(p[b].tolist()) == list(range(n))
        np.testing.assert_array_equal(p[b][i[b]], np.arange(n))
    # permute-then-invert is the identity on any query-axis array
    arr = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, n, 3, 5))
    back = ordering.invert_queries(ordering.permute_queries(arr, perm), inv)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(arr))
    # sort keys are non-decreasing along the permuted order
    keys = np.asarray(ordering.query_sort_keys(refs, LEVELS, method))
    for b in range(p.shape[0]):
        assert (np.diff(keys[b][p[b]]) >= 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**16), st.integers(1, 300),
       st.sampled_from(("raster", "zorder")))
def test_permutation_property(seed, n, method):
    _check_permutation(seed, n, method)


@pytest.mark.parametrize("method", ("raster", "zorder"))
@pytest.mark.parametrize("seed", (0, 7, 1234))
def test_permutation_fixed_seeds(method, seed):
    _check_permutation(seed, 64, method)


def test_raster_keys_follow_dominant_level_raster_order():
    h, w = LEVELS[ordering.dominant_level(LEVELS)]
    refs = jnp.asarray([[[0.5 / w, 0.5 / h],      # pixel (0, 0)
                         [1.5 / w, 0.5 / h],      # pixel (0, 1)
                         [0.5 / w, 1.5 / h]]])    # pixel (1, 0)
    keys = np.asarray(ordering.query_sort_keys(refs, LEVELS, "raster"))[0]
    assert keys[0] < keys[1] < keys[2]
    assert keys[2] - keys[0] == w                 # one full row apart


def test_unknown_method_raises():
    refs = jnp.zeros((1, 4, 2))
    with pytest.raises(ValueError):
        ordering.query_sort_keys(refs, LEVELS, "hilbert")
    with pytest.raises(ValueError):
        ordering.resolve_query_order(
            dataclasses.replace(MSDeformAttnConfig(d_model=D, n_heads=2),
                                query_order="hilbert"))


# --------------------------------------------------------------------------
# policy resolution: config field > env var > default
# --------------------------------------------------------------------------

def test_resolve_query_order_precedence(monkeypatch):
    # the CI query-order leg exports REPRO_MSDA_QUERY_ORDER globally —
    # start from a clean environment so the precedence chain is the one
    # under test
    monkeypatch.delenv("REPRO_MSDA_QUERY_ORDER", raising=False)
    cfg = MSDeformAttnConfig(d_model=D, n_heads=2)
    assert ordering.resolve_query_order(cfg) == "none"
    monkeypatch.setenv("REPRO_MSDA_QUERY_ORDER", "zorder")
    assert ordering.resolve_query_order(cfg) == "zorder"
    cfg_r = dataclasses.replace(cfg, query_order="raster")
    assert ordering.resolve_query_order(cfg_r) == "raster"
    assert ordering.resolve_query_order(cfg_r, "none") == "none"
    # the plan picks the env override up (and memoizes per resolved value)
    plan = msda.make_plan(cfg, LEVELS, backend="jnp_gather")
    assert plan.query_order == "zorder"
    assert "order=zorder" in plan.describe()


def test_plan_measured_tile_window_accounting():
    cfg = MSDeformAttnConfig(d_model=D, n_heads=2, range_narrow=RANGES)
    plan = msda.make_plan(cfg, LEVELS, backend="jnp_gather",
                          n_queries=N_DEC_Q, n_consumers=6)
    refs = jax.random.uniform(jax.random.PRNGKey(9), (B, N_DEC_Q, 2))
    pm = plan.with_measured_tile_window(refs)
    un_max, un_mean, od_max, od_mean = pm.measured_tilewin
    assert 0 < od_mean <= un_mean and 0 < od_max <= un_max
    assert "tilewin=" in pm.describe()
    # ordering never widens the measured mean window, for either method
    for method in ("raster", "zorder"):
        un = ordering.tile_window_stats(
            refs, LEVELS, RANGES, tile_q=plan.tile_q, lanes=D, itemsize=4)
        od = ordering.tile_window_stats(
            refs, LEVELS, RANGES, tile_q=plan.tile_q, lanes=D, itemsize=4,
            order=method)
        assert od["mean_bytes"] <= un["mean_bytes"]
    # no range_narrow -> nothing to measure, plan unchanged
    plan_nr = msda.make_plan(
        dataclasses.replace(cfg, range_narrow=None), LEVELS,
        backend="jnp_gather", n_queries=N_DEC_Q)
    assert plan_nr.with_measured_tile_window(refs).measured_tilewin is None


def test_make_plan_auto_uses_measured_window_bytes(monkeypatch):
    """The auto policy's VMEM-fit check can use the measured (ordered)
    per-tile window instead of the analytic worst case: a budget between
    the two flips the auto pick only when the measurement is passed."""
    cfg = MSDeformAttnConfig(d_model=D, n_heads=2, range_narrow=RANGES)
    probe = msda.make_plan(cfg, LEVELS, backend="pallas_windowed",
                           block_q=64)
    assert probe.window_bytes is not None
    measured = probe.window_bytes // 4
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", str(measured * 2))
    # vmem_budget_bytes=1 knocks out the whole-table pallas_fused pick, so
    # the windowed fit check decides
    auto_analytic = msda.make_plan(cfg, LEVELS, backend="auto", block_q=64,
                                   vmem_budget_bytes=1)
    auto_measured = msda.make_plan(cfg, LEVELS, backend="auto", block_q=64,
                                   vmem_budget_bytes=1,
                                   measured_window_bytes=measured)
    assert auto_analytic.backend != "pallas_windowed"
    assert auto_measured.backend == "pallas_windowed"


# --------------------------------------------------------------------------
# THE parity contract: bit-identical output, every backend x fwp mode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("order", ("raster", "zorder"))
@pytest.mark.parametrize("fwp", ("off", "compact"))
@pytest.mark.parametrize("backend", PERMUTING_BACKENDS)
def test_ordering_is_bit_identical(setup, backend, fwp, order):
    cfg, params, dq, drefs, x = setup
    if fwp != "off":
        cfg = dataclasses.replace(cfg, fwp_mode=fwp, fwp_k=1.0,
                                  fwp_capacity=0.6)
    state = _fwp_state(cfg, params, x) if fwp != "off" else None
    outs = {}
    for qorder in ("none", order):
        plan = msda.make_plan(cfg, LEVELS, backend=backend,
                              n_queries=N_DEC_Q, n_consumers=6,
                              query_order=qorder)
        assert plan.query_order == qorder
        out, _ = msda.msda_attention(params, plan, dq, drefs, x,
                                     state=state)
        outs[qorder] = np.asarray(out)
    np.testing.assert_array_equal(outs[order], outs["none"])


def test_windowed_backend_gates_ordering_to_identity(setup):
    """pallas_windowed is raster_only: requesting an order keeps the
    plan-level policy but the attention pass must NOT permute (the kernel
    derives per-tile windows from raster query position) — output equals
    the unordered run exactly."""
    cfg, params, _, _, x = setup
    q = jax.random.normal(jax.random.PRNGKey(31), (B, N_IN, D))
    refs = jnp.broadcast_to(
        nn.reference_points_for_levels(LEVELS)[None], (B, N_IN, 2))
    assert msda.backend_info("pallas_windowed").raster_only
    outs = {}
    for qorder in ("none", "zorder"):
        plan = msda.make_plan(cfg, LEVELS, backend="pallas_windowed",
                              block_q=64, query_order=qorder)
        assert plan.query_order == qorder
        out, _ = msda.msda_attention(params, plan, q, refs, x)
        outs[qorder] = np.asarray(out)
    np.testing.assert_array_equal(outs["zorder"], outs["none"])


def test_decoder_bit_identical_across_layers(setup):
    """End-to-end: the full decoder (per-layer refinement re-derives the
    permutation from each layer's pre-refinement refs) is bit-identical
    with ordering on vs off."""
    cfg, params, _, _, x = setup
    cfg = dataclasses.replace(cfg, fwp_mode="compact", fwp_k=1.0,
                              fwp_capacity=0.6)
    state = _fwp_state(cfg, params, x)
    dcfg = msda.MSDADecoderConfig(n_layers=2, n_queries=N_DEC_Q, d_ffn=64)
    dparams = msda.init_decoder(jax.random.PRNGKey(41), dcfg, cfg)
    outs = {}
    for qorder in ("none", "raster"):
        plan = msda.make_plan(cfg, LEVELS, backend="pallas_decode",
                              n_queries=dcfg.n_queries,
                              n_consumers=dcfg.n_layers, query_order=qorder)
        h, refs_out, _ = msda.decoder_apply(dparams, dcfg, plan, x, state)
        outs[qorder] = (np.asarray(h), np.asarray(refs_out))
    np.testing.assert_array_equal(outs["raster"][0], outs["none"][0])
    np.testing.assert_array_equal(outs["raster"][1], outs["none"][1])
