"""Hypothesis property tests on system-wide invariants."""
import jax
import jax.numpy as jnp
import numpy as np
# given/settings/st skip property tests cleanly when hypothesis is absent
from conftest import given, settings, st

from repro.models.common import ModelConfig, rope
from repro.models.decoder import window_schedule


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.integers(0, 1000))
def test_rope_preserves_norm(dh2, pos):
    """Rotary embedding is a rotation: per-head norms are invariant."""
    dh = dh2 * 2
    x = jax.random.normal(jax.random.PRNGKey(dh + pos), (1, 1, 2, dh))
    y = rope(x, jnp.asarray([[pos]]), 1e4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 999))
def test_rope_relative_property(delta):
    """<rope(q,p), rope(k,p+d)> depends only on d, not p."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))
    def score(p):
        qr = rope(q, jnp.asarray([[p]]), 1e4)
        kr = rope(k, jnp.asarray([[p + delta]]), 1e4)
        return float(jnp.sum(qr * kr))
    # f32 trig at |angle|~1e3 limits precision to ~1e-3
    np.testing.assert_allclose(score(3), score(1003), rtol=5e-3, atol=5e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 48), st.integers(0, 8), st.integers(1, 8))
def test_window_schedule_invariants(n_layers, n_global, every):
    cfg = ModelConfig(n_layers=n_layers, attn_window=128,
                      global_every=every,
                      global_layers=tuple(range(0, min(n_global, n_layers))))
    win = window_schedule(cfg)
    assert win.shape == (n_layers,)
    assert ((win == 0) | (win == 128)).all()
    for g in cfg.global_layers:
        assert win[g] == 0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6))
def test_zero_spec_shards_or_leaves(dim0_mult, dim1_mult):
    import os
    from jax.sharding import Mesh, PartitionSpec as P
    if len(jax.devices()) < 1:
        return
    from repro.train.step import zero_spec
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shape = (dim0_mult * 4, dim1_mult * 4)
    out = zero_spec(P(None, None), shape, mesh)
    # single-device mesh: nothing to shard, spec unchanged
    assert out == P(None, None)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8))
def test_band_reorder_is_permutation(n_bands):
    from repro.core.distributed_msdeform import (band_reorder,
                                                 pad_levels_to_bands)
    level_shapes = ((11, 6), (5, 3))
    n_in = sum(h * w for h, w in level_shapes)
    x = jnp.arange(2 * n_in * 3, dtype=jnp.float32).reshape(2, n_in, 3)
    xp, padded = pad_levels_to_bands(x, level_shapes, n_bands)
    xb, perm, inv = band_reorder(xp, padded, n_bands)
    assert sorted(perm.tolist()) == list(range(xp.shape[1]))
    np.testing.assert_array_equal(np.asarray(xb[:, inv]), np.asarray(xp))


def test_bank_sim_inter_level_always_conflict_free():
    from benchmarks.bank_sim import simulate
    for seed in range(3):
        r = simulate(n_queries=128, seed=seed)
        assert r["inter_conflict_free"], seed
        assert r["throughput_ratio"] > 1.5


def test_fmap_reuse_window_smaller_than_level():
    from benchmarks.fmap_reuse import report
    r = report()
    assert r["total_ratio"] > 2.0
    for row in r["levels"]:
        assert row["vmem_window_kb"] <= row["vmem_full_kb"] + 1e-9
        assert 0.0 <= row["fetch_reuse_saving_pct"] <= 100.0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16))
def test_moe_capacity_covers_uniform_load(s, e):
    from repro.models.layers import moe_capacity
    cfg = ModelConfig(family="moe", n_experts=e, n_experts_active=min(2, e),
                      expert_capacity_factor=1.0)
    cap = moe_capacity(cfg, s)
    # uniform routing: s*k/e assignments per expert must fit
    assert cap * e >= s * cfg.n_experts_active
