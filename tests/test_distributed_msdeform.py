"""Band-sharded + halo-exchange MSDeformAttn == single-device oracle
(8 virtual devices; the §Perf technique hillclimb's correctness contract)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=420,
                         cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_banded_halo_msdeform_matches_oracle():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core.msdeform_attn import (MSDeformAttnConfig,
                                          init_msdeform_attn,
                                          msdeform_attn_apply)
    from repro.core.distributed_msdeform import (
        band_layout, band_reorder, msdeform_attn_banded, pad_levels_to_bands)

    N_BANDS = 4
    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    level_shapes = ((18, 20), (9, 10), (5, 5), (3, 3))
    cfg = MSDeformAttnConfig(d_model=64, n_heads=4,
                             range_narrow=(3.0, 2.0, 2.0, 1.0),
                             pap_mode="topk", pap_keep=8)
    key = jax.random.PRNGKey(0)
    params = init_msdeform_attn(key, cfg)
    B = 2
    n_in = sum(h * w for h, w in level_shapes)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, n_in, 64))

    # pad rows to band multiples; reference points on the PADDED grid
    xp, padded_shapes = pad_levels_to_bands(x, level_shapes, N_BANDS)
    n_pad = xp.shape[1]
    refs = []
    for hp, w in padded_shapes:
        ys, xs = np.meshgrid((np.arange(hp) + 0.5) / hp,
                             (np.arange(w) + 0.5) / w, indexing="ij")
        refs.append(np.stack([xs.reshape(-1), ys.reshape(-1)], 1))
    refs = jnp.asarray(np.concatenate(refs, 0), jnp.float32)
    refs = jnp.broadcast_to(refs[None], (B, n_pad, 2))
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, n_pad, 64))

    # single-device oracle on the padded pyramid
    want, _ = msdeform_attn_apply(params, cfg, q, refs, xp, padded_shapes)

    # band-major reorder -> shard -> banded apply -> inverse reorder
    qb, perm, inv = band_reorder(q, padded_shapes, N_BANDS)
    xb, _, _ = band_reorder(xp, padded_shapes, N_BANDS)
    rb, _, _ = band_reorder(refs, padded_shapes, N_BANDS)
    with mesh:
        sh = NamedSharding(mesh, P(None, "model", None))
        out_b = jax.jit(lambda p_, q_, r_, x_: msdeform_attn_banded(
            p_, cfg, q_, r_, x_, padded_shapes, mesh))(
            params, jax.device_put(qb, sh), jax.device_put(rb, sh),
            jax.device_put(xb, sh))
    out = np.asarray(out_b)[:, inv]
    np.testing.assert_allclose(out, np.asarray(want), rtol=2e-4, atol=2e-4)
    print("BANDED == ORACLE OK")
    """)
