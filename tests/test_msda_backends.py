"""Oracle parity + planning tests for the repro.msda subsystem.

Every registered backend must produce the same numbers as the pure
per-level oracle (``msdeform_attn_ref``) when pruning is off (or covers
everything), and must agree with the ``jnp_gather`` backend under real
PAP-topk / FWP-compact pruning. Plan auto-selection and the head-packed
(4 heads x Dh=32 -> 128 lanes) dispatch are exercised explicitly."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import msda
from repro.core import nn
from repro.core.msdeform_attn import (
    MSDeformAttnConfig, init_msdeform_attn, msdeform_attn_ref)

LEVELS = ((16, 20), (8, 10), (4, 5), (2, 3))
N_IN = sum(h * w for h, w in LEVELS)
B, D = 1, 64
RANGES = (6.0, 4.0, 3.0, 2.0)
# raster-query backends (pallas_decode is decode-shaped only: its parity
# matrix lives in the "persistent decode" section below)
ALL_BACKENDS = ("jnp_gather", "pallas_fused", "pallas_windowed")


@pytest.fixture(scope="module")
def setup():
    # Raster-ordered encoder queries (pallas_windowed needs Nq == N_in)
    cfg = MSDeformAttnConfig(d_model=D, n_heads=2, range_narrow=RANGES)
    key = jax.random.PRNGKey(0)
    params = init_msdeform_attn(key, cfg)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, N_IN, D))
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, N_IN, D))
    refs = jnp.broadcast_to(
        nn.reference_points_for_levels(LEVELS)[None], (B, N_IN, 2))
    out_ref = msdeform_attn_ref(params, cfg, q, refs, x, LEVELS)
    return cfg, params, q, refs, x, out_ref


def _run(setup_t, backend, state=None, **cfg_kw):
    cfg, params, q, refs, x, _ = setup_t
    cfg2 = dataclasses.replace(cfg, **cfg_kw)
    plan = msda.make_plan(cfg2, LEVELS, backend=backend, block_q=64)
    return msda.msda_attention(params, plan, q, refs, x, state=state)


# --------------------------------------------------------------------------
# oracle parity — all backends vs. the independent per-level reference
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_backend_matches_oracle_plain(setup, backend):
    out, _ = _run(setup, backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(setup[-1]),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_backend_matches_oracle_pap_topk_covering(setup, backend):
    """PAP-topk keeping every point must still equal the oracle exactly."""
    cfg = setup[0]
    out, _ = _run(setup, backend, pap_mode="topk", pap_keep=cfg.n_lp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(setup[-1]),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_backend_matches_oracle_fwp_compact_covering(setup, backend):
    """FWP-compact with full capacity & zero threshold keeps every pixel:
    the compacted execution must reproduce the oracle bit-for-tolerance."""
    _, st1 = _run(setup, "jnp_gather", fwp_mode="compact", fwp_k=0.0,
                  fwp_capacity=1.0)
    out, _ = _run(setup, backend, state=st1, fwp_mode="compact", fwp_k=0.0,
                  fwp_capacity=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(setup[-1]),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# cross-backend parity under REAL pruning (output != oracle by design)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("pallas_fused", "pallas_windowed"))
def test_backend_matches_jnp_pap_topk(setup, backend):
    kw = dict(pap_mode="topk", pap_keep=8)
    want, _ = _run(setup, "jnp_gather", **kw)
    out, _ = _run(setup, backend, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ("pallas_fused", "pallas_windowed"))
def test_backend_matches_jnp_fwp_compact(setup, backend):
    kw = dict(fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6)
    _, st1 = _run(setup, "jnp_gather", **kw)
    want, _ = _run(setup, "jnp_gather", state=st1, **kw)
    out, _ = _run(setup, backend, state=st1, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ("pallas_fused", "pallas_windowed"))
def test_backend_matches_jnp_pap_and_fwp_combined(setup, backend):
    kw = dict(pap_mode="topk", pap_keep=8,
              fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6)
    _, st1 = _run(setup, "jnp_gather", **kw)
    want, _ = _run(setup, "jnp_gather", state=st1, **kw)
    out, _ = _run(setup, backend, state=st1, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# multi-scale-parallel windowed kernel: full pruning/layout matrix
# --------------------------------------------------------------------------

def _combo_setup(packed: bool):
    """Geometry pair: packed (8 heads x Dh=32 -> 4-head lane groups) vs
    genuinely unpacked (Dh=40 does not divide 128 -> pad layout, G=1)."""
    d, heads = (256, 8) if packed else (80, 2)
    cfg = MSDeformAttnConfig(d_model=d, n_heads=heads, range_narrow=RANGES)
    key = jax.random.PRNGKey(11 if packed else 13)
    params = init_msdeform_attn(key, cfg)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, N_IN, d))
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, N_IN, d))
    refs = jnp.broadcast_to(
        nn.reference_points_for_levels(LEVELS)[None], (B, N_IN, 2))
    return cfg, params, q, refs, x


@pytest.mark.parametrize("packed", (False, True), ids=("padlane", "packed"))
@pytest.mark.parametrize("pap", ("off", "topk"))
@pytest.mark.parametrize("fwp", ("off", "mask", "compact"))
def test_windowed_msp_matches_jnp_all_modes(fwp, pap, packed):
    """Single-launch windowed kernel vs the jnp_gather oracle under every
    combination of {FWP-compact, FWP-mask, PAP-topk, head-packed}."""
    cfg, params, q, refs, x = _combo_setup(packed)
    kw = {}
    if pap == "topk":
        kw.update(pap_mode="topk", pap_keep=8)
    if fwp != "off":
        kw.update(fwp_mode=fwp, fwp_k=1.0, fwp_capacity=0.6)
    cfg2 = dataclasses.replace(cfg, **kw)
    plan_j = msda.make_plan(cfg2, LEVELS, backend="jnp_gather", block_q=64)
    plan_w = msda.make_plan(cfg2, LEVELS, backend="pallas_windowed",
                            block_q=64)
    if packed:
        assert plan_w.lane_layout == "pack" and plan_w.head_pack == 4
    else:
        assert plan_w.lane_layout == "pad" and plan_w.head_pack == 1
    state = None
    if fwp != "off":            # block 1 builds the mask block 2 consumes
        _, state = msda.msda_attention(params, plan_j, q, refs, x)
        assert state.fwp is not None
    want, _ = msda.msda_attention(params, plan_j, q, refs, x, state=state)
    out, _ = msda.msda_attention(params, plan_w, q, refs, x, state=state)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


class _TakeAlongAxisSpy:
    """Records every jnp.take_along_axis call's operand rank."""
    def __init__(self):
        self.ndims = []
        self._real = jnp.take_along_axis

    def __call__(self, arr, idx, axis=None, **kwargs):
        self.ndims.append(arr.ndim)
        return self._real(arr, idx, axis=axis, **kwargs)


def _spy_densify(monkeypatch, setup_t, backend):
    cfg, params, q, refs, x, _ = setup_t
    kw = dict(fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6)
    _, st1 = _run(setup_t, "jnp_gather", **kw)
    spy = _TakeAlongAxisSpy()
    monkeypatch.setattr(jnp, "take_along_axis", spy)
    _run(setup_t, backend, state=st1, **kw)
    monkeypatch.undo()
    return spy


def test_windowed_msp_never_densifies_compact_table(setup, monkeypatch):
    """The single-launch windowed path must never materialize the
    densified (B, N_in, H, Dh) table: no take_along_axis on the 4-D
    value table is traced anywhere in the FWP-compact windowed execution
    (5-D calls are the per-point offset selection, 3-D the compact value
    projection — neither touches the staged table)."""
    spy = _spy_densify(monkeypatch, setup, "pallas_windowed")
    assert all(nd != 4 for nd in spy.ndims), spy.ndims


def test_densify_spy_positive_control(setup, monkeypatch):
    """The spy must catch a real backend that densifies, through the SAME
    execution path the negative tests use. (The old positive control was
    the retired pallas_windowed_loop backend; this registers a probe
    backend that densifies the compact table exactly as the loop did —
    pix2slot broadcast + 4-D take_along_axis — then gathers.)"""
    from repro.msda import backends as backend_registry

    @msda.register_backend("densify_probe")
    def densify_probe(plan, v, pts, probs, cache=None):
        if pts.pix2slot is not None:
            idx = pts.pix2slot[:, :, None, None]
            idx = jnp.broadcast_to(idx, (v.shape[0], plan.n_in) + v.shape[2:])
            v = jnp.take_along_axis(v, idx, axis=1)   # the densify the
            #   single-launch kernel exists to avoid
            pts = pts._replace(pix2slot=None, keep_idx=None)
        return backend_registry.jnp_gather(plan, v, pts, probs)

    try:
        spy = _spy_densify(monkeypatch, setup, "densify_probe")
    finally:
        backend_registry._REGISTRY.pop("densify_probe", None)
    assert any(nd == 4 for nd in spy.ndims), spy.ndims


# --------------------------------------------------------------------------
# persistent decode kernel: parity matrix, staging spy, gradients
# --------------------------------------------------------------------------

N_DEC_Q = 20


def _decode_setup(packed: bool, fwp: str):
    """Decode-shaped workload (N_q learned queries) with an optional
    encoder pass to build the FWP link the cache prunes by."""
    cfg, params, q, refs, x = _combo_setup(packed)
    if fwp != "off":
        cfg = dataclasses.replace(cfg, fwp_mode=fwp, fwp_k=1.0,
                                  fwp_capacity=0.6)
    key = jax.random.PRNGKey(17 if packed else 19)
    dq = jax.random.normal(key, (B, N_DEC_Q, cfg.d_model))
    drefs = jax.random.uniform(jax.random.fold_in(key, 1), (B, N_DEC_Q, 2),
                               minval=0.05, maxval=0.95)
    state = None
    if fwp != "off":
        plan_e = msda.make_plan(cfg, LEVELS, backend="jnp_gather")
        _, state = msda.msda_attention(params, plan_e, q, refs, x)
        assert state.fwp is not None
    return cfg, params, dq, drefs, x, state


@pytest.mark.parametrize("packed", (False, True), ids=("padlane", "packed"))
@pytest.mark.parametrize("fwp", ("off", "mask", "compact"))
def test_decode_backend_matches_jnp_all_modes(fwp, packed):
    """pallas_decode vs the jnp_gather oracle on decode-shaped launches
    across {FWP off/mask/compact} x {packed/pad-lane}."""
    cfg, params, dq, drefs, x, state = _decode_setup(packed, fwp)
    outs = {}
    for be in ("jnp_gather", "pallas_decode"):
        plan = msda.make_plan(cfg, LEVELS, backend=be, n_queries=N_DEC_Q,
                              n_consumers=6)
        if packed:
            assert plan.lane_layout == "pack" and plan.head_pack == 4
        else:
            assert plan.lane_layout == "pad" and plan.head_pack == 1
        out, _ = msda.msda_attention(params, plan, dq, drefs, x, state=state)
        outs[be] = np.asarray(out)
    np.testing.assert_allclose(outs["pallas_decode"], outs["jnp_gather"],
                               rtol=2e-5, atol=2e-5)


class _StagingSpy:
    """Counts calls of the once-per-memory decode staging op."""
    def __init__(self):
        self.calls = 0
        self.staged_shapes = []
        from repro.kernels import msgs_decode
        self._real = msgs_decode.stage_decode_table

    def __call__(self, *args, **kwargs):
        self.calls += 1
        out = self._real(*args, **kwargs)
        self.staged_shapes.append(tuple(out.v.shape))
        return out


def test_decode_stages_table_once_per_memory_not_per_layer(monkeypatch):
    """THE persistent-decode contract: a full 6-layer decode against one
    memory stages the compact table exactly ONCE — the single staged
    array covers every (batch, head-group) block — never once per
    layer."""
    from repro.kernels import msgs_decode
    cfg, params, _, _, x, state = _decode_setup(True, "compact")
    dcfg = msda.MSDADecoderConfig(n_layers=6, n_queries=N_DEC_Q, d_ffn=64)
    dparams = msda.init_decoder(jax.random.PRNGKey(23), dcfg, cfg)
    plan = msda.make_plan(cfg, LEVELS, backend="pallas_decode",
                          n_queries=dcfg.n_queries,
                          n_consumers=dcfg.n_layers)
    spy = _StagingSpy()
    monkeypatch.setattr(msgs_decode, "stage_decode_table", spy)
    h, _, dstate = msda.decoder_apply(dparams, dcfg, plan, x, state)
    monkeypatch.undo()
    assert spy.calls == 1, \
        f"table staged {spy.calls}x for {dcfg.n_layers} layers"
    # the ONE staging covers all (batch, head-group) blocks of the memory
    b, n_groups, n_rows, gdh = spy.staged_shapes[0]
    assert (b, n_groups) == (B, cfg.n_heads // plan.head_pack)
    assert n_rows == dstate.cache.n_rows
    assert gdh == plan.head_pack * cfg.head_dim
    assert dstate.cache.staged is not None
    assert len(dstate.block_stats) == dcfg.n_layers
    assert bool(jnp.all(jnp.isfinite(h)))


def test_decode_staging_spy_positive_control(monkeypatch):
    """The spy must catch per-layer restaging through the same execution
    path: sampling a cache built WITHOUT the staged block (a jnp_gather
    plan's cache) through pallas_decode pays the fallback staging on
    every layer — n_layers spy calls, which is exactly what the
    persistent path eliminates."""
    from repro.kernels import msgs_decode
    cfg, params, dq, drefs, x, state = _decode_setup(True, "compact")
    plan_j = msda.make_plan(cfg, LEVELS, backend="jnp_gather",
                            n_queries=N_DEC_Q)
    plan_d = msda.make_plan(cfg, LEVELS, backend="pallas_decode",
                            n_queries=N_DEC_Q)
    cache = msda.build_value_cache(params, plan_j, x, state)
    assert cache.staged is None
    spy = _StagingSpy()
    monkeypatch.setattr(msgs_decode, "stage_decode_table", spy)
    for _ in range(3):
        msda.msda_attention_cached(params, plan_d, dq, drefs, cache,
                                   state, update_fwp=False)
    monkeypatch.undo()
    assert spy.calls == 3, spy.calls


@pytest.mark.skipif(
    os.environ.get("REPRO_MSDA_TABLE_DTYPE") == "int8",
    reason="int8 tables round values onto the code grid: the value "
           "projection's gradient vanishes through round() by "
           "construction, so grad parity is a float-table contract")
def test_decode_grad_parity_through_full_decoder():
    """Gradient-parity smoke through the FULL 6-layer decode: the
    pallas_decode custom_vjp (backward = exact jnp reference) must
    produce the same loss and parameter gradients as the all-jnp oracle
    stack — the first trainable Pallas backend."""
    cfg, params, _, _, x, state = _decode_setup(False, "compact")
    dcfg = msda.MSDADecoderConfig(n_layers=6, n_queries=N_DEC_Q, d_ffn=64)
    dparams = msda.init_decoder(jax.random.PRNGKey(29), dcfg, cfg)

    def loss_for(backend):
        plan = msda.make_plan(cfg, LEVELS, backend=backend,
                              n_queries=dcfg.n_queries,
                              n_consumers=dcfg.n_layers)

        def loss(p):
            h, refs, _ = msda.decoder_apply(p, dcfg, plan, x, state)
            return jnp.mean(jnp.square(h)) + jnp.mean(refs)
        return jax.value_and_grad(loss)(dparams)

    val_j, grads_j = loss_for("jnp_gather")
    val_d, grads_d = loss_for("pallas_decode")
    np.testing.assert_allclose(float(val_d), float(val_j),
                               rtol=1e-4, atol=1e-5)
    flat_j = jax.tree.leaves(grads_j)
    flat_d = jax.tree.leaves(grads_d)
    assert len(flat_j) == len(flat_d)
    for gj, gd in zip(flat_j, flat_d):
        np.testing.assert_allclose(np.asarray(gd), np.asarray(gj),
                                   rtol=1e-3, atol=1e-4)
    # the shared value projection receives gradient through the STAGED
    # table's custom_vjp (transpose-aware backward)
    assert float(np.abs(np.asarray(grads_d["value"]["value_w"])).sum()) > 0


# --------------------------------------------------------------------------
# int8 value table: full sampled-output parity vs the f32 pipeline
# --------------------------------------------------------------------------

INT8_PARITY_BACKENDS = ("jnp_gather", "pallas_fused", "pallas_decode",
                        "pallas_windowed")


@pytest.mark.parametrize("packed", (False, True), ids=("padlane", "packed"))
@pytest.mark.parametrize("fwp", ("off", "compact"))
@pytest.mark.parametrize("backend", INT8_PARITY_BACKENDS)
def test_int8_table_matches_f32_within_quant_tol(backend, fwp, packed):
    """END-TO-END int8 parity: the same geometry sampled through the int8
    table (codes + frozen per-channel scale, dequantized after the
    bilinear aggregation) must match the f32 pipeline within the ANALYTIC
    quantization bound — each code rounds by at most scale/2, and
    bilinear weights x attention probabilities form a convex combination,
    so per-element |err| <= scale/2 (+ float noise). Explicit
    ``table_dtype`` pins BOTH sides regardless of the
    REPRO_MSDA_TABLE_DTYPE env, so the matrix is identical on the CI int8
    leg. The FWP sentinel row must quantize to code 0 (pruned taps stay
    exactly zero)."""
    if backend == "pallas_decode":
        cfg, params, q2, refs2, x, state = _decode_setup(packed, fwp)
        plan_kw = dict(n_queries=N_DEC_Q, n_consumers=6)
    else:
        cfg, params, q2, refs2, x = _combo_setup(packed)
        state = None
        plan_kw = dict(block_q=64)
        if fwp == "compact":
            cfg = dataclasses.replace(cfg, fwp_mode="compact", fwp_k=1.0,
                                      fwp_capacity=0.6)
            plan_e = msda.make_plan(cfg, LEVELS, backend="jnp_gather",
                                    block_q=64)
            _, state = msda.msda_attention(params, plan_e, q2, refs2, x)
            assert state.fwp is not None

    cfg32 = dataclasses.replace(cfg, table_dtype="float32")
    cfg8 = dataclasses.replace(cfg, table_dtype="int8")
    plan32 = msda.make_plan(cfg32, LEVELS, backend="jnp_gather", **plan_kw)
    plan8 = msda.make_plan(cfg8, LEVELS, backend=backend, **plan_kw)
    assert plan8.quantized_table and not plan32.quantized_table
    want, _ = msda.msda_attention(params, plan32, q2, refs2, x, state=state)
    out, _ = msda.msda_attention(params, plan8, q2, refs2, x, state=state)

    # the scale the int8 run derived (deterministic per memory)
    cache8 = msda.build_value_cache(params, plan8, x, state)
    assert cache8.v.dtype == jnp.int8
    assert cache8.scale is not None
    # per-head sampled outputs are convex combinations of table rows, so
    # their error is <= scale/2 per (h, dh) channel; the output
    # projection then mixes channels: |err_d| <= sum_hk |W_o| * scale/2
    scale = np.asarray(cache8.scale, np.float64)      # (B, 1, H, Dh)
    w_abs = np.abs(np.asarray(params["out_w"], np.float64))   # (H, Dh, D)
    tol = np.einsum("bohk,hkd->bod", scale / 2, w_abs) + 2e-5  # (B, 1, D)
    err = np.abs(np.asarray(out, np.float64) - np.asarray(want, np.float64))
    assert np.all(err <= tol), \
        f"max excess {float((err - tol).max()):.3e} over analytic tol"
    if fwp == "compact":
        assert not np.any(np.asarray(cache8.v)[:, -1]), \
            "FWP sentinel row must be code 0 (exact zero)"


# --------------------------------------------------------------------------
# plan resolution
# --------------------------------------------------------------------------

def test_plan_auto_prefers_fused_when_table_fits(setup):
    plan = msda.make_plan(setup[0], LEVELS, backend="auto")
    assert plan.backend == "pallas_fused"
    assert plan.fits_vmem


def test_plan_auto_falls_to_windowed_when_table_exceeds_budget(setup):
    plan = msda.make_plan(setup[0], LEVELS, backend="auto",
                          vmem_budget_bytes=1024)   # table is ~213 KB
    assert plan.backend == "pallas_windowed"
    assert not plan.fits_vmem


def test_plan_auto_respects_query_count_hint(setup):
    """Decoder-style queries (Nq != N_in) can't use the windowed kernel:
    the hint keeps auto from planning a backend that must crash."""
    plan = msda.make_plan(setup[0], LEVELS, backend="auto",
                          vmem_budget_bytes=1024, n_queries=7)
    assert plan.backend == "jnp_gather"
    plan = msda.make_plan(setup[0], LEVELS, backend="auto",
                          vmem_budget_bytes=1024, n_queries=N_IN)
    assert plan.backend == "pallas_windowed"


def test_plan_auto_respects_window_staging_budget(setup, monkeypatch):
    """The auto policy consults the co-resident staged window sum against
    the REPRO_MSDA_VMEM_BUDGET staging budget: when the sum of the L
    level windows can't co-reside, the windowed kernel would blow VMEM,
    so auto must fall back to jnp_gather."""
    plan = msda.make_plan(setup[0], LEVELS, backend="auto",
                          vmem_budget_bytes=1024)
    assert plan.backend == "pallas_windowed"       # fits the default budget
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "1000")
    assert msda.window_staging_budget() == 1000
    plan = msda.make_plan(setup[0], LEVELS, backend="auto",
                          vmem_budget_bytes=1024)
    assert plan.backend == "jnp_gather"
    # block 1 of a compact chain has no FWP link yet and stages the DENSE
    # windows, so the gate must enforce the worst case: a budget between
    # the compact and dense sums is NOT enough for the windowed kernel
    cfg_c = dataclasses.replace(setup[0], fwp_mode="compact",
                                fwp_capacity=0.6)
    probe = msda.make_plan(cfg_c, LEVELS, backend="jnp_gather")
    assert probe.window_bytes_compact < probe.window_bytes
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET",
                       str(probe.window_bytes_compact))
    plan = msda.make_plan(cfg_c, LEVELS, backend="auto",
                          vmem_budget_bytes=1024)
    assert plan.backend == "jnp_gather"
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", str(probe.window_bytes))
    plan = msda.make_plan(cfg_c, LEVELS, backend="auto",
                          vmem_budget_bytes=1024)
    assert plan.backend == "pallas_windowed"


def test_vmem_budget_env_rejects_malformed_values(monkeypatch):
    """REPRO_MSDA_VMEM_BUDGET parsing is hardened: a malformed value
    raises a clear error naming the variable (not a bare int() traceback),
    non-positive values are rejected, and valid decimal/hex parse."""
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "4MB")
    with pytest.raises(ValueError, match="REPRO_MSDA_VMEM_BUDGET"):
        msda.window_staging_budget()
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "-4096")
    with pytest.raises(ValueError, match="positive"):
        msda.window_staging_budget()
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "0")
    with pytest.raises(ValueError, match="positive"):
        msda.window_staging_budget()
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "123456")
    assert msda.window_staging_budget() == 123456
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "0x100000")
    assert msda.window_staging_budget() == 1 << 20
    # zero-padded decimal stays decimal (no surprise octal/base-0 reject)
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "04194304")
    assert msda.window_staging_budget() == 4194304
    monkeypatch.delenv("REPRO_MSDA_VMEM_BUDGET")
    assert msda.window_staging_budget() == msda.DEFAULT_WINDOW_STAGING_BUDGET


def test_vmem_budget_env_parses_once_per_value(monkeypatch):
    """The parse is cached per observed raw string: a stable env is
    parsed once per process, while CHANGING the value mid-process still
    re-parses (plan_for keys its memo on the resolved budget, so no
    stale plan is served either way)."""
    from repro.msda.plan import _parse_budget_env
    _parse_budget_env.cache_clear()
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "777216")
    assert msda.window_staging_budget() == 777216
    misses = _parse_budget_env.cache_info().misses
    for _ in range(3):
        assert msda.window_staging_budget() == 777216
    info = _parse_budget_env.cache_info()
    assert info.misses == misses and info.hits >= 3
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "888832")
    assert msda.window_staging_budget() == 888832    # re-parsed, not stale
    assert _parse_budget_env.cache_info().misses == misses + 1


def test_plan_decode_shaped_tiling(setup):
    """N_q learned queries are a different block_q regime: the tile clamps
    to next_pow2(N_q), the windowed kernel is rejected, and describe()
    surfaces the build-once cache accounting."""
    plan = msda.make_plan(setup[0], LEVELS, backend="jnp_gather",
                          n_queries=40, n_consumers=6)
    assert plan.decode_shaped
    assert plan.block_q == 64                      # next_pow2(40), not 128
    assert plan.tile_q == 64
    assert plan.window_bytes is None               # no raster windows
    assert "q=decode(40)" in plan.describe()
    assert "build-once" in plan.describe()
    with pytest.raises(ValueError):
        msda.make_plan(setup[0], LEVELS, backend="pallas_windowed",
                       n_queries=40)
    # raster query count hint is NOT decode-shaped
    plan = msda.make_plan(setup[0], LEVELS, backend="jnp_gather",
                          n_queries=N_IN)
    assert not plan.decode_shaped


def test_plan_auto_selects_persistent_decode(setup, monkeypatch):
    """Decode-shaped auto prefers the persistent decode kernel when the
    once-staged table + one layer's operands fit the staging budget
    (REPRO_MSDA_VMEM_BUDGET gate, extended with the decode operand
    accounting); degraded budgets fall back fused -> jnp."""
    cfg_c = dataclasses.replace(setup[0], fwp_mode="compact",
                                fwp_capacity=0.6)
    plan = msda.make_plan(cfg_c, LEVELS, backend="auto", n_queries=40,
                          n_consumers=6)
    assert plan.backend == "pallas_decode"
    assert plan.decode_operand_bytes is not None
    assert "staged=1x" in plan.describe()
    assert f"{plan.n_consumers}x table restage" in plan.describe()
    # a staging budget too small for table+operands rejects the decode
    # kernel; the whole-table slab still fits the default VMEM budget
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", "1000")
    plan = msda.make_plan(cfg_c, LEVELS, backend="auto", n_queries=40)
    assert plan.backend == "pallas_fused"
    # WORST-CASE rule: a decoder fed no FWP link stages the DENSE table
    # (build_value_cache's documented fallback), so a budget between the
    # compact and dense footprints must ALSO reject the decode kernel —
    # same argument as value_rows() and the windowed max(dense, compact)
    # dtype-aware: the dense fallback stages the table at the plan's
    # RESOLVED table dtype (int8 under REPRO_MSDA_TABLE_DTYPE=int8 stages
    # 1-byte codes + one f32 scale row, ~4x fewer bytes)
    dense = plan.table_bytes_for_rows(plan.n_in, with_indirection=False)
    assert plan.cache_table_bytes < dense
    monkeypatch.setenv("REPRO_MSDA_VMEM_BUDGET", str(dense - 1))
    plan = msda.make_plan(cfg_c, LEVELS, backend="auto", n_queries=40)
    assert plan.backend == "pallas_fused"
    # and with the VMEM slab gone too, the oracle path remains
    plan = msda.make_plan(cfg_c, LEVELS, backend="auto", n_queries=40,
                          vmem_budget_bytes=1024)
    assert plan.backend == "jnp_gather"


def test_plan_decode_only_backend_rejected_for_raster(setup):
    """pallas_decode needs a decode-shaped plan: raster launches (no
    n_queries, or n_queries == N_in) must be rejected at plan time."""
    with pytest.raises(ValueError):
        msda.make_plan(setup[0], LEVELS, backend="pallas_decode")
    with pytest.raises(ValueError):
        msda.make_plan(setup[0], LEVELS, backend="pallas_decode",
                       n_queries=N_IN)


def test_backend_registry_metadata():
    """The planner consults registry metadata, not name prefixes: the
    windowed kernel is raster-only, the decode kernel decode-only, and
    unregistered probes default to geometry-neutral."""
    assert msda.backend_info("pallas_windowed").raster_only
    assert not msda.backend_info("pallas_windowed").decode_only
    assert msda.backend_info("pallas_decode").decode_only
    assert not msda.backend_info("jnp_gather").raster_only
    assert msda.backend_info("never_registered") == msda.BackendInfo()


def test_plan_auto_falls_to_jnp_without_range_narrowing(setup):
    cfg = dataclasses.replace(setup[0], range_narrow=None)
    plan = msda.make_plan(cfg, LEVELS, backend="auto", vmem_budget_bytes=1024)
    assert plan.backend == "jnp_gather"


def test_plan_windowed_requires_range_narrowing(setup):
    cfg = dataclasses.replace(setup[0], range_narrow=None)
    with pytest.raises(ValueError):
        msda.make_plan(cfg, LEVELS, backend="pallas_windowed")


def test_plan_unknown_backend_rejected(setup):
    with pytest.raises(ValueError):
        msda.make_plan(setup[0], LEVELS, backend="nope")


def test_plan_block_q_clamped_per_level(setup):
    """min(block_q, next_pow2(nq_l)): the (2,3) level's 6 queries tile as
    8, not 128, and the (4,5) level's 20 queries as 32."""
    plan = msda.make_plan(setup[0], LEVELS, backend="jnp_gather", block_q=128)
    assert plan.block_q_levels == (128, 128, 32, 8)
    assert plan.tile_q == 128
    plan = msda.make_plan(setup[0], LEVELS, backend="jnp_gather", block_q=16)
    assert plan.block_q_levels == (16, 16, 16, 8)


def test_plan_describe_reports_window_accounting(setup):
    """The windowed kernel's staged-VMEM accounting shows up in describe:
    dense window always (range_narrow set), compact window when FWP
    compaction shrinks what is actually staged."""
    plan = msda.make_plan(setup[0], LEVELS, backend="pallas_windowed")
    assert plan.window_bytes is not None and plan.window_bytes > 0
    assert plan.window_bytes_compact is None
    assert "win=" in plan.describe()
    cfg2 = dataclasses.replace(setup[0], fwp_mode="compact",
                               fwp_capacity=0.6)
    plan2 = msda.make_plan(cfg2, LEVELS, backend="pallas_windowed")
    assert plan2.window_bytes_compact is not None
    assert plan2.window_bytes_compact < plan2.window_bytes
    assert "compact" in plan2.describe()


def test_plan_legacy_impl_mapping(setup):
    cfg = dataclasses.replace(setup[0], impl="pallas")
    assert msda.make_plan(cfg, LEVELS).backend == "pallas_fused"
    cfg = dataclasses.replace(setup[0], impl="jnp")
    assert msda.make_plan(cfg, LEVELS).backend == "jnp_gather"
    # explicit cfg.backend overrides impl
    cfg = dataclasses.replace(setup[0], impl="jnp", backend="pallas_fused")
    assert msda.make_plan(cfg, LEVELS).backend == "pallas_fused"


def test_registry_lists_all_builtins():
    for name in ALL_BACKENDS + ("pallas_decode",):
        assert name in msda.available_backends()
        assert callable(msda.get_backend(name))


# --------------------------------------------------------------------------
# head-packed lane layout (4 heads x Dh=32 -> one 128-lane group)
# --------------------------------------------------------------------------

def test_lane_layout_resolution():
    assert msda.lane_layout(8, 32) == ("pack", 4)     # 4x32 = 128 lanes
    assert msda.lane_layout(8, 128) == ("native", 1)
    assert msda.lane_layout(3, 40) == ("pad", 1)      # 40 doesn't divide 128


def test_head_packed_backend_matches_oracle():
    """DETR-scale head geometry (8 heads, Dh=32): the plan packs 4 heads
    per lane group and the packed kernel must equal the oracle."""
    cfg = MSDeformAttnConfig(d_model=256, n_heads=8, range_narrow=RANGES)
    key = jax.random.PRNGKey(3)
    params = init_msdeform_attn(key, cfg)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, N_IN, 256))
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, N_IN, 256))
    refs = jnp.broadcast_to(
        nn.reference_points_for_levels(LEVELS)[None], (B, N_IN, 2))
    plan = msda.make_plan(cfg, LEVELS, backend="pallas_fused", block_q=64)
    assert plan.lane_layout == "pack" and plan.head_pack == 4
    out, _ = msda.msda_attention(params, plan, q, refs, x)
    want = msdeform_attn_ref(params, cfg, q, refs, x, LEVELS)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# pipeline state threading
# --------------------------------------------------------------------------

def test_pipeline_state_threads_fwp_chain(setup):
    cfg, params, q, refs, x, _ = setup
    cfg2 = dataclasses.replace(cfg, fwp_mode="compact", fwp_k=1.0,
                               fwp_capacity=0.8)
    plan = msda.make_plan(cfg2, LEVELS, backend="jnp_gather")
    state = msda.MSDAPipelineState.initial()
    assert state.fwp is None and state.block_index == 0
    _, state = msda.msda_attention(params, plan, q, refs, x, state=state,
                                   collect_stats=True)
    assert state.fwp is not None and state.block_index == 1
    assert len(state.block_stats) == 1
    assert "pap_keep_frac" in state.block_stats[0]
    _, state = msda.msda_attention(params, plan, q, refs, x, state=state,
                                   collect_stats=True)
    assert state.block_index == 2 and len(state.block_stats) == 2
    assert "fwp_keep_frac" in state.block_stats[1]


def test_pipeline_block_stats_stay_aligned_when_toggled(setup):
    """Toggling collect_stats mid-chain must NOT silently drop entries:
    block_stats[i] is block i's entry (None when it didn't collect), so
    indices track block_index exactly."""
    cfg, params, q, refs, x, _ = setup
    cfg2 = dataclasses.replace(cfg, fwp_mode="compact", fwp_k=1.0,
                               fwp_capacity=0.8)
    plan = msda.make_plan(cfg2, LEVELS, backend="jnp_gather")
    state = msda.MSDAPipelineState.initial()
    for collect in (False, True, False, True):
        _, state = msda.msda_attention(params, plan, q, refs, x,
                                       state=state, collect_stats=collect)
    assert state.block_index == 4
    assert len(state.block_stats) == 4             # aligned, not compacted
    assert state.block_stats[0] is None and state.block_stats[2] is None
    assert state.block_stats[1] is not None and state.block_stats[3] is not None
    # block 1 consumed block 0's FWP mask: its stats must say so
    assert int(state.block_stats[1]["value_rows"]) < N_IN
    assert state.collected_stats() == (state.block_stats[1],
                                       state.block_stats[3])
