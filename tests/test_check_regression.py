"""benchmarks/check_regression.py — shared-row machine-speed scale
factor, including the empty-intersection guard (the old path could
divide by nothing or silently scale by 1.0)."""
import pytest

from benchmarks.check_regression import (EmptyIntersectionError,
                                         shared_row_scale)


def _rows(**us):
    return {name: {"us_per_call": v} for name, v in us.items()}


def test_scale_is_median_of_shared_ratios():
    base = _rows(msda_a=100.0, msda_b=200.0, msda_c=300.0)
    cur = _rows(msda_a=50.0, msda_b=100.0, msda_c=600.0)
    # ratios: 2.0, 2.0, 0.5 -> median 2.0
    assert shared_row_scale(base, cur) == pytest.approx(2.0)


def test_scale_even_count_averages_middle_pair():
    base = _rows(msda_a=100.0, msda_b=400.0)
    cur = _rows(msda_a=100.0, msda_b=100.0)
    assert shared_row_scale(base, cur) == pytest.approx(2.5)


def test_scale_ignores_non_prefixed_and_extra_rows():
    base = _rows(msda_a=100.0, other=1.0, msda_only_base=5.0)
    cur = _rows(msda_a=50.0, other=99.0, msda_only_cur=7.0)
    assert shared_row_scale(base, cur) == pytest.approx(2.0)


def test_empty_intersection_fails_loudly():
    base = _rows(msda_old=100.0, unrelated=1.0)
    cur = _rows(msda_new=50.0)
    with pytest.raises(EmptyIntersectionError) as ei:
        shared_row_scale(base, cur)
    msg = str(ei.value)
    # both row sets are printed so the mismatch is diagnosable from logs
    assert "msda_old" in msg and "msda_new" in msg
    assert ei.value.base_rows == ["msda_old"]
    assert ei.value.cur_rows == ["msda_new"]


def test_no_gated_rows_at_all_fails_loudly():
    with pytest.raises(EmptyIntersectionError, match="no shared"):
        shared_row_scale({}, {})


def test_zero_time_rows_do_not_divide_by_zero():
    base = _rows(msda_a=100.0, msda_b=200.0)
    cur = _rows(msda_a=0.0, msda_b=100.0)
    assert shared_row_scale(base, cur) == pytest.approx(2.0)
    with pytest.raises(EmptyIntersectionError):
        shared_row_scale(_rows(msda_a=100.0), _rows(msda_a=0.0))
