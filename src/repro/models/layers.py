"""Layer zoo: GQA attention (full / blockwise / sliding-window / decode),
SwiGLU MLP, capacity-based MoE, and the Mamba2 SSD mixer.

Every layer exposes ``*_init(key, cfg) -> params``, ``*_axes(cfg) ->
logical-axis tree`` and pure apply functions. Per-layer params get stacked
by the decoder and sliced by ``lax.scan``."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import act_sharding as acts
from repro.models.common import ModelConfig, init_dense, rms_norm, rope

BIG_NEG = -1e9


def _constrain_attn(cfg: ModelConfig, q, k, v):
    """O1/O2: pin attention activation shardings. Heads shard over the model
    axis when divisible; otherwise fall back to SEQUENCE-parallel attention
    (each rank: all heads x 1/TP of the queries, K/V gathered) instead of
    letting propagation replicate the whole block."""
    tp = acts.model_axis_size()
    if tp == 0:
        return q, k, v
    if cfg.h_phys % tp == 0:
        q = acts.constrain_batch_model(q, 2)
        if cfg.n_kv_heads % tp == 0:
            k = acts.constrain_batch_model(k, 2)
            v = acts.constrain_batch_model(v, 2)
        else:
            k = acts.constrain_batch(k)
            v = acts.constrain_batch(v)
    else:
        q = acts.constrain_batch_seq(q, 1)
        k = acts.constrain_batch(k)
        v = acts.constrain_batch(v)
    return q, k, v


# ===========================================================================
# GQA attention
# ===========================================================================

def attn_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, dh, hq, hkv = cfg.d_model, cfg.dh, cfg.h_phys, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_dense(k1, (d, hq, dh), d, cfg.dtype),
        "wk": init_dense(k2, (d, hkv, dh), d, cfg.dtype),
        "wv": init_dense(k3, (d, hkv, dh), d, cfg.dtype),
        "wo": init_dense(k4, (hq, dh, d), hq * dh, cfg.dtype),
    }


def attn_axes(cfg: ModelConfig) -> dict:
    return {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, hkv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, n_rep, dh)
                            ).reshape(b, s, hkv * n_rep, dh)


def _kv_for_q(cfg: ModelConfig, k: jnp.ndarray) -> jnp.ndarray:
    """Map kv heads to PHYSICAL q heads. Without padding this is the usual
    GQA repeat; with padded q heads, real heads keep their original
    q->kv grouping and padded heads clamp to the last kv head (their output
    is masked to zero anyway)."""
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if cfg.h_phys == cfg.n_heads:
        return _repeat_kv(k, n_rep)
    hmap = np.minimum(np.arange(cfg.h_phys) // n_rep, cfg.n_kv_heads - 1)
    return k[:, :, jnp.asarray(hmap)]


def _head_mask(cfg: ModelConfig, dtype) -> jnp.ndarray | None:
    if cfg.h_phys == cfg.n_heads:
        return None
    m = np.zeros((cfg.h_phys,), np.float32)
    m[:cfg.n_heads] = 1.0
    return jnp.asarray(m, dtype)


def _causal_window_mask(qpos, kpos, window):
    """window: traced int32; <=0 means full causal."""
    win = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max // 2)
    ok = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - win)
    return ok


def _attn_dense(q, k, v, qpos, kpos, window):
    """Whole-matrix attention (small S). q (B,S,Hq,Dh), k/v (B,Sk,Hq,Dh)."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / np.sqrt(dh)
    mask = _causal_window_mask(qpos, kpos, window)
    scores = jnp.where(mask[None, None], scores, BIG_NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _attn_blockwise(q, k, v, window, chunk: int):
    """Flash-style online-softmax attention, O(chunk²) memory per step.

    q,k,v: (B,S,Hq,Dh) (kv already repeated). Causal within/across chunks."""
    b, s, h, dh = q.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, dh)
    kc = k.reshape(b, nc, chunk, h, dh)
    vc = v.reshape(b, nc, chunk, h, dh)
    scale = 1.0 / np.sqrt(dh)

    def q_chunk_body(qi, q_i):
        # q_i: (B, C, H, Dh); scan over kv chunks with running softmax state
        def kv_body(carry, inputs):
            m, l, acc = carry
            kj, (k_j, v_j) = inputs
            s_ij = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j).astype(jnp.float32) * scale
            qpos = qi * chunk + jnp.arange(chunk)
            kpos = kj * chunk + jnp.arange(chunk)
            mask = _causal_window_mask(qpos, kpos, window)
            s_ij = jnp.where(mask[None, None], s_ij, BIG_NEG)
            m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
            p = jnp.exp(s_ij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(q.dtype), v_j).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, chunk), BIG_NEG, jnp.float32)
        l0 = jnp.zeros((b, h, chunk), jnp.float32)
        a0 = jnp.zeros((b, h, chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (jnp.arange(nc), (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4))))
        out = acc / jnp.maximum(l[..., None], 1e-20)
        return out.transpose(0, 2, 1, 3).astype(q.dtype)          # (B,C,H,Dh)

    outs = jax.lax.map(lambda args: q_chunk_body(*args),
                       (jnp.arange(nc), qc.transpose(1, 0, 2, 3, 4)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


def attn_forward(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                 positions: jnp.ndarray, window) -> jnp.ndarray:
    """Full-sequence causal attention. x (B,S,D); positions (S,)."""
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, positions[None], cfg.rope_theta)
    k = rope(k, positions[None], cfg.rope_theta)
    q, k, v = _constrain_attn(cfg, q, k, v)
    if cfg.attn_impl != "dense" and s > 2 * cfg.attn_chunk \
            and s % cfg.attn_chunk == 0:
        out = _attn_blockwise(q, _kv_for_q(cfg, k), _kv_for_q(cfg, v),
                              window, cfg.attn_chunk)
    else:
        out = _attn_dense(q, _kv_for_q(cfg, k), _kv_for_q(cfg, v),
                          positions, positions, window)
    mask = _head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    return acts.constrain_stream(jnp.einsum("bshk,hkd->bsd", out, p["wo"]))


def attn_cache_init(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.dh), cfg.dtype),
        "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.dh), cfg.dtype),
        # per-request slot positions (continuous batching: requests decode at
        # different positions). Empty slots carry a FUTURE position sentinel
        # so the causal check (kpos <= pos) masks them until written.
        "kpos": jnp.full((batch, cache_len), jnp.iinfo(jnp.int32).max // 2,
                         jnp.int32),
    }


def attn_prefill(p, cfg, x, positions, cache, window):
    """Forward over S tokens + write cache slots [0..S). Requires S<=W."""
    b, s, d = x.shape
    w = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, positions[None], cfg.rope_theta)
    k = rope(k, positions[None], cfg.rope_theta)
    q, k, v = _constrain_attn(cfg, q, k, v)
    if cfg.attn_impl != "dense" and s > 2 * cfg.attn_chunk \
            and s % cfg.attn_chunk == 0:
        out = _attn_blockwise(q, _kv_for_q(cfg, k), _kv_for_q(cfg, v),
                              window, cfg.attn_chunk)
    else:
        out = _attn_dense(q, _kv_for_q(cfg, k), _kv_for_q(cfg, v),
                          positions, positions, window)
    mask = _head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    slots = positions % w
    cache = dict(cache)
    cache["k"] = cache["k"].at[:, slots].set(k)
    cache["v"] = cache["v"].at[:, slots].set(v)
    cache["kpos"] = cache["kpos"].at[:, slots].set(positions[None])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def attn_decode(p, cfg, x1, cache, pos, window):
    """One-token decode. x1 (B,1,D); pos (B,) int32 per-request positions
    (continuous batching); ring-buffer cache."""
    b = x1.shape[0]
    w = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x1, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x1, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x1, p["wv"])
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)
    slot = pos % w                                                  # (B,)
    bidx = jnp.arange(b)
    ck = cache["k"].at[bidx, slot].set(k[:, 0])
    cv = cache["v"].at[bidx, slot].set(v[:, 0])
    kpos = cache["kpos"].at[bidx, slot].set(pos)
    win = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max // 2)
    valid = (kpos <= pos[:, None]) & (kpos > pos[:, None] - win)    # (B,W)
    kk = _kv_for_q(cfg, ck)
    vv = _kv_for_q(cfg, cv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) / np.sqrt(cfg.dh)
    scores = jnp.where(valid[:, None, None], scores, BIG_NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(x1.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    mask = _head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv, "kpos": kpos}


# ===========================================================================
# SwiGLU MLP
# ===========================================================================

def mlp_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": init_dense(k2, (d, f), d, cfg.dtype),
        "w_down": init_dense(k3, (f, d), f, cfg.dtype),
    }
    if cfg.mlp_gated:
        p["w_gate"] = init_dense(k1, (d, f), d, cfg.dtype)
    return p


def mlp_axes(cfg: ModelConfig) -> dict:
    ax = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if cfg.mlp_gated:
        ax["w_gate"] = ("embed", "mlp")
    return ax


def mlp_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = acts.constrain_batch_model(h, h.ndim - 1)       # hidden: model-sharded
    return acts.constrain_stream(h @ p["w_down"])


# ===========================================================================
# MoE (token-choice top-k, static capacity, gather/scatter dispatch)
# ===========================================================================

def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": init_dense(k0, (d, e), d, jnp.float32),           # router in f32
        "w_gate": init_dense(k1, (e, d, f), d, cfg.dtype),
        "w_up": init_dense(k2, (e, d, f), d, cfg.dtype),
        "w_down": init_dense(k3, (e, f, d), f, cfg.dtype),
    }


def moe_axes(cfg: ModelConfig) -> dict:
    return {"router": ("embed", None),
            "w_gate": ("expert", "embed", "expert_mlp"),
            "w_up": ("expert", "embed", "expert_mlp"),
            "w_down": ("expert", "expert_mlp", "embed")}


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    ideal = n_tokens * cfg.n_experts_active / cfg.n_experts
    return max(1, int(np.ceil(ideal * cfg.expert_capacity_factor)))


def moe_apply_ep(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    """Explicit expert parallelism (O4'): shard_map over the model axis.

    Propagation-based EP hit XLA scatter-partitioning weaknesses (involuntary
    full rematerialization of the dispatch buffer: olmoe train spent 275 s in
    collectives). Manual dataflow instead: every model rank runs the (cheap)
    router + per-row dispatch redundantly, builds the buffer ONLY for its own
    E/TP experts, runs its expert FFNs locally, and ONE psum over the model
    axis combines the token outputs — per layer collective = B·S·D bytes,
    independent of E."""
    from repro.distributed.act_sharding import _POLICY
    pol = _POLICY.get()
    mesh = pol["mesh"]
    tp_axis = pol["model"]
    tp = mesh.shape[tp_axis]
    batch_axes = pol["batch"] if isinstance(pol["batch"], tuple) \
        else (pol["batch"],)
    e, k = cfg.n_experts, cfg.n_experts_active
    e_loc = e // tp
    b_global, s, d = x.shape
    n_dp = 1
    for a in batch_axes:
        n_dp *= mesh.shape[a]
    if b_global % n_dp != 0:
        batch_axes, n_dp = (), 1                  # replicate odd batches
    b = b_global // n_dp
    cap = moe_capacity(cfg, s)
    sk = s * k
    from jax.sharding import PartitionSpec as P

    def body(router, w_gate, w_up, w_down, xl):
        rank = jax.lax.axis_index(tp_axis)
        logits = jnp.einsum("bsd,de->bse", xl.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)                      # (B,S,k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=(0, 1))
        ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], e), axis=(0, 1))
        aux = e * jnp.sum(me * ce)

        flat_e = top_e.reshape(b, sk)
        is_local = (flat_e // e_loc) == rank
        # non-local assignments sort to the end and never enter capacity
        sort_key = jnp.where(is_local, flat_e, e)
        order = jnp.argsort(sort_key, axis=-1)
        sorted_e = jnp.take_along_axis(sort_key, order, axis=-1)
        first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left")
                         )(sorted_e)
        pos_in_e = jnp.arange(sk)[None] - first
        keep = (pos_in_e < cap) & (sorted_e < e)
        e_rel = jnp.where(keep, sorted_e - rank * e_loc, 0)
        # dropped / non-local assignments scatter into a TRASH slot — never
        # into slot 0 of expert 0 (a .set there would clobber real tokens)
        dest = jnp.where(keep, e_rel * cap + pos_in_e, e_loc * cap)
        token_of = order // k

        bidx = jnp.arange(b)[:, None]
        src = jnp.take_along_axis(xl, token_of[..., None], axis=1) \
            * keep[..., None].astype(xl.dtype)
        buf = jnp.zeros((b, e_loc * cap + 1, d), xl.dtype
                        ).at[bidx, dest].set(src)[:, :-1].reshape(b, e_loc, cap, d)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, w_gate)) \
            * jnp.einsum("becd,edf->becf", buf, w_up)
        h = jnp.einsum("becf,efd->becd", h, w_down).reshape(b, e_loc * cap, d)

        gathered = jnp.take_along_axis(h, dest[..., None], axis=1,
                                       mode="clip")
        gate = (jnp.take_along_axis(top_p.reshape(b, sk), order, axis=-1)
                * keep).astype(xl.dtype)
        out = jnp.zeros((b, s, d), xl.dtype).at[bidx, token_of].add(
            gathered * gate[..., None])
        out = jax.lax.psum(out, tp_axis)
        if batch_axes:
            # per-shard balance loss, pmean'd — the standard EP choice (a
            # global mean would need an extra reduction of the full probs)
            aux = jax.lax.pmean(aux, batch_axes)
        return out, aux

    bspec = batch_axes if len(batch_axes) != 1 else batch_axes[0]
    fn = jax.shard_map(
        body, mesh=mesh, axis_names=set(mesh.axis_names),    # full manual
        in_specs=(P(None, None), P(tp_axis, None, None),
                  P(tp_axis, None, None), P(tp_axis, None, None),
                  P(bspec, None, None) if batch_axes else P(None, None, None)),
        out_specs=(P(bspec, None, None) if batch_axes else P(None, None, None),
                   P()),
        check_vma=False)
    out, aux = fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return acts.constrain_stream(out), aux


def moe_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    """x (B,S,D) -> (out (B,S,D), aux_loss). Dropped-token capacity MoE.

    Dispatch is PER BATCH ROW (sort/position/scatter along the row's own
    S*k assignments): routing stays fully batch-parallel — no cross-device
    sort/gather of the global token set (the baseline's global argsort made
    XLA replicate the whole dispatch; olmoe train was 50x collective-bound).
    Capacity is per (row, expert): ceil(S*k/E * cf), the standard per-rank
    EP capacity semantics."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    tp = acts.model_axis_size()
    if tp > 1 and e % tp == 0:
        return moe_apply_ep(p, cfg, x)                   # O4': explicit EP
    cap = moe_capacity(cfg, s)
    sk = s * k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                          # (B,S,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)          # renormalize

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], e), axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce)

    # --- per-row dispatch ---------------------------------------------------
    flat_e = top_e.reshape(b, sk)
    order = jnp.argsort(flat_e, axis=-1)                            # (B, S*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    first_of_run = jax.vmap(
        lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    pos_in_e = jnp.arange(sk)[None] - first_of_run                  # (B, S*k)
    keep = pos_in_e < cap
    # overflow drops go to a trash slot, not slot 0 of expert 0
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)      # (B, S*k)
    token_of = order // k                                           # (B, S*k)

    bidx = jnp.arange(b)[:, None]
    src = jnp.take_along_axis(x, token_of[..., None], axis=1) \
        * keep[..., None].astype(x.dtype)
    buf = jnp.zeros((b, e * cap + 1, d), x.dtype).at[bidx, dest].set(src)
    buf = acts.constrain_expert(buf[:, :-1].reshape(b, e, cap, d), expert_dim=1)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = acts.constrain_expert(h, expert_dim=1)
    h = jnp.einsum("becf,efd->becd", h, p["w_down"])
    h = acts.constrain_expert(h, expert_dim=1).reshape(b, e * cap, d)

    gathered = jnp.take_along_axis(h, dest[..., None], axis=1,
                                   mode="clip")                     # (B,S*k,D)
    gate = (jnp.take_along_axis(top_p.reshape(b, sk), order, axis=-1)
            * keep).astype(x.dtype)
    out = jnp.zeros((b, s, d), x.dtype).at[bidx, token_of].add(
        gathered * gate[..., None])
    return acts.constrain_stream(out), aux_loss


# ===========================================================================
# Mamba2 SSD mixer (state-space duality, chunked)
# ===========================================================================

def ssd_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, di, n, hs = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n                                           # x, B, C (G=1)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # z / xBC / dt projections kept as SEPARATE params: the concatenated
    # width (2*di+2n+hs, e.g. 3352) is indivisible by the 16-way model axis
    # and would force replication; split, each block shards cleanly (O3).
    return {
        "in_z": init_dense(k1, (d, di), d, cfg.dtype),
        "in_xbc": init_dense(k4, (d, conv_dim), d, cfg.dtype),
        "in_dt": init_dense(k5, (d, hs), d, cfg.dtype),
        "conv_w": init_dense(k2, (cfg.ssm_conv, conv_dim), cfg.ssm_conv, cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, hs)).astype(jnp.float32),
        "d_skip": jnp.ones((hs,), jnp.float32),
        "dt_bias": jnp.zeros((hs,), jnp.float32),
        "norm": jnp.ones((di,), cfg.dtype),
        "out_proj": init_dense(k3, (di, d), di, cfg.dtype),
    }


def ssd_axes(cfg: ModelConfig) -> dict:
    return {"in_z": ("embed", "mlp"), "in_xbc": ("embed", "mlp"),
            "in_dt": ("embed", None), "conv_w": ("conv", "mlp"),
            "conv_b": ("mlp",), "a_log": (None,), "d_skip": (None,),
            "dt_bias": (None,), "norm": ("mlp",), "out_proj": ("mlp", "embed")}


def _project_zxbcdt(p, x):
    return x @ p["in_z"], x @ p["in_xbc"], x @ p["in_dt"]


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. xbc (B,S,C); w (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    return out + b


def _ssd_chunked(xs, b_in, c_in, dt, a_log, chunk: int, init_state=None):
    """SSD core. xs (B,S,H,P); b_in/c_in (B,S,N) (G=1); dt (B,S,H) (post-
    softplus). Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s_orig, h, pdim = xs.shape
    n = b_in.shape[-1]
    q = min(chunk, s_orig)
    pad = (-s_orig) % q
    if pad:        # causal: end-padding never influences the returned prefix
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // q
    a = -jnp.exp(a_log)                                             # (H,)
    da = (a[None, None] * dt).reshape(bsz, nc, q, h)                # log-decay
    xbar = (xs * dt[..., None]).reshape(bsz, nc, q, h, pdim)
    bc = b_in.reshape(bsz, nc, q, n)
    cc = c_in.reshape(bsz, nc, q, n)

    cum = jnp.cumsum(da, axis=2)                                    # (B,nc,Q,H)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j<=i. Mask in LOG space
    # (before exp) — masking after exp leaks NaN through where() gradients.
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]              # (B,nc,Q,K,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.exp(jnp.where(tri[None, None, ..., None], li, -1e30))
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)                      # (B,nc,Q,K)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp",
                         cb.astype(jnp.float32), l_mat, xbar.astype(jnp.float32))

    # chunk summary states: S_c = sum_k exp(cum_end - cum_k) * B_k ⊗ xbar_k
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                 # (B,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchnp",
                        bc.astype(jnp.float32), decay_to_end, xbar.astype(jnp.float32))

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                         # (B,nc,H)

    def scan_fn(r, inp):
        s_c, dk = inp                                               # (B,H,N,P),(B,H)
        r_new = r * dk[..., None, None] + s_c
        return r_new, r                                             # emit state BEFORE chunk

    r0 = jnp.zeros((bsz, h, n, pdim), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)
    final, r_prev = jax.lax.scan(
        scan_fn, r0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    r_prev = r_prev.transpose(1, 0, 2, 3, 4)                        # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         cc.astype(jnp.float32), jnp.exp(cum), r_prev)
    y = (y_intra + y_inter).reshape(bsz, s, h, pdim)[:, :s_orig]
    return y, final


def ssd_forward(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                init_state=None, return_state: bool = False):
    """Full-sequence Mamba2 mixer. x (B,S,D) -> (B,S,D)."""
    di, n, hs, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc_raw, dt = _project_zxbcdt(p, x)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :di].reshape(*x.shape[:2], hs, pdim)
    b_in = xbc[..., di:di + n]
    c_in = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, final = _ssd_chunked(xs.astype(jnp.float32), b_in.astype(jnp.float32),
                            c_in.astype(jnp.float32), dt, p["a_log"],
                            cfg.ssm_chunk, init_state)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        # decode conv cache holds the last K-1 PRE-activation xBC inputs
        kc = cfg.ssm_conv - 1
        tail = jnp.pad(xbc_raw, ((0, 0), (kc, 0), (0, 0)))[:, -kc:]
        return out, {"ssm": final.astype(jnp.float32),
                     "conv": tail.astype(jnp.float32)}
    return out


def ssd_cache_init(cfg: ModelConfig, batch: int) -> dict:
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), jnp.float32),
    }


def ssd_decode(p: dict, cfg: ModelConfig, x1: jnp.ndarray, cache: dict):
    """Single-token recurrent step. x1 (B,1,D)."""
    di, n, hs, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _project_zxbcdt(p, x1)                             # (B,1,*)
    window = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None]                           # (B,1,C)
    xs = xbc1[..., :di].reshape(-1, hs, pdim).astype(jnp.float32)   # (B,H,P)
    b_in = xbc1[:, 0, di:di + n].astype(jnp.float32)                # (B,N)
    c_in = xbc1[:, 0, di + n:].astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(a[None] * dt1)                                  # (B,H)
    xbar = xs * dt1[..., None]                                      # (B,H,P)
    state = cache["ssm"] * decay[..., None, None] \
        + jnp.einsum("bn,bhp->bhnp", b_in, xbar)
    y = jnp.einsum("bn,bhnp->bhp", c_in, state) \
        + p["d_skip"][None, :, None] * xs
    y = y.reshape(-1, 1, di).astype(x1.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]
    new_cache = {"ssm": state,
                 "conv": window[:, 1:].astype(jnp.float32)}
    return out, new_cache
