"""Encoder-decoder backbone (Whisper-family).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, enc_seq_len, D). The encoder is a
non-causal transformer; the decoder adds cross-attention to the encoder
memory. Decode shapes exercise the decoder's self-attn KV cache plus
precomputed cross-attention K/V."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.common import ModelConfig, init_dense, rms_norm, rope


# --- encoder ---------------------------------------------------------------

def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "attn": L.attn_init(ks[0], cfg),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
            "mlp": L.mlp_init(ks[1], cfg)}


def _enc_layer_axes(cfg):
    return {"ln1": (None,), "attn": L.attn_axes(cfg),
            "ln2": (None,), "mlp": L.mlp_axes(cfg)}


def _enc_layer_fwd(p, cfg, x, positions):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    # non-causal: window < 0 sentinel -> full bidirectional
    b, s, d = x.shape
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
    q = rope(q, positions[None], cfg.rope_theta)
    k = rope(k, positions[None], cfg.rope_theta)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, L._repeat_kv(k, n_rep)
                        ).astype(jnp.float32) / np.sqrt(cfg.dh)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, L._repeat_kv(v, n_rep))
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    x = x + L.mlp_apply(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps))
    return x


# --- decoder with cross-attention ------------------------------------------

def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 4)
    return {"ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "attn": L.attn_init(ks[0], cfg),
            "lnx": jnp.ones((cfg.d_model,), cfg.dtype),
            "xattn": L.attn_init(ks[1], cfg),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
            "mlp": L.mlp_init(ks[2], cfg)}


def _dec_layer_axes(cfg):
    return {"ln1": (None,), "attn": L.attn_axes(cfg),
            "lnx": (None,), "xattn": L.attn_axes(cfg),
            "ln2": (None,), "mlp": L.mlp_axes(cfg)}


def _cross_attn(p, cfg, h, mem_k, mem_v):
    """h (B,Sq,D); mem_k/v (B,Sm,Hkv,Dh) precomputed from encoder memory."""
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, L._repeat_kv(mem_k, n_rep)
                        ).astype(jnp.float32) / np.sqrt(cfg.dh)
    probs = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, L._repeat_kv(mem_v, n_rep))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _mem_kv(p, mem):
    k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"])
    return k, v


# --- full model --------------------------------------------------------------

def init_encdec(key: jax.Array, cfg: ModelConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.n_enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": init_dense(k3, (cfg.vocab_size, cfg.d_model), cfg.d_model, cfg.dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "head": init_dense(k4, (cfg.d_model, cfg.vocab_size), cfg.d_model, cfg.dtype),
    }


def encdec_axes(cfg: ModelConfig) -> dict:
    from repro.models.decoder import _stack_axes
    return {
        "embed": ("vocab", "embed"),
        "enc_layers": _stack_axes(_enc_layer_axes(cfg)),
        "enc_norm": (None,),
        "dec_layers": _stack_axes(_dec_layer_axes(cfg)),
        "final_norm": (None,),
        "head": ("embed", "vocab"),
    }


def encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, D) stub frontend embeddings -> encoder memory."""
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(x, lp):
        return _enc_layer_fwd(lp, cfg, x, positions), None
    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, frames, params["enc_layers"],
                        unroll=cfg.scan_unroll)
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, frames: jnp.ndarray, tokens: jnp.ndarray):
    """Teacher-forced training forward. Returns (logits (B,S,V), aux=0)."""
    mem = encode(params, cfg, frames)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    full = jnp.asarray(0, jnp.int32)

    def body(h, lp):
        hh = rms_norm(lp["ln1"], h, cfg.norm_eps)
        h = h + L.attn_forward(lp["attn"], cfg, hh, positions, full)
        hx = rms_norm(lp["lnx"], h, cfg.norm_eps)
        mk, mv = _mem_kv(lp["xattn"], mem)
        h = h + _cross_attn(lp["xattn"], cfg, hx, mk, mv)
        h = h + L.mlp_apply(lp["mlp"], rms_norm(lp["ln2"], h, cfg.norm_eps))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"], unroll=cfg.scan_unroll)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x @ params["head"], jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    def one_layer(_):
        c = L.attn_cache_init(cfg, batch, cache_len)
        c["mem_k"] = jnp.zeros((batch, cfg.enc_seq_len, cfg.n_kv_heads, cfg.dh),
                               cfg.dtype)
        c["mem_v"] = jnp.zeros((batch, cfg.enc_seq_len, cfg.n_kv_heads, cfg.dh),
                               cfg.dtype)
        return c
    return jax.vmap(one_layer)(jnp.arange(cfg.n_layers))


def prefill(params, cfg: ModelConfig, cache: dict, frames: jnp.ndarray,
            tokens: jnp.ndarray):
    """Encode + teacher-force tokens, filling self- and cross-KV caches."""
    mem = encode(params, cfg, frames)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    full = jnp.asarray(0, jnp.int32)

    def body(h, xs):
        lp, lc = xs
        nc = dict(lc)
        hh = rms_norm(lp["ln1"], h, cfg.norm_eps)
        y, ac = L.attn_prefill(lp["attn"], cfg, hh, positions,
                               {k: lc[k] for k in ("k", "v", "kpos")}, full)
        nc.update(ac)
        h = h + y
        hx = rms_norm(lp["lnx"], h, cfg.norm_eps)
        mk, mv = _mem_kv(lp["xattn"], mem)
        nc["mem_k"], nc["mem_v"] = mk, mv
        h = h + _cross_attn(lp["xattn"], cfg, hx, mk, mv)
        h = h + L.mlp_apply(lp["mlp"], rms_norm(lp["ln2"], h, cfg.norm_eps))
        return h, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache),
                                unroll=cfg.scan_unroll)
    x = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return (x @ params["head"])[:, 0].astype(jnp.float32), new_cache


def decode_step(params, cfg: ModelConfig, cache: dict, tokens: jnp.ndarray,
                pos: jnp.ndarray):
    """One decoder token; cross-attn reads cached mem_k/mem_v."""
    x = jnp.take(params["embed"], tokens[:, None], axis=0)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (tokens.shape[0],))
    full = jnp.asarray(0, jnp.int32)

    def body(h, xs):
        lp, lc = xs
        nc = dict(lc)
        hh = rms_norm(lp["ln1"], h, cfg.norm_eps)
        y, ac = L.attn_decode(lp["attn"], cfg, hh,
                              {k: lc[k] for k in ("k", "v", "kpos")}, pos, full)
        nc.update(ac)
        h = h + y
        hx = rms_norm(lp["lnx"], h, cfg.norm_eps)
        h = h + _cross_attn(lp["xattn"], cfg, hx, lc["mem_k"], lc["mem_v"])
        h = h + L.mlp_apply(lp["mlp"], rms_norm(lp["ln2"], h, cfg.norm_eps))
        return h, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache),
                                unroll=cfg.scan_unroll)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return (x @ params["head"])[:, 0].astype(jnp.float32), new_cache
