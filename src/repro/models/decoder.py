"""Unified decoder stack for all LM-family architectures.

One scan-over-layers implementation serves dense / MoE / SSM / hybrid
families: the layer body is selected statically by ``cfg.family``, while
per-layer *data* (sliding-window size; hybrid's periodic global layers) is
carried as a scanned array so the stack stays scan-uniform — HLO size is
O(1) in depth, which keeps 512-device dry-run compiles tractable and gives
remat a single boundary per layer."""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import act_sharding as acts
from repro.models import layers as L
from repro.models.common import ModelConfig, init_dense, rms_norm


def _tag(x, name: str):
    """Name a tensor for the save_comm remat policy (keep post-collective
    outputs so backward recompute skips the per-layer all-reduces)."""
    from jax.ad_checkpoint import checkpoint_name
    return checkpoint_name(x, name)


def _res_add(cfg: ModelConfig, x, y, name: str):
    """Residual add with optional fusion barrier: keeps the TP all-reduce
    of `y` in bf16 instead of the f32 the downstream norm upcast induces."""
    y = _tag(y, name)
    out = x + y
    if cfg.comm_barrier:
        out = jax.lax.optimization_barrier(out)
    return out


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "save_comm":
        return jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out", "moe_out", "ssd_out")
    return jax.checkpoint_policies.nothing_saveable


# ---------------------------------------------------------------------------
# per-layer window schedule (0 = full attention)
# ---------------------------------------------------------------------------

def window_schedule(cfg: ModelConfig) -> np.ndarray:
    win = np.full((cfg.n_layers,), cfg.attn_window, np.int32)
    if cfg.attn_window and cfg.global_every:
        win[::cfg.global_every] = 0                   # periodic global layers
    for gl in cfg.global_layers:                      # explicit global layers
        win[gl] = 0
    return win


# ---------------------------------------------------------------------------
# layer init / axes per family
# ---------------------------------------------------------------------------

def _layer_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": jnp.ones((d,), cfg.dtype)}
    if cfg.family in ("dense", "vlm", "moe", "hybrid"):
        p["attn"] = L.attn_init(ks[0], cfg)
    if cfg.family in ("ssm", "hybrid"):
        p["ssd"] = L.ssd_init(ks[1], cfg)
    if cfg.family == "hybrid":
        p["norm_attn"] = jnp.ones((d,), cfg.dtype)
        p["norm_ssm"] = jnp.ones((d,), cfg.dtype)
    if cfg.family in ("dense", "vlm", "hybrid"):
        p["ln2"] = jnp.ones((d,), cfg.dtype)
        p["mlp"] = L.mlp_init(ks[2], cfg)
    elif cfg.family == "moe":
        p["ln2"] = jnp.ones((d,), cfg.dtype)
        p["moe"] = L.moe_init(ks[3], cfg)
    return p


def _layer_axes(cfg: ModelConfig) -> dict:
    ax: dict = {"ln1": (None,)}
    if cfg.family in ("dense", "vlm", "moe", "hybrid"):
        ax["attn"] = L.attn_axes(cfg)
    if cfg.family in ("ssm", "hybrid"):
        ax["ssd"] = L.ssd_axes(cfg)
    if cfg.family == "hybrid":
        ax["norm_attn"] = (None,)
        ax["norm_ssm"] = (None,)
    if cfg.family in ("dense", "vlm", "hybrid"):
        ax["ln2"] = (None,)
        ax["mlp"] = L.mlp_axes(cfg)
    elif cfg.family == "moe":
        ax["ln2"] = (None,)
        ax["moe"] = L.moe_axes(cfg)
    return ax


def _stack_axes(tree: Any) -> Any:
    """Prepend the (unsharded) layer-stack axis to every leaf."""
    return jax.tree.map(lambda t: (None,) + t, tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


# ---------------------------------------------------------------------------
# layer apply (forward / prefill / decode)
# ---------------------------------------------------------------------------

def _layer_forward(p: dict, cfg: ModelConfig, x, positions, window):
    aux = jnp.zeros((), jnp.float32)
    x = acts.constrain_stream(x)                   # O1: pin batch sharding
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    if cfg.family in ("dense", "vlm", "moe"):
        x = _res_add(cfg, x, L.attn_forward(p["attn"], cfg, h, positions,
                                            window), "attn_out")
    elif cfg.family == "ssm":
        return _res_add(cfg, x, L.ssd_forward(p["ssd"], cfg, h), "ssd_out"), aux
    elif cfg.family == "hybrid":
        ya = L.attn_forward(p["attn"], cfg, h, positions, window)
        ym = L.ssd_forward(p["ssd"], cfg, h)
        mix = 0.5 * (rms_norm(p["norm_attn"], ya, cfg.norm_eps)
                     + rms_norm(p["norm_ssm"], ym, cfg.norm_eps))
        x = _res_add(cfg, x, mix, "attn_out")
    h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = L.moe_apply(p["moe"], cfg, h2)
        x = _res_add(cfg, x, y, "moe_out")
    else:
        x = _res_add(cfg, x, L.mlp_apply(p["mlp"], h2), "mlp_out")
    return x, aux


def _layer_prefill(p, cfg, x, positions, cache, window):
    x = acts.constrain_stream(x)                   # O1: pin batch sharding
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if cfg.family in ("dense", "vlm", "moe"):
        y, ac = L.attn_prefill(p["attn"], cfg, h, positions,
                               {k: cache[k] for k in ("k", "v", "kpos")}, window)
        new_cache.update(ac)
        x = x + y
    elif cfg.family == "ssm":
        y, sc = L.ssd_forward(p["ssd"], cfg, h, return_state=True)
        new_cache.update(sc)
        return x + y, new_cache
    elif cfg.family == "hybrid":
        ya, ac = L.attn_prefill(p["attn"], cfg, h, positions,
                                {k: cache[k] for k in ("k", "v", "kpos")}, window)
        ym, sc = L.ssd_forward(p["ssd"], cfg, h, return_state=True)
        new_cache.update(ac)
        new_cache.update(sc)
        x = x + 0.5 * (rms_norm(p["norm_attn"], ya, cfg.norm_eps)
                       + rms_norm(p["norm_ssm"], ym, cfg.norm_eps))
    h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = L.moe_apply(p["moe"], cfg, h2)
        x = x + y
    else:
        x = x + L.mlp_apply(p["mlp"], h2)
    return x, new_cache


def _layer_decode(p, cfg, x1, cache, pos, window):
    x1 = acts.constrain_stream(x1)                 # O1: pin batch sharding
    h = rms_norm(p["ln1"], x1, cfg.norm_eps)
    new_cache = dict(cache)
    if cfg.family in ("dense", "vlm", "moe"):
        y, ac = L.attn_decode(p["attn"], cfg, h,
                              {k: cache[k] for k in ("k", "v", "kpos")}, pos, window)
        new_cache.update(ac)
        x1 = x1 + y
    elif cfg.family == "ssm":
        y, sc = L.ssd_decode(p["ssd"], cfg, h,
                             {k: cache[k] for k in ("ssm", "conv")})
        new_cache.update(sc)
        return x1 + y, new_cache
    elif cfg.family == "hybrid":
        ya, ac = L.attn_decode(p["attn"], cfg, h,
                               {k: cache[k] for k in ("k", "v", "kpos")}, pos, window)
        ym, sc = L.ssd_decode(p["ssd"], cfg, h,
                              {k: cache[k] for k in ("ssm", "conv")})
        new_cache.update(ac)
        new_cache.update(sc)
        x1 = x1 + 0.5 * (rms_norm(p["norm_attn"], ya, cfg.norm_eps)
                         + rms_norm(p["norm_ssm"], ym, cfg.norm_eps))
    h2 = rms_norm(p["ln2"], x1, cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = L.moe_apply(p["moe"], cfg, h2)
        x1 = x1 + y
    else:
        x1 = x1 + L.mlp_apply(p["mlp"], h2)
    return x1, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_decoder(key: jax.Array, cfg: ModelConfig) -> dict:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    return {
        "embed": init_dense(k_emb, (cfg.vocab_size, cfg.d_model), cfg.d_model,
                            cfg.dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "head": init_dense(k_head, (cfg.d_model, cfg.vocab_size), cfg.d_model,
                           cfg.dtype),
    }


def decoder_axes(cfg: ModelConfig) -> dict:
    return {
        "embed": ("vocab", "embed"),
        "layers": _stack_axes(_layer_axes(cfg)),
        "final_norm": (None,),
        "head": ("embed", "vocab"),
    }


def forward(params: dict, cfg: ModelConfig, tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits (B,S,V) f32-castable, moe_aux)."""
    if embeds is None:
        embeds = jnp.take(params["embed"], tokens, axis=0)
    embeds = acts.constrain_stream(embeds)
    b, s, d = embeds.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    windows = jnp.asarray(window_schedule(cfg))

    def body(x, xs):
        lp, win = xs
        x, aux = _layer_forward(lp, cfg, x, positions, win)
        return x, aux

    if cfg.remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, auxs = jax.lax.scan(body, embeds, (params["layers"], windows),
                           unroll=cfg.scan_unroll)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = acts.constrain_batch_model(x @ params["head"], 2)   # vocab-sharded
    return logits, jnp.sum(auxs)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """Stacked (n_layers leading axis) cache pytree."""
    def one_layer(_):
        c: dict = {}
        if cfg.family in ("dense", "vlm", "moe", "hybrid"):
            c.update(L.attn_cache_init(cfg, batch, cache_len))
        if cfg.family in ("ssm", "hybrid"):
            c.update(L.ssd_cache_init(cfg, batch))
        return c
    return jax.vmap(one_layer)(jnp.arange(cfg.n_layers))


def prefill(params: dict, cfg: ModelConfig, cache: dict,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None):
    """Prefill S tokens into the cache; returns (last-position logits, cache)."""
    if embeds is None:
        embeds = jnp.take(params["embed"], tokens, axis=0)
    embeds = acts.constrain_stream(embeds)
    b, s, d = embeds.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    windows = jnp.asarray(window_schedule(cfg))

    def body(x, xs):
        lp, lc, win = xs
        x, nc = _layer_prefill(lp, cfg, x, positions, lc, win)
        return x, nc

    if cfg.remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, new_cache = jax.lax.scan(body, embeds, (params["layers"], cache, windows),
                                unroll=cfg.scan_unroll)
    x = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = (x @ params["head"])[:, 0]
    return logits.astype(jnp.float32), new_cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    """One decode step. tokens (B,) int32; pos (B,) int32 per-request
    positions (a scalar broadcasts — uniform batch).

    Returns (logits (B,V) f32, new cache)."""
    x = jnp.take(params["embed"], tokens[:, None], axis=0)          # (B,1,D)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (tokens.shape[0],))
    windows = jnp.asarray(window_schedule(cfg))

    def body(x1, xs):
        lp, lc, win = xs
        x1, nc = _layer_decode(lp, cfg, x1, lc, pos, win)
        return x1, nc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, windows),
                                unroll=cfg.scan_unroll)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["head"])[:, 0]
    return logits.astype(jnp.float32), new_cache
