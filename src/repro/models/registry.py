"""Uniform model API across families + the train/serve entry points used by
launch/, tests and benchmarks."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import decoder, encdec
from repro.models.common import ModelConfig, cross_entropy_loss


class ModelAPI(NamedTuple):
    init: Callable
    axes: Callable
    loss_fn: Callable              # (params, cfg, batch) -> (loss, metrics)
    forward: Callable              # (params, cfg, batch) -> logits
    init_cache: Callable           # (cfg, batch, cache_len) -> cache
    prefill: Callable              # (params, cfg, cache, batch) -> (logits, cache)
    decode_step: Callable          # (params, cfg, cache, tokens, pos) -> (logits, cache)


# --- decoder-only families ---------------------------------------------------

def _dec_loss(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    logits, aux = decoder.forward(params, cfg, tokens=tokens[:, :-1])
    loss = cross_entropy_loss(logits, tokens[:, 1:])
    total = loss + 0.01 * aux
    return total, {"ce": loss, "moe_aux": aux}


def _dec_forward(params, cfg, batch):
    logits, _ = decoder.forward(params, cfg, tokens=batch["tokens"])
    return logits


def _dec_prefill(params, cfg, cache, batch):
    return decoder.prefill(params, cfg, cache, tokens=batch["tokens"])


# --- vlm: stub patch embeddings prepended to text ----------------------------

def _vlm_embeds(params, cfg, batch):
    txt = jnp.take(params["embed"], batch["tokens"], axis=0)
    return jnp.concatenate([batch["img_embeds"].astype(txt.dtype), txt], axis=1)


def _vlm_loss(params, cfg: ModelConfig, batch):
    # predict text tokens only; image positions are context
    tokens = batch["tokens"]                       # (B, S_text+1)
    embeds = _vlm_embeds(params, cfg, {"tokens": tokens[:, :-1],
                                       "img_embeds": batch["img_embeds"]})
    logits, aux = decoder.forward(params, cfg, embeds=embeds)
    n_img = batch["img_embeds"].shape[1]
    logits_txt = logits[:, n_img:]
    loss = cross_entropy_loss(logits_txt, tokens[:, 1:])
    return loss + 0.01 * aux, {"ce": loss, "moe_aux": aux}


def _vlm_forward(params, cfg, batch):
    embeds = _vlm_embeds(params, cfg, batch)
    logits, _ = decoder.forward(params, cfg, embeds=embeds)
    return logits


def _vlm_prefill(params, cfg, cache, batch):
    embeds = _vlm_embeds(params, cfg, batch)
    return decoder.prefill(params, cfg, cache, embeds=embeds)


# --- enc-dec ------------------------------------------------------------------

def _encdec_loss(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    logits, aux = encdec.forward(params, cfg, batch["frames"], tokens[:, :-1])
    loss = cross_entropy_loss(logits, tokens[:, 1:])
    return loss, {"ce": loss, "moe_aux": aux}


def _encdec_forward(params, cfg, batch):
    logits, _ = encdec.forward(params, cfg, batch["frames"], batch["tokens"])
    return logits


def _encdec_prefill(params, cfg, cache, batch):
    return encdec.prefill(params, cfg, cache, batch["frames"], batch["tokens"])


_DEC_API = ModelAPI(
    init=decoder.init_decoder, axes=decoder.decoder_axes,
    loss_fn=_dec_loss, forward=_dec_forward,
    init_cache=decoder.init_cache, prefill=_dec_prefill,
    decode_step=decoder.decode_step)


_REGISTRY: dict[str, ModelAPI] = {
    "dense": _DEC_API,
    "moe": _DEC_API,
    "ssm": _DEC_API,
    "hybrid": _DEC_API,
    "vlm": _DEC_API._replace(loss_fn=_vlm_loss, forward=_vlm_forward,
                             prefill=_vlm_prefill),
    "encdec": ModelAPI(
        init=encdec.init_encdec, axes=encdec.encdec_axes,
        loss_fn=_encdec_loss, forward=_encdec_forward,
        init_cache=encdec.init_cache, prefill=_encdec_prefill,
        decode_step=encdec.decode_step),
}


def get_api(cfg: ModelConfig) -> ModelAPI:
    return _REGISTRY[cfg.family]


def rules_overrides(cfg: ModelConfig, model_axis_size: int) -> dict:
    """Per-arch logical-axis adjustments for divisibility on the mesh.

    * kv heads replicate when they don't divide the model axis (MQA/GQA);
    * MoE: shard the expert dim when divisible, else the per-expert ffn dim;
    * heads fall back to unsharded for tiny head counts (smoke configs)."""
    over: dict[str, Any] = {}
    if cfg.n_kv_heads % model_axis_size != 0:
        over["kv_heads"] = None
    if cfg.n_heads % model_axis_size != 0:
        over["heads"] = None
    if cfg.d_ff and cfg.d_ff % model_axis_size != 0:
        over["mlp"] = None
    if cfg.n_experts:
        if cfg.n_experts % model_axis_size == 0:
            over["expert"] = "model"
            over["expert_mlp"] = None
        else:
            over["expert"] = None
            over["expert_mlp"] = "model" if cfg.d_ff % model_axis_size == 0 else None
    if cfg.vocab_size % model_axis_size != 0:
        over["vocab"] = None
    return over
