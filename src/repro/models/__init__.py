from repro.models.common import ModelConfig  # noqa: F401
from repro.models.registry import get_api, rules_overrides, ModelAPI  # noqa: F401
