"""Shared LM substrate: config, norms, rotary, embeddings, losses.

All models are pure-functional parameter pytrees (no flax in the container);
per-layer parameters are STACKED on a leading layer axis so the decoder
stack runs under ``jax.lax.scan`` — this keeps the HLO size independent of
depth (essential for 512-device dry-run compiles) and is what the remat
policy hooks into."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0
    expert_capacity_factor: float = 1.25
    mlp_gated: bool = True                   # SwiGLU; False = 2-matrix GELU
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # --- hybrid / windowed attention ---
    attn_window: int = 0                     # 0 = full attention
    global_every: int = 0                    # hybrid: every k-th layer is global
    global_layers: Tuple[int, ...] = ()      # explicit global layer ids
    # O2': physical padding of q-heads to a TP-divisible count. Padded heads
    # are output-masked (exact semantics); trades ~(pad/h) local compute for
    # eliminating 16x attention replication when heads % TP != 0.
    pad_heads_to: int = 0
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq_len: int = 1500                  # stub frontend output length
    # --- vlm (llava) ---
    n_img_tokens: int = 0                    # stub patch embeddings prepended
    # --- numerics / execution ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 2048                   # blockwise attention threshold/chunk
    attn_impl: str = "blockwise"             # blockwise | dense (dense: dry-run
                                             # cost accounting — no inner loops)
    scan_unroll: int = 1                     # layer-scan unroll (dry-run cost)
    # --- distribution knobs (consumed by launch/) ---
    pure_dp: bool = False                    # small archs: replicate weights,
                                             # model axis carries SEQUENCE
                                             # parallelism + ZeRO instead of TP
    use_fsdp: bool = False
    remat: bool = True
    remat_policy: str = "nothing"            # nothing | save_comm (keep post-
                                             # collective outputs: recompute
                                             # skips per-layer all-reduces)
    comm_barrier: bool = False               # cut fusion at residual adds so
                                             # TP all-reduces run in bf16, not
                                             # the f32 the norm upcast induces
    grad_accum: int = 1
    notes: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def h_phys(self) -> int:
        """Physical q-head count (>= n_heads when pad_heads_to is set)."""
        return max(self.pad_heads_to, self.n_heads) if self.pad_heads_to \
            else self.n_heads

    @property
    def d_inner(self) -> int:                # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        dh, hq, hkv = self.dh, self.n_heads, self.n_kv_heads
        attn = d * dh * hq + 2 * d * dh * hkv + dh * hq * d
        if self.family == "ssm":
            attn = 0
        nmat = 3 if self.mlp_gated else 2
        mlp = nmat * d * f
        if self.n_experts:
            mlp = nmat * d * f * self.n_experts + d * self.n_experts
        ssm = 0
        if self.ssm_state:
            di, n, hs = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = d * (2 * di + 2 * n + hs) + di * d + self.ssm_conv * (di + 2 * n)
        per_layer = attn + (mlp if self.family != "ssm" else 0) + ssm + 2 * d
        total = l * per_layer + 2 * v * d
        if self.family == "encdec":
            enc = self.n_enc_layers * (d * dh * hq * 2 + 2 * d * dh * hkv
                                       + nmat * d * f + 2 * d)
            total += enc + l * (d * dh * hq + 2 * d * dh * hkv + dh * hq * d)  # cross-attn
        return int(total)

    def active_param_count(self) -> int:
        if not self.n_experts:
            return self.param_count()
        d, f, l = self.d_model, self.d_ff, self.n_layers
        nmat = 3 if self.mlp_gated else 2
        dense_mlp = nmat * d * f * self.n_experts
        active_mlp = nmat * d * f * self.n_experts_active
        return int(self.param_count() - l * (dense_mlp - active_mlp))


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freq = 1.0 / (theta ** (np.arange(half, dtype=np.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)               # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def init_dense(key, shape, scale_dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape) / np.sqrt(scale_dim)).astype(dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits (B,S,V) f32-upcast CE; labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
