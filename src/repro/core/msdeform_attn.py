"""Multi-Scale Deformable Attention with the DEFA optimization stack.

Implements Eq. 1 of the paper:

    MSDeformAttn(Q, P, X) = Concat(H_0 .. H_{Nh-1}) W^O
    H_ij = softmax(Q_i W^A_j) · V_j(P_i + ΔP_ij),  V = X W^V,  ΔP = Q W^S

plus the DEFA dataflow (paper §4.1): PAP on the attention probabilities,
FWP on the value projection (mask from the *previous* block), level-wise
range-narrowing of the offsets, INT12 fake-quantization, and the fused
MSGS+aggregation execution (jnp flat-gather or the Pallas kernel).

Conventions (match the official Deformable-DETR):
  * reference points normalized to [0,1]² and shared across levels;
  * sampling_location_l = ref + ΔP_l / (W_l, H_l)  (offsets in pixel units);
  * grid_sample semantics align_corners=False, zero padding:
    pixel-space x = loc_x · W_l − 0.5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fwp as fwp_lib
from repro.core import pap as pap_lib
from repro.core.quant import maybe_fake_quant


# --------------------------------------------------------------------------
# Config / params
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MSDeformAttnConfig:
    d_model: int = 256
    n_heads: int = 8
    n_levels: int = 4
    n_points: int = 4
    # --- DEFA algorithm knobs ---------------------------------------------
    pap_mode: str = "off"                # off | threshold | topk
    pap_threshold: float = 0.02
    pap_keep: int = 4                    # topk mode: points kept of n_levels*n_points
    fwp_mode: str = "off"                # off | mask | compact
    fwp_k: float = 1.0                   # Eq. 2 hyper-parameter
    fwp_capacity: float = 0.6            # compact mode keep fraction
    range_narrow: Optional[Tuple[float, ...]] = None   # per-level |offset| bound (px)
    act_bits: Optional[int] = None       # 12 => INT12 fake-quant (paper default)
    weight_bits: Optional[int] = None
    impl: str = "jnp"                    # jnp | pallas
    dtype: Any = jnp.float32

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_lp(self) -> int:
        return self.n_levels * self.n_points


def init_msdeform_attn(key: jax.Array, cfg: MSDeformAttnConfig) -> dict:
    d, h, lp = cfg.d_model, cfg.n_heads, cfg.n_lp
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d)
    # Offset bias: the Deformable-DETR grid init — points start on a ring
    # around the reference, scaled by point index.
    thetas = np.arange(h) * (2.0 * np.pi / h)
    grid = np.stack([np.cos(thetas), np.sin(thetas)], -1)          # (H, 2)
    grid = grid / np.abs(grid).max(-1, keepdims=True)
    grid = np.tile(grid[:, None, None, :], (1, cfg.n_levels, cfg.n_points, 1))
    grid = grid * (np.arange(cfg.n_points) + 1.0)[None, None, :, None]
    offs_b = grid.reshape(h, lp * 2).astype(np.float32)            # (H, LP*2)
    return {
        "attn_w": (jax.random.normal(k1, (d, h, lp)) * scale).astype(cfg.dtype),
        "attn_b": jnp.zeros((h, lp), cfg.dtype),
        "offs_w": jnp.zeros((d, h, lp * 2), cfg.dtype),            # zero-init (paper)
        "offs_b": jnp.asarray(offs_b, cfg.dtype),
        "value_w": (jax.random.normal(k2, (d, h, cfg.head_dim)) * scale).astype(cfg.dtype),
        "value_b": jnp.zeros((h, cfg.head_dim), cfg.dtype),
        "out_w": (jax.random.normal(k3, (h, cfg.head_dim, d)) * scale).astype(cfg.dtype),
        "out_b": jnp.zeros((d,), cfg.dtype),
    }


def logical_axes(cfg: MSDeformAttnConfig) -> dict:
    """Logical sharding axes per parameter (see distributed/sharding.py)."""
    return {
        "attn_w": ("embed", "heads", None),
        "attn_b": ("heads", None),
        "offs_w": ("embed", "heads", None),
        "offs_b": ("heads", None),
        "value_w": ("embed", "heads", None),
        "value_b": ("heads", None),
        "out_w": ("heads", None, "embed"),
        "out_b": (None,),
    }


def level_meta(level_shapes: Sequence[Tuple[int, int]]):
    """Static per-level arrays: flat starts, widths, heights; total N_in."""
    starts, n_in = fwp_lib.level_starts(level_shapes)
    ws = np.asarray([w for _, w in level_shapes], np.int32)
    hs = np.asarray([h for h, _ in level_shapes], np.int32)
    return jnp.asarray(starts), jnp.asarray(ws), jnp.asarray(hs), n_in


# --------------------------------------------------------------------------
# Reference oracle — independent per-level implementation (no flat tricks)
# --------------------------------------------------------------------------

def _bilinear_sample_level(v: jnp.ndarray, loc: jnp.ndarray) -> jnp.ndarray:
    """v: (B, Hl, Wl, nH, Dh); loc: (B, Nq, nH, P, 2) normalized [0,1].

    Returns (B, Nq, nH, P, Dh). align_corners=False, zero padding."""
    b, hl, wl, nh, dh = v.shape
    x = loc[..., 0] * wl - 0.5
    y = loc[..., 1] * hl - 0.5
    x0 = jnp.floor(x)
    y0 = jnp.floor(y)
    t1 = x - x0            # frac along x
    t0 = y - y0            # frac along y

    def gather(ix, iy):
        valid = ((ix >= 0) & (ix < wl) & (iy >= 0) & (iy < hl))
        ixc = jnp.clip(ix, 0, wl - 1).astype(jnp.int32)
        iyc = jnp.clip(iy, 0, hl - 1).astype(jnp.int32)
        flat = iyc * wl + ixc                                     # (B,Nq,nH,P)
        vflat = v.reshape(b, hl * wl, nh, dh)
        # fold head into batch for take_along_axis
        vv = vflat.transpose(0, 2, 1, 3).reshape(b * nh, hl * wl, dh)
        ii = flat.transpose(0, 2, 1, 3).reshape(b * nh, -1)
        g = jnp.take_along_axis(vv, ii[..., None], axis=1)
        g = g.reshape(b, nh, flat.shape[1], flat.shape[3], dh).transpose(0, 2, 1, 3, 4)
        return g * valid[..., None]

    n00 = gather(x0, y0)
    n10 = gather(x0 + 1, y0)
    n01 = gather(x0, y0 + 1)
    n11 = gather(x0 + 1, y0 + 1)
    w00 = ((1 - t1) * (1 - t0))[..., None]
    w10 = (t1 * (1 - t0))[..., None]
    w01 = ((1 - t1) * t0)[..., None]
    w11 = (t1 * t0)[..., None]
    return n00 * w00 + n10 * w10 + n01 * w01 + n11 * w11


def msdeform_attn_ref(params: dict, cfg: MSDeformAttnConfig,
                      query: jnp.ndarray, ref_points: jnp.ndarray,
                      x_flat: jnp.ndarray,
                      level_shapes: Sequence[Tuple[int, int]]) -> jnp.ndarray:
    """Pure per-level oracle, no pruning/quant/kernel. (B,Nq,D) out."""
    b, nq, d = query.shape
    h, lp, l, p = cfg.n_heads, cfg.n_lp, cfg.n_levels, cfg.n_points
    logits = jnp.einsum("bnd,dhk->bnhk", query, params["attn_w"]) + params["attn_b"]
    probs = jax.nn.softmax(logits, axis=-1)                        # (B,Nq,H,LP)
    offs = jnp.einsum("bnd,dhk->bnhk", query, params["offs_w"]) + params["offs_b"]
    offs = offs.reshape(b, nq, h, l, p, 2)
    if cfg.range_narrow is not None:
        bounds = jnp.asarray(cfg.range_narrow, query.dtype).reshape(1, 1, 1, l, 1, 1)
        offs = jnp.clip(offs, -bounds, bounds)
    v = jnp.einsum("bnd,dhk->bnhk", x_flat, params["value_w"]) + params["value_b"]

    starts, _ = fwp_lib.level_starts(level_shapes)
    out = jnp.zeros((b, nq, h, cfg.head_dim), query.dtype)
    probs_l = probs.reshape(b, nq, h, l, p)
    for li, (hl, wl) in enumerate(level_shapes):
        v_l = jax.lax.dynamic_slice_in_dim(v, int(starts[li]), hl * wl, axis=1)
        v_l = v_l.reshape(b, hl, wl, h, cfg.head_dim)
        norm = jnp.asarray([wl, hl], query.dtype)
        loc = ref_points[:, :, None, None, :] + offs[:, :, :, li] / norm
        sampled = _bilinear_sample_level(v_l, loc)                 # (B,Nq,H,P,Dh)
        out = out + jnp.sum(sampled * probs_l[:, :, :, li, :, None], axis=3)
    out = jnp.einsum("bnhk,hkd->bnd", out, params["out_w"]) + params["out_b"]
    return out


# --------------------------------------------------------------------------
# DEFA dataflow — flat-gather execution with PAP/FWP/quant + Pallas option
# --------------------------------------------------------------------------

def _corner_data(x_px, y_px, wl, hl, start):
    """Per-point corner indices/weights/validity in the flat fmap.

    x_px,y_px,wl,hl,start: (...,) arrays (wl/hl/start already per-point).
    Returns idx (..., 4) int32, wgt (..., 4), valid (..., 4)."""
    x0 = jnp.floor(x_px)
    y0 = jnp.floor(y_px)
    t1 = x_px - x0
    t0 = y_px - y0
    corners = []
    for dy in (0, 1):
        for dx in (0, 1):
            cx = x0 + dx
            cy = y0 + dy
            valid = ((cx >= 0) & (cx < wl) & (cy >= 0) & (cy < hl))
            cxc = jnp.clip(cx, 0, wl - 1).astype(jnp.int32)
            cyc = jnp.clip(cy, 0, hl - 1).astype(jnp.int32)
            idx = start + cyc * wl + cxc
            w = (t1 if dx else (1 - t1)) * (t0 if dy else (1 - t0))
            corners.append((idx, w, valid))
    idx = jnp.stack([c[0] for c in corners], axis=-1)
    wgt = jnp.stack([c[1] for c in corners], axis=-1)
    valid = jnp.stack([c[2] for c in corners], axis=-1)
    return idx, wgt, valid


def _flat_gather_heads(v: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """v: (B, N, H, Dh); idx: (B, Nq, H, M) -> (B, Nq, H, M, Dh)."""
    b, n, h, dh = v.shape
    _, nq, _, m = idx.shape
    vv = v.transpose(0, 2, 1, 3).reshape(b * h, n, dh)
    ii = idx.transpose(0, 2, 1, 3).reshape(b * h, nq * m)
    g = jnp.take_along_axis(vv, ii[..., None], axis=1)
    return g.reshape(b, h, nq, m, dh).transpose(0, 2, 1, 3, 4)


def msdeform_attn_apply(
    params: dict,
    cfg: MSDeformAttnConfig,
    query: jnp.ndarray,                 # (B, Nq, D)
    ref_points: jnp.ndarray,            # (B, Nq, 2) normalized
    x_flat: jnp.ndarray,                # (B, N_in, D) raw fmap features
    level_shapes: Sequence[Tuple[int, int]],
    fwp_state: Optional[fwp_lib.FWPState] = None,
    *,
    collect_stats: bool = False,
):
    """DEFA-optimized MSDeformAttn. Returns (out (B,Nq,D), aux dict).

    aux: {"fwp_state": FWPState|None (for the NEXT block),
          "pap_keep_frac", "fwp_keep_frac", "sampled_frac"} when
    collect_stats or fwp enabled.
    """
    b, nq, d = query.shape
    h, l, p, lp, dh = cfg.n_heads, cfg.n_levels, cfg.n_points, cfg.n_lp, cfg.head_dim
    starts, ws, hs, n_in = level_meta(level_shapes)
    assert x_flat.shape[1] == n_in, (x_flat.shape, n_in)
    aux: dict = {}

    wq = lambda w: maybe_fake_quant(w, cfg.weight_bits)

    # ---- 1. attention probabilities + PAP (paper dataflow step 1) --------
    logits = jnp.einsum("bnd,dhk->bnhk", query, wq(params["attn_w"])) + params["attn_b"]
    probs = jax.nn.softmax(logits, axis=-1)
    probs = maybe_fake_quant(probs, cfg.act_bits)
    sel = pap_lib.pap_select(probs, cfg.pap_mode,
                             threshold=cfg.pap_threshold, k=cfg.pap_keep)
    k_pts = sel.point_idx.shape[-1]

    # ---- 2. masked sampling-point generation (ΔP) ------------------------
    offs = jnp.einsum("bnd,dhk->bnhk", query, wq(params["offs_w"])) + params["offs_b"]
    offs = offs.reshape(b, nq, h, lp, 2)
    # gather only surviving points' offsets
    offs_k = jnp.take_along_axis(
        offs, sel.point_idx[..., None].astype(jnp.int32), axis=3)  # (B,Nq,H,K,2)
    lvl_of_pt = (sel.point_idx // p).astype(jnp.int32)              # (B,Nq,H,K)
    wl = jnp.take(ws, lvl_of_pt)
    hl = jnp.take(hs, lvl_of_pt)
    st = jnp.take(starts, lvl_of_pt)
    if cfg.range_narrow is not None:
        bounds = jnp.take(jnp.asarray(cfg.range_narrow, query.dtype), lvl_of_pt)
        offs_k = jnp.clip(offs_k, -bounds[..., None], bounds[..., None])
    offs_k = maybe_fake_quant(offs_k, cfg.act_bits)     # INT12 BI datapath input

    wl_f = wl.astype(query.dtype)
    hl_f = hl.astype(query.dtype)
    x_px = ref_points[:, :, None, None, 0] * wl_f + offs_k[..., 0] - 0.5
    y_px = ref_points[:, :, None, None, 1] * hl_f + offs_k[..., 1] - 0.5

    # ---- 3. FWP-pruned value projection ----------------------------------
    if fwp_state is not None and cfg.fwp_mode == "compact":
        cap = fwp_state.keep_idx.shape[1]
        x_kept = jnp.take_along_axis(x_flat, fwp_state.keep_idx[..., None], axis=1)
        v = jnp.einsum("bnd,dhk->bnhk", x_kept, wq(params["value_w"])) + params["value_b"]
        v = jnp.concatenate([v, jnp.zeros((b, 1, h, dh), v.dtype)], axis=1)
        pix2slot = fwp_state.pix2slot                               # (B, N_in)
        n_rows = cap + 1
    elif fwp_state is not None and cfg.fwp_mode == "mask":
        xm = x_flat * fwp_state.keep_mask[..., None].astype(x_flat.dtype)
        v = jnp.einsum("bnd,dhk->bnhk", xm, wq(params["value_w"])) + params["value_b"]
        # masked pixels must contribute EXACT zero (bias would leak):
        v = v * fwp_state.keep_mask[..., None, None].astype(v.dtype)
        pix2slot = None
        n_rows = n_in
    else:
        v = jnp.einsum("bnd,dhk->bnhk", x_flat, wq(params["value_w"])) + params["value_b"]
        pix2slot = None
        n_rows = n_in
    v = maybe_fake_quant(v, cfg.act_bits)

    # ---- 4. fused MSGS + aggregation -------------------------------------
    if cfg.impl == "pallas":
        from repro.kernels import ops as kernel_ops
        out_h = kernel_ops.msgs_fused(
            v, x_px, y_px, st, wl, hl, sel.probs, remap=pix2slot)   # (B,Nq,H,Dh)
    else:
        idx, wgt, valid = _corner_data(x_px, y_px, wl, hl, st)      # (B,Nq,H,K,4)
        if pix2slot is not None:
            bidx = jnp.arange(b).reshape(b, 1, 1, 1, 1)
            idx = pix2slot[bidx, idx]                               # pruned -> sentinel
        eff_w = wgt * valid.astype(wgt.dtype) * sel.probs[..., None]
        g = _flat_gather_heads(v, idx.reshape(b, nq, h, k_pts * 4))
        out_h = jnp.sum(g * eff_w.reshape(b, nq, h, k_pts * 4)[..., None], axis=3)

    out = jnp.einsum("bnhk,hkd->bnd", out_h, wq(params["out_w"])) + params["out_b"]

    # ---- 5. FWP frequency counting for the NEXT block --------------------
    need_freq = cfg.fwp_mode != "off"
    if need_freq or collect_stats:
        pt_alive = (sel.probs > 0).astype(jnp.float32)              # pruned pts don't count
        # frequency is counted in ORIGINAL pixel space (pre-compaction)
        idx_orig, _, valid_orig = _corner_data(x_px, y_px, wl, hl, st)
        counted = valid_orig.astype(jnp.float32) * pt_alive[..., None]
        freq = fwp_lib.count_frequency(
            idx_orig.reshape(b, -1), counted.reshape(b, -1), n_in)
        if need_freq:
            aux["fwp_state"] = fwp_lib.build_fwp_state(
                freq, level_shapes, k=cfg.fwp_k,
                mode=cfg.fwp_mode, capacity=cfg.fwp_capacity)
        if collect_stats:
            aux["freq"] = freq
            aux["pap_keep_frac"] = sel.keep_frac
            aux["point_alive_frac"] = jnp.mean(pt_alive)
            if "fwp_state" in aux:
                aux["fwp_keep_frac"] = 1.0 - fwp_lib.fwp_sparsity(aux["fwp_state"])
            aux["value_rows"] = n_rows
    return out, aux
