"""Multi-Scale Deformable Attention with the DEFA optimization stack.

Implements Eq. 1 of the paper:

    MSDeformAttn(Q, P, X) = Concat(H_0 .. H_{Nh-1}) W^O
    H_ij = softmax(Q_i W^A_j) · V_j(P_i + ΔP_ij),  V = X W^V,  ΔP = Q W^S

plus the DEFA dataflow (paper §4.1): PAP on the attention probabilities,
FWP on the value projection (mask from the *previous* block), level-wise
range-narrowing of the offsets, INT12 fake-quantization, and the fused
MSGS+aggregation execution (jnp flat-gather or the Pallas kernel).

Conventions (match the official Deformable-DETR):
  * reference points normalized to [0,1]² and shared across levels;
  * sampling_location_l = ref + ΔP_l / (W_l, H_l)  (offsets in pixel units);
  * grid_sample semantics align_corners=False, zero padding:
    pixel-space x = loc_x · W_l − 0.5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fwp as fwp_lib


# --------------------------------------------------------------------------
# Config / params
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MSDeformAttnConfig:
    d_model: int = 256
    n_heads: int = 8
    n_levels: int = 4
    n_points: int = 4
    # --- DEFA algorithm knobs ---------------------------------------------
    pap_mode: str = "off"                # off | threshold | topk
    pap_threshold: float = 0.02
    pap_keep: int = 4                    # topk mode: points kept of n_levels*n_points
    fwp_mode: str = "off"                # off | mask | compact
    fwp_k: float = 1.0                   # Eq. 2 hyper-parameter
    fwp_capacity: float = 0.6            # compact mode keep fraction
    range_narrow: Optional[Tuple[float, ...]] = None   # per-level |offset| bound (px)
    act_bits: Optional[int] = None       # 12 => INT12 fake-quant (paper default)
    weight_bits: Optional[int] = None
    impl: str = "jnp"                    # legacy: jnp | pallas (see `backend`)
    backend: Optional[str] = None        # msda backend name or "auto";
                                         # overrides `impl` when set
    dtype: Any = jnp.float32
    table_dtype: Optional[str] = None    # value-TABLE storage dtype:
    #   "int8" stores the cache as int8 codes + per-channel f32 scale and
    #   the kernels dequantize in-register after the corner gather; None
    #   resolves via the REPRO_MSDA_TABLE_DTYPE env var, falling back to
    #   `dtype` (see repro.msda.plan.resolve_table_dtype)
    query_order: Optional[str] = None    # cache-local query ordering:
    #   "raster" | "zorder" permute queries by reference point before
    #   sampling and invert on output (bit-identical numerics, tighter
    #   per-tile staged windows — see repro.msda.ordering); None resolves
    #   via the REPRO_MSDA_QUERY_ORDER env var, falling back to "none"

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_lp(self) -> int:
        return self.n_levels * self.n_points


def init_msdeform_attn(key: jax.Array, cfg: MSDeformAttnConfig) -> dict:
    d, h, lp = cfg.d_model, cfg.n_heads, cfg.n_lp
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d)
    # Offset bias: the Deformable-DETR grid init — points start on a ring
    # around the reference, scaled by point index.
    thetas = np.arange(h) * (2.0 * np.pi / h)
    grid = np.stack([np.cos(thetas), np.sin(thetas)], -1)          # (H, 2)
    grid = grid / np.abs(grid).max(-1, keepdims=True)
    grid = np.tile(grid[:, None, None, :], (1, cfg.n_levels, cfg.n_points, 1))
    grid = grid * (np.arange(cfg.n_points) + 1.0)[None, None, :, None]
    offs_b = grid.reshape(h, lp * 2).astype(np.float32)            # (H, LP*2)
    return {
        "attn_w": (jax.random.normal(k1, (d, h, lp)) * scale).astype(cfg.dtype),
        "attn_b": jnp.zeros((h, lp), cfg.dtype),
        "offs_w": jnp.zeros((d, h, lp * 2), cfg.dtype),            # zero-init (paper)
        "offs_b": jnp.asarray(offs_b, cfg.dtype),
        "value_w": (jax.random.normal(k2, (d, h, cfg.head_dim)) * scale).astype(cfg.dtype),
        "value_b": jnp.zeros((h, cfg.head_dim), cfg.dtype),
        "out_w": (jax.random.normal(k3, (h, cfg.head_dim, d)) * scale).astype(cfg.dtype),
        "out_b": jnp.zeros((d,), cfg.dtype),
    }


def logical_axes(cfg: MSDeformAttnConfig) -> dict:
    """Logical sharding axes per parameter (see distributed/sharding.py)."""
    return {
        "attn_w": ("embed", "heads", None),
        "attn_b": ("heads", None),
        "offs_w": ("embed", "heads", None),
        "offs_b": ("heads", None),
        "value_w": ("embed", "heads", None),
        "value_b": ("heads", None),
        "out_w": ("heads", None, "embed"),
        "out_b": (None,),
    }


# --------------------------------------------------------------------------
# Reference oracle — independent per-level implementation (no flat tricks)
# --------------------------------------------------------------------------

def _bilinear_sample_level(v: jnp.ndarray, loc: jnp.ndarray) -> jnp.ndarray:
    """v: (B, Hl, Wl, nH, Dh); loc: (B, Nq, nH, P, 2) normalized [0,1].

    Returns (B, Nq, nH, P, Dh). align_corners=False, zero padding."""
    b, hl, wl, nh, dh = v.shape
    x = loc[..., 0] * wl - 0.5
    y = loc[..., 1] * hl - 0.5
    x0 = jnp.floor(x)
    y0 = jnp.floor(y)
    t1 = x - x0            # frac along x
    t0 = y - y0            # frac along y

    def gather(ix, iy):
        valid = ((ix >= 0) & (ix < wl) & (iy >= 0) & (iy < hl))
        ixc = jnp.clip(ix, 0, wl - 1).astype(jnp.int32)
        iyc = jnp.clip(iy, 0, hl - 1).astype(jnp.int32)
        flat = iyc * wl + ixc                                     # (B,Nq,nH,P)
        vflat = v.reshape(b, hl * wl, nh, dh)
        # fold head into batch for take_along_axis
        vv = vflat.transpose(0, 2, 1, 3).reshape(b * nh, hl * wl, dh)
        ii = flat.transpose(0, 2, 1, 3).reshape(b * nh, -1)
        g = jnp.take_along_axis(vv, ii[..., None], axis=1)
        g = g.reshape(b, nh, flat.shape[1], flat.shape[3], dh).transpose(0, 2, 1, 3, 4)
        return g * valid[..., None]

    n00 = gather(x0, y0)
    n10 = gather(x0 + 1, y0)
    n01 = gather(x0, y0 + 1)
    n11 = gather(x0 + 1, y0 + 1)
    w00 = ((1 - t1) * (1 - t0))[..., None]
    w10 = (t1 * (1 - t0))[..., None]
    w01 = ((1 - t1) * t0)[..., None]
    w11 = (t1 * t0)[..., None]
    return n00 * w00 + n10 * w10 + n01 * w01 + n11 * w11


def msdeform_attn_ref(params: dict, cfg: MSDeformAttnConfig,
                      query: jnp.ndarray, ref_points: jnp.ndarray,
                      x_flat: jnp.ndarray,
                      level_shapes: Sequence[Tuple[int, int]]) -> jnp.ndarray:
    """Pure per-level oracle, no pruning/quant/kernel. (B,Nq,D) out."""
    b, nq, d = query.shape
    h, lp, l, p = cfg.n_heads, cfg.n_lp, cfg.n_levels, cfg.n_points
    logits = jnp.einsum("bnd,dhk->bnhk", query, params["attn_w"]) + params["attn_b"]
    probs = jax.nn.softmax(logits, axis=-1)                        # (B,Nq,H,LP)
    offs = jnp.einsum("bnd,dhk->bnhk", query, params["offs_w"]) + params["offs_b"]
    offs = offs.reshape(b, nq, h, l, p, 2)
    if cfg.range_narrow is not None:
        bounds = jnp.asarray(cfg.range_narrow, query.dtype).reshape(1, 1, 1, l, 1, 1)
        offs = jnp.clip(offs, -bounds, bounds)
    v = jnp.einsum("bnd,dhk->bnhk", x_flat, params["value_w"]) + params["value_b"]
    from repro.msda.plan import resolve_table_dtype
    if resolve_table_dtype(cfg) == "int8":
        # mirror the backends' int8 table storage: the oracle samples the
        # SAME quantized values, so parity holds within float tolerance
        from repro.core.quant import fake_table_quant
        v = fake_table_quant(v)

    starts, _ = fwp_lib.level_starts(level_shapes)
    out = jnp.zeros((b, nq, h, cfg.head_dim), query.dtype)
    probs_l = probs.reshape(b, nq, h, l, p)
    for li, (hl, wl) in enumerate(level_shapes):
        v_l = jax.lax.dynamic_slice_in_dim(v, int(starts[li]), hl * wl, axis=1)
        v_l = v_l.reshape(b, hl, wl, h, cfg.head_dim)
        norm = jnp.asarray([wl, hl], query.dtype)
        loc = ref_points[:, :, None, None, :] + offs[:, :, :, li] / norm
        sampled = _bilinear_sample_level(v_l, loc)                 # (B,Nq,H,P,Dh)
        out = out + jnp.sum(sampled * probs_l[:, :, :, li, :, None], axis=3)
    out = jnp.einsum("bnhk,hkd->bnd", out, params["out_w"]) + params["out_b"]
    return out


# --------------------------------------------------------------------------
# DEFA dataflow — thin compatibility shim over the repro.msda subsystem
# --------------------------------------------------------------------------
# The monolithic implementation moved to repro/msda/ (plan + backends +
# pipeline). This entry point survives for existing callers: it resolves a
# memoized MSDAPlan from the config (legacy cfg.impl maps to a backend
# name) and adapts MSDAPipelineState back to the old aux-dict protocol.

def msdeform_attn_apply(
    params: dict,
    cfg: MSDeformAttnConfig,
    query: jnp.ndarray,                 # (B, Nq, D)
    ref_points: jnp.ndarray,            # (B, Nq, 2) normalized
    x_flat: jnp.ndarray,                # (B, N_in, D) raw fmap features
    level_shapes: Sequence[Tuple[int, int]],
    fwp_state: Optional[fwp_lib.FWPState] = None,
    *,
    collect_stats: bool = False,
):
    """DEFA-optimized MSDeformAttn. Returns (out (B,Nq,D), aux dict).

    aux: {"fwp_state": FWPState|None (for the NEXT block),
          "pap_keep_frac", "fwp_keep_frac", ...} when collect_stats or
    fwp enabled. New code should use repro.msda directly."""
    from repro.msda import MSDAPipelineState, msda_attention, plan_for

    plan = plan_for(cfg, tuple((int(h), int(w)) for h, w in level_shapes),
                    n_queries=int(query.shape[1]))
    state = MSDAPipelineState(fwp=fwp_state)
    out, state = msda_attention(params, plan, query, ref_points, x_flat,
                                state=state, collect_stats=collect_stats)
    aux: dict = {}
    if cfg.fwp_mode != "off":
        aux["fwp_state"] = state.fwp
    if collect_stats and state.block_stats:
        aux.update(state.block_stats[-1])
    return out, aux
