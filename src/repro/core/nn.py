"""Tiny NN primitives for the DETR-family models (pure jnp, no flax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def linear_init(key, d_in, d_out, dtype=jnp.float32):
    w = jax.random.normal(key, (d_in, d_out)) * (1.0 / np.sqrt(d_in))
    return {"w": w.astype(dtype), "b": jnp.zeros((d_out,), dtype)}


def linear(p, x):
    return x @ p["w"] + p["b"]


def layer_norm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def conv_init(key, k, c_in, c_out, dtype=jnp.float32):
    w = jax.random.normal(key, (c_out, c_in, k, k)) * (1.0 / np.sqrt(c_in * k * k))
    return {"w": w.astype(dtype), "b": jnp.zeros((c_out,), dtype)}


def conv2d(p, x, stride=1, padding="SAME"):
    """x: (B, C, H, W) NCHW."""
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + p["b"][None, :, None, None]


def inverse_sigmoid(x, eps: float = 1e-5):
    """logit(x) with clamping — the reference-point refinement inverse."""
    x = jnp.clip(x, eps, 1.0 - eps)
    return jnp.log(x) - jnp.log1p(-x)


def sine_pos_embed_2d(h: int, w: int, d: int, temperature: float = 10000.0):
    """(H*W, D) 2-D sine position embedding (DETR-style)."""
    assert d % 4 == 0
    d4 = d // 4
    ys, xs = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    omega = 1.0 / (temperature ** (np.arange(d4) / d4))
    out = []
    for coord in (ys, xs):
        ang = coord.reshape(-1, 1) * omega[None, :]
        out.extend([np.sin(ang), np.cos(ang)])
    return jnp.asarray(np.concatenate(out, axis=1), jnp.float32)


def reference_points_for_levels(level_shapes):
    """Normalized pixel-centre reference points, concatenated: (N_in, 2)."""
    pts = []
    for (h, w) in level_shapes:
        ys, xs = np.meshgrid((np.arange(h) + 0.5) / h, (np.arange(w) + 0.5) / w,
                             indexing="ij")
        pts.append(np.stack([xs.reshape(-1), ys.reshape(-1)], axis=1))
    return jnp.asarray(np.concatenate(pts, axis=0), jnp.float32)
