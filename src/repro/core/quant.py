"""Fake-quantization for the DEFA INT12 path (paper §5.1.1, §5.2).

The paper quantizes the MSDeformAttn modules of the encoder to INT12 during
inference (INT8 was rejected: −9.7 AP). On TPU there is no INT12 datapath;
we implement *fake quantization* (quantize → dequantize in bf16/f32 compute)
to reproduce the accuracy behaviour, plus an int8-storage variant that gives
a real 2× HBM-bandwidth saving on the value tensor (the TPU-native analogue
of the paper's bandwidth motivation).

Symmetric uniform quantization:  q = clip(round(x / s), -2^(b-1), 2^(b-1)-1),
s = max|x| / (2^(b-1) - 1), per-tensor or per-channel (last dim).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1


def quant_scale(x: jnp.ndarray, bits: int, axis: Optional[int] = None) -> jnp.ndarray:
    """Symmetric scale; per-tensor (axis=None) or per-channel along `axis`."""
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax(bits)


def quantize(x: jnp.ndarray, bits: int, axis: Optional[int] = None):
    """Returns (int32 codes, scale)."""
    s = quant_scale(x, bits, axis)
    q = jnp.clip(jnp.round(x / s), -qmax(bits) - 1, qmax(bits)).astype(jnp.int32)
    return q, s


def dequantize(q: jnp.ndarray, s: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(dtype)) * s.astype(dtype)


@partial(jax.jit, static_argnames=("bits", "axis"))
def fake_quant(x: jnp.ndarray, bits: int = 12, axis: Optional[int] = None) -> jnp.ndarray:
    """quantize→dequantize with a straight-through estimator for training.

    One formula with :func:`fake_quant_with_scale` (the scale is just
    derived here vs frozen there) — full builds and streaming incremental
    updates must quantize on the SAME grid."""
    return fake_quant_with_scale(x, bits, quant_scale(x, bits, axis))


def maybe_fake_quant(x: jnp.ndarray, bits: Optional[int], axis: Optional[int] = None):
    if bits is None or bits <= 0:
        return x
    return fake_quant(x, bits, axis)


def fake_quant_with_scale(x: jnp.ndarray, bits: int,
                          scale: jnp.ndarray) -> jnp.ndarray:
    """quantize→dequantize against a FROZEN scale.

    The streaming incremental value-table update re-projects only a row
    subset, but the whole table must share ONE quantization grid — a
    per-subset scale would make updated rows incommensurable with the
    rest of the table. The scale is captured at the last full build
    (``quant_scale`` of the staged table) and reused for every
    incremental row update until the next full rebuild refreshes it."""
    y = jnp.clip(jnp.round(x / scale), -qmax(bits) - 1, qmax(bits)) * scale
    return x + jax.lax.stop_gradient(y - x)


def maybe_fake_quant_with_scale(x: jnp.ndarray, bits: Optional[int],
                                scale: Optional[jnp.ndarray]):
    if bits is None or bits <= 0 or scale is None:
        return x
    return fake_quant_with_scale(x, bits, scale)


def table_quant_scale(v: jnp.ndarray) -> jnp.ndarray:
    """Per-channel int8 scale of a (B, N_rows, H, Dh) value table.

    The scale is shared across the ROWS axis (shape (B, 1, H, Dh)):
    every row of one (batch, head, channel) lane quantizes on the same
    grid, so a backend may gather int8 codes, run the bilinear
    aggregation in f32 code space, and multiply by the scale ONCE after
    aggregation — bit-identical to dequantizing each gathered corner
    first. The zero sentinel row quantizes to code 0 exactly."""
    return quant_scale(v, 8, axis=1).astype(jnp.float32)


def quantize_table_rows(rows: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Quantize (B, U, H, Dh) table rows onto a FROZEN (B, 1, H, Dh) grid.

    Same clip convention as :func:`pack_int8`. Used both by the full
    cache build (scale just derived) and by streaming incremental row
    updates (scale captured at the last full build), so scattered codes
    stay commensurable with the surrounding table."""
    return jnp.clip(jnp.round(rows / scale), -128, 127).astype(jnp.int8)


def fake_table_quant(v: jnp.ndarray) -> jnp.ndarray:
    """quantize→dequantize a value table on the int8 table grid.

    The reference oracle applies this when the resolved table dtype is
    int8, so oracle-vs-backend parity holds bitwise-modulo-float on the
    SAME quantized values instead of within a scale/2 slack."""
    s = table_quant_scale(v)
    return quantize_table_rows(v, s).astype(v.dtype) * s.astype(v.dtype)


def pack_int8(x: jnp.ndarray):
    """Real int8 storage for the value tensor (bandwidth variant).

    Per-channel over the last dim; returns (int8, f32 scale)."""
    s = quant_scale(x, 8, axis=-1)
    q = jnp.clip(jnp.round(x / s), -128, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def unpack_int8(q: jnp.ndarray, s: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return q.astype(dtype) * s.astype(dtype)
