"""Frequency-Weighted fmap Pruning (FWP) — paper §3.1 (contribution C1).

Block *k* of the encoder counts, for every pixel of the multi-scale fmaps,
how many times bilinear interpolation touched it (each of the 4 neighbours
of a surviving sampling point counts 1). Pixels whose frequency falls below
``T_l = k_h · mean_l(F)`` (per level, Eq. 2) are pruned *in the next block*:
their value projection and their memory traffic are eliminated.

Two executions of the same algorithm:

  * ``mask`` mode — paper-faithful semantics: pruned pixels contribute zero;
    implemented as a multiplicative mask on the value projection input.
    (On the ASIC the mask gates SRAM fetches; on TPU a mask alone saves no
    work — kept for accuracy studies and as the semantics oracle.)
  * ``compact`` mode — the TPU-native realization: a *static-capacity*
    keep-list per level (top-``cap_l`` pixels by frequency). The value
    projection runs only on survivors (``cap × D`` matmul: real FLOP and
    HBM-byte reduction), and grid-sampling indexes the compacted buffer
    through a pixel→slot indirection with a zero sentinel row.

``compact`` == ``mask`` == exact-pruning whenever the capacity covers every
above-threshold pixel (property-tested).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class FWPState(NamedTuple):
    """Mask/keep-list produced by block k, consumed by block k+1."""
    keep_mask: jnp.ndarray          # (B, N_in) bool  — mask mode semantics
    keep_idx: Optional[jnp.ndarray]   # (B, cap) int32 — compact mode
    pix2slot: Optional[jnp.ndarray]   # (B, N_in) int32; pruned -> cap (sentinel)
    freq: jnp.ndarray               # (B, N_in) float32 raw counts


def level_starts(level_shapes: Sequence[Tuple[int, int]]) -> Tuple[np.ndarray, int]:
    sizes = [h * w for h, w in level_shapes]
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)
    return starts, int(sum(sizes))


def level_capacities(level_shapes, capacity: float) -> list[int]:
    return [max(1, int(round(capacity * h * w))) for h, w in level_shapes]


def count_frequency(
    corner_idx: jnp.ndarray,     # (B, M) int32 flat pixel indices (clamped)
    corner_valid: jnp.ndarray,   # (B, M) float/bool — in-bounds & point kept
    n_in: int,
) -> jnp.ndarray:
    """Scatter-add the sampled-times counter F (paper Fig. 2 right)."""
    b = corner_idx.shape[0]
    freq = jnp.zeros((b, n_in), dtype=jnp.float32)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], corner_idx.shape)
    return freq.at[bidx, corner_idx].add(corner_valid.astype(jnp.float32))


def _per_level_threshold(freq: jnp.ndarray, level_shapes, k: float) -> jnp.ndarray:
    """T_l = k * mean_l(F), broadcast back to (B, N_in) (Eq. 2)."""
    starts, _ = level_starts(level_shapes)
    pieces = []
    for (h, w), s in zip(level_shapes, starts):
        f_l = jax.lax.dynamic_slice_in_dim(freq, int(s), h * w, axis=1)
        t_l = k * jnp.mean(f_l, axis=1, keepdims=True)
        pieces.append(jnp.broadcast_to(t_l, f_l.shape))
    return jnp.concatenate(pieces, axis=1)


def build_fwp_state(
    freq: jnp.ndarray,                  # (B, N_in)
    level_shapes: Sequence[Tuple[int, int]],
    *,
    k: float,
    mode: str,                           # "mask" | "compact"
    capacity: float = 0.6,
) -> FWPState:
    thresholds = _per_level_threshold(freq, level_shapes, k)
    keep_mask = freq >= thresholds
    if mode == "mask":
        return FWPState(keep_mask=keep_mask, keep_idx=None, pix2slot=None, freq=freq)

    if mode != "compact":
        raise ValueError(f"unknown FWP mode {mode!r}")
    # Rank pixels by (above-threshold, frequency): capacity fills with the
    # most frequently sampled surviving pixels first. Below-threshold pixels
    # may pad the capacity (static shapes) but are NEVER routed to — the
    # threshold mask is strictly honoured, so compact == mask whenever the
    # capacity covers every survivor (property-tested).
    score = freq + keep_mask.astype(jnp.float32) * (jnp.max(freq) + 1.0)
    return _compact_from_scores(freq, score, keep_mask, level_shapes, capacity)


def _compact_from_scores(
    freq: jnp.ndarray,                  # (B, N_in) raw counts / EMA scores
    score: jnp.ndarray,                 # (B, N_in) capacity ranking score
    keep_mask: jnp.ndarray,             # (B, N_in) bool threshold decision
    level_shapes: Sequence[Tuple[int, int]],
    capacity: float,
) -> FWPState:
    """Shared compact-geometry construction: per-level capacity top-k on
    ``score``, raster-sorted slots, pix2slot with sentinel routing for
    every below-threshold pixel. Both the one-shot ranking
    (:func:`build_fwp_state`) and the temporal hysteresis ranking
    (:func:`build_fwp_state_hysteresis`) end here, so the geometry
    invariants (raster order, slot windows, round-trip) are proved once."""
    starts, n_in = level_starts(level_shapes)
    caps = level_capacities(level_shapes, capacity)
    cap_total = sum(caps)
    b = freq.shape[0]

    keep_parts, slot_parts = [], []
    slot_off = 0
    for li, ((h, w), s, c) in enumerate(zip(level_shapes, starts, caps)):
        score_l = jax.lax.dynamic_slice_in_dim(score, int(s), h * w, axis=1)
        _, idx_l = jax.lax.top_k(score_l, c)                      # (B, c)
        # Slots are RASTER-ORDERED within the level (sorted by pixel index,
        # not by score): a spatial pixel window then maps to a contiguous
        # slot range of the compact table, which is what lets the windowed
        # kernel stage a bounded slot window instead of densifying.
        idx_l = jnp.sort(idx_l, axis=1)
        keep_parts.append(idx_l.astype(jnp.int32) + int(s))
        slot_parts.append(slot_off + jnp.arange(c, dtype=jnp.int32))
        slot_off += c
    keep_idx = jnp.concatenate(keep_parts, axis=1)                # (B, cap_total)
    slots = jnp.concatenate(slot_parts)                           # (cap_total,)

    pix2slot = jnp.full((b, n_in), cap_total, dtype=jnp.int32)    # sentinel
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], keep_idx.shape)
    surviving = jnp.take_along_axis(keep_mask, keep_idx, axis=1)  # (B, cap_total)
    slot_or_sentinel = jnp.where(
        surviving, jnp.broadcast_to(slots, keep_idx.shape), cap_total)
    pix2slot = pix2slot.at[bidx, keep_idx].set(slot_or_sentinel)
    return FWPState(keep_mask=keep_mask, keep_idx=keep_idx, pix2slot=pix2slot, freq=freq)


# --------------------------------------------------------------------------
# Temporal (streaming) FWP: EMA scores + keep-mask hysteresis
# --------------------------------------------------------------------------

def ema_update(ema: jnp.ndarray, freq: jnp.ndarray,
               alpha: float) -> jnp.ndarray:
    """Streaming frequency score: ``ema' = (1-alpha)·ema + alpha·freq``.

    Video frames are a slowly-changing signal, so the pruning decision
    should integrate sampling frequency over time instead of reacting to
    one frame's counts — the EMA is what the hysteresis thresholds read."""
    a = float(alpha)
    return (1.0 - a) * ema + a * freq


def build_fwp_state_hysteresis(
    ema: jnp.ndarray,                   # (B, N_in) streaming EMA scores
    level_shapes: Sequence[Tuple[int, int]],
    *,
    k_enter: float,
    k_exit: float,
    mode: str,                           # "mask" | "compact"
    capacity: float = 0.6,
    prev: Optional[FWPState] = None,
) -> FWPState:
    """FWP keep decision with per-pixel hysteresis for streaming reuse.

    Two per-level thresholds (Eq. 2 shape, two k's): a pixel ENTERS the
    keep set only when its EMA score clears ``T_enter = k_enter·mean_l``
    and EXITS only when it falls below ``T_exit = k_exit·mean_l``
    (``k_enter >= k_exit``); in between, the previous frame's decision
    sticks. Bounded per-frame score drift therefore implies bounded
    keep churn: a pixel can only change state when its previous score was
    within ``(1+k)·drift`` of the corresponding threshold
    (property-tested in tests/test_fwp_invariants.py).

    Compact mode additionally ranks the capacity fill with an INCUMBENCY
    tier: kept incumbents (pixels already holding a slot) outrank kept
    newcomers, which outrank unkept incumbents, which outrank unkept
    padding — so every kept incumbent retains a slot (capacity
    permitting) and ``keep_idx`` churn is driven by mask churn, not by
    marginal score reshuffles. Slots stay raster-ordered per level
    (same :func:`_compact_from_scores` construction as the one-shot
    build), which is what keeps compact-slot windows stable for the
    streaming tile updates."""
    if k_enter < k_exit:
        raise ValueError(
            f"hysteresis needs k_enter >= k_exit (got {k_enter} < {k_exit})")
    t_enter = _per_level_threshold(ema, level_shapes, k_enter)
    t_exit = _per_level_threshold(ema, level_shapes, k_exit)
    if prev is None:
        prev_kept = jnp.zeros(ema.shape, bool)
    else:
        prev_kept = prev.keep_mask
    keep_mask = (ema >= t_enter) | (prev_kept & (ema >= t_exit))
    if mode == "mask":
        return FWPState(keep_mask=keep_mask, keep_idx=None, pix2slot=None,
                        freq=ema)
    if mode != "compact":
        raise ValueError(f"unknown FWP mode {mode!r}")

    incumbent = jnp.zeros(ema.shape, bool)
    if prev is not None and prev.keep_idx is not None:
        b = ema.shape[0]
        bidx = jnp.broadcast_to(jnp.arange(b)[:, None], prev.keep_idx.shape)
        incumbent = incumbent.at[bidx, prev.keep_idx].set(True)
    # Tiered ranking (strictly ordered because m > max(ema)):
    #   kept incumbent (ema+3m) > kept newcomer (ema+2m)
    #   > unkept incumbent (ema+m) > unkept padding (ema).
    m = jnp.max(ema) + 1.0
    score = ema + keep_mask.astype(jnp.float32) * (2.0 * m) \
        + incumbent.astype(jnp.float32) * m
    return _compact_from_scores(ema, score, keep_mask, level_shapes, capacity)


def fwp_sparsity(state: FWPState) -> jnp.ndarray:
    """Fraction of pixels pruned (paper reports ≈43%)."""
    return 1.0 - jnp.mean(state.keep_mask.astype(jnp.float32))
