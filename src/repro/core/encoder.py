"""Deformable-DETR-style encoder: a stack of MSDeformAttn blocks with the
DEFA block-to-block FWP mask chain (paper §3.1/§4.1 dataflow).

Block k counts sampled-pixel frequency during its MSGS and hands the
resulting fmap mask to block k+1, which prunes its value projection with it
(the first block always runs unpruned — there is no mask yet). The chain is
carried by an explicit :class:`repro.msda.MSDAPipelineState`, and every
block executes through one :class:`repro.msda.MSDAPlan` resolved ahead of
the loop (backend, tiling, and lane layout are shape-static)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.core.msdeform_attn import (
    MSDeformAttnConfig, init_msdeform_attn, logical_axes,
)
from repro.msda import MSDAPipelineState, make_plan, msda_attention


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    attn: MSDeformAttnConfig = dataclasses.field(default_factory=MSDeformAttnConfig)
    n_blocks: int = 6
    d_ffn: int = 1024
    dtype: Any = jnp.float32

    @property
    def d_model(self) -> int:
        return self.attn.d_model


def init_encoder(key: jax.Array, cfg: EncoderConfig) -> dict:
    blocks = []
    for i in range(cfg.n_blocks):
        key, k1, k2, k3 = jax.random.split(key, 4)
        blocks.append({
            "attn": init_msdeform_attn(k1, cfg.attn),
            "ln1": nn.layer_norm_init(cfg.d_model, cfg.dtype),
            "ln2": nn.layer_norm_init(cfg.d_model, cfg.dtype),
            "ffn1": nn.linear_init(k2, cfg.d_model, cfg.d_ffn, cfg.dtype),
            "ffn2": nn.linear_init(k3, cfg.d_ffn, cfg.d_model, cfg.dtype),
        })
    return {"blocks": blocks}


def encoder_logical_axes(cfg: EncoderConfig) -> dict:
    blk = {
        "attn": logical_axes(cfg.attn),
        "ln1": {"scale": (None,), "bias": (None,)},
        "ln2": {"scale": (None,), "bias": (None,)},
        "ffn1": {"w": ("embed", "mlp"), "b": ("mlp",)},
        "ffn2": {"w": ("mlp", "embed"), "b": (None,)},
    }
    return {"blocks": [blk for _ in range(cfg.n_blocks)]}


def encoder_apply(
    params: dict,
    cfg: EncoderConfig,
    x_flat: jnp.ndarray,                   # (B, N_in, D) flattened pyramid
    pos_embed: jnp.ndarray,                # (N_in, D)
    ref_points: jnp.ndarray,               # (N_in, 2) or (B, N_in, 2)
    level_shapes: Sequence[Tuple[int, int]],
    *,
    collect_stats: bool = False,
    backend: Optional[str] = None,         # msda backend override (or "auto")
    return_state: bool = False,
):
    """Returns (features (B,N_in,D), aux with per-block DEFA stats).

    ``aux["blocks"]`` has one aligned entry per block (``None`` when that
    block didn't collect). With ``return_state=True`` the final
    :class:`MSDAPipelineState` is returned as a third value — the decoder
    consumes it so its shared value cache inherits the LAST encoder
    block's FWP compaction."""
    b = x_flat.shape[0]
    if ref_points.ndim == 2:
        ref_points = jnp.broadcast_to(ref_points[None], (b,) + ref_points.shape)
    plan = make_plan(cfg.attn, tuple((int(lh), int(lw))
                                     for lh, lw in level_shapes),
                     backend=backend)
    h = x_flat
    state = MSDAPipelineState.initial()
    for blk in params["blocks"]:
        q = h + pos_embed[None]
        attn_out, state = msda_attention(
            blk["attn"], plan, q, ref_points, h,
            state=state, collect_stats=collect_stats)
        h = nn.layer_norm(blk["ln1"], h + attn_out)
        ff = nn.linear(blk["ffn2"], jax.nn.relu(nn.linear(blk["ffn1"], h)))
        h = nn.layer_norm(blk["ln2"], h + ff)
    aux = {"blocks": list(state.block_stats)}
    if return_state:
        return h, aux, state
    return h, aux
