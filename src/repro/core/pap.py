"""Probability-Aware Point pruning (PAP) — paper §3.2 (contribution C2).

After softmax, attention probabilities over the ``N_l × N_p`` sampling
points of one (query, head) sum to 1 and are exponentially peaked; the paper
finds >80 % of them near zero in Deformable DETR and prunes those points,
skipping their grid-sampling and aggregation entirely.

Two executions:
  * ``threshold`` mode — paper-faithful: zero every probability below
    ``pap_threshold`` (exact removal semantics, since the contribution is
    ``prob · sampled_value``); the framework counts the pruned fraction and
    the saved gathers/FLOPs.
  * ``topk`` mode — the TPU-native static-shape realization: keep the
    ``K`` highest-probability points per (query, head) and gather *only*
    those (real gather-traffic and BI/aggregation reduction on SIMD
    hardware). Equals threshold mode whenever K covers all survivors.

Optionally renormalizes surviving probabilities (off by default — the paper
drops mass, it does not renormalize).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PAPSelection(NamedTuple):
    probs: jnp.ndarray       # (B, Nq, H, K) surviving probabilities (zeros allowed)
    point_idx: jnp.ndarray   # (B, Nq, H, K) int32 index into the L*P point axis
    keep_frac: jnp.ndarray   # scalar — fraction of points kept (paper: ~16%)


def pap_threshold_select(probs: jnp.ndarray, threshold: float) -> PAPSelection:
    """Zero near-zero probabilities; keeps the full L*P axis (K = L*P)."""
    mask = probs > threshold
    kept = jnp.where(mask, probs, 0.0)
    lp = probs.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(lp, dtype=jnp.int32), probs.shape)
    return PAPSelection(probs=kept, point_idx=idx,
                        keep_frac=jnp.mean(mask.astype(jnp.float32)))


def pap_topk_select(probs: jnp.ndarray, k: int,
                    threshold: float = 0.0) -> PAPSelection:
    """Keep the top-K points per (query, head); optional threshold on top."""
    top_p, top_i = jax.lax.top_k(probs, k)                      # (..., K)
    if threshold > 0.0:
        keep = top_p > threshold
        top_p = jnp.where(keep, top_p, 0.0)
        kept_frac = jnp.mean(keep.astype(jnp.float32)) * (k / probs.shape[-1])
    else:
        kept_frac = jnp.asarray(k / probs.shape[-1], dtype=jnp.float32)
    return PAPSelection(probs=top_p, point_idx=top_i.astype(jnp.int32),
                        keep_frac=kept_frac)


def pap_select(probs: jnp.ndarray, mode: str, *, threshold: float, k: int) -> PAPSelection:
    if mode == "off":
        lp = probs.shape[-1]
        idx = jnp.broadcast_to(jnp.arange(lp, dtype=jnp.int32), probs.shape)
        return PAPSelection(probs=probs, point_idx=idx,
                            keep_frac=jnp.asarray(1.0, jnp.float32))
    if mode == "threshold":
        return pap_threshold_select(probs, threshold)
    if mode == "topk":
        return pap_topk_select(probs, k, threshold=0.0)
    raise ValueError(f"unknown PAP mode {mode!r}")
