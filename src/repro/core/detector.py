"""Toy end-to-end detector around the deformable encoder.

COCO is not available offline, so the paper's accuracy experiments (Fig. 6a)
are reproduced on a synthetic rectangle-detection task (see
repro/data/detection.py): a conv backbone builds a 4-level pyramid, the
DEFA encoder refines it, and a per-query head predicts class + box. The
pruning/quant AP deltas are measured on this task (EXPERIMENTS.md compares
*relative* AP drops against the paper's COCO numbers)."""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.core.encoder import EncoderConfig, init_encoder, encoder_apply, encoder_logical_axes


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    encoder: EncoderConfig = dataclasses.field(default_factory=EncoderConfig)
    img_size: int = 64
    n_classes: int = 4                     # + background
    backbone_width: int = 32
    dtype: Any = jnp.float32

    @property
    def level_shapes(self) -> Tuple[Tuple[int, int], ...]:
        s = self.img_size
        return tuple((s // k, s // k) for k in (4, 8, 16, 32))

    @property
    def d_model(self) -> int:
        return self.encoder.d_model


def init_detector(key: jax.Array, cfg: DetectorConfig) -> dict:
    keys = jax.random.split(key, 10)
    w, d = cfg.backbone_width, cfg.d_model
    return {
        "stem": nn.conv_init(keys[0], 3, 3, w, cfg.dtype),         # stride 2
        "c1": nn.conv_init(keys[1], 3, w, w, cfg.dtype),           # stride 2 -> /4
        "c2": nn.conv_init(keys[2], 3, w, w, cfg.dtype),           # stride 2 -> /8
        "c3": nn.conv_init(keys[3], 3, w, w, cfg.dtype),           # stride 2 -> /16
        "c4": nn.conv_init(keys[4], 3, w, w, cfg.dtype),           # stride 2 -> /32
        "proj": [nn.linear_init(keys[5 + i], w, d, cfg.dtype) for i in range(4)],
        "encoder": init_encoder(keys[9], cfg.encoder),
        "cls_head": nn.linear_init(jax.random.fold_in(key, 101),
                                   d, cfg.n_classes + 1, cfg.dtype),
        "box_head": nn.linear_init(jax.random.fold_in(key, 102), d, 4, cfg.dtype),
    }


def detector_logical_axes(cfg: DetectorConfig) -> dict:
    conv_ax = {"w": (None, None, None, None), "b": (None,)}
    lin_ax = {"w": ("embed", None), "b": (None,)}
    return {
        "stem": conv_ax, "c1": conv_ax, "c2": conv_ax, "c3": conv_ax, "c4": conv_ax,
        "proj": [{"w": (None, "embed"), "b": (None,)} for _ in range(4)],
        "encoder": encoder_logical_axes(cfg.encoder),
        "cls_head": lin_ax, "box_head": lin_ax,
    }


def _pyramid(params, cfg: DetectorConfig, images: jnp.ndarray):
    """images (B,3,S,S) -> list of 4 fmaps (B, w, H_l, W_l)."""
    x = jax.nn.relu(nn.conv2d(params["stem"], images, stride=2))
    feats = []
    for name in ("c1", "c2", "c3", "c4"):
        x = jax.nn.relu(nn.conv2d(params[name], x, stride=2))
        feats.append(x)
    return feats


def detector_apply(params: dict, cfg: DetectorConfig, images: jnp.ndarray,
                   *, collect_stats: bool = False,
                   backend: str | None = None):
    """Returns (cls_logits (B,N_in,C+1), boxes (B,N_in,4 cxcywh), aux).

    ``backend`` overrides the encoder's MSDA backend ("auto" lets the
    plan pick by VMEM fit; see repro/msda/plan.py)."""
    feats = _pyramid(params, cfg, images)
    flat = []
    for f, proj in zip(feats, params["proj"]):
        b, c, h, w = f.shape
        flat.append(nn.linear(proj, f.transpose(0, 2, 3, 1).reshape(b, h * w, c)))
    x_flat = jnp.concatenate(flat, axis=1)                          # (B, N_in, D)

    level_shapes = cfg.level_shapes
    pos = jnp.concatenate(
        [nn.sine_pos_embed_2d(h, w, cfg.d_model) for h, w in level_shapes], axis=0)
    refs = nn.reference_points_for_levels(level_shapes)
    enc, aux = encoder_apply(params["encoder"], cfg.encoder, x_flat, pos, refs,
                             level_shapes, collect_stats=collect_stats,
                             backend=backend)
    cls_logits = nn.linear(params["cls_head"], enc)
    boxes = jax.nn.sigmoid(nn.linear(params["box_head"], enc))
    return cls_logits, boxes, aux


def detection_loss(params: dict, cfg: DetectorConfig, images: jnp.ndarray,
                   tgt_cls: jnp.ndarray, tgt_box: jnp.ndarray):
    """Dense per-query assignment loss.

    tgt_cls: (B, N_in) int — class index, n_classes == background.
    tgt_box: (B, N_in, 4) — cxcywh of owning box (zeros for background)."""
    cls_logits, boxes, _ = detector_apply(params, cfg, images)
    logp = jax.nn.log_softmax(cls_logits, axis=-1)
    ce = -jnp.take_along_axis(logp, tgt_cls[..., None], axis=-1)[..., 0]
    pos = (tgt_cls < cfg.n_classes).astype(jnp.float32)
    # class-balanced: background dominates, weight positives up
    w = jnp.where(pos > 0, 5.0, 1.0)
    cls_loss = jnp.sum(ce * w) / jnp.sum(w)
    l1 = jnp.sum(jnp.abs(boxes - tgt_box), axis=-1)
    box_loss = jnp.sum(l1 * pos) / jnp.maximum(jnp.sum(pos), 1.0)
    return cls_loss + box_loss, {"cls_loss": cls_loss, "box_loss": box_loss}
