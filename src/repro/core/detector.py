"""Toy end-to-end detector around the deformable encoder.

COCO is not available offline, so the paper's accuracy experiments (Fig. 6a)
are reproduced on a synthetic rectangle-detection task (see
repro/data/detection.py): a conv backbone builds a 4-level pyramid, the
DEFA encoder refines it, and a head predicts class + box. Two heads exist:

  * the seed's dense per-pixel head (one prediction per encoder query) —
    the default, used by the dense-assignment accuracy experiments;
  * a deformable-DETR-style DECODER head (``DetectorConfig.decoder``):
    N_q learned queries cross-attend against the encoder memory through
    ONE shared :class:`repro.msda.MSDAValueCache` — the paper's
    feature-map-reusing decoder workload (build-once, sample-everywhere;
    see repro/msda/decoder.py).

The pruning/quant AP deltas are measured on this task (EXPERIMENTS.md
compares *relative* AP drops against the paper's COCO numbers)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nn

try:                                       # optional dep (scipy)
    from scipy.optimize import linear_sum_assignment as _linear_sum_assignment
except ImportError:                        # pragma: no cover - env-dependent
    _linear_sum_assignment = None
from repro.core.encoder import EncoderConfig, init_encoder, encoder_apply, encoder_logical_axes
from repro.msda.decoder import (MSDADecoderConfig, decoder_apply,
                                decoder_logical_axes, init_decoder)


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    encoder: EncoderConfig = dataclasses.field(default_factory=EncoderConfig)
    img_size: int = 64
    n_classes: int = 4                     # + background
    backbone_width: int = 32
    dtype: Any = jnp.float32
    # None => the seed's dense per-pixel head; set => DETR-style decoder
    # head over a shared value cache (build-once, sample-everywhere)
    decoder: Optional[MSDADecoderConfig] = None

    @property
    def level_shapes(self) -> Tuple[Tuple[int, int], ...]:
        s = self.img_size
        return tuple((s // k, s // k) for k in (4, 8, 16, 32))

    @property
    def d_model(self) -> int:
        return self.encoder.d_model


def init_detector(key: jax.Array, cfg: DetectorConfig) -> dict:
    keys = jax.random.split(key, 10)
    w, d = cfg.backbone_width, cfg.d_model
    params = {
        "stem": nn.conv_init(keys[0], 3, 3, w, cfg.dtype),         # stride 2
        "c1": nn.conv_init(keys[1], 3, w, w, cfg.dtype),           # stride 2 -> /4
        "c2": nn.conv_init(keys[2], 3, w, w, cfg.dtype),           # stride 2 -> /8
        "c3": nn.conv_init(keys[3], 3, w, w, cfg.dtype),           # stride 2 -> /16
        "c4": nn.conv_init(keys[4], 3, w, w, cfg.dtype),           # stride 2 -> /32
        "proj": [nn.linear_init(keys[5 + i], w, d, cfg.dtype) for i in range(4)],
        "encoder": init_encoder(keys[9], cfg.encoder),
        "cls_head": nn.linear_init(jax.random.fold_in(key, 101),
                                   d, cfg.n_classes + 1, cfg.dtype),
        "box_head": nn.linear_init(jax.random.fold_in(key, 102), d, 4, cfg.dtype),
    }
    if cfg.decoder is not None:
        params["decoder"] = init_decoder(jax.random.fold_in(key, 103),
                                         cfg.decoder, cfg.encoder.attn)
    return params


def detector_logical_axes(cfg: DetectorConfig) -> dict:
    conv_ax = {"w": (None, None, None, None), "b": (None,)}
    lin_ax = {"w": ("embed", None), "b": (None,)}
    axes = {
        "stem": conv_ax, "c1": conv_ax, "c2": conv_ax, "c3": conv_ax, "c4": conv_ax,
        "proj": [{"w": (None, "embed"), "b": (None,)} for _ in range(4)],
        "encoder": encoder_logical_axes(cfg.encoder),
        "cls_head": lin_ax, "box_head": lin_ax,
    }
    if cfg.decoder is not None:
        axes["decoder"] = decoder_logical_axes(cfg.decoder)
    return axes


def decoder_plan(cfg: DetectorConfig, backend: Optional[str] = None):
    """The decode-shaped MSDAPlan for this detector's decoder head.

    Single source of the raster-only-backend fallback: raster-only
    kernels (the windowed kernel) have no decode-shaped launch, so an
    explicit (or config-level) request for one degrades to ``auto`` for
    the decoder (which may then pick the persistent decode kernel)."""
    from repro.msda import backend_info
    from repro.msda.plan import plan_for
    assert cfg.decoder is not None, "decoder head required"
    dec_backend = backend or getattr(cfg.encoder.attn, "backend", None)
    if dec_backend is not None and dec_backend != "auto" \
            and backend_info(dec_backend).raster_only:
        dec_backend = "auto"
    # memoized (plan_for): the serve engine resolves one plan per shape
    # bucket and every forward of that bucket shares it
    return plan_for(cfg.encoder.attn, cfg.level_shapes, dec_backend,
                    cfg.decoder.n_queries, cfg.decoder.n_layers)


def encoder_backend(backend: Optional[str]) -> Optional[str]:
    """The mirror fallback for the raster ENCODER: decode-only backends
    (``pallas_decode``) have no raster launch, so such a request degrades
    to ``auto`` for the encoder while staying in force for the decoder
    (``examples/detr_serve.py --backend pallas_decode``)."""
    from repro.msda import backend_info
    if backend is not None and backend != "auto" \
            and backend_info(backend).decode_only:
        return "auto"
    return backend


def _pyramid(params, cfg: DetectorConfig, images: jnp.ndarray):
    """images (B,3,S,S) -> list of 4 fmaps (B, w, H_l, W_l)."""
    x = jax.nn.relu(nn.conv2d(params["stem"], images, stride=2))
    feats = []
    for name in ("c1", "c2", "c3", "c4"):
        x = jax.nn.relu(nn.conv2d(params[name], x, stride=2))
        feats.append(x)
    return feats


def detector_apply(params: dict, cfg: DetectorConfig, images: jnp.ndarray,
                   *, collect_stats: bool = False,
                   backend: str | None = None):
    """Returns (cls_logits (B,Nq,C+1), boxes (B,Nq,4 cxcywh), aux).

    Nq is N_in (per-pixel head) or ``cfg.decoder.n_queries`` (decoder
    head). ``backend`` overrides the MSDA backend ("auto" lets the plan
    pick by VMEM fit; see repro/msda/plan.py). With the decoder head,
    ``aux["decoder_blocks"]`` carries the per-layer decoder stats and
    the decoder samples ONE shared value cache built from the encoder
    memory under the encoder chain's final FWP compaction."""
    feats = _pyramid(params, cfg, images)
    flat = []
    for f, proj in zip(feats, params["proj"]):
        b, c, h, w = f.shape
        flat.append(nn.linear(proj, f.transpose(0, 2, 3, 1).reshape(b, h * w, c)))
    x_flat = jnp.concatenate(flat, axis=1)                          # (B, N_in, D)

    level_shapes = cfg.level_shapes
    pos = jnp.concatenate(
        [nn.sine_pos_embed_2d(h, w, cfg.d_model) for h, w in level_shapes], axis=0)
    refs = nn.reference_points_for_levels(level_shapes)
    enc, aux, state = encoder_apply(
        params["encoder"], cfg.encoder, x_flat, pos, refs, level_shapes,
        collect_stats=collect_stats, backend=encoder_backend(backend),
        return_state=True)

    if cfg.decoder is None:
        cls_logits = nn.linear(params["cls_head"], enc)
        boxes = jax.nn.sigmoid(nn.linear(params["box_head"], enc))
        return cls_logits, boxes, aux

    # ---- decoder head: build-once shared cache, N_q learned queries ------
    plan = decoder_plan(cfg, backend)
    hs, dec_refs, dstate = decoder_apply(params["decoder"], cfg.decoder,
                                         plan, enc, state,
                                         collect_stats=collect_stats)
    cls_logits = nn.linear(params["cls_head"], hs)
    raw = nn.linear(params["box_head"], hs)
    # centers refine the decoder's reference points (deformable-DETR)
    cxy = jax.nn.sigmoid(raw[..., :2] + nn.inverse_sigmoid(dec_refs))
    wh = jax.nn.sigmoid(raw[..., 2:])
    boxes = jnp.concatenate([cxy, wh], axis=-1)
    aux = dict(aux)
    aux["decoder_blocks"] = list(dstate.block_stats)
    return cls_logits, boxes, aux


def detection_loss(params: dict, cfg: DetectorConfig, images: jnp.ndarray,
                   tgt_cls: jnp.ndarray, tgt_box: jnp.ndarray):
    """Dense per-query assignment loss (per-pixel head).

    tgt_cls: (B, N_in) int — class index, n_classes == background.
    tgt_box: (B, N_in, 4) — cxcywh of owning box (zeros for background)."""
    cls_logits, boxes, _ = detector_apply(params, cfg, images)
    logp = jax.nn.log_softmax(cls_logits, axis=-1)
    ce = -jnp.take_along_axis(logp, tgt_cls[..., None], axis=-1)[..., 0]
    pos = (tgt_cls < cfg.n_classes).astype(jnp.float32)
    # class-balanced: background dominates, weight positives up
    w = jnp.where(pos > 0, 5.0, 1.0)
    cls_loss = jnp.sum(ce * w) / jnp.sum(w)
    l1 = jnp.sum(jnp.abs(boxes - tgt_box), axis=-1)
    box_loss = jnp.sum(l1 * pos) / jnp.maximum(jnp.sum(pos), 1.0)
    return cls_loss + box_loss, {"cls_loss": cls_loss, "box_loss": box_loss}


_INACTIVE_COST = 1e6


def _hungarian_owners_host(cost: np.ndarray) -> np.ndarray:
    """Host-side optimal assignment per batch element: owner[b, m] is the
    query column assigned to gt row m (rows than columns or fewer)."""
    owner = np.zeros(cost.shape[:2], np.int32)
    for b in range(cost.shape[0]):
        row, col = _linear_sum_assignment(cost[b])
        owner[b, row] = col.astype(np.int32)
    return owner


def match_queries(cost: jnp.ndarray, gt_active: jnp.ndarray,
                  matcher: Optional[str] = None) -> jnp.ndarray:
    """gt -> query assignment for the set-prediction loss.

    ``cost`` (B, M, Nq) is consumed under ``stop_gradient`` (the
    assignment is a discrete decision; gradients flow through the matched
    boxes, not the matching). Matchers:

      * ``"hungarian"`` — ``scipy.optimize.linear_sum_assignment`` via
        ``jax.pure_callback`` (jit-safe): globally optimal, every active
        gt gets a DISTINCT query. Inactive gt rows are flattened to a
        constant cost so they take leftover queries without disturbing
        the active rows' optimum (they are masked out of the loss anyway).
      * ``"greedy"`` — the seed matcher: per-gt argmin, collisions
        allowed. The fallback when scipy is absent (optional dep) or the
        gt count exceeds the query count.

    ``matcher=None`` auto-selects hungarian when scipy is available."""
    if matcher is None:
        matcher = "hungarian" if _linear_sum_assignment is not None \
            else "greedy"
    if matcher not in ("hungarian", "greedy"):
        raise ValueError(f"unknown matcher {matcher!r}")
    cost = jax.lax.stop_gradient(cost)
    b, m, nq = cost.shape
    if matcher == "greedy" or _linear_sum_assignment is None or m > nq:
        return jnp.argmin(cost, axis=-1).astype(jnp.int32)
    cost = jnp.where(gt_active[:, :, None], cost, _INACTIVE_COST)
    # a diverged step (NaN/inf boxes) must degrade to a garbage-but-valid
    # assignment and a detectable NaN loss, like the greedy argmin does —
    # linear_sum_assignment raises on non-finite entries
    cost = jnp.nan_to_num(cost, nan=_INACTIVE_COST, posinf=_INACTIVE_COST,
                          neginf=-_INACTIVE_COST)
    return jax.pure_callback(
        _hungarian_owners_host,
        jax.ShapeDtypeStruct((b, m), jnp.int32), cost)


def decoder_detection_loss(params: dict, cfg: DetectorConfig,
                           images: jnp.ndarray, gt_cls: jnp.ndarray,
                           gt_box: jnp.ndarray, gt_active: jnp.ndarray,
                           matcher: Optional[str] = None):
    """Set-prediction loss for the decoder head (Hungarian matching).

    Each ACTIVE ground-truth box is assigned the query whose predicted
    box is closest in L1 — optimally via :func:`match_queries`
    (``linear_sum_assignment``; greedy per-gt argmin fallback when scipy
    is missing or ``matcher="greedy"``). The assignment happens under
    ``stop_gradient``; matched queries learn class + box, the rest learn
    background. The class targets are derived query-side (no
    duplicate-index scatter), so an inactive GT slot can never claim a
    query; under the greedy fallback a collision between two active GTs
    resolves deterministically to the lowest GT index (Hungarian
    assignments are collision-free by construction).

    gt_cls (B, M) int, gt_box (B, M, 4) cxcywh, gt_active (B, M) bool."""
    assert cfg.decoder is not None, "decoder head required"
    cls_logits, boxes, _ = detector_apply(params, cfg, images)
    b, nq, _ = cls_logits.shape

    cost = jnp.sum(jnp.abs(boxes[:, None] - gt_box[:, :, None]), -1)  # (B,M,Nq)
    owner = match_queries(cost, gt_active, matcher)                   # (B,M)

    # query-side targets: query q is positive iff some ACTIVE gt owns it
    claimed = (owner[:, :, None] == jnp.arange(nq)[None, None]) \
        & gt_active[:, :, None]                                       # (B,M,Nq)
    matched = jnp.any(claimed, axis=1)                                # (B,Nq)
    first_m = jnp.argmax(claimed, axis=1)                             # (B,Nq)
    cls_of = jnp.take_along_axis(gt_cls.astype(jnp.int32), first_m, axis=1)
    tgt_cls = jnp.where(matched, cls_of, cfg.n_classes)

    logp = jax.nn.log_softmax(cls_logits, axis=-1)
    ce = -jnp.take_along_axis(logp, tgt_cls[..., None], axis=-1)[..., 0]
    pos = (tgt_cls < cfg.n_classes).astype(jnp.float32)
    w = jnp.where(pos > 0, 5.0, 1.0)
    cls_loss = jnp.sum(ce * w) / jnp.sum(w)

    matched_box = jnp.take_along_axis(boxes, owner[..., None], axis=1)  # (B,M,4)
    l1 = jnp.sum(jnp.abs(matched_box - gt_box), axis=-1)
    act = gt_active.astype(jnp.float32)
    box_loss = jnp.sum(l1 * act) / jnp.maximum(jnp.sum(act), 1.0)
    return cls_loss + box_loss, {"cls_loss": cls_loss, "box_loss": box_loss}
