"""Distributed MSDeformAttn: band sharding + bounded halo exchange (§Perf
hillclimb 3 — the beyond-paper scaling of DEFA's range-narrowing insight).

The paper's level-wise range-narrowing (C3) bounds every sampling offset to
±R_l pixels; on the ASIC that bounds the on-chip window (C7). At pod scale
the same bound turns distribution of the encoder from "all-gather the whole
multi-scale fmap" into a 2-neighbour halo exchange:

  * every model-axis rank owns one horizontal BAND of the image — the same
    normalized y-interval of every pyramid level (queries AND value rows);
  * the value projection V = X·W^V runs band-locally (1/TP of the pixels);
  * each rank ppermutes its top/bottom halo_l = ceil(R_l)+2 value rows to
    its neighbours — range-narrowing guarantees every bilinear corner of a
    band's queries lands inside band ± halo;
  * sampling + aggregation are then fully rank-local.

Per-layer communication: 2·Σ_l halo_l·W_l·D bytes (independent of image
height and batch-per-rank query count) versus Σ_l H_l·W_l·D for the
all-gather a naive query-sharded encoder needs.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.msdeform_attn import MSDeformAttnConfig
from repro.core.quant import maybe_fake_quant
from repro.msda.sampling import corner_data, select_points


def band_layout(level_shapes, n_bands: int, ranges):
    """Per-level padded band geometry: (rows_per_band_l, halo_l)."""
    rows, halos = [], []
    for li, (h, w) in enumerate(level_shapes):
        rb = int(np.ceil(h / n_bands))
        halos.append(int(np.ceil(ranges[li])) + 2)
        rows.append(rb)
    return rows, halos


def pad_levels_to_bands(x_flat, level_shapes, n_bands: int):
    """Pad each level's rows to n_bands*rows_per_band and re-flatten.

    x_flat: (B, N_in, D) -> (B, N_pad, D), plus padded level shapes."""
    b, _, d = x_flat.shape
    rows, _ = band_layout(level_shapes, n_bands, [0] * len(level_shapes))
    pieces, padded_shapes = [], []
    start = 0
    for (h, w), rb in zip(level_shapes, rows):
        seg = x_flat[:, start:start + h * w].reshape(b, h, w, d)
        hp = rb * n_bands
        seg = jnp.pad(seg, ((0, 0), (0, hp - h), (0, 0), (0, 0)))
        pieces.append(seg.reshape(b, hp * w, d))
        padded_shapes.append((hp, w))
        start += h * w
    return jnp.concatenate(pieces, axis=1), tuple(padded_shapes)


def _band_slices(padded_shapes, n_bands):
    """Flat index ranges of ONE band across levels (band-local layout)."""
    locs = []
    start = 0
    for (hp, w) in padded_shapes:
        rb = hp // n_bands
        locs.append((start, rb, w))
        start += rb * w
    return locs, start                 # per-level (band start, rows, W), band size


def msdeform_attn_banded(
    params: dict,
    cfg: MSDeformAttnConfig,
    query: jnp.ndarray,                 # (B, N_pad, D) — padded, band-ordered
    ref_points: jnp.ndarray,            # (B, N_pad, 2)
    x_flat: jnp.ndarray,                # (B, N_pad, D) padded pyramid
    padded_shapes: Sequence[Tuple[int, int]],
    mesh: Mesh,
    axis: str = "model",
    batch_axes: Tuple[str, ...] = (),
):
    """Band-sharded MSDeformAttn. Requires cfg.range_narrow set (the bound
    IS what makes the halo finite). Returns (B, N_pad, D).

    The flat layout here is BAND-MAJOR: for band r, its rows of level 0,
    then its rows of level 1, ... (callers reorder with band_reorder)."""
    assert cfg.range_narrow is not None, "halo exchange needs range-narrowing"
    n_bands = mesh.shape[axis]
    h, l, p_pts, dh = cfg.n_heads, cfg.n_levels, cfg.n_points, cfg.head_dim
    rows, halos = band_layout(
        [(hp, w) for hp, w in padded_shapes], 1, cfg.range_narrow)
    locs, band_n = _band_slices(padded_shapes, n_bands)

    def body(prm, q_b, ref_b, x_b):
        rank = jax.lax.axis_index(axis)
        b, nq_b, d = q_b.shape
        wq = lambda w_: maybe_fake_quant(w_, cfg.weight_bits)

        # --- band-local value projection (1/TP of the pixels) -------------
        v = jnp.einsum("bnd,dhk->bnhk", x_b, wq(prm["value_w"])) \
            + prm["value_b"]
        v = maybe_fake_quant(v, cfg.act_bits)

        # --- halo exchange per level (2-neighbour ppermute) ----------------
        up = [(i, (i - 1) % n_bands) for i in range(n_bands)]
        down = [(i, (i + 1) % n_bands) for i in range(n_bands)]
        v_locals = []                 # (window (B,rows,W,H,Dh), gathered?)
        for li, ((hp, w_l), (st, rb, _)) in enumerate(zip(padded_shapes, locs)):
            hal = int(np.ceil(cfg.range_narrow[li])) + 2
            seg = jax.lax.dynamic_slice_in_dim(v, st, rb * w_l, axis=1)
            seg = seg.reshape(b, rb, w_l, h, dh)
            if hal >= rb:
                # band thinner than the sampling radius: a 1-hop halo can't
                # cover it — replicate this (small) level via all-gather
                vfull = jax.lax.all_gather(seg, axis, axis=1, tiled=True)
                v_locals.append((vfull, True))
                continue
            top, bot = seg[:, :hal], seg[:, -hal:]
            # halo ABOVE band j = band j-1's BOTTOM rows (bottoms sent down);
            # halo BELOW band j = band j+1's TOP rows (tops sent up).
            from_above = jax.lax.ppermute(bot, axis, down)
            from_below = jax.lax.ppermute(top, axis, up)
            # first/last band: zero halo beyond the image (wrap is masked out
            # by the validity check, but zero it for exactness)
            from_above = jnp.where(rank == 0, 0.0, from_above)
            from_below = jnp.where(rank == n_bands - 1, 0.0, from_below)
            v_locals.append((jnp.concatenate(
                [from_above, seg, from_below], axis=1), False))

        # --- sampling-point generation (PAP-aware, shared with msda) -------
        sel, offs_k, lvl_of_pt = select_points(prm, cfg, q_b)

        # --- per-level local gather + Eq.4 BI + aggregation ----------------
        out_h = jnp.zeros((b, nq_b, h, dh), q_b.dtype)
        for li, ((hp, w_l), (st, rb, _)) in enumerate(zip(padded_shapes, locs)):
            hal = int(np.ceil(cfg.range_narrow[li])) + 2
            window, gathered = v_locals[li]
            vloc = window.reshape(b, -1, h, dh)              # rows*(W) flat
            n_rows_loc = window.shape[1]
            on_lvl = (lvl_of_pt == li)
            wl_f = jnp.asarray(w_l, q_b.dtype)
            hp_f = jnp.asarray(hp, q_b.dtype)
            x_px = ref_b[:, :, None, None, 0] * wl_f + offs_k[..., 0] - 0.5
            y_px = ref_b[:, :, None, None, 1] * hp_f + offs_k[..., 1] - 0.5
            # band-local row coordinates (halo offset added); gathered levels
            # use global coordinates directly
            if gathered:
                y_loc = y_px
            else:
                y_loc = y_px - rank * rb + hal
            ones = jnp.ones_like(lvl_of_pt)
            idx, wgt, valid = corner_data(
                x_px, y_loc, ones * w_l, ones * n_rows_loc,
                jnp.zeros_like(ones))
            # validity in GLOBAL image coords. Built as a stacked mask, not
            # per-corner .at[].set(): the boolean scatter miscompiles under
            # shard_map on multi-device CPU (silently corrupts one corner).
            yg = jnp.floor(y_px)
            extra = jnp.stack([((yg + dy) >= 0) & ((yg + dy) < hp)
                               for dy in (0, 0, 1, 1)], axis=-1)
            valid = valid & extra
            eff_w = wgt * valid.astype(wgt.dtype) \
                * (sel.probs * on_lvl.astype(wgt.dtype))[..., None]
            k_pts = idx.shape[3]
            vv = vloc.transpose(0, 2, 1, 3).reshape(b * h, -1, dh)
            ii = idx.transpose(0, 2, 1, 3, 4).reshape(b * h, -1)
            g = jnp.take_along_axis(vv, ii[..., None], axis=1, mode="clip")
            g = g.reshape(b, h, nq_b, k_pts, 4, dh).transpose(0, 2, 1, 3, 4, 5)
            out_h = out_h + jnp.sum(
                g * eff_w[..., None], axis=(3, 4)).astype(out_h.dtype)

        out = jnp.einsum("bnhk,hkd->bnd", out_h, wq(prm["out_w"])) \
            + prm["out_b"]
        return out

    bspec = (batch_axes if len(batch_axes) != 1 else batch_axes[0]) \
        if batch_axes else None
    in_specs = (P(), P(bspec, axis, None), P(bspec, axis, None),
                P(bspec, axis, None))
    out_specs = P(bspec, axis, None)
    if hasattr(jax, "shard_map"):                    # jax >= 0.6
        fn = jax.shard_map(body, mesh=mesh, axis_names=set(mesh.axis_names),
                           in_specs=in_specs, out_specs=out_specs,
                           check_vma=False)
    else:                                            # 0.4.x experimental API
        from jax.experimental.shard_map import shard_map as _shard_map
        fn = _shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return fn(params, query, ref_points, x_flat)


def band_reorder(flat_padded: jnp.ndarray, padded_shapes, n_bands: int):
    """Level-major padded layout -> band-major layout (and inverse perm)."""
    perm = []
    starts = np.concatenate(
        [[0], np.cumsum([hp * w for hp, w in padded_shapes])[:-1]])
    for r in range(n_bands):
        for (hp, w), st in zip(padded_shapes, starts):
            rb = hp // n_bands
            base = st + r * rb * w
            perm.extend(range(base, base + rb * w))
    perm = np.asarray(perm)
    inv = np.argsort(perm)
    return flat_padded[:, perm], perm, inv
