"""Checkpointing: atomic per-leaf .npy stores with a JSON manifest, an async
writer thread, and ELASTIC restore (re-shard onto any mesh / device count).

Layout:  <dir>/step_<N>.tmp-<pid>/ ... -> atomic rename -> <dir>/step_<N>/
         <dir>/step_<N>/manifest.json  + one .npy per flattened leaf.

Fault-tolerance contract (tested): a crash mid-write never corrupts the
latest complete checkpoint (the tmp dir is simply abandoned), and restoring
on a *different* mesh reproduces bitwise-identical training (elastic
scaling)."""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.tree import flatten_dict, unflatten_dict


def _to_host(tree: Any) -> dict:
    flat = flatten_dict(_as_dict(tree))
    return {k: np.asarray(v) for k, v in flat.items()}


def _as_dict(tree: Any) -> Any:
    """NamedTuples -> dicts so flatten/unflatten round-trips through JSON."""
    if hasattr(tree, "_asdict"):
        return {k: _as_dict(v) for k, v in tree._asdict().items()}
    if isinstance(tree, dict):
        return {k: _as_dict(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {f"__seq{i}": _as_dict(v) for i, v in enumerate(tree)}
    return tree


def _fn_safe(key: str) -> str:
    return key.replace("/", "__")


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    flat = _to_host(tree)
    manifest = {}
    for k, v in flat.items():
        fname = _fn_safe(k) + ".npy"
        np.save(os.path.join(tmp, fname), v)
        manifest[k] = {"file": fname, "shape": list(v.shape), "dtype": str(v.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None) -> tuple[int, dict]:
    """Returns (step, flat-dict of np arrays). Use `reshard` to place them."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {k: np.load(os.path.join(path, meta["file"]))
            for k, meta in manifest["leaves"].items()}
    return manifest["step"], unflatten_dict(flat)


def restore_into(template: Any, loaded: dict) -> Any:
    """Map a loaded nested dict back into the structure of `template`
    (NamedTuples / tuples restored, leaf dtypes preserved)."""
    def rec(tmpl, node):
        if hasattr(tmpl, "_asdict"):
            return type(tmpl)(**{k: rec(v, node[k])
                                 for k, v in tmpl._asdict().items()})
        if isinstance(tmpl, dict):
            return {k: rec(v, node[k]) for k, v in tmpl.items()}
        if isinstance(tmpl, (list, tuple)):
            vals = [rec(v, node[f"__seq{i}"]) for i, v in enumerate(tmpl)]
            return type(tmpl)(vals) if isinstance(tmpl, list) else tuple(vals)
        arr = np.asarray(node)
        return arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr
    return rec(template, node=loaded)


def reshard(tree: Any, shardings: Any) -> Any:
    """Elastic placement: device_put each leaf with its NamedSharding —
    works across different meshes / device counts than the save-time mesh."""
    return jax.tree.map(jax.device_put, tree, shardings)


class AsyncCheckpointer:
    """Background writer: snapshot to host sync, write async (training
    continues during serialization — the v5e-fleet pattern)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:          # surfaced on next save/wait
                self._err = e

    def _gc(self):
        steps = sorted(s for s in (latest_step(self.ckpt_dir),) if s is not None)
        names = sorted(n for n in os.listdir(self.ckpt_dir)
                       if n.startswith("step_") and ".tmp" not in n)
        for name in names[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, name), ignore_errors=True)

    def save(self, step: int, tree: Any):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(np.asarray, tree)   # sync snapshot, async write
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join() if False else None
        while not self._q.empty():
            import time
            time.sleep(0.01)
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join(timeout=10)
