"""grok-1-314b — MoE LM, 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768/expert vocab=131072."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    n_experts=8, n_experts_active=2, expert_capacity_factor=1.25,
    dtype=jnp.bfloat16, remat=True, use_fsdp=True, grad_accum=8,
    notes="8 experts don't divide the 16-way model axis: per-expert d_ff "
          "shards over model instead; params FSDP over data (+pod)."
)

SMOKE = ModelConfig(
    name="grok-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    n_experts=4, n_experts_active=2, expert_capacity_factor=2.0,
    dtype=jnp.float32, remat=False,
)
