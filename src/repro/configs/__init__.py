"""Architecture registry: the 10 assigned archs + the paper's DETR family."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.common import ModelConfig

_ARCH_MODULES = {
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "granite-20b": "repro.configs.granite_20b",
    "minitron-8b": "repro.configs.minitron_8b",
    "minitron-4b": "repro.configs.minitron_4b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(_ARCH_MODULES[arch]).SMOKE


def get_detr_config(name: str):
    from repro.configs.detr_family import CONFIGS
    return CONFIGS[name]
