"""olmoe-1b-7b — MoE LM, 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    n_experts=64, n_experts_active=8, expert_capacity_factor=1.25,
    dtype=jnp.bfloat16, remat=True, grad_accum=1,
    notes="MoE 64e top-8; experts shard over the model axis (64/16=4 per chip)."
)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab_size=512,
    n_experts=8, n_experts_active=2, expert_capacity_factor=2.0,
    dtype=jnp.float32, remat=False,
)
