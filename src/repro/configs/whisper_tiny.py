"""whisper-tiny — enc-dec audio backbone [arXiv:2212.04356; unverified].

4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536 vocab=51865. The conv/mel
frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, 1500, 384)."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, enc_seq_len=1500,
    mlp_gated=False,
    dtype=jnp.bfloat16, remat=True, grad_accum=1,
    notes="Assigned shapes exceed whisper's native 448-token decoder context;"
          " applied mechanically to the backbone per the assignment. 6 heads"
          " replicate over model=16; mlp=1536 shards (96/chip)."
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, enc_seq_len=16,
    mlp_gated=False, dtype=jnp.float32, remat=False,
)
