"""llava-next-34b — VLM backbone (anyres tiling) [hf:llava-hf/llava-v1.6;
unverified]. 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings — anyres 5 tiles x 576 patches = 2880 image
tokens prepended to the text sequence."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    n_img_tokens=2880,
    dtype=jnp.bfloat16, remat=True, use_fsdp=True, grad_accum=4,
    notes="56 heads don't divide model=16 -> heads replicate; mlp shards. "
          "anyres: 4 tiles + 1 base x 576 patches = 2880 stub patch embeds."
)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab_size=512, n_img_tokens=16,
    dtype=jnp.float32, remat=False,
)
