"""deepseek-7b — dense llama-arch LM, MHA [arXiv:2401.02954; hf].

30L d_model=4096 32H (GQA kv=32) d_ff=11008 vocab=102400."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    dtype=jnp.bfloat16, remat=True, grad_accum=1,
    notes="Full MHA (kv=32); d_ff=11008=16*688 shards over model."
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=8,
    d_ff=172, vocab_size=512, dtype=jnp.float32, remat=False,
)
