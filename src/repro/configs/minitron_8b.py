"""minitron-8b — pruned nemotron dense LM [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256000,
    mlp_gated=False,
    dtype=jnp.bfloat16, remat=True, grad_accum=2,
    notes="256k vocab: embedding+head shard over model; CE loss computed "
          "in vocab chunks to bound the f32 logits buffer."
)

SMOKE = ModelConfig(
    name="minitron8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab_size=512, mlp_gated=False, dtype=jnp.float32, remat=False,
)
