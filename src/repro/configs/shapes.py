"""Assigned input-shape set (one per cell of the arch × shape matrix).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV/
state cache of seq_len), not ``train_step``. ``long_500k`` requires
sub-quadratic attention — run for SSM/hybrid, skipped for pure
full-attention archs (recorded in DESIGN.md §Arch-applicability)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# families whose decode cost is sub-quadratic in context (SSM state and/or
# sliding-window attention) — the only ones long_500k applies to
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shapes_for(family: str) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if family in LONG_CONTEXT_FAMILIES:
        names.append("long_500k")
    return names
