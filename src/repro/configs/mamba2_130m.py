"""mamba2-130m — attention-free SSD LM [arXiv:2405.21060; unverified].

24L d_model=768 vocab=50280 ssm_state=128 (SSD: expand 2, head_dim 64)."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,   # unused (attn-free)
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_conv=4, ssm_head_dim=64, ssm_chunk=256,
    dtype=jnp.bfloat16, remat=True, grad_accum=1,
    notes="Attention-free: runs long_500k (state-space decode is O(1) per "
          "token). d_inner=1536 -> 24 SSD heads."
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_head_dim=16, ssm_chunk=8,
    dtype=jnp.float32, remat=False,
)
