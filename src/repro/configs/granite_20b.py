"""granite-20b — dense code LM, llama-arch, MQA [arXiv:2405.04324; hf].

52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    mlp_gated=False,
    dtype=jnp.bfloat16, remat=True, use_fsdp=True, grad_accum=2,
    notes="MQA (kv=1): KV heads replicated across the model axis."
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=1,
    d_ff=128, vocab_size=512, mlp_gated=False, dtype=jnp.float32, remat=False,
)
