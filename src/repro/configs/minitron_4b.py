"""minitron-4b — pruned nemotron dense LM [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000,
    mlp_gated=False,
    dtype=jnp.bfloat16, remat=True, grad_accum=1,
    notes="24 heads don't divide model=16: heads replicate, mlp/vocab shard. "
          "(24%16!=0 -> heads unsharded; d_ff=9216 divides 16.)"
)

SMOKE = ModelConfig(
    name="minitron4b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
    d_ff=96, vocab_size=512, mlp_gated=False, dtype=jnp.float32, remat=False,
)
