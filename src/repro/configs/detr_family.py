"""The paper's OWN benchmark family: Deformable-DETR / DN-DETR / DINO
encoder stacks built around MSDeformAttn + the DEFA optimization stack.

These are extra configs beyond the 10 assigned archs — they carry the
paper-representative cells of the dry-run/roofline and the
technique-representative §Perf hillclimb. Standard encoder geometry:
d_model=256, 8 heads, 4 levels x 4 points, 6 blocks; pyramid for an
800x1333 COCO image (strides 8/16/32/64)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.encoder import EncoderConfig
from repro.core.msdeform_attn import MSDeformAttnConfig

# 800x1333 input, strides 8,16,32,64 (official deformable-DETR pyramid)
LEVEL_SHAPES = ((100, 167), (50, 84), (25, 42), (13, 21))
N_IN = sum(h * w for h, w in LEVEL_SHAPES)                 # 21900 queries


@dataclasses.dataclass(frozen=True)
class DetrArchConfig:
    name: str
    encoder: EncoderConfig
    level_shapes: tuple = LEVEL_SHAPES
    serve_batch: int = 64          # images per serving step (fleet-scale)
    train_batch: int = 256


def _enc(n_blocks: int, defa: bool, dtype=jnp.bfloat16) -> EncoderConfig:
    attn = MSDeformAttnConfig(
        d_model=256, n_heads=8, n_levels=4, n_points=4,
        pap_mode="topk" if defa else "off", pap_keep=4,
        fwp_mode="compact" if defa else "off", fwp_k=1.0, fwp_capacity=0.6,
        range_narrow=(16.0, 12.0, 8.0, 4.0) if defa else None,
        act_bits=12 if defa else None, weight_bits=12 if defa else None,
        impl="jnp", dtype=dtype)
    return EncoderConfig(attn=attn, n_blocks=n_blocks, d_ffn=1024, dtype=dtype)


# baseline (paper-faithful MSDeformAttn, no pruning) and DEFA-optimized
CONFIGS = {
    "deformable-detr": DetrArchConfig("deformable-detr", _enc(6, defa=False)),
    "deformable-detr-defa": DetrArchConfig("deformable-detr-defa", _enc(6, defa=True)),
    "dn-detr": DetrArchConfig("dn-detr", _enc(6, defa=False)),
    "dino": DetrArchConfig("dino", _enc(6, defa=False)),
    "dino-defa": DetrArchConfig("dino-defa", _enc(6, defa=True)),
}
