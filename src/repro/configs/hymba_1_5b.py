"""hymba-1.5b — hybrid parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 ssm_state=16 vocab=32001.
Sliding-window attention everywhere except 3 global layers (first/middle/
last, per the paper); the SSM path gives O(1)-state long-range memory, so
long_500k decode runs with bounded attention cache."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_head_dim=64, ssm_chunk=256,
    attn_window=1024, global_layers=(0, 15, 31),
    dtype=jnp.bfloat16, remat=True, grad_accum=1,
    notes="25 heads / kv=5 / d_ff=5504 / vocab=32001 are all 16-indivisible:"
          " attention+mlp replicate over model; batch carries parallelism."
          " Hymba meta-tokens omitted (backbone assignment). For long_500k"
          " the 3 global layers fall back to sliding window (cache bound);"
          " production would use a dual global/SWA cache."
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    ssm_state=8, ssm_expand=2, ssm_conv=4, ssm_head_dim=16, ssm_chunk=8,
    attn_window=8, global_layers=(0,),
    dtype=jnp.float32, remat=False,
)
