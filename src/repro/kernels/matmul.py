"""Pallas TPU kernel: tiled matmul — DEFA's "MM mode" (reconfigurable PE
array, paper §4.3) mapped to the MXU, with the INT-quantized variant fused.

The ASIC's PE array multiplies a 16-element query vector with a 16×16 weight
tile output-stationary; the MXU analogue is a (bm × bk) · (bk × bn) tile
accumulated in an f32 VMEM scratch across the K grid dimension. The
quantized variant keeps weights as int8 codes in HBM (2× bandwidth saving —
the TPU-meaningful analogue of the paper's INT12 datapath) and dequantizes
inside the kernel right before the MXU dot."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)
    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_q_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
    w = w_ref[...].astype(jnp.float32) * s_ref[...]      # dequant in-kernel
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)
    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_pallas(x: jnp.ndarray, w: jnp.ndarray, w_scale=None, *,
                  bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = False) -> jnp.ndarray:
    """x (M,K) @ w (K,N) [+ per-column w_scale (1,N) if w is int8]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    grid = ((m + pm) // bm, (n + pn) // bn, (k + pk) // bk)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, l: (i, l))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, l: (l, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, l: (i, j))
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]

    if w.dtype == jnp.int8:
        assert w_scale is not None
        w_scale = jnp.pad(w_scale, ((0, 0), (0, pn))) if pn else w_scale
        s_spec = pl.BlockSpec((1, bn), lambda i, j, l: (0, j))
        out = pl.pallas_call(
            _mm_q_kernel, grid=grid,
            in_specs=[x_spec, w_spec, s_spec], out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), x.dtype),
            scratch_shapes=scratch,
            interpret=interpret, name="matmul_int8",
        )(x, w, w_scale)
    else:
        out = pl.pallas_call(
            _mm_kernel, grid=grid,
            in_specs=[x_spec, w_spec], out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), x.dtype),
            scratch_shapes=scratch,
            interpret=interpret, name="matmul",
        )(x, w)
    return out[:m, :n]
