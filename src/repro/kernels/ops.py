"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as traced Python); on TPU the same calls compile natively.
``REPRO_FORCE_INTERPRET=0`` forces native mode (for real TPU runs)."""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import msgs_decode as msgs_decode_kernel
from repro.kernels.msgs_fused import msgs_fused_pallas, msgs_fused_packed_pallas
from repro.kernels.msgs_windowed import msgs_windowed_msp_pallas
from repro.kernels.matmul import matmul_pallas


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def msgs_fused(v, x_px, y_px, start, wl, hl, probs,
               remap: Optional[jnp.ndarray] = None,
               scale: Optional[jnp.ndarray] = None, *,
               block_q: int = 128, interpret: Optional[bool] = None):
    """Fused grid-sample + aggregation. See kernels/msgs_fused.py.
    ``scale`` is the int8 table's (B, 1, H, Dh) dequant scale."""
    interp = _interpret_default() if interpret is None else interpret
    return msgs_fused_pallas(v, x_px, y_px, start.astype(jnp.int32),
                             wl.astype(jnp.int32), hl.astype(jnp.int32),
                             probs, remap, scale,
                             block_q=block_q, interpret=interp)


def msgs_fused_packed(v, x_px, y_px, start, wl, hl, probs,
                      remap: Optional[jnp.ndarray] = None,
                      scale: Optional[jnp.ndarray] = None, *,
                      head_pack: int = 4, block_q: int = 128,
                      interpret: Optional[bool] = None):
    """Head-packed fused grid-sample + aggregation: ``head_pack`` heads
    share one 128-lane group (see kernels/msgs_fused.py)."""
    interp = _interpret_default() if interpret is None else interpret
    return msgs_fused_packed_pallas(v, x_px, y_px, start.astype(jnp.int32),
                                    wl.astype(jnp.int32), hl.astype(jnp.int32),
                                    probs, remap, scale, head_pack=head_pack,
                                    block_q=block_q, interpret=interp)


def msgs_windowed_msp(v, x_px, y_px, lvl_of_pt, probs,
                      remap: Optional[jnp.ndarray] = None,
                      keep_idx: Optional[jnp.ndarray] = None,
                      scale: Optional[jnp.ndarray] = None, *,
                      level_shapes, ranges, tile_q: int = 128,
                      head_pack: int = 1, caps=None,
                      interpret: Optional[bool] = None):
    """Single-launch multi-scale-parallel windowed MSGS + fused in-kernel
    level aggregation; FWP-compact-native. ``scale`` is the int8 table's
    per-group (B, n_groups, G, Dh) dequant scale.
    See kernels/msgs_windowed.py."""
    interp = _interpret_default() if interpret is None else interpret
    return msgs_windowed_msp_pallas(
        v, x_px, y_px, lvl_of_pt.astype(jnp.int32), probs,
        remap, keep_idx, scale,
        level_shapes=tuple(tuple(int(x) for x in s) for s in level_shapes),
        ranges=tuple(float(r) for r in ranges), tile_q=tile_q,
        head_pack=head_pack,
        caps=None if caps is None else tuple(int(c) for c in caps),
        interpret=interp)


def stage_decode_table(v, remap=None, *, head_pack: int = 1, scale=None):
    """Stage the value table ONCE in the decode launch layout (see
    kernels/msgs_decode.py); int8 tables stage codes + the per-group
    scale row together. Routed through the module attribute so the
    staging-spy tests can count stagings per memory."""
    return msgs_decode_kernel.stage_decode_table(v, remap,
                                                 head_pack=head_pack,
                                                 scale=scale)


def msgs_decode(staged, x_px, y_px, start, wl, hl, probs, *,
                block_q: int = 128, interpret: Optional[bool] = None):
    """Per-layer persistent decode sampling against a pre-staged table.
    Differentiable (custom_vjp backward = exact jnp reference)."""
    interp = _interpret_default() if interpret is None else interpret
    return msgs_decode_kernel.msgs_decode_pallas(
        staged, x_px, y_px, start, wl, hl, probs,
        block_q=block_q, interpret=interp)


def msgs_decode_layers(staged, x_px, y_px, start, wl, hl, probs, *,
                       block_q: int = 128,
                       interpret: Optional[bool] = None):
    """Stacked multi-layer persistent decode: one launch, all layers'
    points, table staged once per (batch, head-group)."""
    interp = _interpret_default() if interpret is None else interpret
    return msgs_decode_kernel.msgs_decode_layers_pallas(
        staged, x_px, y_px, start, wl, hl, probs,
        block_q=block_q, interpret=interp)


def matmul(x, w, w_scale=None, *, bm: int = 128, bn: int = 128, bk: int = 128,
           interpret: Optional[bool] = None):
    """Tiled MXU matmul; int8-weight variant dequantizes in-kernel."""
    interp = _interpret_default() if interpret is None else interpret
    return matmul_pallas(x, w, w_scale, bm=bm, bn=bn, bk=bk, interpret=interp)


def flash_decode(q, k, v, valid, *, chunk: int = 512,
                 interpret: Optional[bool] = None):
    """Fused one-token GQA decode attention over a (masked) KV cache."""
    from repro.kernels.flash_decode import flash_decode_pallas
    interp = _interpret_default() if interpret is None else interpret
    return flash_decode_pallas(q, k, v, valid, chunk=chunk, interpret=interp)
