"""Pallas TPU kernel: fused MSGS (bilinear grid-sampling) + aggregation.

This is DEFA contribution C6 mapped to the TPU: one kernel computes corner
indices, gathers the four neighbour rows from the value buffer resident in
VMEM, evaluates the paper's 3-multiplier factorized bilinear form (Eq. 4)

    S = N0 + (N2-N0)·t0 + [(N1-N0) + (N3-N2-N1+N0)·t0]·t1

and immediately applies the probability-weighted aggregation — the sampled
values never round-trip through HBM (on the ASIC: never leave the PE array).

C5 (inter-level parallelism) maps to the *layout*: the K point axis is
level-major, so the per-lane gathers of one query spread across the disjoint
per-level segments of the flat value buffer — the VMEM analogue of "4 points
from 4 levels hit 4 disjoint bank groups". A cycle-accurate bank model
(benchmarks/bank_sim.py) quantifies the ASIC-side claim.

Grid: (B, H, Nq/TQ). The whole value table (N_rows, Dh) for one (batch,
head) is staged in VMEM (DETR-scale fmaps fit comfortably: the paper's
biggest multi-scale pyramid is ~9.8 MB *before* FWP, ~55% of that after,
per-head slices are 1/8 of it). For fmaps beyond VMEM use the windowed
variant (msgs_windowed.py) which exploits C3 range-narrowing + C7 reuse.

TPU alignment note: Dh (typically 32 in DETR-family) is below the 128-lane
width. ``msgs_fused_packed_pallas`` packs ``head_pack = 128 // Dh`` heads
per 128-lane group (grid (B, H/G, Nq/TQ)): one staged (N_rows, G·Dh) table
row carries G heads, so the lane groups that a padded layout would leave
idle do real work. The MSDAPlan (repro/msda/plan.py) decides pad vs. pack.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _eq4_sample_agg(x, y, st, wl, hl, probs, v,
                    remap: Optional[jnp.ndarray] = None,
                    lanes: Optional[Tuple[int, int]] = None,
                    scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Shared Eq. 4 corner gather + factorized bilinear + aggregation.

    x, y, st, wl, hl, probs: (TQ, K); v: (N_rows, Dv). ``remap`` is the
    optional FWP-compact pixel -> slot indirection (N_pix,). ``lanes``
    selects a (lo, n) lane slice of the gathered rows — used by the
    head-packed layout where Dv = G·Dh holds G heads side by side.
    ``scale`` is the int8 table's per-channel (Dv,) dequant scale: the
    corners gather 1-byte codes, the bilinear/aggregation arithmetic runs
    in the compute dtype (int8 corner DIFFERENCES can reach ±254 — the
    cast must happen before Eq. 4), and the scale multiplies ONCE after
    aggregation — exact, because the scale is shared across rows.
    Returns (TQ, n) with n = Dv unless sliced."""
    x0 = jnp.floor(x)
    y0 = jnp.floor(y)
    t1 = (x - x0)[..., None]                    # frac along x
    t0 = (y - y0)[..., None]                    # frac along y
    x0i = x0.astype(jnp.int32)
    y0i = y0.astype(jnp.int32)

    def corner(dx, dy):
        cx = x0i + dx
        cy = y0i + dy
        valid = (cx >= 0) & (cx < wl) & (cy >= 0) & (cy < hl)
        idx = st + jnp.clip(cy, 0, hl - 1) * wl + jnp.clip(cx, 0, wl - 1)
        if remap is not None:
            idx = jnp.take(remap, idx.reshape(-1)).reshape(idx.shape)
        g = jnp.take(v, idx.reshape(-1), axis=0).reshape(idx.shape + (v.shape[-1],))
        if lanes is not None:
            g = g[..., lanes[0]:lanes[0] + lanes[1]]
        if scale is not None:
            g = g.astype(probs.dtype)
        return g * valid[..., None]

    n0 = corner(0, 0)
    n1 = corner(1, 0)
    n2 = corner(0, 1)
    n3 = corner(1, 1)
    # Eq. 4 — exactly three multiplies by the fractional coordinates:
    s = n0 + (n2 - n0) * t0 + ((n1 - n0) + (n3 - n2 - n1 + n0) * t0) * t1
    out = jnp.sum(s * probs[..., None], axis=1)
    if scale is not None:
        sc = scale if lanes is None else scale[lanes[0]:lanes[0] + lanes[1]]
        out = out * sc
    return out


def _make_kernel(use_remap: bool, use_scale: bool):
    """Per-head kernel: one grid step serves one (batch, head) slice."""
    def kernel(*refs):
        x_ref, y_ref, st_ref, wl_ref, hl_ref, p_ref = refs[:6]
        refs = refs[6:]
        remap = None
        if use_remap:
            remap, refs = refs[0][0, :], refs[1:]
        v_ref = refs[0]
        scale = refs[1][0, 0, 0, :] if use_scale else None
        o_ref = refs[-1]
        o_ref[0, :, 0, :] = _eq4_sample_agg(
            x_ref[0, :, 0, :], y_ref[0, :, 0, :], st_ref[0, :, 0, :],
            wl_ref[0, :, 0, :], hl_ref[0, :, 0, :], p_ref[0, :, 0, :],
            v_ref[0, :, 0, :], remap=remap, scale=scale)
    return kernel


def _make_kernel_packed(head_pack: int, dh: int, use_remap: bool,
                        use_scale: bool):
    """Head-packed kernel: one grid step serves ``head_pack`` heads whose
    value rows are packed side by side into a (N_rows, G·Dh) lane group."""
    def kernel(*refs):
        x_ref, y_ref, st_ref, wl_ref, hl_ref, p_ref = refs[:6]
        refs = refs[6:]
        remap = None
        if use_remap:
            remap, refs = refs[0][0, :], refs[1:]
        v_ref = refs[0]
        o_ref = refs[-1]
        n_rows = v_ref.shape[1]
        vp = v_ref[0].reshape(n_rows, head_pack * dh)   # packed lane group
        scale = None
        if use_scale:                   # (1, 1, G, Dh) -> (G*Dh,)
            scale = refs[1][0, 0].reshape(head_pack * dh)
        for g in range(head_pack):                       # static unroll
            o_ref[0, :, g, :] = _eq4_sample_agg(
                x_ref[0, :, g, :], y_ref[0, :, g, :], st_ref[0, :, g, :],
                wl_ref[0, :, g, :], hl_ref[0, :, g, :], p_ref[0, :, g, :],
                vp, remap=remap, lanes=(g * dh, dh), scale=scale)
    return kernel


def _pad_points(nq, tq, x_px, y_px, probs, start, wl, hl):
    pad = (-nq) % tq
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        x_px, y_px, probs = zf(x_px), zf(y_px), zf(probs)
        start = zf(start)
        wl = jnp.pad(wl, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1)
        hl = jnp.pad(hl, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1)
    return pad, x_px, y_px, probs, start, wl, hl


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def msgs_fused_pallas(
    v: jnp.ndarray,                      # (B, N_rows, H, Dh)
    x_px: jnp.ndarray,                   # (B, Nq, H, K)
    y_px: jnp.ndarray,
    start: jnp.ndarray,                  # int32
    wl: jnp.ndarray,                     # int32
    hl: jnp.ndarray,                     # int32
    probs: jnp.ndarray,
    remap: Optional[jnp.ndarray] = None,  # (B, N_pix) int32
    scale: Optional[jnp.ndarray] = None,  # (B, 1, H, Dh) f32 dequant scale
    *,
    block_q: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, n_rows, h, dh = v.shape
    _, nq, _, k = x_px.shape
    tq = min(block_q, nq)
    pad, x_px, y_px, probs, start, wl, hl = _pad_points(
        nq, tq, x_px, y_px, probs, start, wl, hl)
    nq_p = nq + pad
    grid = (b, h, nq_p // tq)

    pt_spec = pl.BlockSpec((1, tq, 1, k), lambda bi, hi, qi: (bi, qi, hi, 0))
    v_spec = pl.BlockSpec((1, n_rows, 1, dh), lambda bi, hi, qi: (bi, 0, hi, 0))
    out_spec = pl.BlockSpec((1, tq, 1, dh), lambda bi, hi, qi: (bi, qi, hi, 0))
    out_dtype = v.dtype if scale is None else probs.dtype
    out_shape = jax.ShapeDtypeStruct((b, nq_p, h, dh), out_dtype)

    in_specs = [pt_spec] * 6
    inputs = [x_px, y_px, start, wl, hl, probs]
    name = "msgs_fused"
    if remap is not None:
        in_specs.append(pl.BlockSpec((1, remap.shape[1]),
                                     lambda bi, hi, qi: (bi, 0)))
        inputs.append(remap)
        name += "_remap"
    in_specs.append(v_spec)
    inputs.append(v)
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, 1, 1, dh),
                                     lambda bi, hi, qi: (bi, 0, hi, 0)))
        inputs.append(scale)
        name += "_int8"
    out = pl.pallas_call(
        _make_kernel(use_remap=remap is not None,
                     use_scale=scale is not None),
        grid=grid, in_specs=in_specs,
        out_specs=out_spec, out_shape=out_shape,
        interpret=interpret, name=name,
    )(*inputs)
    return out[:, :nq] if pad else out


@functools.partial(jax.jit, static_argnames=("head_pack", "block_q", "interpret"))
def msgs_fused_packed_pallas(
    v: jnp.ndarray,                      # (B, N_rows, H, Dh)
    x_px: jnp.ndarray,                   # (B, Nq, H, K)
    y_px: jnp.ndarray,
    start: jnp.ndarray,                  # int32
    wl: jnp.ndarray,                     # int32
    hl: jnp.ndarray,                     # int32
    probs: jnp.ndarray,
    remap: Optional[jnp.ndarray] = None,  # (B, N_pix) int32
    scale: Optional[jnp.ndarray] = None,  # (B, 1, H, Dh) f32 dequant scale
    *,
    head_pack: int = 4,
    block_q: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Head-packed fused MSGS: G = head_pack heads share one 128-lane
    group — grid (B, H/G, Nq/TQ), staged table (N_rows, G·Dh)."""
    b, n_rows, h, dh = v.shape
    _, nq, _, k = x_px.shape
    assert h % head_pack == 0, (h, head_pack)
    tq = min(block_q, nq)
    pad, x_px, y_px, probs, start, wl, hl = _pad_points(
        nq, tq, x_px, y_px, probs, start, wl, hl)
    nq_p = nq + pad
    g = head_pack
    grid = (b, h // g, nq_p // tq)

    pt_spec = pl.BlockSpec((1, tq, g, k), lambda bi, gi, qi: (bi, qi, gi, 0))
    v_spec = pl.BlockSpec((1, n_rows, g, dh), lambda bi, gi, qi: (bi, 0, gi, 0))
    out_spec = pl.BlockSpec((1, tq, g, dh), lambda bi, gi, qi: (bi, qi, gi, 0))
    out_dtype = v.dtype if scale is None else probs.dtype
    out_shape = jax.ShapeDtypeStruct((b, nq_p, h, dh), out_dtype)

    in_specs = [pt_spec] * 6
    inputs = [x_px, y_px, start, wl, hl, probs]
    name = "msgs_fused_packed"
    if remap is not None:
        in_specs.append(pl.BlockSpec((1, remap.shape[1]),
                                     lambda bi, gi, qi: (bi, 0)))
        inputs.append(remap)
        name += "_remap"
    in_specs.append(v_spec)
    inputs.append(v)
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, 1, g, dh),
                                     lambda bi, gi, qi: (bi, 0, gi, 0)))
        inputs.append(scale)
        name += "_int8"
    kernel = _make_kernel_packed(g, dh, use_remap=remap is not None,
                                 use_scale=scale is not None)
    out = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs,
        out_specs=out_spec, out_shape=out_shape,
        interpret=interpret, name=name,
    )(*inputs)
    return out[:, :nq] if pad else out
