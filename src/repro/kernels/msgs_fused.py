"""Pallas TPU kernel: fused MSGS (bilinear grid-sampling) + aggregation.

This is DEFA contribution C6 mapped to the TPU: one kernel computes corner
indices, gathers the four neighbour rows from the value buffer resident in
VMEM, evaluates the paper's 3-multiplier factorized bilinear form (Eq. 4)

    S = N0 + (N2-N0)·t0 + [(N1-N0) + (N3-N2-N1+N0)·t0]·t1

and immediately applies the probability-weighted aggregation — the sampled
values never round-trip through HBM (on the ASIC: never leave the PE array).

C5 (inter-level parallelism) maps to the *layout*: the K point axis is
level-major, so the per-lane gathers of one query spread across the disjoint
per-level segments of the flat value buffer — the VMEM analogue of "4 points
from 4 levels hit 4 disjoint bank groups". A cycle-accurate bank model
(benchmarks/bank_sim.py) quantifies the ASIC-side claim.

Grid: (B, H, Nq/TQ). The whole value table (N_rows, Dh) for one (batch,
head) is staged in VMEM (DETR-scale fmaps fit comfortably: the paper's
biggest multi-scale pyramid is ~9.8 MB *before* FWP, ~55% of that after,
per-head slices are 1/8 of it). For fmaps beyond VMEM use the windowed
variant (msgs_windowed.py) which exploits C3 range-narrowing + C7 reuse.

TPU alignment note: Dh (typically 32 in DETR-family) is below the 128-lane
width; production tiling pads Dh→128 or packs 4 heads per lane group. The
kernel keeps the logical layout; padding is the wrapper's job (ops.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, st_ref, wl_ref, hl_ref, p_ref, v_ref, o_ref):
    v = v_ref[0, :, 0, :]                       # (N_rows, Dh)
    x = x_ref[0, :, 0, :]                       # (TQ, K)
    y = y_ref[0, :, 0, :]
    st = st_ref[0, :, 0, :]
    wl = wl_ref[0, :, 0, :]
    hl = hl_ref[0, :, 0, :]
    probs = p_ref[0, :, 0, :]

    x0 = jnp.floor(x)
    y0 = jnp.floor(y)
    t1 = (x - x0)[..., None]                    # frac along x
    t0 = (y - y0)[..., None]                    # frac along y
    x0i = x0.astype(jnp.int32)
    y0i = y0.astype(jnp.int32)

    def corner(dx, dy):
        cx = x0i + dx
        cy = y0i + dy
        valid = (cx >= 0) & (cx < wl) & (cy >= 0) & (cy < hl)
        idx = st + jnp.clip(cy, 0, hl - 1) * wl + jnp.clip(cx, 0, wl - 1)
        g = jnp.take(v, idx.reshape(-1), axis=0).reshape(idx.shape + (v.shape[-1],))
        return g * valid[..., None]

    n0 = corner(0, 0)
    n1 = corner(1, 0)
    n2 = corner(0, 1)
    n3 = corner(1, 1)
    # Eq. 4 — exactly three multiplies by the fractional coordinates:
    s = n0 + (n2 - n0) * t0 + ((n1 - n0) + (n3 - n2 - n1 + n0) * t0) * t1
    o_ref[0, :, 0, :] = jnp.sum(s * probs[..., None], axis=1)


def _kernel_remap(x_ref, y_ref, st_ref, wl_ref, hl_ref, p_ref, r_ref, v_ref, o_ref):
    """FWP-compact variant: corner pixel -> compacted slot indirection."""
    v = v_ref[0, :, 0, :]
    remap = r_ref[0, :]
    x = x_ref[0, :, 0, :]
    y = y_ref[0, :, 0, :]
    st = st_ref[0, :, 0, :]
    wl = wl_ref[0, :, 0, :]
    hl = hl_ref[0, :, 0, :]
    probs = p_ref[0, :, 0, :]

    x0 = jnp.floor(x)
    y0 = jnp.floor(y)
    t1 = (x - x0)[..., None]
    t0 = (y - y0)[..., None]
    x0i = x0.astype(jnp.int32)
    y0i = y0.astype(jnp.int32)

    def corner(dx, dy):
        cx = x0i + dx
        cy = y0i + dy
        valid = (cx >= 0) & (cx < wl) & (cy >= 0) & (cy < hl)
        pix = st + jnp.clip(cy, 0, hl - 1) * wl + jnp.clip(cx, 0, wl - 1)
        slot = jnp.take(remap, pix.reshape(-1)).reshape(pix.shape)
        g = jnp.take(v, slot.reshape(-1), axis=0).reshape(pix.shape + (v.shape[-1],))
        return g * valid[..., None]

    n0 = corner(0, 0)
    n1 = corner(1, 0)
    n2 = corner(0, 1)
    n3 = corner(1, 1)
    s = n0 + (n2 - n0) * t0 + ((n1 - n0) + (n3 - n2 - n1 + n0) * t0) * t1
    o_ref[0, :, 0, :] = jnp.sum(s * probs[..., None], axis=1)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def msgs_fused_pallas(
    v: jnp.ndarray,                      # (B, N_rows, H, Dh)
    x_px: jnp.ndarray,                   # (B, Nq, H, K)
    y_px: jnp.ndarray,
    start: jnp.ndarray,                  # int32
    wl: jnp.ndarray,                     # int32
    hl: jnp.ndarray,                     # int32
    probs: jnp.ndarray,
    remap: Optional[jnp.ndarray] = None,  # (B, N_pix) int32
    *,
    block_q: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, n_rows, h, dh = v.shape
    _, nq, _, k = x_px.shape
    tq = min(block_q, nq)
    pad = (-nq) % tq
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        x_px, y_px, probs = zf(x_px), zf(y_px), zf(probs)
        start = zf(start)
        wl = jnp.pad(wl, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1)
        hl = jnp.pad(hl, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1)
    nq_p = nq + pad
    grid = (b, h, nq_p // tq)

    pt_spec = pl.BlockSpec((1, tq, 1, k), lambda bi, hi, qi: (bi, qi, hi, 0))
    v_spec = pl.BlockSpec((1, n_rows, 1, dh), lambda bi, hi, qi: (bi, 0, hi, 0))
    out_spec = pl.BlockSpec((1, tq, 1, dh), lambda bi, hi, qi: (bi, qi, hi, 0))
    out_shape = jax.ShapeDtypeStruct((b, nq_p, h, dh), v.dtype)

    if remap is None:
        out = pl.pallas_call(
            _kernel, grid=grid,
            in_specs=[pt_spec, pt_spec, pt_spec, pt_spec, pt_spec, pt_spec, v_spec],
            out_specs=out_spec, out_shape=out_shape,
            interpret=interpret, name="msgs_fused",
        )(x_px, y_px, start, wl, hl, probs, v)
    else:
        r_spec = pl.BlockSpec((1, remap.shape[1]), lambda bi, hi, qi: (bi, 0))
        out = pl.pallas_call(
            _kernel_remap, grid=grid,
            in_specs=[pt_spec, pt_spec, pt_spec, pt_spec, pt_spec, pt_spec,
                      r_spec, v_spec],
            out_specs=out_spec, out_shape=out_shape,
            interpret=interpret, name="msgs_fused_remap",
        )(x_px, y_px, start, wl, hl, probs, remap, v)
    return out[:, :nq] if pad else out
