"""Pallas TPU kernel: persistent-cache decode-shaped MSGS + aggregation.

The decoder workload (N_q ≈ 300 learned queries, 6 layers, ONE fixed
memory) is where DEFA's feature-map reusing pays at the *staging* level,
not just the projection level: PR 3's build-once ``MSDAValueCache``
removed the per-layer value projection, but every ``pallas_fused`` launch
still re-staged the (head-sliced) table into VMEM — 6 layers, 6 stagings
per (batch, head-group). This kernel closes that gap:

  * :func:`stage_decode_table` runs ONCE per memory: it lays the
    (B, N_rows, H, Dh) table out in the decode launch layout
    (B, n_groups, N_rows, G·Dh) — ``G = head_pack`` heads packed side by
    side per 128-lane group — so every subsequent launch consumes the
    staged block verbatim. This is the ``plan``-keyed staging decision:
    ``build_value_cache`` stages exactly when the plan's backend is
    ``pallas_decode``, and the spy-testable call count proves one staging
    per (batch, head-group) per memory, never per layer.
  * :func:`msgs_decode_pallas` launches over grid
    (B × head-group × query-tile × layer) with the **layer axis
    innermost** and the table BlockSpec indexed by (batch, head-group)
    only — Pallas's block-revisiting rule then keeps the staged table
    resident in VMEM across the whole (query-tile × layer) sweep of one
    (batch, head-group): the multi-layer persistent launch. Per-layer
    sampling points / probabilities ride in as stacked
    (B, n_layers, N_q, H, K) operands and the stacked
    (B, n_layers, N_q, H, Dh) output holds every layer's samples.

Decode queries arrive in arbitrary learned order; cache-local query
ordering (``repro/msda/ordering.py``, ``plan.query_order``) permutes
them by reference point OUTSIDE this kernel — the launch itself is
order-agnostic, it just sees query tiles whose sampling points happen
to cluster, so a tile's touched table rows span fewer cache lines
(measured: ``plan.with_measured_tile_window`` / the
``msda_decode6_ordered`` micro row).

Two consumption modes:

  * **per-layer persistent** (the decoder fast path, ``n_layers=1``
    launches): the decoder interleaves cross-attention with self-attn /
    FFN / reference refinement, so layer l's sampling coordinates only
    exist after layer l-1's output — a single launch across all 6 layers
    is infeasible for the *interleaved* forward. Each layer launches this
    kernel against the ONE staged table; the layout/packing/indirection
    work is never repeated (and on real hardware the staged block is a
    single contiguous DMA, vs. ``pallas_fused``'s per-head re-slicing of
    the (B, N_rows, H, Dh) table every layer).
  * **stacked multi-layer** (one launch): when all layers' coordinates
    are known up front (offline scoring, the microbench, any
    coords-precomputed replay), the stacked operands execute in ONE
    launch and the table is staged once per (batch, head-group) for all
    ``n_layers`` — ``benchmarks/microbench.py`` measures both.

Differentiability: ``pallas_call`` has no autodiff rule (even in
interpret mode), so the public entry points carry a ``jax.custom_vjp``
whose backward is the exact jnp reference (:func:`msgs_decode_ref`,
the same flat corner-gather math as the ``jnp_gather`` backend) — this
is the first Pallas backend the decoder can *train* through, which the
gradient-parity suite in tests/test_msda_backends.py pins.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.msgs_fused import _eq4_sample_agg


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DecodeStagedTable:
    """The once-per-memory staged value table in decode launch layout.

    ``v`` is (B, n_groups, N_rows, G·Dh): ``head_pack`` heads of one
    lane group packed side by side, ready for the decode kernel's
    (batch, head-group)-indexed BlockSpec. ``remap`` is the FWP-compact
    pixel -> slot indirection (None when dense). ``table_bytes`` is the
    bytes staged per (batch, head-group) — the unit the 1×-vs-n_layers×
    staging comparison in ``MSDAPlan.describe()`` is measured in.

    Registered as a pytree whose integer metadata is STATIC aux data (not
    leaves): the kernel needs ``n_rows``/``head_pack``/``dh`` as Python
    ints for its BlockSpecs, so a staged table that crosses a ``jit``
    boundary as an argument must not get them traced."""
    v: jnp.ndarray                      # (B, n_groups, N_rows, G*Dh)
    remap: Optional[jnp.ndarray]        # (B, N_pix) int32 or None
    n_rows: int
    head_pack: int
    dh: int
    table_bytes: int
    scale: Optional[jnp.ndarray] = None  # (B, n_groups, G*Dh) f32 dequant
    #   scale when ``v`` holds int8 codes (per-channel, shared across
    #   rows — the kernel multiplies once after aggregation); None for
    #   float tables

    def tree_flatten(self):
        return (self.v, self.remap, self.scale), \
            (self.n_rows, self.head_pack, self.dh, self.table_bytes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        v, remap, scale = children
        n_rows, head_pack, dh, table_bytes = aux
        return cls(v=v, remap=remap, scale=scale, n_rows=n_rows,
                   head_pack=head_pack, dh=dh, table_bytes=table_bytes)


def stage_decode_table(v: jnp.ndarray,
                       remap: Optional[jnp.ndarray] = None,
                       *, head_pack: int = 1,
                       scale: Optional[jnp.ndarray] = None
                       ) -> DecodeStagedTable:
    """Stage the value table ONCE for all decode launches of one memory.

    (B, N_rows, H, Dh) -> (B, H/G, N_rows, G·Dh): the same head-packed
    lane layout ``msgs_fused_packed`` rebuilds per launch, materialized
    once so every per-layer launch (and the stacked multi-layer launch)
    consumes it verbatim. ``scale`` is the int8 table's (B, 1, H, Dh)
    per-channel dequant scale — packed into the same per-group lane
    layout and staged next to the codes (one f32 row per group). Call
    through the module attribute (``msgs_decode.stage_decode_table``) so
    the staging-spy tests can count stagings per memory."""
    # trace-time staging event (process-wide registry): counts persistent
    # decode staging layouts created, not per-execution traffic
    from repro.obs.metrics import default_registry
    default_registry().counter(
        "msda_decode_stage_traces_total",
        "stage_decode_table tracings (persistent decode stagings)"
    ).inc(head_pack=str(head_pack))
    b, n_rows, h, dh = v.shape
    g = head_pack if (head_pack > 1 and h % head_pack == 0) else 1
    vp = v.reshape(b, n_rows, h // g, g, dh)
    vp = vp.transpose(0, 2, 1, 3, 4).reshape(b, h // g, n_rows, g * dh)
    table_bytes = n_rows * g * dh * jnp.dtype(v.dtype).itemsize
    if remap is not None:
        table_bytes += remap.shape[-1] * 4
    sp = None
    if scale is not None:
        sp = scale.reshape(b, h, dh).reshape(b, h // g, g * dh) \
            .astype(jnp.float32)
        table_bytes += g * dh * 4
    return DecodeStagedTable(v=vp, remap=remap, scale=sp, n_rows=n_rows,
                             head_pack=g, dh=dh, table_bytes=table_bytes)


def update_staged_rows(staged: DecodeStagedTable,
                       row_idx: jnp.ndarray,       # (B, U) int32 table rows
                       rows: jnp.ndarray,          # (B, U, H, Dh) new values
                       ) -> DecodeStagedTable:
    """Scatter re-projected rows into the staged decode layout IN PLACE
    (functionally): the streaming temporal-reuse path updates only the
    changed tiles' slots of one persistent staged table instead of
    re-running :func:`stage_decode_table` per frame. The row subset is
    re-packed exactly like the full staging ((B, U, H, Dh) ->
    per-group (B, n_groups, U, G·Dh)) and scattered along the row axis,
    so the staged block stays bit-identical to a fresh
    ``stage_decode_table`` of the updated table (parity-tested). The
    ``remap`` indirection is untouched — a tile update never changes the
    keep geometry (keep transitions trigger a full rebuild instead).
    ``rows`` must already be in the staged dtype: an int8 table only
    accepts int8 codes (quantized against the FROZEN table scale) —
    silently scattering f32 rows would corrupt the code space."""
    if rows.dtype != staged.v.dtype:
        raise TypeError(
            f"update_staged_rows: rows dtype {rows.dtype} does not match "
            f"the staged table dtype {staged.v.dtype}; quantize rows "
            f"against the frozen table scale (int8 tables) or rebuild "
            f"the staging if the table dtype changed")
    b, u, h, dh = rows.shape
    g = staged.head_pack
    n_groups = staged.v.shape[1]
    packed = rows.reshape(b, u, n_groups, g * dh).transpose(0, 2, 1, 3)
    bidx = jnp.arange(b)[:, None, None]
    gidx = jnp.arange(n_groups)[None, :, None]
    new_v = staged.v.at[bidx, gidx, row_idx[:, None, :]].set(packed)
    return dataclasses.replace(staged, v=new_v)


# --------------------------------------------------------------------------
# kernel body — one (batch, head-group, query-tile, layer) grid step
# --------------------------------------------------------------------------

def _make_decode_kernel(head_pack: int, dh: int, use_remap: bool,
                        use_scale: bool):
    """Kernel for grid (B, H/G, T_q, L); the staged table block is indexed
    by (batch, head-group) only, so Pallas keeps it resident across the
    whole (query-tile × layer) sweep — staged once per (b, head-group).
    With ``use_scale`` the staged rows are int8 codes and the group's
    (G·Dh,) f32 scale row rides in as one extra operand: 4 one-byte
    corner loads per point plus one scale row, dequantized in-register
    after aggregation."""
    def kernel(*refs):
        x_ref, y_ref, st_ref, wl_ref, hl_ref, p_ref = refs[:6]
        refs = refs[6:]
        remap = None
        if use_remap:
            remap, refs = refs[0][0], refs[1:]
        v_ref = refs[0]
        scale = refs[1][0, 0] if use_scale else None   # (G*Dh,)
        o_ref = refs[-1]
        vp = v_ref[0, 0]                          # (N_rows, G*Dh) staged
        for j in range(head_pack):                # static unroll
            o_ref[0, 0, :, j, :] = _eq4_sample_agg(
                x_ref[0, 0, :, j, :], y_ref[0, 0, :, j, :],
                st_ref[0, 0, :, j, :], wl_ref[0, 0, :, j, :],
                hl_ref[0, 0, :, j, :], p_ref[0, 0, :, j, :],
                vp, remap=remap, lanes=(j * dh, dh), scale=scale)
    return kernel


def _pad_q(nq: int, tq: int, x, y, probs, st, wl, hl):
    """Pad the stacked (B, L, Nq, H, K) point axis to a tile multiple."""
    pad = (-nq) % tq
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        zf = lambda a: jnp.pad(a, widths)
        x, y, probs = zf(x), zf(y), zf(probs)
        st = zf(st)
        wl = jnp.pad(wl, widths, constant_values=1)
        hl = jnp.pad(hl, widths, constant_values=1)
    return pad, x, y, probs, st, wl, hl


@functools.partial(jax.jit, static_argnames=(
    "n_rows", "head_pack", "dh", "block_q", "interpret"))
def _decode_pallas_call(
    vp: jnp.ndarray,                     # (B, n_groups, N_rows, G*Dh)
    x_px: jnp.ndarray,                   # (B, L, Nq, H, K)
    y_px: jnp.ndarray,
    start: jnp.ndarray,                  # int32
    wl: jnp.ndarray,                     # int32
    hl: jnp.ndarray,                     # int32
    probs: jnp.ndarray,
    remap: Optional[jnp.ndarray],        # (B, N_pix) int32 or None
    scale: Optional[jnp.ndarray],        # (B, n_groups, G*Dh) f32 or None
    *,
    n_rows: int, head_pack: int, dh: int,
    block_q: int, interpret: bool,
) -> jnp.ndarray:
    b, n_groups, _, gdh = vp.shape
    _, n_layers, nq, h, k = x_px.shape
    g = head_pack
    tq = min(block_q, nq)
    pad, x_px, y_px, probs, start, wl, hl = _pad_q(
        nq, tq, x_px, y_px, probs, start, wl, hl)
    nq_p = nq + pad

    # layer axis INNERMOST: for one (b, head-group) the table block index
    # never changes across the (query-tile x layer) sweep, so the staged
    # block is fetched once per (batch, head-group) and revisited.
    grid = (b, n_groups, nq_p // tq, n_layers)
    pt = pl.BlockSpec((1, 1, tq, g, k),
                      lambda bi, gi, qi, li: (bi, li, qi, gi, 0))
    v_spec = pl.BlockSpec((1, 1, n_rows, gdh),
                          lambda bi, gi, qi, li: (bi, gi, 0, 0))
    out_spec = pl.BlockSpec((1, 1, tq, g, dh),
                            lambda bi, gi, qi, li: (bi, li, qi, gi, 0))
    out_dtype = vp.dtype if scale is None else probs.dtype
    out_shape = jax.ShapeDtypeStruct((b, n_layers, nq_p, h, dh), out_dtype)

    kernel = _make_decode_kernel(g, dh, use_remap=remap is not None,
                                 use_scale=scale is not None)
    in_specs = [pt, pt, pt, pt, pt, pt]
    inputs = [x_px, y_px, start, wl, hl, probs]
    name = "msgs_decode_persistent"
    if remap is not None:
        in_specs.append(pl.BlockSpec((1, remap.shape[1]),
                                     lambda bi, gi, qi, li: (bi, 0)))
        inputs.append(remap)
    in_specs.append(v_spec)
    inputs.append(vp)
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, 1, gdh),
                                     lambda bi, gi, qi, li: (bi, gi, 0)))
        inputs.append(scale)
        name += "_int8"
    out = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs,
        out_specs=out_spec, out_shape=out_shape,
        interpret=interpret, name=name,
    )(*inputs)
    return out[:, :, :nq] if pad else out


# --------------------------------------------------------------------------
# jnp reference — the custom_vjp backward and the parity oracle
# --------------------------------------------------------------------------

def msgs_decode_ref(vp, x_px, y_px, start, wl, hl, probs, remap,
                    scale=None, *, head_pack: int, dh: int) -> jnp.ndarray:
    """Pure-jnp reference over the STAGED layout (same flat corner-gather
    math as the ``jnp_gather`` backend). Used as the exact backward of
    the custom_vjp and by the parity tests. ``scale`` dequantizes an
    int8 staged table (per-channel, shared across rows) up front —
    mathematically identical to the kernel's dequant-after-aggregation."""
    from repro.msda.sampling import corner_data, flat_gather_heads
    b, n_groups, n_rows, gdh = vp.shape
    _, n_layers, nq, h, k = x_px.shape
    if scale is not None:
        vp = vp.astype(probs.dtype) * scale[:, :, None, :].astype(probs.dtype)
    # un-stage back to (B, N_rows, H, Dh) — a transpose, not a gather
    v4 = vp.reshape(b, n_groups, n_rows, head_pack, dh)
    v4 = v4.transpose(0, 2, 1, 3, 4).reshape(b, n_rows, h, dh)
    idx, wgt, valid = corner_data(x_px, y_px, wl, hl, start)
    idx = idx.reshape(b, n_layers * nq, h, k * 4)
    if remap is not None:
        bidx = jnp.arange(b).reshape(b, 1, 1, 1)
        idx = remap[bidx, idx]
    eff_w = (wgt * valid.astype(wgt.dtype) * probs[..., None]) \
        .reshape(b, n_layers * nq, h, k * 4)
    g = flat_gather_heads(v4, idx)
    out = jnp.sum(g * eff_w[..., None], axis=3)
    return out.reshape(b, n_layers, nq, h, dh)


class _DecodeStatic(NamedTuple):
    """Hashable static config for the custom_vjp entry point."""
    n_rows: int
    head_pack: int
    dh: int
    block_q: int
    interpret: bool


def _float0_zeros(x):
    return np.zeros(np.shape(x), dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _msgs_decode(static: _DecodeStatic, vp, x_px, y_px, start, wl, hl,
                 probs, remap, scale):
    return _decode_pallas_call(
        vp, x_px, y_px, start, wl, hl, probs, remap, scale,
        n_rows=static.n_rows, head_pack=static.head_pack, dh=static.dh,
        block_q=static.block_q, interpret=static.interpret)


def _msgs_decode_fwd(static, vp, x_px, y_px, start, wl, hl, probs, remap,
                     scale):
    out = _msgs_decode(static, vp, x_px, y_px, start, wl, hl, probs, remap,
                       scale)
    return out, (vp, x_px, y_px, start, wl, hl, probs, remap, scale)


def _msgs_decode_bwd(static, res, g_out):
    """Exact backward via the jnp reference (pallas_call itself has no AD
    rule): cotangents for the staged table, the sampling coordinates and
    the probabilities; float0 for the integer geometry. An int8 table's
    codes get a float0 cotangent (integers are non-differentiable — the
    straight-through path for training lives in the f32 fake-quant, not
    here) while the f32 scale gets a real gradient."""
    vp, x_px, y_px, start, wl, hl, probs, remap, scale = res
    if scale is None:
        _, vjp = jax.vjp(
            lambda v_, x_, y_, p_: msgs_decode_ref(
                v_, x_, y_, start, wl, hl, p_, remap,
                head_pack=static.head_pack, dh=static.dh),
            vp, x_px, y_px, probs)
        d_vp, d_x, d_y, d_p = vjp(g_out)
        d_s = None
    else:
        _, vjp = jax.vjp(
            lambda x_, y_, p_, s_: msgs_decode_ref(
                vp, x_, y_, start, wl, hl, p_, remap, s_,
                head_pack=static.head_pack, dh=static.dh),
            x_px, y_px, probs, scale)
        d_x, d_y, d_p, d_s = vjp(g_out)
        d_vp = _float0_zeros(vp)
    return (d_vp, d_x, d_y, _float0_zeros(start), _float0_zeros(wl),
            _float0_zeros(hl), d_p, None if remap is None
            else _float0_zeros(remap), d_s)


_msgs_decode.defvjp(_msgs_decode_fwd, _msgs_decode_bwd)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def msgs_decode_layers_pallas(
    staged: DecodeStagedTable,
    x_px: jnp.ndarray,                   # (B, L, Nq, H, K)
    y_px: jnp.ndarray,
    start: jnp.ndarray,
    wl: jnp.ndarray,
    hl: jnp.ndarray,
    probs: jnp.ndarray,
    *,
    block_q: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Stacked multi-layer persistent decode: ONE launch samples the
    staged table for all ``n_layers`` layers' points. Returns
    (B, n_layers, Nq, H, Dh). Differentiable (custom_vjp)."""
    static = _DecodeStatic(n_rows=staged.n_rows, head_pack=staged.head_pack,
                           dh=staged.dh, block_q=block_q,
                           interpret=interpret)
    return _msgs_decode(static, staged.v, x_px, y_px,
                        start.astype(jnp.int32), wl.astype(jnp.int32),
                        hl.astype(jnp.int32), probs, staged.remap,
                        staged.scale)


def msgs_decode_pallas(
    staged: DecodeStagedTable,
    x_px: jnp.ndarray,                   # (B, Nq, H, K)
    y_px: jnp.ndarray,
    start: jnp.ndarray,
    wl: jnp.ndarray,
    hl: jnp.ndarray,
    probs: jnp.ndarray,
    *,
    block_q: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-layer persistent decode launch (the decoder fast path: layer
    l's coordinates only exist after layer l-1, so the interleaved
    forward launches one layer at a time against the ONE staged table).
    Returns (B, Nq, H, Dh). Differentiable (custom_vjp)."""
    add_l = lambda a: a[:, None]
    out = msgs_decode_layers_pallas(
        staged, add_l(x_px), add_l(y_px), add_l(start), add_l(wl),
        add_l(hl), add_l(probs), block_q=block_q, interpret=interpret)
    return out[:, 0]
