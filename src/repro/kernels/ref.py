"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: tests sweep shapes/dtypes and
``assert_allclose`` kernel outputs (interpret=True on CPU) against these."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def msgs_fused_ref(
    v: jnp.ndarray,        # (B, N_rows, H, Dh)
    x_px: jnp.ndarray,     # (B, Nq, H, K) absolute pixel x
    y_px: jnp.ndarray,     # (B, Nq, H, K)
    start: jnp.ndarray,    # (B, Nq, H, K) int32 flat level start
    wl: jnp.ndarray,       # (B, Nq, H, K) int32 level width
    hl: jnp.ndarray,       # (B, Nq, H, K) int32 level height
    probs: jnp.ndarray,    # (B, Nq, H, K) attention probabilities
    remap: Optional[jnp.ndarray] = None,   # (B, N_pix) int32 pixel->row
) -> jnp.ndarray:
    """Bilinear grid-sample + probability-weighted aggregation. (B,Nq,H,Dh)."""
    b, n_rows, h, dh = v.shape
    x0 = jnp.floor(x_px)
    y0 = jnp.floor(y_px)
    t1 = x_px - x0
    t0 = y_px - y0

    def corner(dx, dy):
        cx = x0 + dx
        cy = y0 + dy
        valid = (cx >= 0) & (cx < wl) & (cy >= 0) & (cy < hl)
        idx = start + jnp.clip(cy, 0, hl - 1).astype(jnp.int32) * wl \
            + jnp.clip(cx, 0, wl - 1).astype(jnp.int32)
        if remap is not None:
            bidx = jnp.arange(b).reshape(b, 1, 1, 1)
            idx = remap[bidx, idx]
        # gather rows of v per (b, h)
        vv = v.transpose(0, 2, 1, 3).reshape(b * h, n_rows, dh)
        ii = idx.transpose(0, 2, 1, 3).reshape(b * h, -1)
        g = jnp.take_along_axis(vv, ii[..., None], axis=1)
        g = g.reshape(b, h, idx.shape[1], idx.shape[3], dh).transpose(0, 2, 1, 3, 4)
        return g * valid[..., None]

    n00 = corner(0, 0)
    n10 = corner(1, 0)
    n01 = corner(0, 1)
    n11 = corner(1, 1)
    w00 = ((1 - t1) * (1 - t0))[..., None]
    w10 = (t1 * (1 - t0))[..., None]
    w01 = ((1 - t1) * t0)[..., None]
    w11 = (t1 * t0)[..., None]
    s = n00 * w00 + n10 * w10 + n01 * w01 + n11 * w11      # (B,Nq,H,K,Dh)
    return jnp.sum(s * probs[..., None], axis=3)


def msgs_unfused_ref(v, x_px, y_px, start, wl, hl, probs, remap=None):
    """Identical math, but 'materializes' sampled values as a separate stage
    (the baseline the paper fuses away; benchmarks count its extra bytes)."""
    b, _, h, dh = v.shape
    x0 = jnp.floor(x_px)
    y0 = jnp.floor(y_px)
    t1 = x_px - x0
    t0 = y_px - y0

    def corner(dx, dy):
        cx = x0 + dx
        cy = y0 + dy
        valid = (cx >= 0) & (cx < wl) & (cy >= 0) & (cy < hl)
        idx = start + jnp.clip(cy, 0, hl - 1).astype(jnp.int32) * wl \
            + jnp.clip(cx, 0, wl - 1).astype(jnp.int32)
        if remap is not None:
            bidx = jnp.arange(b).reshape(b, 1, 1, 1)
            idx = remap[bidx, idx]
        vv = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], dh)
        ii = idx.transpose(0, 2, 1, 3).reshape(b * h, -1)
        g = jnp.take_along_axis(vv, ii[..., None], axis=1)
        g = g.reshape(b, h, idx.shape[1], idx.shape[3], dh).transpose(0, 2, 1, 3, 4)
        return g * valid[..., None]

    sampled = (corner(0, 0) * ((1 - t1) * (1 - t0))[..., None]
               + corner(1, 0) * (t1 * (1 - t0))[..., None]
               + corner(0, 1) * ((1 - t1) * t0)[..., None]
               + corner(1, 1) * (t1 * t0)[..., None])
    sampled = jax.lax.optimization_barrier(sampled)     # forced materialization
    return jnp.sum(sampled * probs[..., None], axis=3)


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
               w_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x (M,K) @ w (K,N); if w is int8, dequantize with per-column w_scale."""
    if w.dtype == jnp.int8:
        w = w.astype(jnp.float32) * w_scale
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def flash_decode_ref(q, k, v, valid):
    """Decode attention oracle. q (B,Hq,Dh); k/v (B,W,Hkv,Dh); valid (B,W)."""
    b, hq, dh = q.shape
    hkv = k.shape[2]
    n_rep = max(1, hq // hkv)
    import numpy as _np
    hmap = _np.minimum(_np.arange(hq) // n_rep, hkv - 1)
    kq = k[:, :, hmap, :]
    vq = v[:, :, hmap, :]
    s = jnp.einsum("bhd,bwhd->bhw", q, kq).astype(jnp.float32) / (dh ** 0.5)
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhw,bwhd->bhd", p, vq.astype(jnp.float32)
                      ).astype(q.dtype)
