"""Pallas TPU kernel: GQA flash-decode (one query token vs a long KV cache).

The LM-side serving hot-spot: decode_32k/long_500k cells are memory-bound on
cache reads (EXPERIMENTS.md §Roofline), so the kernel's job is to stream
K/V through VMEM exactly once at full HBM bandwidth with the softmax fused
(online max/sum — no score round-trip). Grid: (batch, kv-chunks); the chunk
axis is SEQUENTIAL and accumulates the online-softmax state in VMEM scratch.

Layout notes for TPU: per (batch, chunk) step the kernel touches
(C, Hkv·Dh) K/V tiles — C is the sublane dim (multiple of 8), Hkv·Dh the
lane dim (multiple of 128 for GQA configs with Dh=128). Per-position
validity (ring-buffer slots, sliding windows) rides a precomputed mask so
the kernel is oblivious to cache policy."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, m_ref, o_ref, acc_ref, mx_ref, den_ref,
            *, scale: float, n_rep: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mx_ref[...] = jnp.full_like(mx_ref, -1e30)
        den_ref[...] = jnp.zeros_like(den_ref)

    q = q_ref[0, :, :]                       # (Hq, Dh)
    k = k_ref[0, :, :, :]                    # (C, Hkv, Dh)
    v = v_ref[0, :, :, :]
    valid = m_ref[0, :]                      # (C,)

    hq = q.shape[0]
    c_len, hkv, dh = k.shape
    # GQA: repeat kv heads to q heads (broadcast-reshape — a gather with a
    # captured index table is not allowed inside a Pallas kernel)
    def rep(t):
        t = jnp.broadcast_to(t[:, :, None, :], (c_len, hkv, n_rep, dh))
        return t.reshape(c_len, hkv * n_rep, dh)[:, :hq]
    kq = rep(k)                              # (C, Hq, Dh)
    vq = rep(v)

    s = jnp.einsum("hd,chd->hc", q, kq).astype(jnp.float32) * scale
    s = jnp.where(valid[None, :], s, -1e30)  # (Hq, C)

    m_prev = mx_ref[...]                     # (Hq, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
    p = jnp.exp(s - m_new)                   # (Hq, C)
    corr = jnp.exp(m_prev - m_new)           # (Hq, 1)
    den_ref[...] = den_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr \
        + jnp.einsum("hc,chd->hd", p, vq.astype(jnp.float32))
    mx_ref[...] = m_new

    @pl.when(ci == pl.num_programs(1) - 1)
    def _done():
        o_ref[0, :, :] = (acc_ref[...] / jnp.maximum(den_ref[...], 1e-20)
                          ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def flash_decode_pallas(
    q: jnp.ndarray,       # (B, Hq, Dh) — one new token per sequence
    k: jnp.ndarray,       # (B, W, Hkv, Dh) cache
    v: jnp.ndarray,       # (B, W, Hkv, Dh)
    valid: jnp.ndarray,   # (B, W) bool — slot validity (causality/window)
    *,
    chunk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, dh = q.shape
    _, w, hkv, _ = k.shape
    n_rep = max(1, -(-hq // hkv))            # ceil: covers hq % hkv != 0
    assert hkv * n_rep >= hq, (hq, hkv)
    c = min(chunk, w)
    pad = (-w) % c
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    wp = w + pad
    grid = (b, wp // c)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / float(np.sqrt(dh)),
                          n_rep=n_rep),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hq, dh), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, c, hkv, dh), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, c, hkv, dh), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, c), lambda bi, ci: (bi, ci)),
        ],
        out_specs=pl.BlockSpec((1, hq, dh), lambda bi, ci: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((hq, dh), jnp.float32),
                        pltpu.VMEM((hq, 1), jnp.float32),
                        pltpu.VMEM((hq, 1), jnp.float32)],
        interpret=interpret, name="flash_decode",
    )(q, k, v, valid)
    return out
