"""Pallas TPU kernels: windowed MSGS — fmap reuse via bounded ranges (C3+C7).

DEFA bounds sampling offsets per level (range-narrowing) so only a bounded
window of the fmap around a query tile's reference points can ever be
touched; neighbouring tiles' windows overlap and the overlap is reused
on-chip (paper Fig. 4).

``msgs_windowed_msp_pallas`` — the **multi-scale-parallel** kernel (paper
C5 at the launch level): ONE ``pallas_call`` whose grid spans

    (batch x head-group x query-tile)

with the sampled-level axis unrolled *inside* each grid step. Every step
stages all L range-narrowed level windows into VMEM at once — each level
gets its own statically-sized BlockSpec window, so the big level's
window never inflates the small levels' staging (a level axis in the
grid would force one uniform window extent on every level). The L
partial sums accumulate in registers and the output block is written
once — cross-level aggregation is fused in-kernel instead of
materialized as L HBM-sized accumulators, and the co-resident level
windows are the VMEM analogue of DEFA's inter-level parallel PE groups.
The kernel is **FWP-compact-native**: when the value table is compacted,
each level window is a *slot* window of the compact table (slots are
raster-ordered per level, so a pixel window maps to one contiguous slot
range located by ``searchsorted(keep_idx, window_start)`` and bounded
statically by ``min(window_pixels, level_capacity)``), and the corner
gather goes through a windowed slice of the ``pix2slot`` indirection —
the densified (B, N_in, H, Dh) table is never built. Dynamic window
starts ride in as scalar-prefetch arguments so the BlockSpec index maps
can DMA the right slab.

(The first generation — ``msgs_windowed_pallas``, one launch per
(query-level x sampled-level) pair — served its one release as the
``pallas_windowed_loop`` numeric diff target and is deleted; the parity
suite now diffs the multi-scale-parallel kernel against the ``jnp_gather``
oracle directly.)

The per-tile windows above derive from raster query POSITION (tile t
covers queries [t*tile, (t+1)*tile) of the raster encoder order), which
is why the backend registers ``raster_only=True``: cache-local query
ordering (``repro/msda/ordering.py``) must not permute the queries fed
to this kernel, and the attention pass gates it to the identity path.
The ordering layer's measured per-tile accounting
(``plan.with_measured_tile_window``) uses the same window geometry to
size what a permutation-aware decode tile would stage.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ==========================================================================
# Static window geometry for the multi-scale-parallel kernel
# ==========================================================================

class WindowGeometry(NamedTuple):
    """Static (numpy) per-(tile, sampled-level) window plan.

    Tiles partition the *padded* raster query axis level by level (tiles
    never straddle a query-level boundary, so every tile has one static
    reference-row span). All arrays are host-side numpy: the geometry is
    resolved once per (level_shapes, ranges, tile_q) and closed over by
    the jit'd kernel wrapper."""
    level_shapes: Tuple[Tuple[int, int], ...]
    level_starts: Tuple[int, ...]     # flat start of each level
    tile_q: int                       # uniform query-tile size
    n_tiles: int                      # total tiles across query levels
    nq_padded: int                    # tile_q * n_tiles
    pad_offsets: Tuple[int, ...]      # per query level: start in padded axis
    tile_qlevel: np.ndarray           # (T,) query level of each tile
    pix_lo: np.ndarray                # (T, L) natural flat-pixel window start
    win_pix: np.ndarray               # (T, L) pixel-window size (rows * w_l)
    w_pix_levels: Tuple[int, ...]     # per sampled level: staged pixel
    #   window (max over tiles) — the static BlockSpec extent of level l
    pstart: np.ndarray                # (T, L) pix_lo clipped per level so a
    #   w_pix_levels[l] window always stays inside the flat table
    n_in: int

    def slot_windows(self, caps: Sequence[int]) -> Tuple[int, ...]:
        """Per-level compact-table slot windows: a pixel window of
        ``w_pix_levels[l]`` pixels holds at most ``min(that, cap_l)``
        slots (slots are raster-ordered per level)."""
        return tuple(min(w, int(c))
                     for w, c in zip(self.w_pix_levels, caps))

    def staged_bytes(self, lanes: int, itemsize: int,
                     caps: Optional[Sequence[int]] = None) -> int:
        """Value-window VMEM staged per grid step (all L level windows
        are co-resident). With ``caps`` (FWP-compact): the slot windows
        of the compacted table plus the int32 ``pix2slot`` slices. The
        single source of truth for plan accounting and benchmarks."""
        if caps is None:
            return sum(self.w_pix_levels) * lanes * itemsize
        return (sum(self.slot_windows(caps)) * lanes * itemsize
                + sum(self.w_pix_levels) * 4)


@functools.lru_cache(maxsize=64)
def window_geometry(level_shapes: Tuple[Tuple[int, int], ...],
                    ranges: Tuple[float, ...],
                    tile_q: int) -> WindowGeometry:
    """Resolve the static window plan.

    For tile t (query level ql, reference rows [qr0, qr1]) sampling level
    sl, the touched rows are bounded by the pixel-centre reference mapping
    y = (r + 0.5) / h_ql * h_sl - 0.5 plus the range-narrowing bound
    R_sl, one bilinear-corner row, and one row of quantization margin.

    Note the static extents are maxima over ALL tiles: a coarse query
    level's tile spans many of its rows, so its references cover most of
    the image and its fine-level windows approach the whole level. The
    fine (large) query levels hold the vast majority of tiles and keep
    tight windows; under FWP-compact every extent is additionally
    capacity-bounded via :meth:`WindowGeometry.slot_windows`."""
    starts = np.concatenate(
        [[0], np.cumsum([h * w for h, w in level_shapes])[:-1]]).astype(np.int64)
    n_in = int(sum(h * w for h, w in level_shapes))
    n_l = len(level_shapes)

    tiles = []                       # (ql, first query row, last query row)
    pad_offsets = []
    off = 0
    for ql, (h, w) in enumerate(level_shapes):
        pad_offsets.append(off)
        n = h * w
        for i in range(0, n, tile_q):
            qr0 = i // w
            qr1 = (min(i + tile_q, n) - 1) // w
            tiles.append((ql, qr0, qr1))
        off += tile_q * math.ceil(n / tile_q)
    n_tiles = len(tiles)

    pix_lo = np.zeros((n_tiles, n_l), np.int64)
    win_pix = np.zeros((n_tiles, n_l), np.int64)
    for t, (ql, qr0, qr1) in enumerate(tiles):
        h_ql = level_shapes[ql][0]
        for sl, (h_sl, w_sl) in enumerate(level_shapes):
            r_bound = float(ranges[sl])
            ymin = (qr0 + 0.5) / h_ql * h_sl - 0.5 - r_bound - 1.0
            ymax = (qr1 + 0.5) / h_ql * h_sl - 0.5 + r_bound + 1.0
            r0 = max(0, int(math.floor(ymin)))
            r1 = min(h_sl - 1, int(math.floor(ymax)) + 1)
            pix_lo[t, sl] = starts[sl] + r0 * w_sl
            win_pix[t, sl] = (r1 - r0 + 1) * w_sl
    w_pix_levels = tuple(int(w) for w in win_pix.max(axis=0))
    pstart = np.stack(
        [np.clip(pix_lo[:, l], 0, n_in - w_pix_levels[l])
         for l in range(n_l)], axis=1)
    return WindowGeometry(
        level_shapes=level_shapes, level_starts=tuple(int(s) for s in starts),
        tile_q=tile_q, n_tiles=n_tiles,
        nq_padded=tile_q * n_tiles, pad_offsets=tuple(pad_offsets),
        tile_qlevel=np.asarray([t[0] for t in tiles], np.int64),
        pix_lo=pix_lo, win_pix=win_pix, w_pix_levels=w_pix_levels,
        pstart=pstart.astype(np.int32), n_in=n_in)


def repack_queries(geo: WindowGeometry, arr: jnp.ndarray,
                   fill=0) -> jnp.ndarray:
    """Re-lay a raster-ordered (B, Nq, ...) per-query array into the
    tile-packed padded layout (B, nq_padded, ...)."""
    parts = []
    for ql, (h, w) in enumerate(geo.level_shapes):
        n = h * w
        seg = arr[:, geo.level_starts[ql]:geo.level_starts[ql] + n]
        pad = geo.tile_q * math.ceil(n / geo.tile_q) - n
        if pad:
            widths = [(0, 0), (0, pad)] + [(0, 0)] * (arr.ndim - 2)
            seg = jnp.pad(seg, widths, constant_values=fill)
        parts.append(seg)
    return jnp.concatenate(parts, axis=1)


def unpack_queries(geo: WindowGeometry, arr: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`repack_queries` (drops the per-level padding)."""
    parts = []
    for ql, (h, w) in enumerate(geo.level_shapes):
        off = geo.pad_offsets[ql]
        parts.append(arr[:, off:off + h * w])
    return jnp.concatenate(parts, axis=1)


# ==========================================================================
# Multi-scale-parallel windowed kernel (single launch, fused aggregation)
# ==========================================================================

def _make_msp_kernel(geo: WindowGeometry, w_rows_v: Tuple[int, ...],
                     head_pack: int, dh: int, use_remap: bool,
                     use_scale: bool = False):
    """Kernel body for grid (B, H/G, T); sampled levels unrolled in-body.

    Refs (after the scalar-prefetch window starts): x, y, level, probs
    point blocks (1, TQ, G, K); per level an optional remap window
    (1, w_pix_levels[l]) and a value window (1, w_rows_v[l], G, Dh);
    with ``use_scale`` the group's (1, 1, G, Dh) f32 dequant scale block;
    output block (1, TQ, G, Dh). All L level windows are resident in the
    same grid step — the VMEM analogue of DEFA's inter-level parallel PE
    groups — and their partial sums accumulate in registers, so level
    aggregation is fused with no HBM round-trip and no output revisiting.
    Int8 windows gather 1-byte codes, cast to the accumulator dtype
    before Eq. 4 (corner differences overflow int8), and the scale
    multiplies the accumulated sum ONCE at the end — exact, because the
    scale is shared across rows."""
    n_l = len(geo.level_shapes)

    def kernel(*refs):
        if use_remap:
            vstart_ref, pstart_ref = refs[0], refs[1]
            x_ref, y_ref, lvl_ref, p_ref = refs[2:6]
            r_refs = refs[6:6 + n_l]
            v_refs = refs[6 + n_l:6 + 2 * n_l]
        else:
            vstart_ref = refs[0]
            x_ref, y_ref, lvl_ref, p_ref = refs[1:5]
            v_refs = refs[5:5 + n_l]
        s_ref = refs[-2] if use_scale else None
        o_ref = refs[-1]
        b = pl.program_id(0)
        t = pl.program_id(2)

        x = x_ref[0]                                     # (TQ, G, K)
        y = y_ref[0]
        lvlp = lvl_ref[0]
        probs = p_ref[0]
        gid = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

        x0 = jnp.floor(x)
        y0 = jnp.floor(y)
        t1 = (x - x0)[..., None]
        t0 = (y - y0)[..., None]
        x0i = x0.astype(jnp.int32)
        y0i = y0.astype(jnp.int32)

        acc = jnp.zeros(x.shape[:2] + (dh,), o_ref.dtype)
        for l, (h_l, w_l) in enumerate(geo.level_shapes):
            st_l = geo.level_starts[l]
            wv = w_rows_v[l]
            wp = geo.w_pix_levels[l]
            # The whole head group is processed vectorized: the packed
            # level window is viewed as (wv * G, Dh) and each head's
            # corner gather addresses row*G + head, so one flat take
            # serves all G heads with no per-head lane slicing.
            v3 = v_refs[l][0].reshape(wv * head_pack, dh)
            on = lvlp == l                               # point on level l
            if use_remap:
                r2 = r_refs[l][0]
                s_lo = vstart_ref[b, t, l]
                p_lo = pstart_ref[t, l]
            else:
                s_lo = vstart_ref[t, l]

            def corner(dx, dy):
                cx = x0i + dx
                cy = y0i + dy
                valid = on & (cx >= 0) & (cx < w_l) & (cy >= 0) & (cy < h_l)
                pix = (st_l + jnp.clip(cy, 0, h_l - 1) * w_l
                       + jnp.clip(cx, 0, w_l - 1))
                if use_remap:
                    lpix = pix - p_lo
                    valid &= (lpix >= 0) & (lpix < wp)
                    lpix = jnp.clip(lpix, 0, wp - 1)
                    slot = jnp.take(r2, lpix.reshape(-1)).reshape(lpix.shape)
                    lrow = slot - s_lo                   # slot-window local
                else:
                    lrow = pix - s_lo                    # pixel-window local
                valid &= (lrow >= 0) & (lrow < wv)
                idx = jnp.clip(lrow, 0, wv - 1) * head_pack + gid
                gat = jnp.take(v3, idx.reshape(-1), axis=0).reshape(
                    idx.shape + (dh,))
                if use_scale:
                    gat = gat.astype(o_ref.dtype)
                return gat * valid[..., None]

            n0 = corner(0, 0)
            n1 = corner(1, 0)
            n2 = corner(0, 1)
            n3 = corner(1, 1)
            # Eq. 4 — three multiplies by the fractional coordinates:
            s = (n0 + (n2 - n0) * t0
                 + ((n1 - n0) + (n3 - n2 - n1 + n0) * t0) * t1)
            acc += jnp.sum(s * probs[..., None], axis=2)
        if use_scale:
            acc = acc * s_ref[0, 0]              # (G, Dh) broadcasts
        o_ref[0] = acc
    return kernel


def _v_index(l: int, g: int, use_remap: bool):
    if use_remap:
        return lambda bi, gi, ti, vs, ps: (bi, vs[bi, ti, l], gi * g, 0)
    return lambda bi, gi, ti, vs: (bi, vs[ti, l], gi * g, 0)


def _r_index(l: int):
    return lambda bi, gi, ti, vs, ps: (bi, ps[ti, l])


def _elem_spec(shape: Tuple[int, ...], index_map) -> pl.BlockSpec:
    """Element-offset window BlockSpec across jax versions: every dim is
    element-indexed (the index maps return element offsets for all dims,
    e.g. ``gi * g`` for the head axis) — ``pl.Element`` per dim on
    jax >= 0.5, ``indexing_mode=pl.Unblocked()`` before."""
    if hasattr(pl, "Element"):           # jax >= 0.5 spelling
        return pl.BlockSpec(tuple(pl.Element(s) for s in shape), index_map)
    return pl.BlockSpec(shape, index_map, indexing_mode=pl.Unblocked())


@functools.partial(jax.jit, static_argnames=(
    "level_shapes", "ranges", "tile_q", "head_pack", "caps", "interpret"))
def msgs_windowed_msp_pallas(
    v: jnp.ndarray,          # (B, N_rows, H, Dh) value table (maybe compact)
    x_px: jnp.ndarray,       # (B, Nq, H, K) absolute pixel x in own level
    y_px: jnp.ndarray,       # (B, Nq, H, K)
    lvl_of_pt: jnp.ndarray,  # (B, Nq, H, K) int32 level index per point
    probs: jnp.ndarray,      # (B, Nq, H, K)
    remap: Optional[jnp.ndarray] = None,      # (B, N_in) pix -> slot
    keep_idx: Optional[jnp.ndarray] = None,   # (B, cap) slot -> pix, sorted
    scale: Optional[jnp.ndarray] = None,      # (B, n_groups, G, Dh) f32
    *,
    level_shapes: Tuple[Tuple[int, int], ...],
    ranges: Tuple[float, ...],               # per-level |offset| bound (px)
    tile_q: int = 128,
    head_pack: int = 1,
    caps: Optional[Tuple[int, ...]] = None,  # compact per-level capacities
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-launch multi-scale-parallel windowed MSGS + fused aggregation.

    Queries must be raster-ordered encoder queries (Nq == N_in). Returns
    (B, Nq, H, Dh). ``remap``/``keep_idx``/``caps`` together enable the
    FWP-compact-native path (v is the compacted table + sentinel row)."""
    b, n_rows, h, dh = v.shape
    nq = x_px.shape[1]
    k = x_px.shape[-1]
    use_remap = remap is not None
    assert h % head_pack == 0, (h, head_pack)
    g = head_pack
    n_groups = h // g

    geo = window_geometry(level_shapes, ranges, tile_q)
    assert nq == geo.n_in, (nq, geo.n_in)
    n_l = len(level_shapes)

    pack = lambda a, fill=0: repack_queries(geo, a, fill=fill)
    x_px, y_px, probs = pack(x_px), pack(y_px), pack(probs)
    lvl_of_pt = pack(lvl_of_pt, -1)          # padding matches no level

    if use_remap:
        # Window of the COMPACT table: first slot at-or-after the pixel
        # window start (slots are raster-ordered per level), clipped so
        # the static per-level slot window always fits the table.
        # Clipping only moves the start down, which keeps every kept
        # slot of the pixel window covered.
        w_rows_v = tuple(min(w, n_rows) for w in (
            geo.slot_windows(caps) if caps is not None else geo.w_pix_levels))
        pix_lo = jnp.asarray(geo.pix_lo.reshape(-1), jnp.int32)
        vstart = jax.vmap(lambda ki: jnp.searchsorted(ki, pix_lo))(keep_idx)
        vstart = vstart.reshape(b, geo.n_tiles, n_l)
        hi = jnp.asarray([n_rows - wv for wv in w_rows_v], jnp.int32)
        vstart = jnp.clip(vstart, 0, hi[None, None, :]).astype(jnp.int32)
        pstart = jnp.asarray(geo.pstart, jnp.int32)
        scalars = (vstart, pstart)
    else:
        w_rows_v = geo.w_pix_levels
        vstart = jnp.asarray(geo.pstart, jnp.int32)      # pixel == row space
        scalars = (vstart,)

    grid = (b, n_groups, geo.n_tiles)
    pt = pl.BlockSpec((1, geo.tile_q, g, k),
                      lambda bi, gi, ti, *s: (bi, ti, gi, 0))
    v_specs = [_elem_spec((1, w_rows_v[l], g, dh), _v_index(l, g, use_remap))
               for l in range(n_l)]
    if use_remap:
        r_specs = [_elem_spec((1, geo.w_pix_levels[l]), _r_index(l))
                   for l in range(n_l)]
        in_specs = [pt, pt, pt, pt] + r_specs + v_specs
        inputs = ((x_px, y_px, lvl_of_pt, probs) + (remap,) * n_l
                  + (v,) * n_l)
    else:
        in_specs = [pt, pt, pt, pt] + v_specs
        inputs = (x_px, y_px, lvl_of_pt, probs) + (v,) * n_l
    name = "msgs_windowed_msp"
    if scale is not None:
        in_specs = in_specs + [pl.BlockSpec(
            (1, 1, g, dh), lambda bi, gi, ti, *s: (bi, gi, 0, 0))]
        inputs = inputs + (scale,)
        name += "_int8"
    out_spec = pl.BlockSpec((1, geo.tile_q, g, dh),
                            lambda bi, gi, ti, *s: (bi, ti, gi, 0))
    out_dtype = v.dtype if scale is None else probs.dtype

    kernel = _make_msp_kernel(geo, w_rows_v, g, dh, use_remap,
                              use_scale=scale is not None)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(scalars), grid=grid,
            in_specs=in_specs, out_specs=out_spec),
        out_shape=jax.ShapeDtypeStruct((b, geo.nq_padded, h, dh), out_dtype),
        interpret=interpret, name=name,
    )(*scalars, *inputs)
    return unpack_queries(geo, out)

