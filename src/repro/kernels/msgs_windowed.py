"""Pallas TPU kernel: windowed MSGS — fmap reuse via bounded ranges (C3+C7).

DEFA bounds sampling offsets per level (range-narrowing) so only a bounded
window of the fmap around a query tile's reference points can ever be
touched; neighbouring tiles' windows overlap and the overlap is reused
on-chip (paper Fig. 4). On TPU this becomes a BlockSpec with an
*element-offset* window (``pl.Element`` on jax >= 0.5,
``indexing_mode=pl.Unblocked`` before): for query tile t the kernel
receives fmap rows [row0(t) − R, row0(t) + tile_rows + R]; Pallas's
double-buffered pipeline fetches each window once and VMEM holds only the
window, not the level — the VMEM working set drops from H·W·Dh to
window·W·Dh (measured in benchmarks/fmap_reuse.py).

Single-level, single-(batch·head) view: callers vmap over batch/head and
invoke per (query-level × sampled-level) pair; queries are raster-ordered
over their level (encoder queries are the fmap pixels themselves).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(tile_q: int, w_query: int, halo: int, window_rows: int,
                 h_level: int, rows_scale: float):
    def kernel(x_ref, y_ref, p_ref, v_ref, o_ref):
        t = pl.program_id(0)
        # first reference row of this query tile (query-level rows), scaled
        # to the sampled level
        q_row0 = (t * tile_q) // w_query
        row0 = jnp.clip((q_row0 * rows_scale).astype(jnp.int32) - halo,
                        0, max(0, h_level - window_rows))
        w_fmap = v_ref.shape[1]           # sampled level's width (!= w_query
        #                                   when query and fmap levels differ)
        v = v_ref[...].reshape(window_rows * w_fmap, v_ref.shape[2])
        x = x_ref[...]                    # (TQ, K) absolute pixel coords
        y = y_ref[...]
        probs = p_ref[...]

        x0 = jnp.floor(x)
        y0 = jnp.floor(y)
        t1 = (x - x0)[..., None]
        t0 = (y - y0)[..., None]
        x0i = x0.astype(jnp.int32)
        y0i = y0.astype(jnp.int32)

        def corner(dx, dy):
            cx = x0i + dx
            cy = y0i + dy
            valid = ((cx >= 0) & (cx < w_fmap) & (cy >= 0) & (cy < h_level)
                     & (cy >= row0) & (cy < row0 + window_rows))
            ly = jnp.clip(cy - row0, 0, window_rows - 1)
            idx = ly * w_fmap + jnp.clip(cx, 0, w_fmap - 1)
            g = jnp.take(v, idx.reshape(-1), axis=0).reshape(idx.shape + (v.shape[-1],))
            return g * valid[..., None]

        n0 = corner(0, 0)
        n1 = corner(1, 0)
        n2 = corner(0, 1)
        n3 = corner(1, 1)
        s = n0 + (n2 - n0) * t0 + ((n1 - n0) + (n3 - n2 - n1 + n0) * t0) * t1
        o_ref[...] = jnp.sum(s * probs[..., None], axis=1)
    return kernel


@functools.partial(jax.jit, static_argnames=(
    "query_level_width", "halo", "block_q", "interpret"))
def msgs_windowed_pallas(
    v2d: jnp.ndarray,       # (Hl, Wl, Dh) — the sampled level
    x_px: jnp.ndarray,      # (Nq, K) absolute pixel x (|offset| ≤ halo)
    y_px: jnp.ndarray,      # (Nq, K)
    probs: jnp.ndarray,     # (Nq, K)
    *,
    query_level_width: int,          # Wq of the level the queries live on
    halo: int,                        # R: the range-narrowing bound (pixels)
    block_q: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    hl, wl, dh = v2d.shape
    nq, k = x_px.shape
    tq = min(block_q, nq)
    pad = (-nq) % tq
    if pad:
        x_px = jnp.pad(x_px, ((0, pad), (0, 0)))
        y_px = jnp.pad(y_px, ((0, pad), (0, 0)))
        probs = jnp.pad(probs, ((0, pad), (0, 0)))
    nq_p = nq + pad

    # rows of the sampled level per query row (cross-level scaling)
    h_query = max(1, (nq + query_level_width - 1) // query_level_width)
    rows_scale = hl / h_query
    tile_rows = math.ceil(tq / query_level_width * rows_scale) + 1
    window_rows = min(hl, tile_rows + 2 * halo + 2)

    grid = (nq_p // tq,)
    tile_q = tq

    def v_index(t):
        q_row0 = (t * tile_q) // query_level_width
        row0 = jnp.clip((q_row0 * rows_scale).astype(jnp.int32) - halo,
                        0, max(0, hl - window_rows))
        return (row0, 0, 0)

    if hasattr(pl, "Element"):           # jax >= 0.5 spelling
        v_spec = pl.BlockSpec((pl.Element(window_rows), wl, dh), v_index)
    else:                                # 0.4.x spelling
        v_spec = pl.BlockSpec((window_rows, wl, dh), v_index,
                              indexing_mode=pl.Unblocked())
    pt_spec = pl.BlockSpec((tq, k), lambda t: (t, 0))
    out_spec = pl.BlockSpec((tq, dh), lambda t: (t, 0))

    kernel = _make_kernel(tq, query_level_width, halo, window_rows, hl, rows_scale)
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[pt_spec, pt_spec, pt_spec, v_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((nq_p, dh), v2d.dtype),
        interpret=interpret, name="msgs_windowed",
    )(x_px, y_px, probs, v2d)
    return out[:nq] if pad else out
