"""Terminal dashboard: tail a live engine's JSONL event log and render
queue depth, rps, per-span latency, staged-bytes and plan provenance.

Run against a live engine (point ``REPRO_OBS_JSONL`` at a file, start
the engine, then)::

    python -m repro.obs.dashboard --jsonl /tmp/obs.jsonl --follow

or render a finished log once (``--once`` is the default).  Pure
functions (``build_model`` / ``render_dashboard``) are kept separate
from the tailing loop so tests can feed synthetic events.
"""
from __future__ import annotations

import argparse
import collections
import json
import sys
import time
from typing import Dict, List, Optional

_SPAN_KEEP = 2048  # per-name durations retained for percentile estimates


def new_model() -> dict:
    return {
        "spans": collections.defaultdict(
            lambda: collections.deque(maxlen=_SPAN_KEEP)),
        "metrics": None,          # latest metrics snapshot event
        "metrics_prev": None,     # the one before (for rates)
        "plans": [],              # plan events in arrival order
        "t_first": None,
        "t_last": None,
        "events": 0,
    }


def feed_event(model: dict, ev: dict) -> None:
    t = ev.get("t")
    if isinstance(t, (int, float)):
        if model["t_first"] is None:
            model["t_first"] = t
        model["t_last"] = t
    model["events"] += 1
    etype = ev.get("type")
    if etype == "span_end":
        dur = ev.get("dur_s")
        name = ev.get("name")
        if name and isinstance(dur, (int, float)):
            model["spans"][name].append(float(dur))
    elif etype == "metrics":
        model["metrics_prev"] = model["metrics"]
        model["metrics"] = ev
    elif etype == "plan":
        model["plans"].append(ev)


def feed_lines(model: dict, lines) -> None:
    for raw in lines:
        raw = raw.strip()
        if not raw:
            continue
        try:
            feed_event(model, json.loads(raw))
        except json.JSONDecodeError:
            continue  # torn tail line mid-write; next poll completes it


def _pct(durs: List[float], q: float) -> float:
    if not durs:
        return float("nan")
    s = sorted(durs)
    return s[min(len(s) - 1, int(round(q * (len(s) - 1))))]


def _counter_values(snap: Optional[dict], name: str) -> List[dict]:
    if not snap:
        return []
    return (snap.get("data", {}).get("counters", {})
            .get(name, {}).get("values", []))


def _gauge_values(snap: Optional[dict], name: str) -> List[dict]:
    if not snap:
        return []
    return (snap.get("data", {}).get("gauges", {})
            .get(name, {}).get("values", []))


def _counter_total(snap: Optional[dict], name: str,
                   **match: str) -> float:
    tot = 0.0
    for v in _counter_values(snap, name):
        if all(v["labels"].get(k) == str(val) for k, val in match.items()):
            tot += v["value"]
    return tot


def render_dashboard(model: dict, width: int = 72) -> str:
    bar = "─" * (width - 2)
    out = [f"┌{bar}┐"]

    def row(text: str = "") -> None:
        out.append("│ " + text[:width - 4].ljust(width - 4) + " │")

    def section(title: str) -> None:
        out.append(f"├{bar}┤")
        row(title)

    snap = model["metrics"]
    elapsed = ((model["t_last"] - model["t_first"])
               if model["t_first"] is not None else 0.0)
    completed = _counter_total(snap, "serve_requests_total",
                               outcome="completed")
    # rate over the last metrics interval when it saw completions (live
    # view), else the whole-log average (finished logs end with flush
    # events whose interval completed nothing)
    prev = model["metrics_prev"]
    rps = completed / elapsed if elapsed > 0 else 0.0
    if prev is not None and snap is not None:
        dt = snap.get("t", 0.0) - prev.get("t", 0.0)
        dc = completed - _counter_total(prev, "serve_requests_total",
                                        outcome="completed")
        if dt > 0 and dc > 0:
            rps = dc / dt

    row(f"repro.obs dashboard — {model['events']} events, "
        f"{elapsed:.1f}s window")
    row(f"requests completed: {completed:.0f}   rps: {rps:.1f}")

    depths = _gauge_values(snap, "serve_queue_depth")
    if depths:
        section("queue depth (per bucket)")
        for v in depths:
            b = v["labels"].get("bucket", "?")
            n = int(v["value"])
            row(f"  bucket {b:>5}: {'█' * min(n, 40)}{n:>4}")

    if model["spans"]:
        section("latency by span (ms)        count      p50      p99")
        for name in sorted(model["spans"]):
            durs = list(model["spans"][name])
            row(f"  {name:<24} {len(durs):>8} {_pct(durs, .5)*1e3:>8.2f} "
                f"{_pct(durs, .99)*1e3:>8.2f}")

    staged = _counter_values(snap, "staged_bytes_total")
    frames_tot = _counter_total(snap, "stream_frames_total")
    if staged:
        section("staged bytes")
        for v in staged:
            mode = v["labels"].get("mode", "?")
            kb = v["value"] / 1024.0
            per = (f"  ({v['value']/frames_tot/1024.0:.1f} KB/frame)"
                   if mode == "incremental" and frames_tot else "")
            row(f"  mode={mode:<12} {kb:>10.1f} KB{per}")
        inc = _counter_total(snap, "stream_frames_total", mode="incremental")
        reb = _counter_total(snap, "stream_frames_total", mode="rebuild")
        if inc or reb:
            row(f"  incremental:rebuild frames = {inc:.0f}:{reb:.0f}")
        for v in _counter_values(snap, "stream_rebuilds_total"):
            row(f"  rebuild reason {v['labels'].get('reason', '?'):<16} "
                f"x{v['value']:.0f}")

    if model["plans"]:
        section("plans (budget provenance)")
        for ev in model["plans"][-6:]:
            p = ev.get("plan", {})
            where = ev.get("bucket", ev.get("engine", "?"))
            row(f"  [{where}] backend={p.get('backend', '?')} "
                f"budget={p.get('budget_source', '?')} "
                f"tdtype={p.get('table_dtype', '?')} "
                f"table={p.get('value_table_bytes', 0)/1024.0:.0f}KB")

    out.append(f"└{bar}┘")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jsonl", required=True, help="event log to tail")
    ap.add_argument("--follow", action="store_true",
                    help="keep tailing and re-rendering (default: once)")
    ap.add_argument("--interval", type=float, default=1.0)
    ap.add_argument("--width", type=int, default=72)
    args = ap.parse_args(argv)

    model = new_model()
    with open(args.jsonl) as f:
        feed_lines(model, f)
        if not args.follow:
            print(render_dashboard(model, width=args.width))
            return 0
        while True:
            sys.stdout.write("\x1b[2J\x1b[H")
            print(render_dashboard(model, width=args.width))
            time.sleep(args.interval)
            feed_lines(model, f)


if __name__ == "__main__":
    sys.exit(main())
