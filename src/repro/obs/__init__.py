"""Unified observability layer: metrics, request tracing, exporters.

Everything in this package is host-side Python — no jax imports on the
hot path, nothing traced.  Engines bump counters / open spans strictly
outside jit, so instrumentation can never introduce a retrace; the only
sanctioned in-trace touch point is a *trace-time* counter bump (the
compile-spy pattern), which executes once per compilation and costs
zero per executed step.
"""
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    default_registry,
)
from repro.obs.trace import NullTracer, Span, Tracer
from repro.obs.obs import Observability
from repro.obs.export import (
    json_snapshot,
    parse_prometheus_text,
    prometheus_text,
    write_json_snapshot,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRegistry",
    "NullTracer",
    "Observability",
    "Span",
    "Tracer",
    "default_registry",
    "json_snapshot",
    "parse_prometheus_text",
    "prometheus_text",
    "write_json_snapshot",
]
