"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

Design constraints (see ISSUE 10 / README "Observability"):

- **Host-side only.**  Nothing here imports jax; values are plain
  Python floats.  Instrumented engines call these strictly outside jit
  (or, for compile spies, at trace time), so the registry can never
  cause a retrace.
- **Lock-free hot path.**  ``inc`` / ``set`` / ``observe`` are plain
  dict/list mutations — atomic enough under the GIL for the
  single-writer-per-label-set pattern the engines follow (e.g. the
  request-latency histogram is only touched by the postproc worker
  thread).  Only metric *creation* takes a lock.
- **Fixed buckets.**  Histograms pre-declare their upper bounds; an
  observation is two list index bumps and two float adds.

Label sets are passed as keyword arguments and stored keyed by the
sorted ``(key, value)`` tuple, Prometheus-style::

    reg = MetricsRegistry()
    reg.counter("serve_requests_total").inc(bucket="64", outcome="completed")
    reg.value("serve_requests_total", bucket="64", outcome="completed")  # 1.0
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Default latency buckets (seconds): sub-ms through 10 s, roughly
# logarithmic — wide enough for CPU-interpret dry runs and real TPU.
DEFAULT_LATENCY_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

# Byte-count buckets: 1 KiB .. 1 GiB.
DEFAULT_BYTES_BUCKETS = tuple(float(1 << s) for s in range(10, 31, 2))


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing value, optionally labelled."""

    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment {amount}")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: str) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def total(self) -> float:
        """Sum across all label sets."""
        return float(sum(self._values.values()))

    def collect(self) -> List[dict]:
        return [{"labels": dict(k), "value": v}
                for k, v in sorted(self._values.items())]


class Gauge:
    """Last-write-wins value, optionally labelled."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: str) -> None:
        self._values[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels: str) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: str) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def collect(self) -> List[dict]:
        return [{"labels": dict(k), "value": v}
                for k, v in sorted(self._values.items())]


class _HistSeries:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int) -> None:
        # counts[i] observations <= bounds[i]; counts[-1] is +Inf overflow
        self.counts = [0] * (n_buckets + 1)
        self.sum = 0.0
        self.count = 0


class Histogram:
    """Fixed-bucket histogram (cumulative on export, Prometheus-style)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS) -> None:
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram {name}: buckets must be sorted "
                             f"and non-empty, got {buckets!r}")
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        self._series: Dict[LabelKey, _HistSeries] = {}

    def observe(self, value: float, **labels: str) -> None:
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series.setdefault(key, _HistSeries(len(self.buckets)))
        s.counts[bisect.bisect_left(self.buckets, value)] += 1
        s.sum += value
        s.count += 1

    def count(self, **labels: str) -> int:
        s = self._series.get(_label_key(labels))
        return s.count if s else 0

    def total_count(self) -> int:
        return sum(s.count for s in self._series.values())

    def sum_value(self, **labels: str) -> float:
        s = self._series.get(_label_key(labels))
        return s.sum if s else 0.0

    def quantile(self, q: float, **labels: str) -> Optional[float]:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the q-th observation); None when empty."""
        s = self._series.get(_label_key(labels))
        if not s or not s.count:
            return None
        rank = q * s.count
        cum = 0
        for i, c in enumerate(s.counts):
            cum += c
            if cum >= rank:
                return self.buckets[i] if i < len(self.buckets) else float("inf")
        return float("inf")

    def collect(self) -> List[dict]:
        out = []
        for key, s in sorted(self._series.items()):
            cum, cum_counts = 0, []
            for c in s.counts[:-1]:
                cum += c
                cum_counts.append(cum)
            out.append({"labels": dict(key), "count": s.count, "sum": s.sum,
                        "buckets": [[b, c] for b, c
                                    in zip(self.buckets, cum_counts)]})
        return out


class MetricsRegistry:
    """Named metric family store.  ``counter``/``gauge``/``histogram``
    get-or-create; ``snapshot`` renders everything to plain dicts."""

    enabled = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get_or_create(self, cls, name: str, help: str, **kwargs):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered "
                                f"as {type(m).__name__}")
            return m
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kwargs)
                self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def value(self, name: str, **labels: str) -> float:
        """Convenience: current value of a counter/gauge label set
        (0.0 when the metric or label set does not exist)."""
        m = self._metrics.get(name)
        if m is None or isinstance(m, Histogram):
            return 0.0
        return m.value(**labels)

    def snapshot(self) -> dict:
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in self.names():
            m = self._metrics[name]
            sec = {"counter": "counters", "gauge": "gauges",
                   "histogram": "histograms"}[m.kind]
            entry = {"help": m.help, "values": m.collect()}
            if m.kind == "histogram":
                entry["bucket_bounds"] = list(m.buckets)
            out[sec][name] = entry
        return out


class _NullMetric:
    """Accepts every Counter/Gauge/Histogram call and does nothing."""

    def __init__(self, name: str = "") -> None:
        self.name = name
        self.help = ""
        self.buckets = ()

    def inc(self, *a, **k): pass
    def dec(self, *a, **k): pass
    def set(self, *a, **k): pass
    def observe(self, *a, **k): pass
    def value(self, **labels): return 0.0
    def total(self): return 0.0
    def count(self, **labels): return 0
    def total_count(self): return 0
    def sum_value(self, **labels): return 0.0
    def quantile(self, q, **labels): return None
    def collect(self): return []


class NullRegistry(MetricsRegistry):
    """Same API as MetricsRegistry, zero work: the uninstrumented mode.

    Returned metrics swallow every update, so engine code carries no
    ``if obs.enabled`` branches on the hot path."""

    enabled = False

    def __init__(self) -> None:
        super().__init__()
        self._null = _NullMetric()

    def counter(self, name: str, help: str = "") -> Counter:  # type: ignore[override]
        return self._null  # type: ignore[return-value]

    def gauge(self, name: str, help: str = "") -> Gauge:  # type: ignore[override]
        return self._null  # type: ignore[return-value]

    def histogram(self, name: str, help: str = "", buckets=()):  # type: ignore[override]
        return self._null

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}


_default_registry: Optional[MetricsRegistry] = None
_default_lock = threading.Lock()


def default_registry() -> MetricsRegistry:
    """Process-wide shared registry.

    Engines default to their *own* registries (exact per-engine
    assertions); the shared one collects process-global trace-time
    events — e.g. ``msda_cache_build_traces_total`` bumped inside
    ``build_value_cache``'s traced body, where no per-engine handle can
    reach."""
    global _default_registry
    if _default_registry is None:
        with _default_lock:
            if _default_registry is None:
                _default_registry = MetricsRegistry()
    return _default_registry
