"""Per-request span tracing: bounded ring buffer + optional JSONL log.

A *span* is a named, timed interval attached to a request id.  The
serve engine opens spans across threads (``queue`` starts on the
submit thread, ``postproc`` ends on the worker thread), so ``start``
returns an opaque span id and ``end`` may be called from anywhere.
Single-thread scopes use the ``span(...)`` context manager, which also
carries the opt-in ``jax.profiler.TraceAnnotation`` bridge so spans
line up with XLA traces on real hardware.

All timestamps are ``time.perf_counter()`` — monotonic by contract.
``end`` asserts it: a negative-duration span raises ``ValueError``
instead of silently corrupting percentiles (callers may inject
explicit timestamps, e.g. replaying a log, which is where the check
earns its keep).

Event-log schema (one JSON object per line)::

    {"type": "span_start", "span": "t1-3", "name": "queue",
     "rid": 7, "t": 123.4, ...attrs}
    {"type": "span_end",   "span": "t1-3", "name": "queue",
     "rid": 7, "t": 123.9, "dur_s": 0.5, ...attrs}
    {"type": "plan" | "metrics" | ..., "t": 124.0, ...payload}

The validator (``python -m repro.obs.validate``) asserts every span in
a log is well-formed: paired start/end, non-negative duration.
"""
from __future__ import annotations

import collections
import contextlib
import itertools
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

_tracer_ids = itertools.count(1)


@dataclass
class Span:
    span_id: str
    name: str
    rid: Optional[object] = None
    t0: float = 0.0
    t1: Optional[float] = None
    attrs: dict = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else float("nan")

    def to_dict(self) -> dict:
        return {"span_id": self.span_id, "name": self.name, "rid": self.rid,
                "t0": self.t0, "t1": self.t1,
                "dur_s": self.duration_s, **self.attrs}


def _percentile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1, max(0, int(round(q * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


class Tracer:
    """Span recorder with a bounded ring buffer and optional JSONL sink."""

    enabled = True

    def __init__(self, capacity: int = 4096,
                 jsonl_path: Optional[str] = None,
                 xla_annotations: bool = False) -> None:
        self._prefix = f"t{next(_tracer_ids)}"
        self._seq = itertools.count(1)
        self._open: Dict[str, Span] = {}
        self._lock = threading.Lock()
        self.spans: Deque[Span] = collections.deque(maxlen=capacity)
        self.jsonl_path = jsonl_path
        self._sink = None
        self.xla_annotations = xla_annotations
        if jsonl_path:
            # line-buffered append: whole-line writes interleave safely
            # when several tracers in one process share a path
            self._sink = open(jsonl_path, "a", buffering=1)

    # -- raw event sink -------------------------------------------------
    def event(self, type: str, **fields) -> None:
        """Write an arbitrary event to the JSONL log (no-op without one)."""
        if self._sink is None:
            return
        rec = {"type": type, "t": time.perf_counter(), **fields}
        with self._lock:
            self._sink.write(json.dumps(rec, default=str) + "\n")

    # -- spans ----------------------------------------------------------
    def start(self, name: str, rid: Optional[object] = None,
              t: Optional[float] = None, **attrs) -> str:
        t0 = time.perf_counter() if t is None else t
        span_id = f"{self._prefix}-{next(self._seq)}"
        sp = Span(span_id, name, rid, t0, None, dict(attrs))
        with self._lock:
            self._open[span_id] = sp
            if self._sink is not None:
                self._sink.write(json.dumps(
                    {"type": "span_start", "span": span_id, "name": name,
                     "rid": rid, "t": t0, **attrs}, default=str) + "\n")
        return span_id

    def end(self, span_id: str, t: Optional[float] = None, **attrs) -> Span:
        t1 = time.perf_counter() if t is None else t
        with self._lock:
            sp = self._open.pop(span_id, None)
            if sp is None:
                raise KeyError(f"end() on unknown/already-ended span {span_id!r}")
            if t1 < sp.t0:
                # put it back so the failure is observable, then refuse
                self._open[span_id] = sp
                raise ValueError(
                    f"span {sp.name!r} ({span_id}): negative duration "
                    f"({t1 - sp.t0:.9f}s) — timestamps must come from "
                    f"time.perf_counter()")
            sp.t1 = t1
            sp.attrs.update(attrs)
            self.spans.append(sp)
            if self._sink is not None:
                self._sink.write(json.dumps(
                    {"type": "span_end", "span": span_id, "name": sp.name,
                     "rid": sp.rid, "t": t1, "dur_s": t1 - sp.t0,
                     **sp.attrs}, default=str) + "\n")
        return sp

    @contextlib.contextmanager
    def span(self, name: str, rid: Optional[object] = None, **attrs):
        """Same-thread scope.  With ``xla_annotations=True`` the scope is
        also pushed as a ``jax.profiler.TraceAnnotation`` so host spans
        line up with XLA device traces (best-effort: silently skipped
        when the profiler is unavailable)."""
        ann = None
        if self.xla_annotations:
            try:
                from jax.profiler import TraceAnnotation
                ann = TraceAnnotation(name)
                ann.__enter__()
            except Exception:
                ann = None
        span_id = self.start(name, rid, **attrs)
        try:
            yield span_id
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            self.end(span_id)

    # -- aggregation ----------------------------------------------------
    def open_count(self) -> int:
        return len(self._open)

    def span_stats(self) -> Dict[str, dict]:
        """Per-span-name {count, p50_ms, p99_ms, mean_ms, total_s} over
        the ring buffer (exact percentiles over retained spans)."""
        by_name: Dict[str, List[float]] = {}
        with self._lock:
            finished = list(self.spans)
        for sp in finished:
            by_name.setdefault(sp.name, []).append(sp.duration_s)
        out = {}
        for name, durs in sorted(by_name.items()):
            durs.sort()
            out[name] = {
                "count": len(durs),
                "p50_ms": round(_percentile(durs, 0.50) * 1e3, 3),
                "p99_ms": round(_percentile(durs, 0.99) * 1e3, 3),
                "mean_ms": round((sum(durs) / len(durs)) * 1e3, 3),
                "total_s": round(sum(durs), 6),
            }
        return out

    def snapshot(self, last: int = 256) -> List[dict]:
        with self._lock:
            finished = list(self.spans)[-last:]
        return [sp.to_dict() for sp in finished]

    def close(self) -> None:
        with self._lock:
            if self._sink is not None:
                self._sink.flush()
                self._sink.close()
                self._sink = None


class NullTracer(Tracer):
    """No-op tracer with the same surface (the uninstrumented mode)."""

    enabled = False

    def __init__(self) -> None:
        super().__init__(capacity=1)

    def event(self, type: str, **fields) -> None:
        pass

    def start(self, name, rid=None, t=None, **attrs) -> str:
        return ""

    def end(self, span_id, t=None, **attrs) -> Span:
        return Span("", "", None, 0.0, 0.0)

    @contextlib.contextmanager
    def span(self, name, rid=None, **attrs):
        yield ""

    def span_stats(self) -> Dict[str, dict]:
        return {}

    def snapshot(self, last: int = 256) -> List[dict]:
        return []
