"""Exporters: Prometheus exposition text + JSON snapshots.

``prometheus_text`` renders a ``MetricsRegistry`` snapshot into the
text exposition format (HELP/TYPE headers, cumulative ``_bucket``
series with ``le`` labels, ``_sum``/``_count``).  ``parse_prometheus_text``
is the strict inverse used by the CI validator — it raises ``ValueError``
on any malformed line, so "the Prometheus text parses" is a real gate.

``json_snapshot`` bundles metrics + span stats (+ optional extras such
as plan snapshots) into one machine-readable dict that
``make_experiments_md`` and the dashboard consume.
"""
from __future__ import annotations

import json
import re
import time
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")
_LINE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^ ]+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '{}="{}"'.format(k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() else repr(f)


def prometheus_text(registry: MetricsRegistry) -> str:
    snap = registry.snapshot()
    lines: List[str] = []
    for section, kind in (("counters", "counter"), ("gauges", "gauge"),
                          ("histograms", "histogram")):
        for name, entry in snap[section].items():
            if entry["help"]:
                lines.append(f"# HELP {name} {entry['help']}")
            lines.append(f"# TYPE {name} {kind}")
            if kind != "histogram":
                for v in entry["values"]:
                    lines.append(f"{name}{_fmt_labels(v['labels'])} "
                                 f"{_fmt_value(v['value'])}")
                continue
            for v in entry["values"]:
                cum = 0
                for le, c in v["buckets"]:
                    cum = c
                    lab = dict(v["labels"], le=_fmt_value(le))
                    lines.append(f"{name}_bucket{_fmt_labels(lab)} {c}")
                lab = dict(v["labels"], le="+Inf")
                lines.append(f"{name}_bucket{_fmt_labels(lab)} {v['count']}")
                lines.append(f"{name}_sum{_fmt_labels(v['labels'])} "
                             f"{_fmt_value(v['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(v['labels'])} "
                             f"{v['count']}")
    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> Dict[str, List[Tuple[dict, float]]]:
    """Strict parser for the exposition format we emit.

    Returns {metric_name: [(labels, value), ...]}; histogram series come
    back under their ``_bucket``/``_sum``/``_count`` names.  Raises
    ``ValueError`` on any line that is neither a comment nor a valid
    sample."""
    out: Dict[str, List[Tuple[dict, float]]] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 2)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(f"line {lineno}: malformed comment {raw!r}")
            continue
        m = _LINE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample {raw!r}")
        labels: Dict[str, str] = {}
        body = m.group("labels")
        if body:
            consumed = 0
            for lm in _LABEL_RE.finditer(body):
                labels[lm.group(1)] = lm.group(2)
                consumed = lm.end()
            rest = body[consumed:].strip().strip(",")
            if rest:
                raise ValueError(f"line {lineno}: malformed labels {body!r}")
        val_s = m.group("value")
        try:
            value = float("inf") if val_s == "+Inf" else float(val_s)
        except ValueError:
            raise ValueError(f"line {lineno}: malformed value {val_s!r}")
        out.setdefault(m.group("name"), []).append((labels, value))
    return out


def json_snapshot(registry: MetricsRegistry,
                  tracer: Optional[Tracer] = None,
                  extra: Optional[dict] = None) -> dict:
    snap = {
        "schema": "repro.obs/v1",
        "wall_time": time.time(),
        "metrics": registry.snapshot(),
        "spans": tracer.span_stats() if tracer is not None else {},
    }
    if extra:
        snap.update(extra)
    return snap


def write_json_snapshot(path: str, registry: MetricsRegistry,
                        tracer: Optional[Tracer] = None,
                        extra: Optional[dict] = None) -> dict:
    snap = json_snapshot(registry, tracer, extra)
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, default=str)
        f.write("\n")
    return snap
