"""The per-component observability bundle: one registry + one tracer.

Each engine/manager owns an ``Observability`` (isolated counters, so
``engine.obs.metrics.value("msda_compiles_total", ...)`` is exact for
that engine); ``Observability.disabled()`` is the measurably-zero-cost
uninstrumented mode used by the overhead benchmark.
"""
from __future__ import annotations

import os
import time
from typing import Optional

from repro.obs.metrics import MetricsRegistry, NullRegistry
from repro.obs.trace import NullTracer, Tracer

# Environment switch: when set, engines created with obs=None log their
# span/plan/metrics events to this JSONL path (the CI obs smoke leg).
OBS_JSONL_ENV = "REPRO_OBS_JSONL"


class Observability:
    def __init__(self, metrics: MetricsRegistry, tracer: Tracer) -> None:
        self.metrics = metrics
        self.tracer = tracer

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled

    @classmethod
    def create(cls, jsonl_path: Optional[str] = None,
               capacity: int = 4096,
               xla_annotations: bool = False) -> "Observability":
        return cls(MetricsRegistry(),
                   Tracer(capacity=capacity, jsonl_path=jsonl_path,
                          xla_annotations=xla_annotations))

    @classmethod
    def default(cls, capacity: int = 4096) -> "Observability":
        """What engines build when constructed with ``obs=None``:
        enabled metrics + tracer, JSONL sink iff REPRO_OBS_JSONL is set."""
        return cls.create(jsonl_path=os.environ.get(OBS_JSONL_ENV) or None,
                          capacity=capacity)

    @classmethod
    def disabled(cls) -> "Observability":
        return cls(NullRegistry(), NullTracer())

    def flush_metrics(self) -> None:
        """Write a metrics snapshot event into the JSONL log (dashboard
        refresh point).  No-op without a sink."""
        self.tracer.event("metrics", wall_time=time.time(),
                          data=self.metrics.snapshot())

    def close(self) -> None:
        self.tracer.close()
