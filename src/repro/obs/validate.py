"""CI validator for observability artifacts.

Usage (the obs smoke leg)::

    python -m repro.obs.validate --jsonl /tmp/obs.jsonl \
        --prom /tmp/metrics.prom \
        --require msda_compiles_total serve_requests_total \
                  serve_request_latency_seconds

Asserts:
- the Prometheus text parses (strict parser, any malformed line fails);
- every ``--require`` metric name is present (histograms may appear via
  their ``_count`` series);
- every span in the JSONL log is well-formed: ``span_end`` pairs with a
  prior ``span_start`` of the same id/name, durations are non-negative,
  and no span is left open.

Exit code 0 on success, 1 with a reason on stderr otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.obs.export import parse_prometheus_text


def validate_jsonl(path: str) -> dict:
    """Returns {"events", "spans", "names"} counts; raises ValueError on
    any structural problem."""
    open_spans: Dict[str, dict] = {}
    n_events = n_spans = 0
    names = set()
    with open(path) as f:
        for lineno, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                ev = json.loads(raw)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: invalid JSON ({e})")
            if not isinstance(ev, dict) or "type" not in ev:
                raise ValueError(f"{path}:{lineno}: event without type")
            n_events += 1
            etype = ev["type"]
            if etype == "span_start":
                sid = ev.get("span")
                if not sid:
                    raise ValueError(f"{path}:{lineno}: span_start without id")
                if sid in open_spans:
                    raise ValueError(f"{path}:{lineno}: duplicate span_start "
                                     f"for {sid!r}")
                open_spans[sid] = ev
            elif etype == "span_end":
                sid = ev.get("span")
                start = open_spans.pop(sid, None)
                if start is None:
                    raise ValueError(f"{path}:{lineno}: span_end for "
                                     f"{sid!r} without matching span_start")
                if ev.get("name") != start.get("name"):
                    raise ValueError(
                        f"{path}:{lineno}: span {sid!r} name mismatch "
                        f"({start.get('name')!r} -> {ev.get('name')!r})")
                dur = ev.get("dur_s")
                if dur is None or dur < 0:
                    raise ValueError(f"{path}:{lineno}: span {sid!r} has "
                                     f"negative/missing duration {dur!r}")
                if ev.get("t", 0.0) < start.get("t", 0.0):
                    raise ValueError(f"{path}:{lineno}: span {sid!r} ends "
                                     f"before it starts")
                n_spans += 1
                names.add(ev.get("name"))
    if open_spans:
        sids = sorted(open_spans)[:5]
        raise ValueError(f"{path}: {len(open_spans)} span(s) never ended "
                         f"(e.g. {sids})")
    return {"events": n_events, "spans": n_spans,
            "names": sorted(n for n in names if n)}


def validate_prometheus(path: str, require: List[str]) -> dict:
    with open(path) as f:
        parsed = parse_prometheus_text(f.read())
    present = set(parsed)
    missing = [name for name in require
               if name not in present and f"{name}_count" not in present]
    if missing:
        raise ValueError(f"{path}: required metrics missing: {missing} "
                         f"(present: {sorted(present)})")
    return {"series": len(parsed), "names": sorted(present)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jsonl", default=None, help="span event log to check")
    ap.add_argument("--prom", default=None, help="Prometheus text to check")
    ap.add_argument("--require", nargs="*", default=[],
                    help="metric names that must be present in --prom")
    ap.add_argument("--min-spans", type=int, default=1,
                    help="minimum finished spans expected in --jsonl")
    args = ap.parse_args(argv)
    if not args.jsonl and not args.prom:
        ap.error("nothing to validate: pass --jsonl and/or --prom")
    try:
        if args.jsonl:
            r = validate_jsonl(args.jsonl)
            if r["spans"] < args.min_spans:
                raise ValueError(f"{args.jsonl}: only {r['spans']} finished "
                                 f"span(s), expected >= {args.min_spans}")
            print(f"[obs-validate] {args.jsonl}: {r['events']} events, "
                  f"{r['spans']} well-formed spans "
                  f"({', '.join(r['names'])})")
        if args.prom:
            r = validate_prometheus(args.prom, args.require)
            print(f"[obs-validate] {args.prom}: {r['series']} series parse; "
                  f"required metrics present")
    except (ValueError, OSError) as e:
        print(f"[obs-validate] FAIL: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
