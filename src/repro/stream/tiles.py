"""Row-aligned tile geometry for temporal feature-map diffing.

The streaming subsystem decides WHAT to re-project at tile granularity:
each pyramid level (h, w) is cut into horizontal bands of ``tile_rows``
full rows. Row alignment is load-bearing, not cosmetic — it is the same
raster-window invariant the FWP compact geometry is built on
(tests/test_fwp_invariants.py): a row-aligned pixel window ``[lo, hi)``
of a level maps to ONE contiguous slot range of the compacted value
table (``searchsorted(keep_idx)``), so a changed tile's slots are a
contiguous scatter target and the per-level slot windows the windowed
consumers stage stay valid across incremental updates.

Everything here is static per (level_shapes, tile_rows): the maps are
numpy at build time and closed over by the manager's jitted diff/update
functions.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import fwp as fwp_lib


@dataclasses.dataclass(frozen=True)
class TileGeometry:
    """Static per-level row-band tiling of the flat multi-scale fmap."""
    level_shapes: Tuple[Tuple[int, int], ...]
    tile_rows: int
    n_tiles: int
    tile_of_pixel: np.ndarray      # (N_in,) int32 pixel -> tile id
    tile_level: np.ndarray         # (n_tiles,) int32 owning level
    tile_pix_start: np.ndarray     # (n_tiles,) int32 flat start pixel
    tile_pix_count: np.ndarray     # (n_tiles,) int32 pixels in the tile

    @property
    def n_in(self) -> int:
        return int(self.tile_of_pixel.shape[0])


def tile_geometry(level_shapes: Sequence[Tuple[int, int]],
                  tile_rows: int) -> TileGeometry:
    """Cut every level into row-aligned bands of ``tile_rows`` rows."""
    if tile_rows < 1:
        raise ValueError(f"tile_rows must be >= 1, got {tile_rows}")
    level_shapes = tuple((int(h), int(w)) for h, w in level_shapes)
    starts, n_in = fwp_lib.level_starts(level_shapes)
    tile_of_pixel = np.empty((n_in,), np.int32)
    tile_level, tile_start, tile_count = [], [], []
    tid = 0
    for li, ((h, w), s) in enumerate(zip(level_shapes, starts)):
        for r0 in range(0, h, tile_rows):
            r1 = min(r0 + tile_rows, h)
            lo = int(s) + r0 * w
            hi = int(s) + r1 * w
            tile_of_pixel[lo:hi] = tid
            tile_level.append(li)
            tile_start.append(lo)
            tile_count.append(hi - lo)
            tid += 1
    return TileGeometry(
        level_shapes=level_shapes, tile_rows=int(tile_rows), n_tiles=tid,
        tile_of_pixel=tile_of_pixel,
        tile_level=np.asarray(tile_level, np.int32),
        tile_pix_start=np.asarray(tile_start, np.int32),
        tile_pix_count=np.asarray(tile_count, np.int32))


def changed_tiles(geo: TileGeometry, x_new: jnp.ndarray, x_ref: jnp.ndarray,
                  threshold: float) -> jnp.ndarray:
    """Per-tile change mask: ``max-abs`` feature delta over the tile.

    A tile is CHANGED when its max-abs elementwise delta is >= the
    threshold — so ``threshold=0`` marks EVERY tile changed (the parity
    mode: the incremental path must then reproduce a full rebuild
    exactly), and a positive threshold is the per-pixel feature drift the
    stale table row is allowed to carry (the diff reference ``x_ref`` is
    the memory as of each tile's last re-projection, so sub-threshold
    drift cannot accumulate unboundedly). Returns (B, n_tiles) bool."""
    d = jnp.max(jnp.abs(x_new - x_ref), axis=-1)            # (B, N_in)
    b = d.shape[0]
    t_of_p = jnp.asarray(geo.tile_of_pixel)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], d.shape)
    tile_d = jnp.zeros((b, geo.n_tiles), d.dtype) \
        .at[bidx, jnp.broadcast_to(t_of_p[None], d.shape)].max(d)
    return tile_d >= jnp.asarray(threshold, d.dtype)
