"""Synthetic drifting-scene video: the streaming benchmark workload.

Real video streams have a mostly-static background with small moving
foreground — exactly the regime temporal tile reuse targets. The
generator emits encoder-memory frames (B, N_in, D) built from a static
per-level background plus a small "object" band of ``obj_rows`` rows per
level that marches down ``speed_rows`` rows per frame (wrapping), with
optional sub-threshold background noise. Frame-to-frame, only the rows
the object left and entered change — a handful of row-aligned tiles —
so the drifting-scene staged-bytes ratio is a MEASURED number (what
fraction of tiles a moving object actually dirties), not an assumption.

Shared by ``examples/detr_stream.py``, ``benchmarks/fmap_reuse.py``, the
``msda_stream_*`` microbench rows, and tests/test_stream.py.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core import fwp as fwp_lib


def drifting_scene(seed: int, level_shapes: Sequence[Tuple[int, int]],
                   d_model: int, n_frames: int, *, batch: int = 1,
                   obj_rows: int = 1, speed_rows: int = 1,
                   amplitude: float = 2.0, noise: float = 0.0
                   ) -> List[np.ndarray]:
    """Generate ``n_frames`` memories (B, N_in, D) of a drifting scene."""
    rng = np.random.default_rng(seed)
    starts, n_in = fwp_lib.level_starts(level_shapes)
    bg = rng.standard_normal((batch, n_in, d_model)).astype(np.float32)
    blobs = [rng.standard_normal((batch, obj_rows * w, d_model))
             .astype(np.float32) for h, w in level_shapes]
    frames = []
    for t in range(n_frames):
        x = bg.copy()
        if noise > 0.0:
            x += (noise * rng.standard_normal(x.shape)).astype(np.float32)
        for (h, w), s, blob in zip(level_shapes, starts, blobs):
            span = max(1, h - obj_rows + 1)
            r = (t * speed_rows) % span
            lo = int(s) + r * w
            x[:, lo:lo + obj_rows * w] += amplitude * blob
        frames.append(x)
    return frames
