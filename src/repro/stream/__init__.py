"""repro.stream — temporal feature-map reuse for streaming video.

The frame-to-frame extension of DEFA's feature-map reusing: PRs 3–4
amortized the value-cache build across decoder layers of ONE memory;
this subsystem amortizes it across FRAMES of a video stream. A
:class:`TemporalCacheManager` diffs each incoming frame's multi-scale
memory against its diff reference at row-aligned tile granularity,
re-projects and re-stages only the changed tiles' slots into the
persistent :class:`~repro.msda.cache.MSDAValueCache` (scattering through
the existing pix2slot geometry, including the persistent decode
staging), and runs the FWP keep decision as a streaming EMA with
keep-mask hysteresis so slot geometry stays stable between (rare) keep
transitions. ``serve.engine.StreamingDetrEngine`` maps N concurrent
video sessions onto the manager's batch slots; the driver is
``examples/detr_stream.py``.
"""
from repro.stream.synthetic import drifting_scene
from repro.stream.temporal import (StreamConfig, TemporalCacheManager,
                                   plan_slot_count, resolve_stream_config,
                                   stream_update_cap)
from repro.stream.tiles import TileGeometry, changed_tiles, tile_geometry

__all__ = [
    "StreamConfig", "TemporalCacheManager", "plan_slot_count",
    "resolve_stream_config", "stream_update_cap",
    "TileGeometry", "changed_tiles", "tile_geometry", "drifting_scene",
]
