"""TemporalCacheManager — frame-to-frame value-cache reuse for streaming.

PRs 3–4 amortized the value-cache build across decoder *layers* (build
once per memory, sample everywhere). A video stream adds the temporal
axis: consecutive frames' encoder memories are a slowly-changing signal,
yet a naive deployment re-projects, re-compacts and re-stages the whole
table every frame. This manager treats the cache as PERSISTENT state:

  * **tile diff** — each incoming frame is diffed against ``x_ref`` (the
    memory as of each tile's last re-projection) at row-aligned tile
    granularity (:mod:`repro.stream.tiles`); only tiles whose max-abs
    feature delta clears ``delta_threshold`` are re-projected.
  * **static update capacity** — the incremental path re-projects at most
    ``update_rows`` table rows per frame (a static budget, same
    shape-discipline as FWP's static capacity): the dirty slots are
    gathered, projected as a (B, U, D) matmul, and scattered into the
    existing table — and into the persistent decode staging
    (``kernels/msgs_decode.update_staged_rows``) — via the existing
    pix2slot geometry. Frames with more dirty slots than the budget fall
    back to a full rebuild (host-side decision, two compiled paths, no
    per-pattern recompilation).
  * **streaming FWP** — sampling frequencies feed an EMA
    (:func:`repro.core.fwp.ema_update`) and the keep decision runs with
    keep-mask hysteresis (:func:`repro.core.fwp.build_fwp_state_hysteresis`),
    so ``keep_idx`` churn is bounded and the compact-slot windows stay
    stable; a keep-geometry transition (rare by construction) restages
    only the CHANGED levels on the next frame: each level's slots are
    one contiguous range of the compact table (``_compact_from_scores``
    keeps slots raster-ordered per level), so a transition confined to a
    subset of levels re-projects exactly those ranges and swaps the
    geometry arrays — a full rebuild happens only when every level's
    keep set moved (or FWP is off/mask, where there is no slot range).
  * **frozen quant scale** — partial updates fake-quant against the scale
    captured at the last full build (the whole table must share one
    grid); full rebuilds refresh it.

Accounting: every frame records mode (``rebuild`` | ``incremental``),
the staged-bytes delta actually moved, and what a full per-frame rebuild
would have staged — the rebuild-vs-incremental story
``benchmarks/fmap_reuse.py`` and the ``msda_stream_*`` microbench rows
report. With ``delta_threshold=0`` every tile is marked changed and the
incremental path reproduces a full rebuild bit-for-bit (parity-tested
across keep transitions in tests/test_stream.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fwp as fwp_lib
from repro.msda import plan as plan_lib
from repro.msda.cache import (MSDAValueCache, build_value_cache,
                              cache_act_scale, update_value_cache_rows)
from repro.msda.pipeline import MSDAPipelineState
from repro.obs import Observability
from repro.stream.tiles import TileGeometry, changed_tiles, tile_geometry


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static knobs of the temporal-reuse subsystem."""
    tile_rows: int = 2            # rows per diff tile (per level, row-aligned)
    delta_threshold: float = 1e-5  # max-abs feature drift a stale row may carry
    #   (0 => every tile changed every frame: the parity mode)
    update_frac: float = 0.25     # static per-frame re-projection budget as a
    #   fraction of the table's updatable rows (overridden by
    #   plan.stream_update_rows when the plan carries one)
    ema_alpha: float = 0.25       # streaming frequency EMA coefficient
    hyst_enter: float = 1.25      # k_enter = fwp_k * hyst_enter
    hyst_exit: float = 0.75       # k_exit  = fwp_k * hyst_exit
    diff_channel_stride: int = 1  # tile diffing probes every s-th feature
    #   channel (1 = exact). The diff (and the stored reference) is
    #   O(N_in·D/s): a real knob on bandwidth-starved hosts, at the cost
    #   of missing a change confined to unprobed channels — the
    #   re-projection itself always reads the FULL current features, so a
    #   probed diff only delays a sub-probe change, never corrupts rows
    #   it does update


def resolve_stream_config(scfg: Optional[StreamConfig] = None) -> StreamConfig:
    """The effective streaming knobs. An explicit config always wins,
    untouched. With no config, the defaults — overlaid with the
    autotuner's measured diff-vs-reprojection crossover
    (``diff_channel_stride`` / ``update_frac``) whenever a tuned plan
    table is applied (see :mod:`repro.msda.autotune`): "no config" means
    "measured best", not "hardcoded guess"."""
    if scfg is not None:
        return scfg
    tuned = plan_lib.tuned_stream_params()
    if not tuned:
        return StreamConfig()
    return dataclasses.replace(
        StreamConfig(),
        diff_channel_stride=int(tuned["diff_channel_stride"]),
        update_frac=float(tuned["update_frac"]))


def plan_slot_count(plan) -> int:
    """Updatable table rows of a plan's cache (compact: the capacity
    slots, excluding the zero sentinel; else every pixel row). The ONE
    derivation behind both the manager's slot space and the update cap."""
    cfg = plan.cfg
    if cfg.fwp_mode == "compact":
        return sum(fwp_lib.level_capacities(plan.level_shapes,
                                            cfg.fwp_capacity))
    return plan.n_in


def stream_update_cap(plan, update_frac: float) -> int:
    """The static incremental budget: rows re-projected per frame."""
    n_slots = plan_slot_count(plan)
    return max(1, min(n_slots, int(round(update_frac * n_slots))))


class TemporalCacheManager:
    """Persistent, incrementally updated MSDAValueCache for one stream.

    ``batch`` is the number of concurrent sessions sharing the manager
    (the streaming engine maps sessions onto batch slots); every slot
    carries its own diff reference, EMA scores and keep geometry rows.
    Host-side control flow picks between two jitted paths per frame
    (incremental update vs full rebuild); all heavy compute is jitted
    over arrays, so nothing retraces frame to frame."""

    def __init__(self, plan, value_params: dict,
                 scfg: Optional[StreamConfig] = None, *, batch: int = 1,
                 obs: Optional[Observability] = None):
        scfg = resolve_stream_config(scfg)
        if scfg.diff_channel_stride < 1:
            raise ValueError("diff_channel_stride must be >= 1")
        self.params = value_params
        self.scfg = scfg
        self.batch = int(batch)
        # unified telemetry: standalone managers get their own enabled
        # registry (the trace_counts view below must count for real);
        # the streaming engine passes its bundle in so manager counters
        # and engine spans share one registry/event log
        self.obs = obs if obs is not None else Observability.default(
            capacity=1024)
        m = self.obs.metrics
        self._m_traces = m.counter(
            "msda_traces_total",
            "jitted-path tracings by fn (trace-time spies: flat after "
            "warmup = session churn never retraces)")
        self._m_frames = m.counter(
            "stream_frames_total", "frames by update mode")
        self._m_rebuilds = m.counter(
            "stream_rebuilds_total", "full rebuilds by reason")
        self._m_staged = m.counter(
            "staged_bytes_total", "bytes actually staged, by update mode")
        self._m_dirty = m.gauge(
            "stream_dirty_slots", "dirty slot count of the last frame")
        self._m_span = m.histogram(
            "stream_span_seconds", "per-stage frame latency (label span=)")

        # ---- mutable stream state (host-held, arrays on device) ----------
        self.cache: Optional[MSDAValueCache] = None
        self.x_ref: Optional[jnp.ndarray] = None   # PROBED diff reference:
        #   the (B, N_in, ceil(D/stride)) channel slice of each tile's
        #   last-reprojected memory — all the diff ever reads
        self.ema: Optional[jnp.ndarray] = None
        self.fwp: Optional[fwp_lib.FWPState] = None
        self.act_scale: Optional[jnp.ndarray] = None
        self._cache_fwp: Optional[fwp_lib.FWPState] = None  # geometry the
        #   current cache was built with (stale detection self-heals)
        self._cache_plan = None                     # plan the current jitted
        #   paths were traced against — ``step`` detects a mid-stream swap
        #   (``mgr.plan = other_plan``) and reconfigures + rebuilds
        self._geometry_stale = True                 # first frame: full build
        self._pending_admit: set = set()            # slots scheduled for a
        #   per-slot admission build on the next frame (reset_slot)
        self.frame_index = 0
        self.rebuild_frames = 0
        self.partial_frames = 0                     # per-level restages
        self.staged_bytes_total = 0
        self.rebuild_bytes_total = 0                # per-frame-rebuild cost
        self.last_stats: Optional[dict] = None

        self._reconfigure(plan)

    @contextlib.contextmanager
    def _timed_span(self, name: str, **attrs):
        """Trace span + ``stream_span_seconds{span=name}`` histogram."""
        t0 = time.perf_counter()
        with self.obs.tracer.span(name, **attrs):
            yield
        self._m_span.observe(time.perf_counter() - t0, span=name)

    @property
    def trace_counts(self) -> dict:
        """Trace-time spies: each jitted impl bumps ``msda_traces_total``
        in its traced body, so the counts move ONLY on (re)compilation —
        tests assert session churn never retraces. A dict view over the
        registry counter (the same numbers production scrapes)."""
        return {k: int(self._m_traces.value(fn=k))
                for k in ("build", "frame", "restage")}

    def _reconfigure(self, plan) -> None:
        """(Re-)derive every plan-dependent static AND re-jit the compiled
        paths. Called at construction and when ``step`` detects the
        manager's plan was swapped mid-stream (a table-dtype or act-bits
        change, a backend move): the jitted closures close over the plan
        at TRACE time, so a swap without a re-jit would silently keep
        executing the old plan's build/update — wrong dtype, wrong
        accounting. The next frame after a swap always full-rebuilds
        (reason ``plan-change``): the existing table's codes live on the
        old plan's grid."""
        cfg = plan.cfg
        if cfg.fwp_mode not in ("off", "mask", "compact"):
            raise ValueError(f"unknown fwp_mode {cfg.fwp_mode!r}")
        self.plan = plan
        self.geo: TileGeometry = tile_geometry(plan.level_shapes,
                                               self.scfg.tile_rows)
        self._compact = cfg.fwp_mode == "compact"
        self.n_slots = plan_slot_count(plan)
        if self._compact:
            caps = fwp_lib.level_capacities(plan.level_shapes,
                                            cfg.fwp_capacity)
            self._n_rows = self.n_slots + 1            # + zero sentinel
            self._slot_windows = tuple(
                min(int(c), self._n_rows - 1) for c in caps)
        else:
            self._n_rows = plan.n_in
            self._slot_windows: Tuple[int, ...] = ()
        self._full_bytes = plan.table_bytes_for_rows(
            self._n_rows, with_indirection=self._compact)
        self.update_rows = plan.stream_update_rows \
            if plan.stream_update_rows is not None \
            else stream_update_cap(plan, self.scfg.update_frac)
        self.update_rows = max(1, min(self.update_rows, self.n_slots))
        self._incr_bytes = plan.table_bytes_for_rows(
            self.update_rows, with_indirection=False)
        # static per-level geometry for the partial (per-level) restage:
        # slot range [slot_offs[l], slot_offs[l+1]) and pixel range
        # [pix_starts[l], pix_starts[l]+h*w) of level l
        starts, _ = fwp_lib.level_starts(plan.level_shapes)
        self._pix_starts = tuple(int(s) for s in starts)
        if self._compact:
            caps = fwp_lib.level_capacities(plan.level_shapes,
                                            cfg.fwp_capacity)
            self._slot_offs = tuple(
                int(o) for o in np.concatenate([[0], np.cumsum(caps)]))
        else:
            self._slot_offs = ()

        self._jit_build = jax.jit(self._build_impl)
        self._jit_frame = jax.jit(self._frame_impl)
        self._jit_restage = jax.jit(self._restage_impl)
        k = float(cfg.fwp_k)
        scfg = self.scfg
        self._jit_hyst = jax.jit(lambda ema, prev: fwp_lib.build_fwp_state_hysteresis(
            ema, plan.level_shapes,
            k_enter=k * scfg.hyst_enter, k_exit=k * scfg.hyst_exit,
            mode=cfg.fwp_mode, capacity=cfg.fwp_capacity, prev=prev))

    # ---- jitted internals -------------------------------------------------
    def _build_impl(self, params, x_flat, fwp):
        self._m_traces.inc(fn="build")
        return build_value_cache(params, self.plan, x_flat,
                                 MSDAPipelineState(fwp=fwp))

    def _probe(self, x: jnp.ndarray) -> jnp.ndarray:
        s = self.scfg.diff_channel_stride
        return x if s == 1 else x[..., ::s]

    def _diff_impl(self, x_new, x_ref, keep_idx):
        changed = changed_tiles(self.geo, self._probe(x_new), x_ref,
                                self.scfg.delta_threshold)   # (B, n_tiles)
        t_of_p = jnp.asarray(self.geo.tile_of_pixel)
        if keep_idx is not None:                     # compact: slot -> tile
            slot_tile = t_of_p[keep_idx]             # (B, n_slots)
        else:                                        # dense: slot == pixel
            slot_tile = jnp.broadcast_to(t_of_p[None],
                                         (x_new.shape[0], self.n_slots))
        bidx = jnp.arange(x_new.shape[0])[:, None]
        slot_dirty = changed[bidx, slot_tile]        # (B, n_slots)
        return changed, slot_dirty, jnp.sum(slot_dirty, axis=1)

    def _update_impl(self, params, x_new, x_ref, v, staged, keep_idx,
                     keep_mask, changed, slot_dirty, act_scale, table_scale):
        # dirty slots first; clean fillers re-project unchanged (or
        # sub-threshold-drifted) pixels, which is harmless by construction
        _, idx_u = jax.lax.top_k(slot_dirty.astype(jnp.float32),
                                 self.update_rows)
        idx_u = jnp.sort(idx_u, axis=1)
        # the ONE row-update path (cache.py): project + scatter into the
        # table and its decode staging. The temp cache just pairs the
        # traced arrays with this manager's static metadata. ``table_scale``
        # is the int8 table's FROZEN per-channel dequant scale: refreshed
        # rows re-quantize against it, so streaming stays int8 end-to-end
        # without ever materializing a dense float table.
        tmp = MSDAValueCache(v=v, pix2slot=None, keep_idx=keep_idx,
                             n_rows=self._n_rows,
                             slot_windows=self._slot_windows,
                             table_bytes=self._full_bytes, staged=staged,
                             scale=table_scale)
        upd, _ = update_value_cache_rows(params, self.plan, tmp, x_new,
                                         idx_u, act_scale=act_scale,
                                         keep_mask=keep_mask)
        pix_changed = changed[jnp.arange(x_new.shape[0])[:, None],
                              jnp.asarray(self.geo.tile_of_pixel)[None]]
        x_ref = jnp.where(pix_changed[..., None], self._probe(x_new), x_ref)
        return upd.v, upd.staged, x_ref

    def _frame_impl(self, params, x_new, x_ref, v, staged, keep_idx,
                    keep_mask, act_scale, table_scale):
        """ONE dispatch per frame: diff + speculative incremental update.

        The update runs unconditionally (its work is bounded by the
        static budget either way); the host commits it only when the
        dirty count fits the budget, else it discards the result and
        rebuilds — a rare path by construction, and fusing diff+update
        into one program keeps the per-frame dispatch count at one."""
        self._m_traces.inc(fn="frame")
        changed, slot_dirty, nd = self._diff_impl(x_new, x_ref, keep_idx)
        v, staged, x_ref = self._update_impl(
            params, x_new, x_ref, v, staged, keep_idx, keep_mask, changed,
            slot_dirty, act_scale, table_scale)
        return jnp.max(nd), jnp.sum(changed), v, staged, x_ref

    def _restage_impl(self, params, x_new, v, staged, new_keep_idx,
                      slot_idx, act_scale, table_scale):
        """Per-level partial restage: re-project the ``slot_idx`` slot
        ranges of the CHANGED levels from the current frame, addressed
        through the NEW keep geometry (slot -> pixel via
        ``new_keep_idx``), under the frozen act/table quant scales —
        the same row-update path as the incremental frame, just with a
        fresh slot->pixel map for the restaged ranges."""
        self._m_traces.inc(fn="restage")
        tmp = MSDAValueCache(v=v, pix2slot=None, keep_idx=new_keep_idx,
                             n_rows=self._n_rows,
                             slot_windows=self._slot_windows,
                             table_bytes=self._full_bytes, staged=staged,
                             scale=table_scale)
        upd, _ = update_value_cache_rows(params, self.plan, tmp, x_new,
                                         slot_idx, act_scale=act_scale)
        return upd.v, upd.staged

    # ---- host-side orchestration ------------------------------------------
    def _warm_fwp(self, batch: int) -> Optional[fwp_lib.FWPState]:
        """Warm-start keep state for fresh sessions: keep everything the
        capacity admits (k=0 thresholds), raster-first — the EMA then
        specializes it as real sampling frequencies arrive."""
        cfg = self.plan.cfg
        if cfg.fwp_mode == "off":
            return None
        ones = jnp.ones((batch, self.plan.n_in), jnp.float32)
        return fwp_lib.build_fwp_state(ones, self.plan.level_shapes, k=0.0,
                                       mode=cfg.fwp_mode,
                                       capacity=cfg.fwp_capacity)

    def _restore_meta(self, cache: MSDAValueCache) -> MSDAValueCache:
        """Re-pin the python-int metadata a jit boundary arrayified."""
        return cache._replace(n_rows=self._n_rows,
                              slot_windows=self._slot_windows,
                              table_bytes=self._full_bytes)

    def _full_build(self, x_new: jnp.ndarray) -> None:
        cfg = self.plan.cfg
        if cfg.fwp_mode != "off" and self.fwp is None:
            self.fwp = self._warm_fwp(x_new.shape[0])
            self.ema = jnp.ones((x_new.shape[0], self.plan.n_in),
                                jnp.float32)
        cache = self._jit_build(self.params, x_new, self.fwp)
        self.cache = self._restore_meta(cache)
        self.act_scale = cache_act_scale(self.cache, cfg)
        self.x_ref = self._probe(x_new)
        self._cache_fwp = self.fwp
        self._cache_plan = self.plan
        self._geometry_stale = False
        self._pending_admit.clear()    # a full build covers every slot

    def _transition_levels(self) -> Optional[Tuple[int, ...]]:
        """Which levels' keep geometry changed vs the cache's, or None
        when a partial restage is not applicable (not compact, no
        geometry to compare, nothing changed, or EVERY level changed —
        then a full rebuild moves the same bytes with one build)."""
        new, old = self.fwp, self._cache_fwp
        if not self._compact or new is None or old is None \
                or new.keep_idx is None or old.keep_idx is None:
            return None
        changed = []
        for li, (h, w) in enumerate(self.plan.level_shapes):
            s0, s1 = self._slot_offs[li], self._slot_offs[li + 1]
            p0 = self._pix_starts[li]
            if bool(jnp.any(new.keep_idx[:, s0:s1] != old.keep_idx[:, s0:s1])) \
                    or bool(jnp.any(new.pix2slot[:, p0:p0 + h * w]
                                    != old.pix2slot[:, p0:p0 + h * w])):
                changed.append(li)
        if not changed or len(changed) == len(self.plan.level_shapes):
            return None
        return tuple(changed)

    def _partial_restage(self, x_new: jnp.ndarray,
                         levels: Tuple[int, ...]) -> int:
        """Restage only the changed levels' contiguous slot ranges.

        Re-projects those ranges from the current frame through the NEW
        keep geometry, swaps ``keep_idx``/``pix2slot`` (and the decode
        staging's ``remap``) wholesale — they are whole-array int32
        geometry, cheap next to the value rows — and refreshes the diff
        reference for the changed levels' pixel ranges. Quant scales
        stay FROZEN (same grid as the surrounding table, exactly like
        the incremental row path). Returns the staged-bytes delta:
        the restaged rows under the plan's lane layout plus the changed
        levels' share of the pix2slot indirection."""
        slot_np = np.concatenate([
            np.arange(self._slot_offs[l], self._slot_offs[l + 1])
            for l in levels]).astype(np.int32)
        b = x_new.shape[0]
        slot_idx = jnp.broadcast_to(jnp.asarray(slot_np)[None],
                                    (b, len(slot_np)))
        v, staged = self._jit_restage(
            self.params, x_new, self.cache.v, self.cache.staged,
            self.fwp.keep_idx, slot_idx, self.act_scale, self.cache.scale)
        if staged is not None:
            staged = dataclasses.replace(staged, remap=self.fwp.pix2slot)
        self.cache = self.cache._replace(
            v=v, staged=staged, keep_idx=self.fwp.keep_idx,
            pix2slot=self.fwp.pix2slot)
        x_ref = self.x_ref
        probe = self._probe(x_new)
        pix_restaged = 0
        for l in levels:
            h, w = self.plan.level_shapes[l]
            p0 = self._pix_starts[l]
            x_ref = x_ref.at[:, p0:p0 + h * w].set(probe[:, p0:p0 + h * w])
            pix_restaged += h * w
        self.x_ref = x_ref
        self._cache_fwp = self.fwp
        self._geometry_stale = False
        return self.plan.table_bytes_for_rows(
            len(slot_np), with_indirection=False) + pix_restaged * 4

    def permute_slots(self, perm) -> None:
        """Reorder the batch (session) slots of every per-slot array.

        ``perm`` has gather semantics: new slot ``i`` takes the state
        previously held at slot ``perm[i]`` (so ``perm`` must be a
        permutation of ``range(batch)``). The streaming engine uses this
        to place sessions whose reference points cluster on adjacent
        batch slots, so their dirty-row scatters and decode staging
        share windows. A pure state permutation — no values change, no
        rebuild is triggered, and stepping after it is equivalent to
        stepping the unpermuted manager with permuted frame rows."""
        p = np.asarray(perm, np.int32)
        if sorted(p.tolist()) != list(range(self.batch)):
            raise ValueError(
                f"permute_slots needs a permutation of range({self.batch}), "
                f"got {p.tolist()}")
        pj = jnp.asarray(p)
        take = lambda a: None if a is None else jnp.take(a, pj, axis=0)
        if self.cache is not None:
            staged = self.cache.staged
            if staged is not None:
                staged = dataclasses.replace(
                    staged, v=take(staged.v), remap=take(staged.remap),
                    scale=take(staged.scale))
            self.cache = self.cache._replace(
                v=take(self.cache.v), pix2slot=take(self.cache.pix2slot),
                keep_idx=take(self.cache.keep_idx), staged=staged,
                scale=take(self.cache.scale))
        self.x_ref = take(self.x_ref)
        self.ema = take(self.ema)
        if self.act_scale is not None and self.act_scale.ndim > 0 \
                and self.act_scale.shape[0] == self.batch:
            self.act_scale = take(self.act_scale)
        for name in ("fwp", "_cache_fwp"):
            st = getattr(self, name)
            if st is not None:
                setattr(self, name, fwp_lib.FWPState(
                    keep_mask=take(st.keep_mask),
                    keep_idx=take(st.keep_idx),
                    pix2slot=take(st.pix2slot),
                    freq=take(st.freq)))
        if self._pending_admit:
            inv = {int(old): new for new, old in enumerate(p.tolist())}
            self._pending_admit = {inv[s] for s in self._pending_admit}

    def step(self, x_new, force_full: bool = False
             ) -> Tuple[MSDAValueCache, dict]:
        """Ingest one frame's memory; returns (cache, frame stats).

        The cache is persistent: an incremental frame scatter-updates the
        existing table (and its decode staging) in place; a keep-geometry
        transition confined to a subset of levels restages only those
        levels' contiguous slot ranges (mode ``partial``); a full rebuild
        happens only on the first frame, on whole-geometry keep
        transitions, on ``force_full`` (session admission), or when the
        dirty-slot count exceeds the static update budget."""
        x_new = jnp.asarray(x_new)
        assert x_new.ndim == 3 and x_new.shape[1] == self.plan.n_in, \
            (x_new.shape, self.plan.n_in)
        n_dirty = tiles_hit = 0
        plan_change = self.cache is not None \
            and self.plan is not self._cache_plan
        if plan_change:
            # mid-stream plan swap (table dtype, act_bits, backend, ...):
            # the jitted paths and accounting were traced against the old
            # plan and the table's codes live on the old plan's grid —
            # reconfigure everything and rebuild from this frame's memory
            old = self._cache_plan
            self._reconfigure(self.plan)
            if (self.plan.level_shapes != old.level_shapes
                    or self.plan.cfg.fwp_mode != old.cfg.fwp_mode
                    or self.plan.cfg.fwp_capacity != old.cfg.fwp_capacity):
                # keep state rows were derived under the OLD geometry
                self.fwp = self.ema = None
        keep_transition = self._geometry_stale and self.cache is not None \
            and not plan_change
        restaged_levels: Tuple[int, ...] = ()
        partial_bytes = 0
        if keep_transition and not force_full:
            # per-level partial restage: each level's slots are ONE
            # contiguous range of the compact table, so a transition that
            # only moved some levels' keep sets restages those ranges
            # instead of rebuilding the whole table. The restage swaps
            # the geometry and re-projects the changed levels from this
            # frame; the UNCHANGED levels' feature drift then flows
            # through the ordinary incremental diff below.
            partial = self._transition_levels()
            if partial:
                restaged_levels = partial
                with self._timed_span("scatter", kind="partial-restage",
                                          levels=partial):
                    partial_bytes = self._partial_restage(x_new, partial)
        admitted: Tuple[int, ...] = ()
        admit_bytes = 0
        if self._pending_admit and self.cache is not None \
                and not self._geometry_stale and not force_full \
                and not plan_change:
            # per-slot session admission: rebuild ONLY the joining slots'
            # rows from their own frames; the rest of the batch proceeds
            # incrementally below (the admitted slots' diff reference was
            # just refreshed, so they contribute zero dirty tiles)
            admitted = tuple(sorted(self._pending_admit))
            self._pending_admit.clear()
            with self._timed_span("scatter", kind="admission",
                                      slots=admitted):
                admit_bytes = self._admit_slots(x_new, admitted)
        if self.cache is None or self._geometry_stale or force_full \
                or plan_change:
            mode, reason = "rebuild", (
                "first-frame" if self.cache is None else
                "plan-change" if plan_change else
                "keep-transition" if keep_transition else "forced")
            with self._timed_span("rebuild", reason=reason):
                self._full_build(x_new)
            staged_bytes = self._full_bytes
        else:
            keep_idx = self.cache.keep_idx if self._compact else None
            keep_mask = None
            if self.plan.cfg.fwp_mode == "mask":
                keep_mask = self.fwp.keep_mask
            with self._timed_span("diff"):
                nd, tiles, v, staged, x_ref = self._jit_frame(
                    self.params, x_new, self.x_ref, self.cache.v,
                    self.cache.staged, keep_idx, keep_mask, self.act_scale,
                    self.cache.scale)
                n_dirty = int(nd)
                tiles_hit = int(tiles)
            if n_dirty > self.update_rows:
                # speculative update discarded: dirt exceeds the static
                # budget, the table must be rebuilt wholesale
                mode, reason = "rebuild", "dirty>budget"
                with self._timed_span("rebuild", reason=reason):
                    self._full_build(x_new)
                staged_bytes = partial_bytes + admit_bytes \
                    + self._full_bytes
            else:
                mode = "partial" if restaged_levels else "incremental"
                reason = "keep-transition" if restaged_levels else ""
                self.cache = self.cache._replace(v=v, staged=staged)
                self.x_ref = x_ref
                staged_bytes = partial_bytes + admit_bytes \
                    + self._incr_bytes
        self.frame_index += 1
        self.rebuild_frames += mode == "rebuild"
        self.partial_frames += mode == "partial"
        self.staged_bytes_total += staged_bytes
        self.rebuild_bytes_total += self._full_bytes
        self.last_stats = {
            # scope: the whole BATCH (all sessions sharing this manager
            # advance together) — per-session consumers must not sum
            # staged_bytes across sessions of one frame
            "scope": "batch",
            "frame": self.frame_index - 1, "mode": mode, "reason": reason,
            "staged_bytes": staged_bytes,
            "rebuild_bytes": self._full_bytes,
            "n_dirty": n_dirty, "tiles_changed": tiles_hit,
            "keep_transition": bool(keep_transition),
            "restaged_levels": restaged_levels,
            "admitted_slots": admitted,
            "update_rows": self.update_rows,
        }
        # unified metrics mirror of last_stats (host-side, outside jit)
        self._m_frames.inc(mode=mode)
        self._m_staged.inc(staged_bytes, mode=mode)
        if mode == "rebuild":
            self._m_rebuilds.inc(reason=reason)
        self._m_dirty.set(n_dirty)
        return self.cache, self.last_stats

    def observe(self, freq: jnp.ndarray) -> bool:
        """Feed back one frame's sampling frequencies (B, N_in).

        Updates the streaming EMA and re-derives the keep decision with
        hysteresis; returns True when the keep GEOMETRY changed vs what
        the current cache was built with (the next ``step`` then does a
        full rebuild). No-op when FWP is off."""
        cfg = self.plan.cfg
        if cfg.fwp_mode == "off":
            return False
        freq = jnp.asarray(freq, jnp.float32)
        self.ema = freq if self.ema is None \
            else fwp_lib.ema_update(self.ema, freq, self.scfg.ema_alpha)
        self.fwp = self._jit_hyst(self.ema, self.fwp)
        stale = self._fwp_geometry_differs(self.fwp, self._cache_fwp)
        self._geometry_stale = stale
        return stale

    @staticmethod
    def _fwp_geometry_differs(a: Optional[fwp_lib.FWPState],
                              b: Optional[fwp_lib.FWPState]) -> bool:
        if a is None or b is None:
            return a is not b
        if a.keep_idx is not None:
            return bool(jnp.any(a.keep_idx != b.keep_idx)) \
                or bool(jnp.any(a.pix2slot != b.pix2slot))
        return bool(jnp.any(a.keep_mask != b.keep_mask))

    def _admit_slots(self, x_new: jnp.ndarray, slots: Tuple[int, ...]
                     ) -> int:
        """Per-slot admission: build each admitted slot's table rows from
        its OWN frame (a batch-1 build through the already-traced
        ``_jit_build`` — batch 1 is one extra trace at most, shared by
        every admission) and scatter them into this slot's rows of the
        persistent cache, its decode staging, the diff reference and the
        cache-geometry record. Every other slot's state is untouched, so
        the rest of the batch rides the ordinary incremental path — a
        session joining never rebuild-storms its neighbours. Returns the
        staged-bytes delta (the admitted slots' share of a full build)."""
        for slot in slots:
            fwp1 = None
            if self.fwp is not None:
                f = self.fwp
                fwp1 = fwp_lib.FWPState(
                    keep_mask=f.keep_mask[slot:slot + 1],
                    keep_idx=None if f.keep_idx is None
                    else f.keep_idx[slot:slot + 1],
                    pix2slot=None if f.pix2slot is None
                    else f.pix2slot[slot:slot + 1],
                    freq=f.freq[slot:slot + 1])
            built = self._restore_meta(
                self._jit_build(self.params, x_new[slot:slot + 1], fwp1))
            c = self.cache
            srow = lambda a, b: None if a is None else a.at[slot].set(b[0])
            staged = c.staged
            if staged is not None:
                bs = built.staged
                staged = dataclasses.replace(
                    staged, v=staged.v.at[slot].set(bs.v[0]),
                    remap=srow(staged.remap, bs.remap),
                    scale=srow(staged.scale, bs.scale))
            self.cache = c._replace(
                v=c.v.at[slot].set(built.v[0]),
                pix2slot=srow(c.pix2slot, built.pix2slot),
                keep_idx=srow(c.keep_idx, built.keep_idx),
                scale=srow(c.scale, built.scale), staged=staged)
            self.x_ref = self.x_ref.at[slot].set(self._probe(x_new)[slot])
            if self._cache_fwp is not None:
                g, f = self._cache_fwp, self.fwp
                self._cache_fwp = fwp_lib.FWPState(
                    keep_mask=g.keep_mask.at[slot].set(f.keep_mask[slot]),
                    keep_idx=None if g.keep_idx is None
                    else g.keep_idx.at[slot].set(f.keep_idx[slot]),
                    pix2slot=None if g.pix2slot is None
                    else g.pix2slot.at[slot].set(f.pix2slot[slot]),
                    freq=g.freq.at[slot].set(f.freq[slot]))
        # accounting unit is per (batch, head-group) = per batch element:
        # k admitted slots cost their k/batch share of a full build
        return (self._full_bytes * len(slots) + self.batch - 1) \
            // self.batch

    def reset_slot(self, slot: int) -> None:
        """Reset one batch slot for a newly admitted session: warm-start
        its EMA/keep rows and schedule a PER-SLOT build on the next frame
        (``_admit_slots``). Falls back to flagging a full rebuild before
        the first frame (nothing to scatter into yet) and under frozen
        per-tensor activation quantization (``act_scale``): the admitted
        slot's build would re-derive the shared act grid, so exactness
        requires rebuilding the whole batch against one fresh scale."""
        if self.cache is None or self.act_scale is not None:
            self._geometry_stale = True
        else:
            self._pending_admit.add(slot)
        if self.ema is None:
            return
        self.ema = self.ema.at[slot].set(1.0)
        warm = self._warm_fwp(1)
        self.fwp = fwp_lib.FWPState(
            keep_mask=self.fwp.keep_mask.at[slot].set(warm.keep_mask[0]),
            keep_idx=None if self.fwp.keep_idx is None
            else self.fwp.keep_idx.at[slot].set(warm.keep_idx[0]),
            pix2slot=None if self.fwp.pix2slot is None
            else self.fwp.pix2slot.at[slot].set(warm.pix2slot[0]),
            freq=self.fwp.freq.at[slot].set(1.0))

    def pipeline_state(self) -> MSDAPipelineState:
        """The chain state a consumer threads through its layers: the
        streaming FWP link plus this frame's temporal-reuse accounting."""
        return MSDAPipelineState(fwp=self.fwp).with_stream(self.last_stats)

    def report(self) -> dict:
        """Cumulative rebuild-vs-incremental accounting."""
        staged = max(self.staged_bytes_total, 1)
        return {
            "frames": self.frame_index,
            "table_dtype": self.plan.table_dtype,
            "rebuild_frames": self.rebuild_frames,
            "partial_frames": self.partial_frames,
            "incremental_frames": self.frame_index - self.rebuild_frames
            - self.partial_frames,
            "update_rows": self.update_rows,
            "n_slots": self.n_slots,
            "staged_bytes_total": self.staged_bytes_total,
            "rebuild_bytes_total": self.rebuild_bytes_total,
            "bytes_ratio": self.rebuild_bytes_total / staged,
            "full_bytes_per_frame": self._full_bytes,
            "incremental_bytes_per_frame": self._incr_bytes,
        }
