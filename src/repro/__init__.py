"""repro — DEFA (MSDeformAttn acceleration) reproduced as a multi-pod JAX framework.

Layers:
  core/         the paper's contribution: MSDeformAttn + FWP/PAP/range-narrowing/quant
  kernels/      Pallas TPU kernels (fused MSGS+aggregation, windowed reuse, matmul)
  models/       LM model zoo substrate (dense GQA, MoE, SSD, hybrid, enc-dec, VLM)
  configs/      assigned architectures + paper's DETR-family configs
  data/         deterministic synthetic data pipelines
  optim/        AdamW, ZeRO sharding, grad compression
  train/        train-step builder (scan, remat, grad accumulation)
  serve/        KV/SSM caches, prefill/decode, continuous batcher
  checkpoint/   atomic sharded checkpoints, elastic re-sharding
  distributed/  mesh + logical sharding rules
  launch/       mesh.py, dryrun.py, train.py, serve.py
"""

__version__ = "1.0.0"
