"""Activation-sharding constraints (§Perf optimization O1).

The baseline relies on XLA sharding propagation from parameter shardings
alone; the dry-run HLO showed propagation REPLICATING activations over the
data axis for several archs (full-global-batch [256,4096,*] tensors inside
per-layer all-reduces — granite train's collective term was 74.6 s/step).
The standard fix (MaxText-style) is to pin the batch dim of activations at
layer boundaries with with_sharding_constraint.

Models are mesh-agnostic, so the policy rides a context variable set by the
launch layer; when unset every constrain_* call is a no-op (tests and
single-device runs are unaffected)."""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: contextvars.ContextVar = contextvars.ContextVar(
    "act_sharding_policy", default=None)


@contextlib.contextmanager
def activation_policy(mesh: Mesh, batch_axes, model_axis: Optional[str] = "model",
                      seq_shard: bool = False):
    """Enable activation constraints: batch dims -> `batch_axes`.

    seq_shard=True (pure-DP strategy): the model axis carries SEQUENCE
    parallelism — (B,S,...) streams pin dim 1 to the model axis and weights
    stay replicated (no per-layer TP all-reduces)."""
    token = _POLICY.set({"mesh": mesh, "batch": batch_axes,
                         "model": model_axis if (model_axis in mesh.axis_names)
                         else None,
                         "seq": seq_shard})
    try:
        yield
    finally:
        _POLICY.reset(token)


def policy_active() -> bool:
    return _POLICY.get() is not None


def model_axis_size() -> int:
    """TP degree under the active policy (0 = no policy / no model axis)."""
    pol = _POLICY.get()
    if pol is None or pol["model"] is None:
        return 0
    return pol["mesh"].shape[pol["model"]]


def _constrain(x, spec: P):
    pol = _POLICY.get()
    if pol is None:
        return x
    # drop axes the tensor dims can't honour (divisibility)
    mesh = pol["mesh"]
    fixed = []
    for i, s in enumerate(spec):
        if s is None:
            fixed.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        fixed.append(s if x.shape[i] % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def constrain_batch(x, n_extra_dims: Optional[int] = None):
    """Pin dim 0 to the batch axes, rest unsharded. x: (B, ...)."""
    pol = _POLICY.get()
    if pol is None:
        return x
    extra = x.ndim - 1 if n_extra_dims is None else n_extra_dims
    return _constrain(x, P(pol["batch"], *([None] * extra)))


def constrain_stream(x):
    """Pin a (B, S, ...) residual-stream tensor: batch on dim 0, and — in
    seq_shard mode — the sequence dim 1 on the model axis."""
    pol = _POLICY.get()
    if pol is None:
        return x
    if pol.get("seq") and pol["model"] is not None and x.ndim >= 3:
        spec = [None] * x.ndim
        spec[0] = pol["batch"]
        spec[1] = pol["model"]
        return _constrain(x, P(*spec))
    return constrain_batch(x)


def constrain_batch_model(x, model_dim: int):
    """Pin dim 0 to batch axes and `model_dim` to the model axis (in
    seq_shard mode the model axis holds the SEQUENCE dim instead)."""
    pol = _POLICY.get()
    if pol is None or pol["model"] is None:
        return constrain_batch(x)
    if pol.get("seq"):
        return constrain_stream(x)
    spec = [None] * x.ndim
    spec[0] = pol["batch"]
    spec[model_dim] = pol["model"]
    return _constrain(x, P(*spec))


def constrain_batch_seq(x, seq_dim: int = 1):
    """Sequence parallelism: pin dim 0 to batch axes and `seq_dim` to the
    model axis. Used when attention heads don't divide the model axis —
    every rank computes ALL heads for 1/TP of the queries instead of
    replicating the whole attention block (O2)."""
    pol = _POLICY.get()
    if pol is None or pol["model"] is None:
        return constrain_batch(x)
    spec = [None] * x.ndim
    spec[0] = pol["batch"]
    spec[seq_dim] = pol["model"]
    return _constrain(x, P(*spec))


def constrain_expert(x, expert_dim: int = 1):
    """Pin a (B, E, C, D) MoE dispatch buffer: batch on dim 0 AND expert dim
    on the model axis. (None dims in with_sharding_constraint mean REPLICATE
    — omitting the batch pin would broadcast every row to every expert rank,
    which is exactly the 16x blow-up this constraint exists to prevent.)"""
    pol = _POLICY.get()
    if pol is None or pol["model"] is None:
        return x
    spec = [None] * x.ndim
    spec[0] = pol["batch"]
    spec[expert_dim] = pol["model"]
    return _constrain(x, P(*spec))
