from repro.distributed.sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    FSDP_RULES,
    logical_to_spec,
    specs_for_tree,
    named_sharding_tree,
    batch_spec,
    MeshAxes,
)
