"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Every parameter leaf in the model zoo carries a tuple of *logical* axis
names (one per tensor dim, ``None`` for unsharded dims). A rule table maps
logical axes onto physical mesh axes ``("pod", "data", "model")``. Two rule
tables ship by default:

  * DEFAULT_RULES — tensor parallelism only (params replicated over data);
  * FSDP_RULES    — additionally shards the *fsdp-tagged* dim over "data"
                    (+"pod" when present), for models that don't fit
                    replicated (grok-314b, llava-34b, granite-20b).

Logical axes used across the zoo:
  embed        d_model dim                     -> unsharded (or fsdp)
  heads        attention-head dim              -> model
  kv_heads     kv-head dim                     -> model when divisible
  mlp          ffn hidden dim                  -> model
  expert       MoE expert dim                  -> model (when E % model == 0)
  expert_mlp   per-expert ffn dim              -> model (when experts aren't)
  vocab        vocabulary dim                  -> model
  conv / state SSM internals                   -> unsharded
  fsdp         the dim chosen for ZeRO-3       -> ("data",) / ("pod","data")
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class MeshAxes:
    POD = "pod"
    DATA = "data"
    MODEL = "model"


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> physical mesh axis (str, tuple or None)."""
    rules: Mapping[str, Any]

    def physical(self, logical: Optional[str]) -> Any:
        if logical is None:
            return None
        if logical not in self.rules:
            raise KeyError(f"unknown logical axis {logical!r}")
        return self.rules[logical]


_BASE = {
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "expert_mlp": "model",
    "vocab": "model",
    "conv": None,
    "state": None,
    "fsdp": None,           # DEFAULT: no FSDP
    "q_per_kv": None,
    "head_dim": None,
}

DEFAULT_RULES = AxisRules(dict(_BASE))
FSDP_RULES = AxisRules({**_BASE, "fsdp": "data"})


def fsdp_rules_for_mesh(mesh: Mesh) -> AxisRules:
    """FSDP over ("pod","data") when the mesh has a pod axis, else ("data",)."""
    if "pod" in mesh.axis_names:
        return AxisRules({**_BASE, "fsdp": ("pod", "data")})
    return FSDP_RULES


def logical_to_spec(axes: Sequence[Optional[str]], rules: AxisRules) -> P:
    """Tuple of logical axis names (len == ndim) -> PartitionSpec."""
    return P(*[rules.physical(a) for a in axes])


def specs_for_tree(logical_tree: Any, rules: AxisRules) -> Any:
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_to_spec(axes, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def sanitize_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (replicate them).

    Centralized divisibility guard: odd dims (SSD in_proj=3352, 25 heads,
    vocab=32001, ...) fall back to replication instead of erroring."""
    new = []
    for i, s in enumerate(spec):
        if s is None:
            new.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        new.append(s if (i < len(shape) and shape[i] % n == 0) else None)
    return P(*new)


def sanitize_specs_tree(spec_tree: Any, shape_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda sp, sh: sanitize_spec(sp, sh.shape, mesh),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P))


def named_sharding_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh, *, replicate: bool = False) -> P:
    """PartitionSpec for the leading batch dim: shard over (pod, data)."""
    if replicate:
        return P(None)
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def seq_spec(mesh: Mesh) -> Any:
    """Axis to shard a sequence dim over (sequence parallelism for batch=1)."""
    return "data" if "data" in mesh.axis_names else None
