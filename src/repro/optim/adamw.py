"""Hand-rolled AdamW (the container has no optax): f32 moments, decoupled
weight decay, global-norm clipping, warmup+cosine schedule.

ZeRO-1 happens at the sharding layer: the train-step builder gives the m/v
trees shardings that additionally split over the data(+pod) axes (see
train/step.py::zero_spec) — XLA then keeps only 1/N of the moments per
device and reduce-scatters the update."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params: Any, grads: Any, state: dict, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (update + cfg.weight_decay
                                              * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
