"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At 2 pods x 50 GB/s ICI, the cross-pod gradient reduction of a 314B-param
model is the slowest collective in the system. The classic mitigation
(1-bit Adam / EF-SGD lineage): quantize the *cross-pod* reduction to int8
with an error-feedback residual so the quantization noise is re-injected
next step instead of lost. Within-pod reductions stay full precision.

Usage (inside shard_map over the ("pod","data") axes):

    g_local = psum(g, "data")                 # full-precision within pod
    g_global, ef = compressed_psum(g_local + ef, "pod")

The pure quantize/dequantize pieces are exposed separately so the unit test
can verify the EF contraction property without a mesh."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: jnp.ndarray


def quantize_grad(g: jnp.ndarray, bits: int = 8):
    """Symmetric per-tensor quantization -> (int codes, f32 scale)."""
    qmax = (1 << (bits - 1)) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / qmax
    q = jnp.clip(jnp.round(g / scale), -qmax - 1, qmax).astype(jnp.int32)
    return q, scale.astype(jnp.float32)


def dequantize_grad(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis_name: str, bits: int = 8,
                    residual: jnp.ndarray | None = None):
    """int-quantized psum over `axis_name` with error feedback.

    Must be called inside shard_map with `axis_name` bound. Returns
    (mean-reduced g (f32), new residual)."""
    if residual is not None:
        g = g.astype(jnp.float32) + residual
    q, scale = quantize_grad(g, bits)
    # max-reduce scales so all ranks dequantize identically, then int psum
    scale = jax.lax.pmax(scale, axis_name)
    qmax = (1 << (bits - 1)) - 1
    q = jnp.clip(jnp.round(g / scale), -qmax - 1, qmax).astype(jnp.int32)
    sent = q.astype(jnp.float32) * scale
    new_residual = g - sent                      # what this rank failed to send
    total = jax.lax.psum(q, axis_name).astype(jnp.float32) * scale
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total / n, new_residual
