from repro.optim.adamw import OptConfig, adamw_init, adamw_update, lr_at  # noqa: F401
from repro.optim.compress import (  # noqa: F401
    quantize_grad, dequantize_grad, compressed_psum, ErrorFeedback,
)
