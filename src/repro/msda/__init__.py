"""repro.msda — the layered MSDeformAttn subsystem.

Four layers, one seam for every future backend:

  * :mod:`repro.msda.plan` — static :class:`MSDAPlan` resolved once per
    (config, level_shapes): backend choice, query tiling (raster AND
    decode-shaped), VMEM fit, TPU lane layout (pad Dh -> 128 vs. pack
    128/Dh heads per lane group);
  * :mod:`repro.msda.cache` — :class:`MSDAValueCache`, the projected,
    head-laid-out, optionally FWP-compacted value table built ONCE per
    memory (:func:`build_value_cache`) and sampled by every consumer —
    build-once, sample-everywhere;
  * :mod:`repro.msda.backends` — named-backend registry (``jnp_gather``,
    ``pallas_fused``, ``pallas_windowed`` — the single-launch
    multi-scale-parallel windowed kernel — and ``pallas_decode`` — the
    persistent-cache decode kernel sampling a table staged ONCE per
    memory — plus the ``auto`` policy) with a uniform
    ``(plan, v, pts, probs, cache=None) -> out`` contract;
  * :mod:`repro.msda.pipeline` / :mod:`repro.msda.attention` /
    :mod:`repro.msda.decoder` — the planned block execution threading an
    explicit :class:`MSDAPipelineState` (FWP mask chain + stats + shared
    cache) across encoder blocks and decoder layers.

Quickstart::

    from repro import msda
    plan = msda.make_plan(cfg, level_shapes, backend="auto")
    state = msda.MSDAPipelineState.initial()
    # encoder block (memory changes every block -> build + sample):
    out, state = msda.msda_attention(params, plan, q, refs, x, state=state)
    # decoder (one memory, many layers -> build once, sample everywhere):
    cache = msda.build_value_cache(params_value, plan_dec, memory, state)
    out, st = msda.msda_attention_cached(layer_params, plan_dec, q, refs,
                                         cache, update_fwp=False)
"""
from repro.msda.attention import (msda_attention, msda_attention_cached,
                                  project_values)
from repro.msda.autotune import ensure_applied, plan_autotune
from repro.msda.backends import (BackendInfo, available_backends,
                                 backend_info, candidate_backends,
                                 get_backend, register_backend)
from repro.msda.cache import MSDAValueCache, build_value_cache
from repro.msda.decoder import (MSDADecoderConfig, decoder_apply,
                                decoder_logical_axes, init_decoder)
from repro.msda.ordering import (QUERY_ORDERS, invert_queries,
                                 permute_queries, query_permutation,
                                 query_sort_keys, resolve_query_order,
                                 tile_window_stats)
from repro.msda.pipeline import MSDAPipelineState
from repro.msda.plan import (DEFAULT_VMEM_BUDGET,
                             DEFAULT_WINDOW_STAGING_BUDGET, MSDAPlan,
                             apply_tuned_plan_table, block_q_for_levels,
                             lane_layout, make_plan, next_pow2, plan_for,
                             resolve_table_dtype, staging_budget_source,
                             tuned_decode_sweep, tuned_entry,
                             tuned_generation, tuned_stream_params,
                             window_staging_budget, windowed_eligible)
from repro.msda.sampling import (SamplingPoints, corner_data,
                                 flat_gather_heads, generate_points,
                                 level_meta, select_points)

__all__ = [
    "msda_attention", "msda_attention_cached", "project_values",
    "ensure_applied", "plan_autotune",
    "BackendInfo", "available_backends", "backend_info",
    "candidate_backends", "get_backend", "register_backend",
    "MSDAValueCache", "build_value_cache",
    "MSDADecoderConfig", "decoder_apply", "decoder_logical_axes",
    "init_decoder",
    "MSDAPipelineState",
    "QUERY_ORDERS", "invert_queries", "permute_queries",
    "query_permutation", "query_sort_keys", "resolve_query_order",
    "tile_window_stats",
    "DEFAULT_VMEM_BUDGET", "DEFAULT_WINDOW_STAGING_BUDGET", "MSDAPlan",
    "apply_tuned_plan_table", "block_q_for_levels", "lane_layout",
    "make_plan", "next_pow2", "plan_for", "resolve_table_dtype",
    "staging_budget_source", "tuned_decode_sweep", "tuned_entry",
    "tuned_generation", "tuned_stream_params", "window_staging_budget",
    "windowed_eligible",
    "SamplingPoints", "corner_data", "flat_gather_heads",
    "generate_points", "level_meta", "select_points",
]
