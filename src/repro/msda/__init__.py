"""repro.msda — the layered MSDeformAttn subsystem.

Three layers, one seam for every future backend:

  * :mod:`repro.msda.plan` — static :class:`MSDAPlan` resolved once per
    (config, level_shapes): backend choice, query tiling, VMEM fit,
    TPU lane layout (pad Dh -> 128 vs. pack 128/Dh heads per lane group);
  * :mod:`repro.msda.backends` — named-backend registry (``jnp_gather``,
    ``pallas_fused``, ``pallas_windowed`` — the single-launch
    multi-scale-parallel windowed kernel — and the retired
    ``pallas_windowed_loop`` diff target, plus the ``auto`` policy) with
    a uniform ``(plan, v, pts, probs) -> out`` contract;
  * :mod:`repro.msda.pipeline` / :mod:`repro.msda.attention` — the
    planned block execution threading explicit
    :class:`MSDAPipelineState` (FWP mask chain + stats) across blocks.

Quickstart::

    from repro import msda
    plan = msda.make_plan(cfg, level_shapes, backend="auto")
    state = msda.MSDAPipelineState.initial()
    out, state = msda.msda_attention(params, plan, q, refs, x, state=state)
"""
from repro.msda.attention import msda_attention, project_values
from repro.msda.backends import (available_backends, get_backend,
                                 register_backend)
from repro.msda.pipeline import MSDAPipelineState
from repro.msda.plan import (DEFAULT_VMEM_BUDGET, MSDAPlan,
                             block_q_for_levels, lane_layout, make_plan,
                             next_pow2, plan_for, windowed_eligible)
from repro.msda.sampling import (SamplingPoints, corner_data,
                                 flat_gather_heads, generate_points,
                                 level_meta, select_points)

__all__ = [
    "msda_attention", "project_values",
    "available_backends", "get_backend", "register_backend",
    "MSDAPipelineState",
    "DEFAULT_VMEM_BUDGET", "MSDAPlan", "block_q_for_levels", "lane_layout",
    "make_plan", "next_pow2", "plan_for", "windowed_eligible",
    "SamplingPoints", "corner_data", "flat_gather_heads",
    "generate_points", "level_meta", "select_points",
]
