"""Planned MSDA execution — the algorithm level of the DEFA dataflow.

Two entry points, one seam:

  * :func:`msda_attention_cached` — the sample-everywhere half: PAP'd
    probabilities, masked sampling-point generation, backend-dispatched
    fused MSGS+aggregation, and (optionally) FWP frequency counting, all
    against a prebuilt :class:`~repro.msda.cache.MSDAValueCache`.
  * :func:`msda_attention` — the legacy monolithic block, now a thin
    build-cache-then-sample wrapper: it builds a fresh cache from
    ``x_flat`` and immediately samples it. Encoder blocks use this (their
    memory changes every block); decoder layers call the cached form
    against ONE shared cache (see ``repro/msda/decoder.py``).

The gather+aggregate step is a registry lookup — backends never leak into
this file.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import fwp as fwp_lib
from repro.core.quant import maybe_fake_quant
from repro.msda import backends as backend_registry
from repro.msda import ordering as ordering_lib
from repro.msda.cache import MSDAValueCache, build_value_cache, project_values
from repro.msda.pipeline import MSDAPipelineState
from repro.msda.plan import MSDAPlan
from repro.msda.sampling import corner_data, generate_points

__all__ = ["msda_attention", "msda_attention_cached", "project_values"]


def msda_attention_cached(
    params: dict,
    plan: MSDAPlan,
    query: jnp.ndarray,                 # (B, Nq, D)
    ref_points: jnp.ndarray,            # (B, Nq, 2) normalized
    cache: MSDAValueCache,              # prebuilt shared value table
    state: Optional[MSDAPipelineState] = None,
    *,
    collect_stats: bool = False,
    update_fwp: bool = True,
) -> Tuple[jnp.ndarray, MSDAPipelineState]:
    """One planned MSDA sampling pass against a prebuilt value cache.

    ``params`` needs the per-layer sampling weights (``attn_w``/``attn_b``,
    ``offs_w``/``offs_b``, ``out_w``/``out_b``) but NOT the value
    projection — that lives in the cache. ``update_fwp=False`` (decoder
    layers, any repeated sampling of one fixed memory) skips the frequency
    count and carries the existing FWP chain link through unchanged: the
    cache's compaction is fixed, so re-deriving a mask per layer would be
    wasted work. Returns (out (B, Nq, D), next state)."""
    cfg = plan.cfg
    b, nq, _ = query.shape
    if state is None:
        state = MSDAPipelineState.initial()
    wq = lambda w: maybe_fake_quant(w, cfg.weight_bits)

    # ---- 0. cache-local query ordering (plan policy) ---------------------
    # sort queries by reference point so each kernel tile touches a tight
    # slot window, run the whole pass permuted, and invert on the output.
    # Every per-query op below is row-independent, so the result is
    # BIT-IDENTICAL to the unordered pass (tests/test_msda_ordering.py).
    # Raster-only backends (pallas_windowed) derive their tile->window
    # geometry from raster query POSITION, so for them the permutation
    # stays off and ordering is accounting-only (plan.measured_tilewin).
    # Per-layer decoder calls re-derive the permutation here from each
    # layer's own (pre-refinement) reference points — refined refs shift
    # every layer, so no permutation is carried across layers.
    inv_perm = None
    if plan.query_order != "none" \
            and not backend_registry.backend_info(plan.backend).raster_only:
        perm, inv_perm = ordering_lib.query_permutation(
            ref_points, plan.level_shapes, plan.query_order)
        query = ordering_lib.permute_queries(query, perm)
        ref_points = ordering_lib.permute_queries(ref_points, perm)

    # ---- 1+2. PAP'd probabilities + masked point generation --------------
    # compact-table geometry rides along with the point geometry: the
    # windowed kernel locates slot windows by searchsorting keep_idx
    sel, pts = generate_points(params, cfg, query, ref_points,
                               plan.level_shapes, pix2slot=cache.pix2slot,
                               keep_idx=cache.keep_idx)

    # ---- 3. backend-dispatched fused MSGS + aggregation ------------------
    # the cache rides along as a kwarg: backends that consume build-once
    # artifacts (pallas_decode's pre-staged table) find them there,
    # everyone else ignores it
    backend = backend_registry.get_backend(plan.backend)
    out_h = backend(plan, cache.v, pts, sel.probs, cache=cache)

    out = jnp.einsum("bnhk,hkd->bnd", out_h, wq(params["out_w"])) \
        + params["out_b"]
    if inv_perm is not None:
        out = ordering_lib.invert_queries(out, inv_perm)

    # ---- 4. FWP frequency counting for the NEXT block --------------------
    need_freq = update_fwp and cfg.fwp_mode != "off"
    next_fwp = None if update_fwp else state.fwp
    stats = None
    if need_freq or collect_stats:
        pt_alive = (sel.probs > 0).astype(jnp.float32)   # pruned pts don't count
        # frequency is counted in ORIGINAL pixel space (pre-compaction)
        idx_orig, _, valid_orig = corner_data(pts.x_px, pts.y_px,
                                              pts.wl, pts.hl, pts.start)
        counted = valid_orig.astype(jnp.float32) * pt_alive[..., None]
        freq = fwp_lib.count_frequency(
            idx_orig.reshape(b, -1), counted.reshape(b, -1), plan.n_in)
        if need_freq:
            next_fwp = fwp_lib.build_fwp_state(
                freq, plan.level_shapes, k=cfg.fwp_k,
                mode=cfg.fwp_mode, capacity=cfg.fwp_capacity)
        if collect_stats:
            stats = {
                "freq": freq,
                "pap_keep_frac": sel.keep_frac,
                "point_alive_frac": jnp.mean(pt_alive),
                "value_rows": cache.n_rows,
                "cache_table_bytes": cache.table_bytes,
            }
            if update_fwp and next_fwp is not None:
                stats["fwp_keep_frac"] = 1.0 - fwp_lib.fwp_sparsity(next_fwp)
    return out, state.advance(next_fwp, stats)


def msda_attention(
    params: dict,
    plan: MSDAPlan,
    query: jnp.ndarray,                 # (B, Nq, D)
    ref_points: jnp.ndarray,            # (B, Nq, 2) normalized
    x_flat: jnp.ndarray,                # (B, N_in, D) raw fmap features
    state: Optional[MSDAPipelineState] = None,
    *,
    collect_stats: bool = False,
) -> Tuple[jnp.ndarray, MSDAPipelineState]:
    """One planned MSDA block: build the value cache, then sample it.

    Thin wrapper over :func:`~repro.msda.cache.build_value_cache` +
    :func:`msda_attention_cached` for callers whose memory changes every
    call (encoder blocks). Returns (out (B, Nq, D), next state)."""
    assert x_flat.shape[1] == plan.n_in, (x_flat.shape, plan.n_in)
    if state is None:
        state = MSDAPipelineState.initial()
    cache = build_value_cache(params, plan, x_flat, state)
    return msda_attention_cached(params, plan, query, ref_points, cache,
                                 state, collect_stats=collect_stats)
