"""Planned MSDA execution — the algorithm level of the DEFA dataflow.

``msda_attention`` runs the five paper steps (PAP'd probabilities, masked
sampling-point generation, FWP-pruned value projection, backend-dispatched
fused MSGS+aggregation, frequency counting for the next block) against a
static :class:`~repro.msda.plan.MSDAPlan`. The gather+aggregate step is a
registry lookup — backends never leak into this file.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import fwp as fwp_lib
from repro.core.quant import maybe_fake_quant
from repro.msda import backends as backend_registry
from repro.msda.pipeline import MSDAPipelineState
from repro.msda.plan import MSDAPlan
from repro.msda.sampling import SamplingPoints, corner_data, generate_points


def project_values(params: dict, cfg, x_flat: jnp.ndarray,
                   fwp_state: Optional[fwp_lib.FWPState]):
    """FWP-pruned value projection V = X W^V.

    Returns (v (B, N_rows, H, Dh), pix2slot or None, n_rows)."""
    b = x_flat.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    n_in = x_flat.shape[1]
    wq = lambda w: maybe_fake_quant(w, cfg.weight_bits)
    if fwp_state is not None and cfg.fwp_mode == "compact":
        cap = fwp_state.keep_idx.shape[1]
        x_kept = jnp.take_along_axis(x_flat, fwp_state.keep_idx[..., None], axis=1)
        v = jnp.einsum("bnd,dhk->bnhk", x_kept, wq(params["value_w"])) \
            + params["value_b"]
        v = jnp.concatenate([v, jnp.zeros((b, 1, h, dh), v.dtype)], axis=1)
        pix2slot = fwp_state.pix2slot                    # (B, N_in)
        n_rows = cap + 1
    elif fwp_state is not None and cfg.fwp_mode == "mask":
        xm = x_flat * fwp_state.keep_mask[..., None].astype(x_flat.dtype)
        v = jnp.einsum("bnd,dhk->bnhk", xm, wq(params["value_w"])) \
            + params["value_b"]
        # masked pixels must contribute EXACT zero (bias would leak):
        v = v * fwp_state.keep_mask[..., None, None].astype(v.dtype)
        pix2slot = None
        n_rows = n_in
    else:
        v = jnp.einsum("bnd,dhk->bnhk", x_flat, wq(params["value_w"])) \
            + params["value_b"]
        pix2slot = None
        n_rows = n_in
    return maybe_fake_quant(v, cfg.act_bits), pix2slot, n_rows


def msda_attention(
    params: dict,
    plan: MSDAPlan,
    query: jnp.ndarray,                 # (B, Nq, D)
    ref_points: jnp.ndarray,            # (B, Nq, 2) normalized
    x_flat: jnp.ndarray,                # (B, N_in, D) raw fmap features
    state: Optional[MSDAPipelineState] = None,
    *,
    collect_stats: bool = False,
) -> Tuple[jnp.ndarray, MSDAPipelineState]:
    """One planned MSDA block. Returns (out (B, Nq, D), next state)."""
    cfg = plan.cfg
    b, nq, _ = query.shape
    assert x_flat.shape[1] == plan.n_in, (x_flat.shape, plan.n_in)
    if state is None:
        state = MSDAPipelineState.initial()
    wq = lambda w: maybe_fake_quant(w, cfg.weight_bits)

    # ---- 1+2. PAP'd probabilities + masked point generation --------------
    v, pix2slot, n_rows = project_values(params, cfg, x_flat, state.fwp)
    # compact-table geometry rides along with the point geometry: the
    # windowed kernel locates slot windows by searchsorting keep_idx
    keep_idx = state.fwp.keep_idx if pix2slot is not None else None
    sel, pts = generate_points(params, cfg, query, ref_points,
                               plan.level_shapes, pix2slot=pix2slot,
                               keep_idx=keep_idx)

    # ---- 3. backend-dispatched fused MSGS + aggregation ------------------
    backend = backend_registry.get_backend(plan.backend)
    out_h = backend(plan, v, pts, sel.probs)             # (B, Nq, H, Dh)

    out = jnp.einsum("bnhk,hkd->bnd", out_h, wq(params["out_w"])) \
        + params["out_b"]

    # ---- 4. FWP frequency counting for the NEXT block --------------------
    need_freq = cfg.fwp_mode != "off"
    next_fwp = None
    stats = None
    if need_freq or collect_stats:
        pt_alive = (sel.probs > 0).astype(jnp.float32)   # pruned pts don't count
        # frequency is counted in ORIGINAL pixel space (pre-compaction)
        idx_orig, _, valid_orig = corner_data(pts.x_px, pts.y_px,
                                              pts.wl, pts.hl, pts.start)
        counted = valid_orig.astype(jnp.float32) * pt_alive[..., None]
        freq = fwp_lib.count_frequency(
            idx_orig.reshape(b, -1), counted.reshape(b, -1), plan.n_in)
        if need_freq:
            next_fwp = fwp_lib.build_fwp_state(
                freq, plan.level_shapes, k=cfg.fwp_k,
                mode=cfg.fwp_mode, capacity=cfg.fwp_capacity)
        if collect_stats:
            stats = {
                "freq": freq,
                "pap_keep_frac": sel.keep_frac,
                "point_alive_frac": jnp.mean(pt_alive),
                "value_rows": n_rows,
            }
            if next_fwp is not None:
                stats["fwp_keep_frac"] = 1.0 - fwp_lib.fwp_sparsity(next_fwp)
    return out, state.advance(next_fwp, stats)
