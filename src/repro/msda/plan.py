"""MSDAPlan — the static execution plan for one (config, level_shapes).

Resolved ONCE per shape family (cache it, or let ``plan_for`` memoize);
everything in it is Python-static so it can be closed over by jit'd code.
The plan decides, ahead of execution:

  * **backend** — which registered kernel runs the fused gather+aggregate
    step (``jnp_gather`` | ``pallas_fused`` | ``pallas_windowed``; the
    ``auto`` policy picks by VMEM fit, mirroring the NPU follow-up work's
    shape-specialized kernel selection — including the windowed kernel's
    co-resident staged-window sum vs. the ``REPRO_MSDA_VMEM_BUDGET``
    staging budget);
  * **query tiling** — a global ``block_q`` plus the per-level clamp
    ``block_q_levels[l] = min(block_q, next_pow2(nq_l))`` and the
    single-launch windowed kernel's uniform ``tile_q``, with the
    windowed/compact staged-VMEM accounting (``window_bytes`` /
    ``window_bytes_compact``). Decode-shaped workloads (N_q learned
    queries instead of N_in raster queries — pass ``n_queries``) clamp
    ``block_q`` to ``next_pow2(N_q)``: a 300-query decoder launch must
    not tile as if it had 20k encoder queries;
  * **VMEM fit** — whether the whole per-(batch, head-group) value table
    fits the configured VMEM slab (fused whole-table kernel) or only a
    bounded window does (windowed kernel, needs range-narrowing);
  * **TPU lane layout** — Dh is usually 32 in the DETR family, far below
    the 128-lane vector width. The plan either pads Dh -> 128 (7/8 of the
    lanes idle) or *packs* ``128 // Dh`` heads per lane group so one
    staged table row carries several heads (``head_pack``).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import fwp as fwp_lib

#: Default VMEM slab reserved for the fused kernel's staged value table.
#: Real TPU cores have ~16 MB of VMEM; half is left for the double-buffered
#: point/output tiles and the rest of the program.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024

#: Conservative default budget for the windowed kernel's co-resident staged
#: window sum (all L level windows live in VMEM at once, next to the
#: double-buffered point/output tiles). Pin with the
#: ``REPRO_MSDA_VMEM_BUDGET`` env var (bytes); the measured ceiling comes
#: from the autotuner (:func:`repro.msda.autotune.plan_autotune`), which
#: replaces this static guess when a per-platform table entry is applied.
DEFAULT_WINDOW_STAGING_BUDGET = 4 * 1024 * 1024

_LANE_WIDTH = 128

# --------------------------------------------------------------------------
# Measured plan table (written by repro.msda.autotune, read everywhere)
# --------------------------------------------------------------------------
# plan.py OWNS the applied-calibration state so stream/ and serve/ can
# consult it through plain accessors without importing autotune (which
# imports plan — the other direction would be a cycle). autotune.py is the
# only writer; ``_TUNED_GENERATION`` bumps on every apply/clear so memo
# keys built on the resolved values stay exact even if two different
# tables happen to resolve the same budget.

_TUNED: Optional[dict] = None
_TUNED_GENERATION = 0


def apply_tuned_plan_table(entry: Optional[dict]) -> None:
    """Install (or with ``None`` clear) one platform's measured calibration
    entry — ``staging_budget_bytes``, the streaming crossover under
    ``stream``, and the ``decode_sweep_beneficial`` verdict. Every plan
    resolved afterwards sees the measured values; ``plan_for``'s memo is
    keyed on the resolved budget + provenance, so no stale plan survives
    the switch."""
    global _TUNED, _TUNED_GENERATION
    _TUNED = dict(entry) if entry is not None else None
    _TUNED_GENERATION += 1


def tuned_entry() -> Optional[dict]:
    """The currently applied autotune entry (None => static formulas)."""
    return None if _TUNED is None else dict(_TUNED)


def tuned_generation() -> int:
    return _TUNED_GENERATION


def tuned_stream_params() -> Optional[dict]:
    """The measured streaming crossover ({diff_channel_stride,
    update_frac}) of the applied entry, or None — consumed by
    :func:`repro.stream.temporal.resolve_stream_config`."""
    if _TUNED is None:
        return None
    s = _TUNED.get("stream")
    return dict(s) if isinstance(s, dict) else None


def tuned_decode_sweep() -> Optional[bool]:
    """The measured verdict on whether the ``pallas_decode`` (query-tile x
    layer) sweep actually spares the HBM->VMEM table refetch on this
    platform. None => no measurement applied (the static assumption —
    that it does — stands)."""
    if _TUNED is None:
        return None
    v = _TUNED.get("decode_sweep_beneficial")
    return None if v is None else bool(v)


@functools.lru_cache(maxsize=16)
def _parse_budget_env(raw: str) -> int:
    """Parse one observed ``REPRO_MSDA_VMEM_BUDGET`` value.

    Cached per distinct raw string: the parse (and its validation) runs
    once per process for a stable env, while CHANGING the env mid-process
    still re-parses (and ``plan_for`` keys its memo on the resolved
    budget, so no stale plan is served either way)."""
    try:
        # decimal (leading zeros allowed) or explicit 0x.. hex
        base = 16 if raw.strip().lower().lstrip("+-").startswith("0x") else 10
        value = int(raw, base)
    except ValueError:
        raise ValueError(
            f"REPRO_MSDA_VMEM_BUDGET must be an integer byte count "
            f"(e.g. 4194304), got {raw!r}") from None
    if value <= 0:
        raise ValueError(
            f"REPRO_MSDA_VMEM_BUDGET must be a positive byte count, "
            f"got {value}")
    return value


def window_staging_budget() -> int:
    """The windowed kernel's staged-window budget.

    Precedence: the ``REPRO_MSDA_VMEM_BUDGET`` env pin (an operator
    override always wins — the documented way to pin static budgets) >
    the applied autotune entry's measured ceiling > the conservative
    static default."""
    env = os.environ.get("REPRO_MSDA_VMEM_BUDGET")
    if env:
        return _parse_budget_env(env)
    if _TUNED is not None:
        b = _TUNED.get("staging_budget_bytes")
        if isinstance(b, int) and b > 0:
            return b
    return DEFAULT_WINDOW_STAGING_BUDGET


def staging_budget_source() -> str:
    """Provenance of :func:`window_staging_budget`'s current answer:
    ``"measured"`` when an autotune entry supplies it, else ``"static"``
    (the default constant, or an explicit env pin — a pin is an
    operator's static decision even when a table is applied)."""
    if os.environ.get("REPRO_MSDA_VMEM_BUDGET"):
        return "static"
    if _TUNED is not None and isinstance(
            _TUNED.get("staging_budget_bytes"), int) \
            and _TUNED["staging_budget_bytes"] > 0:
        return "measured"
    return "static"


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (max(1, int(n)) - 1).bit_length()


#: Table storage dtypes the cache/kernels understand. ``int8`` is the
#: quantized layout (codes + per-channel f32 scale, dequantized in-register
#: after the corner gather); the float dtypes store the table verbatim.
_TABLE_DTYPES = ("int8", "float32", "bfloat16", "float16")


def resolve_table_dtype(cfg, override: Optional[str] = None) -> str:
    """Resolve the value-table storage dtype for one config.

    Precedence: explicit ``override`` (the ``make_plan`` kwarg) >
    ``cfg.table_dtype`` > the ``REPRO_MSDA_TABLE_DTYPE`` env var >
    ``cfg.dtype`` (store the table in the compute dtype — the pre-int8
    behaviour). Returns a canonical dtype name string."""
    choice = override
    if choice is None:
        choice = getattr(cfg, "table_dtype", None)
    if choice is None:
        choice = os.environ.get("REPRO_MSDA_TABLE_DTYPE") or None
    if choice is None:
        return jnp.dtype(cfg.dtype).name
    name = jnp.dtype(choice).name
    if name not in _TABLE_DTYPES:
        raise ValueError(
            f"unsupported MSDA table dtype {name!r}; "
            f"supported: {_TABLE_DTYPES}")
    return name


def block_q_for_levels(level_shapes: Sequence[Tuple[int, int]],
                       block_q: int) -> Tuple[int, ...]:
    """Per-query-level tile size: ``min(block_q, next_pow2(nq_l))``.

    A global 128 tile would pad the (2, 3) level's 6 queries and the
    (4, 5) level's 20 queries all the way to 128; clamping to the next
    power of two keeps the tiny levels' tiles tiny."""
    return tuple(min(block_q, next_pow2(h * w)) for h, w in level_shapes)


def lane_layout(n_heads: int, head_dim: int) -> Tuple[str, int]:
    """TPU last-dim layout for a (rows, Dh) value table.

    Returns (layout, head_pack): ``("native", 1)`` when Dh already fills
    the 128-lane width, ``("pack", g)`` when g = gcd(n_heads, 128 // Dh)
    heads can share one lane group, else ``("pad", 1)``."""
    if head_dim % _LANE_WIDTH == 0:
        return "native", 1
    if head_dim < _LANE_WIDTH and _LANE_WIDTH % head_dim == 0:
        g = math.gcd(n_heads, _LANE_WIDTH // head_dim)
        if g > 1:
            return "pack", g
    return "pad", 1


def value_rows(level_shapes: Sequence[Tuple[int, int]]) -> int:
    """WORST-CASE rows of the value table a backend gathers from.

    FWP-compact shrinks the table from block 2 onward, but block 1 always
    runs unpruned (there is no mask yet), so the VMEM-fit decision must be
    made against the full n_in-row table — a plan that only fits the
    compacted table would blow VMEM on the first block."""
    _, n_in = fwp_lib.level_starts(level_shapes)
    return n_in


def windowed_eligible(cfg) -> bool:
    """The windowed kernel needs a finite sampling radius (C3) to bound
    its fmap window — without range-narrowing there is no window."""
    return cfg.range_narrow is not None


def _table_bytes(n_rows: int, lanes: int, itemsize: int, n_in: int,
                 with_indirection: bool, scale_row: bool = False) -> int:
    """THE value-table staging formula: rows x lanes x itemsize, plus the
    int32 pix2slot indirection when compacted, plus ONE f32 scale row
    when the table is stored quantized (the per-channel dequant scale the
    kernels stage next to the codes). Single source for
    ``MSDAPlan.table_bytes_for_rows``/``cache_table_bytes`` AND the auto
    policy's pre-construction decode gate — they must never diverge."""
    b = n_rows * lanes * itemsize
    if with_indirection:
        b += n_in * 4
    if scale_row:
        b += lanes * 4
    return b


@dataclasses.dataclass(frozen=True)
class MSDAPlan:
    """Static per-(config, level_shapes) execution plan. Hashable."""
    cfg: "object"                                   # MSDeformAttnConfig
    level_shapes: Tuple[Tuple[int, int], ...]
    backend: str                 # resolved registry name (never "auto")
    block_q: int                 # query tile for the Pallas kernels
    lane_layout: str             # "native" | "pad" | "pack"
    head_pack: int               # heads per 128-lane group (1 unless packed)
    vmem_budget_bytes: int
    value_table_bytes: int       # staged (rows, lanes) slab for pallas_fused
    n_in: int                    # total flat pixels across levels
    block_q_levels: Tuple[int, ...] = ()   # per-query-level tile size:
    #   min(block_q, next_pow2(nq_l)) — the (2,3) level tiles 6 queries
    #   as 8, not 128 (raster-query launches only)
    tile_q: int = 128            # uniform tile of the single-launch
    #   multi-scale-parallel windowed kernel (= max(block_q_levels))
    window_bytes: Optional[int] = None           # dense fmap window staged
    #   per grid step by the windowed kernel (max over tile x level pairs)
    window_bytes_compact: Optional[int] = None   # FWP-compact-native window:
    #   slot window of the compacted table + the pix2slot window slice —
    #   the VMEM the windowed kernel actually stages when fwp_mode=compact
    n_queries: Optional[int] = None   # decode-shaped launches: the learned
    #   query count (None => raster encoder queries, Nq == n_in)
    n_consumers: int = 1          # attention layers sharing ONE built value
    #   cache (decoder: n_layers); drives the build-once staged-bytes
    #   accounting in describe()
    decode_operand_bytes: Optional[int] = None   # persistent decode kernel:
    #   per-layer point/probability/output blocks staged per
    #   (batch, head-group) launch step — the part that IS per-layer even
    #   when the table is staged once (stacked n_consumers x in describe())
    stream_update_rows: Optional[int] = None     # streaming temporal reuse:
    #   static per-frame re-projection budget (table rows refreshed by an
    #   incremental frame update); None => no streaming consumer. Drives
    #   the rebuild-vs-incremental staged-bytes accounting in describe()
    #   and the TemporalCacheManager's update capacity (repro/stream/)
    table_dtype: str = "float32"  # value-TABLE storage dtype (resolved by
    #   resolve_table_dtype): "int8" => the cache stores int8 codes + a
    #   per-channel f32 scale row, kernels dequantize in-register, and
    #   every bytes figure below is 1-byte-per-element + the scale row
    query_order: str = "none"     # cache-local query ordering policy
    #   (resolved by repro.msda.ordering.resolve_query_order): "raster" |
    #   "zorder" sort queries by reference point before sampling and
    #   invert the permutation on output — numerics bit-identical,
    #   per-tile staged windows tighter. Raster-only backends keep their
    #   queries unpermuted (their window geometry is raster-derived)
    measured_tilewin: Optional[Tuple[int, int, int, int]] = None
    #   MEASURED per-tile window bytes for a concrete query set
    #   (with_measured_tile_window): (unordered max, unordered mean,
    #   ordered max, ordered mean) — the ordered/unordered ratio is the
    #   quantity query ordering improves; surfaced by describe()
    staging_budget_bytes: int = DEFAULT_WINDOW_STAGING_BUDGET
    #   the staged-window budget the auto policy's windowed/decode gates
    #   were evaluated against — resolved ONCE at make_plan (env pin >
    #   applied autotune entry > static default), never re-read later
    budget_source: str = "static"   # provenance of staging_budget_bytes:
    #   "measured" (autotune table) | "static" (default or env pin) —
    #   describe()'s ``budget=`` tag, and part of plan_for's memo key

    @property
    def quantized_table(self) -> bool:
        """True when the table is stored as int8 codes + f32 scale."""
        return self.table_dtype == "int8"

    @property
    def table_itemsize(self) -> int:
        return jnp.dtype(self.table_dtype).itemsize

    @property
    def fits_vmem(self) -> bool:
        return self.value_table_bytes <= self.vmem_budget_bytes

    @property
    def decode_shaped(self) -> bool:
        """True for learned-query (decoder-style) launches."""
        return self.n_queries is not None and self.n_queries != self.n_in

    @property
    def decode_head_pack(self) -> int:
        """Heads per lane group for the persistent decode staging — THE
        single source for every consumer (cache staging, backend
        fallback): the staged layout and the kernel BlockSpecs sized
        against it must always agree."""
        return self.head_pack if self.lane_layout == "pack" else 1

    def table_bytes_for_rows(self, n_rows: int,
                             with_indirection: bool) -> int:
        """Bytes staged per (batch, head-group) for an ``n_rows`` value
        table under this plan's lane layout, plus the int32 ``pix2slot``
        indirection when the table is compacted. The ONE formula behind
        both the static plan estimate (:attr:`cache_table_bytes`) and the
        built cache's actual accounting (``MSDAValueCache.table_bytes``).
        Itemsize comes from the TABLE dtype (int8 tables stage 1-byte
        codes plus one f32 scale row), not the compute dtype."""
        lanes = self.cfg.head_dim if self.lane_layout == "native" \
            else _LANE_WIDTH
        return _table_bytes(n_rows, lanes, self.table_itemsize, self.n_in,
                            with_indirection, scale_row=self.quantized_table)

    @property
    def cache_table_bytes(self) -> int:
        """STATIC estimate of the bytes staged per (batch, head-group) to
        build the value cache once. Assumes the FWP compaction is in
        effect; the actually-built table's accounting is
        ``MSDAValueCache.table_bytes`` (dense until the first FWP link
        exists)."""
        if self.cfg.fwp_mode == "compact":
            caps = fwp_lib.level_capacities(self.level_shapes,
                                            self.cfg.fwp_capacity)
            return self.table_bytes_for_rows(sum(caps) + 1,
                                             with_indirection=True)
        return self.table_bytes_for_rows(self.n_in, with_indirection=False)

    def with_measured_tile_window(self, ref_points) -> "MSDAPlan":
        """Measure per-tile window bytes for a CONCRETE query set and
        return a plan carrying the figures (``measured_tilewin``).

        The static ``window_bytes`` accounting is a worst case over
        raster tiles; this runs the same span formula over ``tile_q``
        consecutive queries of the given reference points — once in
        arrival order, once under this plan's ordering policy (falling
        back to ``raster`` when the plan order is ``none``, so the
        accounting always shows what ordering would buy). The DENSE
        window is measured (the same headline as ``window_bytes`` — the
        staging worst case; the FWP capacity clamp saturates both
        figures identically, see ``tile_window_stats``'s ``capacity``
        kwarg for the compact variant). Host-side numpy; needs
        ``cfg.range_narrow`` (no bound => no finite window => returns
        self unchanged)."""
        if self.cfg.range_narrow is None:
            return self
        from repro.msda import ordering
        lanes = self.cfg.head_dim if self.lane_layout == "native" \
            else _LANE_WIDTH
        order = self.query_order if self.query_order != "none" else "raster"
        kw = dict(level_shapes=self.level_shapes,
                  ranges=tuple(float(r) for r in self.cfg.range_narrow),
                  tile_q=self.tile_q, lanes=lanes,
                  itemsize=self.table_itemsize)
        un = ordering.tile_window_stats(ref_points, order="none", **kw)
        od = ordering.tile_window_stats(ref_points, order=order, **kw)
        return dataclasses.replace(
            self, measured_tilewin=(un["max_bytes"], int(un["mean_bytes"]),
                                    od["max_bytes"], int(od["mean_bytes"])))

    def snapshot(self) -> dict:
        """Structured twin of :meth:`describe`: every static decision
        and staged-bytes figure as plain JSON-able values.

        ``describe()`` is a *formatter* over this dict; exporters,
        ``make_experiments_md`` and the obs dashboard consume the dict
        directly — no string parsing.  ``decode`` / ``stream`` are
        ``None`` unless the plan has those consumers."""
        snap = {
            "backend": self.backend,
            "block_q": self.block_q,
            "block_q_levels": list(self.block_q_levels),
            "tile_q": self.tile_q,
            "lane_layout": self.lane_layout,
            "head_pack": self.head_pack,
            "table_dtype": self.table_dtype,
            "quantized_table": self.quantized_table,
            "value_table_bytes": self.value_table_bytes,
            "vmem_budget_bytes": self.vmem_budget_bytes,
            "fits_vmem": self.fits_vmem,
            "staging_budget_bytes": self.staging_budget_bytes,
            "budget_source": self.budget_source,
            "window_bytes": self.window_bytes,
            "window_bytes_compact": self.window_bytes_compact,
            "query_order": self.query_order,
            "measured_tilewin": (list(self.measured_tilewin)
                                 if self.measured_tilewin is not None
                                 else None),
            "n_in": self.n_in,
            "level_shapes": [list(s) for s in self.level_shapes],
            "decode": None,
            "stream": None,
        }
        if self.decode_shaped:
            cb = self.cache_table_bytes
            snap["decode"] = {
                "n_queries": self.n_queries,
                "n_consumers": self.n_consumers,
                "cache_table_bytes": cb,
                # staging the cache once vs rebuilding per consumer layer
                "rebuild_bytes": self.n_consumers * cb,
                "decode_operand_bytes": self.decode_operand_bytes,
            }
        if self.stream_update_rows is not None:
            snap["stream"] = {
                "update_rows": self.stream_update_rows,
                # incremental frame update: at most update_rows table rows
                # re-staged (no pix2slot restage between keep transitions)
                # vs a full per-frame cache rebuild
                "update_bytes": self.table_bytes_for_rows(
                    self.stream_update_rows, with_indirection=False),
                "rebuild_bytes": self.cache_table_bytes,
            }
        return snap

    def describe(self) -> str:
        """One-line human summary of every static decision — a pure
        formatter over :meth:`snapshot`.

        ``win=`` reports the windowed kernel's staged-VMEM accounting:
        the dense per-step window, plus (when FWP-compact is on) the
        compact-native window actually staged instead. Decode-shaped
        plans report ``q=decode(Nq)`` and the build-once value-cache
        accounting: staging the cache ONCE vs. rebuilding it for each of
        the ``n_consumers`` layers."""
        s = self.snapshot()
        win = ""
        if s["window_bytes"] is not None:
            win = f", win={s['window_bytes']/1024:.0f}KB"
            if s["window_bytes_compact"] is not None:
                win += f"(compact {s['window_bytes_compact']/1024:.0f}KB)"
        if s["query_order"] != "none":
            win += f", order={s['query_order']}"
        if s["measured_tilewin"] is not None:
            # measured per-tile staged window (with_measured_tile_window):
            # unordered -> ordered, max and mean over query tiles
            umax, umean, omax, omean = s["measured_tilewin"]
            win += (f", tilewin={umax/1024:.0f}->{omax/1024:.0f}KB max / "
                    f"{umean/1024:.0f}->{omean/1024:.0f}KB mean "
                    f"({umean/max(omean, 1):.1f}x)")
        q = ""
        if s["decode"] is not None:
            d = s["decode"]
            cb = d["cache_table_bytes"]
            q = (f", q=decode({d['n_queries']}), "
                 f"cache={cb/1024:.0f}KB build-once")
            if d["n_consumers"] > 1:
                q += (f" (vs {d['n_consumers']}-layer rebuild "
                      f"{d['rebuild_bytes']/1024:.0f}KB, "
                      f"{float(d['n_consumers']):.1f}x)")
            if s["backend"] == "pallas_decode" \
                    and d["decode_operand_bytes"] is not None:
                # persistent decode staging: the table is staged ONCE per
                # (batch, head-group) per memory; only the stacked
                # per-layer operands scale with the layer count — vs. the
                # n_consumers x table restage a per-layer fused launch pays
                ob = d["decode_operand_bytes"]
                q += (f", staged=1x{cb/1024:.0f}KB table + "
                      f"{d['n_consumers']}x{ob/1024:.0f}KB operands "
                      f"(vs {d['n_consumers']}x table restage "
                      f"{d['rebuild_bytes']/1024:.0f}KB)")
        if s["stream"] is not None:
            st = s["stream"]
            q += (f", stream<={st['update_rows']}rows/frame "
                  f"({st['update_bytes']/1024:.0f}KB vs "
                  f"{st['rebuild_bytes']/1024:.0f}KB rebuild, "
                  f"{st['rebuild_bytes']/max(st['update_bytes'], 1):.1f}x)")
        return (f"MSDAPlan(backend={s['backend']}, block_q={s['block_q']}, "
                f"block_q_levels={tuple(s['block_q_levels'])}, "
                f"lanes={s['lane_layout']}x{s['head_pack']}, "
                f"tdtype={s['table_dtype']}, "
                f"table={s['value_table_bytes']/1024:.0f}KB/"
                f"{s['vmem_budget_bytes']/1024:.0f}KB, "
                f"budget={s['budget_source']}"
                f"({s['staging_budget_bytes']/1024:.0f}KB){win}{q}, "
                f"n_in={s['n_in']})")


def make_plan(cfg, level_shapes: Sequence[Tuple[int, int]], *,
              backend: Optional[str] = None,
              block_q: int = 128,
              vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
              n_queries: Optional[int] = None,
              n_consumers: int = 1,
              stream_update_rows: Optional[int] = None,
              table_dtype: Optional[str] = None,
              query_order: Optional[str] = None,
              measured_window_bytes: Optional[int] = None,
              staging_budget_bytes: Optional[int] = None,
              budget_source: Optional[str] = None) -> MSDAPlan:
    """Resolve the static plan.

    Backend precedence: explicit ``backend`` arg > ``cfg.backend`` >
    the legacy ``cfg.impl`` string ("pallas" -> pallas_fused, "jnp" ->
    jnp_gather). Any of them may be ``"auto"``: fused whole-table kernel
    when the staged value table fits the VMEM budget; else the windowed
    kernel when range-narrowing bounds the window AND the worst-case
    co-resident staged window sum — ``max(window_bytes,
    window_bytes_compact)``, since block 1 of a compact chain stages the
    dense windows — fits the staging budget; else the jnp gather.

    ``staging_budget_bytes`` / ``budget_source``: the staged-window budget
    the windowed/decode gates evaluate against, and its provenance
    (``"measured"`` | ``"static"``). Both default to the process-wide
    resolution (``REPRO_MSDA_VMEM_BUDGET`` env pin > applied autotune
    entry > ``DEFAULT_WINDOW_STAGING_BUDGET``) — resolved ONCE here and
    recorded on the plan, so every gate below and every later consumer
    sees the same number (no double read racing a mid-process env or
    table change). ``plan_for`` passes the exact values it keyed its
    memo on.

    ``n_queries``: the query count for decode-shaped workloads (learned
    queries, Nq != N_in). It (a) keeps ``auto`` from planning the windowed
    kernel, whose raster-query precondition is already known to fail,
    (b) clamps ``block_q`` to ``next_pow2(n_queries)`` — N_q≈300 decoder
    launches are a different tiling regime than N_in≈20k encoder launches
    — and (c) lets ``auto`` plan the persistent-cache decode kernel
    (``pallas_decode``) when the once-staged compact table plus one
    layer's operand blocks fit both the VMEM budget and the staging
    budget — unless an applied autotune entry measured the (query-tile x
    layer) sweep as NOT sparing the table refetch on this platform
    (``tuned_decode_sweep() is False``), in which case ``auto`` falls
    back to the per-layer fused kernel.

    ``n_consumers``: how many attention layers will sample ONE built value
    cache (decoder: n_layers). Accounting only — surfaced by
    ``describe()`` and the fmap-reuse benchmark.

    ``stream_update_rows``: the streaming temporal-reuse consumer's static
    per-frame re-projection budget (see ``repro/stream/``). Accounting +
    capacity only — surfaced by ``describe()`` and consumed by the
    ``TemporalCacheManager`` as its incremental update cap.

    ``table_dtype``: value-table storage dtype override; resolution is
    arg > ``cfg.table_dtype`` > ``REPRO_MSDA_TABLE_DTYPE`` > ``cfg.dtype``
    (:func:`resolve_table_dtype`). Every staged-bytes figure below — the
    fused whole-table fit, the windowed staged-window sums, the decode
    gate — is computed with the TABLE itemsize, so an int8 table lets the
    ``auto`` policy admit ~4x more rows per budget.

    ``query_order``: cache-local query ordering policy; resolution is
    arg > ``cfg.query_order`` > ``REPRO_MSDA_QUERY_ORDER`` > ``"none"``
    (:func:`repro.msda.ordering.resolve_query_order`).

    ``measured_window_bytes``: a MEASURED per-tile staged-window figure
    for the actual (ordered) query set — e.g. ``max_bytes`` from
    :func:`repro.msda.ordering.tile_window_stats`. When provided, the
    ``auto`` policy's windowed VMEM-fit check uses it in place of the
    static worst case when it is tighter: an ordered query set whose
    measured windows fit the staging budget can plan the windowed kernel
    even though the unordered worst case would not."""
    from repro.msda import backends as backend_registry
    from repro.msda import ordering as ordering_lib

    level_shapes = tuple((int(h), int(w)) for h, w in level_shapes)
    if staging_budget_bytes is None:
        staging_budget_bytes = window_staging_budget()
    if budget_source is None:
        budget_source = staging_budget_source()
    _, n_in = fwp_lib.level_starts(level_shapes)
    layout, pack = lane_layout(cfg.n_heads, cfg.head_dim)
    itemsize = jnp.dtype(cfg.dtype).itemsize
    qorder = ordering_lib.resolve_query_order(cfg, query_order)
    tdtype = resolve_table_dtype(cfg, table_dtype)
    t_item = jnp.dtype(tdtype).itemsize
    quantized = tdtype == "int8"
    lanes = cfg.head_dim if layout == "native" else _LANE_WIDTH
    scale_extra = lanes * 4 if quantized else 0
    table_bytes = value_rows(level_shapes) * lanes * t_item + scale_extra

    decode_shaped = n_queries is not None and n_queries != n_in
    decode_operand_bytes = None
    cache_bytes = None
    if decode_shaped:
        block_q = min(block_q, next_pow2(n_queries))
        block_q_levels = (block_q,)
        tile_q = block_q
        # Persistent decode staging accounting (the ``_table_bytes``
        # formula behind table_bytes_for_rows/cache_table_bytes, computed
        # pre-construction because the auto policy consults it): the
        # compact table + pix2slot staged ONCE, plus the per-layer point
        # operand blocks (x/y/probs + int32 st/wl/hl + the output tile)
        # staged per (batch, head-group) launch step. The gate uses the
        # WORST CASE — a decoder fed no FWP link (state=None, or fwp off)
        # stages the DENSE n_in-row table (same argument as value_rows()
        # and the windowed branch's max(dense, compact) rule below).
        cache_bytes = _table_bytes(n_in, lanes, t_item, n_in, False,
                                   scale_row=quantized)
        if cfg.fwp_mode == "compact":
            caps = fwp_lib.level_capacities(level_shapes, cfg.fwp_capacity)
            cache_bytes = max(cache_bytes,
                              _table_bytes(sum(caps) + 1, lanes, t_item,
                                           n_in, True, scale_row=quantized))
        g = pack if layout == "pack" else 1
        decode_operand_bytes = (block_q * g * cfg.n_lp
                                * (3 * itemsize + 3 * 4)
                                + block_q * g * cfg.head_dim * itemsize)
    else:
        block_q_levels = block_q_for_levels(level_shapes, block_q)
        tile_q = max(block_q_levels)

    # Windowed staged-window accounting (raster launches only: the windowed
    # kernel has no decode-shaped mode). Needed BEFORE backend selection —
    # the auto policy consults it.
    window_bytes = window_bytes_compact = None
    if windowed_eligible(cfg) and not decode_shaped:
        from repro.kernels.msgs_windowed import window_geometry
        geo = window_geometry(level_shapes,
                              tuple(float(r) for r in cfg.range_narrow),
                              tile_q)
        window_bytes = geo.staged_bytes(lanes, t_item) + scale_extra
        if cfg.fwp_mode == "compact":
            caps = fwp_lib.level_capacities(level_shapes, cfg.fwp_capacity)
            window_bytes_compact = geo.staged_bytes(lanes, t_item,
                                                    caps=caps) + scale_extra

    requested = backend
    if requested is None:
        requested = getattr(cfg, "backend", None)
    if requested is None:
        legacy = {"jnp": "jnp_gather", "pallas": "pallas_fused"}
        requested = legacy.get(cfg.impl, cfg.impl)

    if requested == "auto":
        if decode_shaped:
            # Persistent decode gate (extends the staging-budget gate):
            # the once-staged compact table + one layer's operand blocks
            # must co-reside in the staging slab AND fit the kernel's
            # VMEM budget. When they do, the decode kernel is better than
            # re-staging the table per layer — the static assumption the
            # autotuner checks: a measured verdict that the (query-tile x
            # layer) sweep does NOT spare the refetch on this platform
            # vetoes it.
            staged_decode = cache_bytes + decode_operand_bytes
            if staged_decode <= min(vmem_budget_bytes,
                                    staging_budget_bytes) \
                    and tuned_decode_sweep() is not False:
                requested = "pallas_decode"
            elif table_bytes <= vmem_budget_bytes:
                requested = "pallas_fused"
            else:
                requested = "jnp_gather"
        else:
            # WORST-CASE co-resident staged sum across the chain: block 1
            # of a compact chain has no FWP link yet, so it stages the
            # DENSE level windows — the compact number only holds from
            # block 2 onward (same argument as value_rows() for the fused
            # table). Both accounting fields are consulted; the max is
            # what must fit.
            staged = None if window_bytes is None \
                else max(window_bytes, window_bytes_compact or 0)
            if staged is not None and measured_window_bytes is not None:
                # the caller measured the ACTUAL (ordered) per-tile
                # windows — admit the windowed kernel on the tighter of
                # the static worst case and the measured figure
                staged = min(staged, int(measured_window_bytes))
            windowed_fits = staged is not None \
                and staged <= staging_budget_bytes
            if table_bytes <= vmem_budget_bytes:
                requested = "pallas_fused"
            elif windowed_eligible(cfg) and windowed_fits:
                requested = "pallas_windowed"
            else:
                requested = "jnp_gather"

    if requested not in backend_registry.available_backends():
        raise ValueError(
            f"unknown MSDA backend {requested!r}; "
            f"available: {backend_registry.available_backends()}")
    info = backend_registry.backend_info(requested)
    if requested.startswith("pallas_windowed") and not windowed_eligible(cfg):
        raise ValueError(f"{requested} needs cfg.range_narrow set "
                         "(the bound IS what makes the fmap window finite)")
    if info.raster_only and decode_shaped:
        raise ValueError(
            f"{requested} needs raster encoder queries (Nq == N_in); "
            f"decode-shaped launches (n_queries={n_queries}) must plan "
            "jnp_gather, pallas_fused, or pallas_decode")
    if info.decode_only and not decode_shaped:
        raise ValueError(
            f"{requested} is a decode-shaped backend (N_q learned "
            f"queries): pass n_queries != N_in, or plan a raster backend")

    return MSDAPlan(cfg=cfg, level_shapes=level_shapes, backend=requested,
                    block_q=block_q, lane_layout=layout, head_pack=pack,
                    vmem_budget_bytes=vmem_budget_bytes,
                    value_table_bytes=table_bytes, n_in=n_in,
                    block_q_levels=block_q_levels, tile_q=tile_q,
                    window_bytes=window_bytes,
                    window_bytes_compact=window_bytes_compact,
                    n_queries=n_queries, n_consumers=n_consumers,
                    decode_operand_bytes=decode_operand_bytes,
                    stream_update_rows=stream_update_rows,
                    table_dtype=tdtype, query_order=qorder,
                    staging_budget_bytes=staging_budget_bytes,
                    budget_source=budget_source)


def plan_for(cfg, level_shapes: Tuple[Tuple[int, int], ...],
             backend: Optional[str] = None,
             n_queries: Optional[int] = None,
             n_consumers: int = 1) -> MSDAPlan:
    """Memoized make_plan for hot call sites (the compat shim and the
    serve engine's per-bucket plans).

    The memo is keyed on RESOLVED values, never raw env strings or table
    identity: the staging budget (env pin > applied autotune entry >
    static default) plus its provenance and the tuned-table generation,
    the table dtype (``REPRO_MSDA_TABLE_DTYPE``), the query order
    (``REPRO_MSDA_QUERY_ORDER``), and the decode-sweep verdict. Changing
    any env var — or applying/clearing an autotune table — mid-process
    must not serve a stale plan; every resolved value is then PASSED
    INTO make_plan rather than re-read there, so the plan built on a
    cache miss is exactly the plan the key promised (no double-read race
    against a concurrent env/table change)."""
    from repro.msda import ordering as ordering_lib
    return _plan_for_cached(cfg, level_shapes, backend, n_queries,
                            n_consumers, window_staging_budget(),
                            staging_budget_source(), tuned_generation(),
                            resolve_table_dtype(cfg),
                            ordering_lib.resolve_query_order(cfg))


@functools.lru_cache(maxsize=256)
def _plan_for_cached(cfg, level_shapes, backend, n_queries, n_consumers,
                     staging_budget: int, budget_source: str,
                     _tuned_gen: int, table_dtype: str,
                     query_order: str) -> MSDAPlan:
    return make_plan(cfg, level_shapes, backend=backend, n_queries=n_queries,
                     n_consumers=n_consumers, table_dtype=table_dtype,
                     query_order=query_order,
                     staging_budget_bytes=staging_budget,
                     budget_source=budget_source)


def level_shapes_for_resolution(resolution: int,
                                strides: Tuple[int, ...] = (4, 8, 16, 32)
                                ) -> Tuple[Tuple[int, int], ...]:
    """The square pyramid level shapes of one serving resolution bucket.

    Mirrors ``DetectorConfig.level_shapes`` (img_size // stride per
    level) but validates divisibility up front: a bucket resolution that
    does not divide every stride would silently truncate the pyramid and
    desynchronize the plan's geometry from the detector's."""
    r = int(resolution)
    if r <= 0:
        raise ValueError(f"bucket resolution must be positive, got {r}")
    bad = [s for s in strides if r % s]
    if bad:
        raise ValueError(
            f"bucket resolution {r} is not divisible by pyramid "
            f"stride(s) {bad}; serving buckets must be multiples of "
            f"{max(strides)}")
    return tuple((r // s, r // s) for s in strides)
