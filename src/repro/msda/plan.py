"""MSDAPlan — the static execution plan for one (config, level_shapes).

Resolved ONCE per shape family (cache it, or let ``plan_for`` memoize);
everything in it is Python-static so it can be closed over by jit'd code.
The plan decides, ahead of execution:

  * **backend** — which registered kernel runs the fused gather+aggregate
    step (``jnp_gather`` | ``pallas_fused`` | ``pallas_windowed`` |
    ``pallas_windowed_loop``; the ``auto`` policy picks by VMEM fit,
    mirroring the NPU follow-up work's shape-specialized kernel
    selection);
  * **query tiling** — a global ``block_q`` plus the per-level clamp
    ``block_q_levels[l] = min(block_q, next_pow2(nq_l))`` and the
    single-launch windowed kernel's uniform ``tile_q``, with the
    windowed/compact staged-VMEM accounting (``window_bytes`` /
    ``window_bytes_compact``);
  * **VMEM fit** — whether the whole per-(batch, head-group) value table
    fits the configured VMEM slab (fused whole-table kernel) or only a
    bounded window does (windowed kernel, needs range-narrowing);
  * **TPU lane layout** — Dh is usually 32 in the DETR family, far below
    the 128-lane vector width. The plan either pads Dh -> 128 (7/8 of the
    lanes idle) or *packs* ``128 // Dh`` heads per lane group so one
    staged table row carries several heads (``head_pack``).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import fwp as fwp_lib

#: Default VMEM slab reserved for the fused kernel's staged value table.
#: Real TPU cores have ~16 MB of VMEM; half is left for the double-buffered
#: point/output tiles and the rest of the program.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024

_LANE_WIDTH = 128


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (max(1, int(n)) - 1).bit_length()


def block_q_for_levels(level_shapes: Sequence[Tuple[int, int]],
                       block_q: int) -> Tuple[int, ...]:
    """Per-query-level tile size: ``min(block_q, next_pow2(nq_l))``.

    A global 128 tile would pad the (2, 3) level's 6 queries and the
    (4, 5) level's 20 queries all the way to 128; clamping to the next
    power of two keeps the tiny levels' tiles tiny."""
    return tuple(min(block_q, next_pow2(h * w)) for h, w in level_shapes)


def lane_layout(n_heads: int, head_dim: int) -> Tuple[str, int]:
    """TPU last-dim layout for a (rows, Dh) value table.

    Returns (layout, head_pack): ``("native", 1)`` when Dh already fills
    the 128-lane width, ``("pack", g)`` when g = gcd(n_heads, 128 // Dh)
    heads can share one lane group, else ``("pad", 1)``."""
    if head_dim % _LANE_WIDTH == 0:
        return "native", 1
    if head_dim < _LANE_WIDTH and _LANE_WIDTH % head_dim == 0:
        g = math.gcd(n_heads, _LANE_WIDTH // head_dim)
        if g > 1:
            return "pack", g
    return "pad", 1


def value_rows(level_shapes: Sequence[Tuple[int, int]]) -> int:
    """WORST-CASE rows of the value table a backend gathers from.

    FWP-compact shrinks the table from block 2 onward, but block 1 always
    runs unpruned (there is no mask yet), so the VMEM-fit decision must be
    made against the full n_in-row table — a plan that only fits the
    compacted table would blow VMEM on the first block."""
    _, n_in = fwp_lib.level_starts(level_shapes)
    return n_in


def windowed_eligible(cfg) -> bool:
    """The windowed kernel needs a finite sampling radius (C3) to bound
    its fmap window — without range-narrowing there is no window."""
    return cfg.range_narrow is not None


@dataclasses.dataclass(frozen=True)
class MSDAPlan:
    """Static per-(config, level_shapes) execution plan. Hashable."""
    cfg: "object"                                   # MSDeformAttnConfig
    level_shapes: Tuple[Tuple[int, int], ...]
    backend: str                 # resolved registry name (never "auto")
    block_q: int                 # query tile for the Pallas kernels
    lane_layout: str             # "native" | "pad" | "pack"
    head_pack: int               # heads per 128-lane group (1 unless packed)
    vmem_budget_bytes: int
    value_table_bytes: int       # staged (rows, lanes) slab for pallas_fused
    n_in: int                    # total flat pixels across levels
    block_q_levels: Tuple[int, ...] = ()   # per-query-level tile size:
    #   min(block_q, next_pow2(nq_l)) — the (2,3) level tiles 6 queries
    #   as 8, not 128 (used by the pallas_windowed_loop per-level dispatch)
    tile_q: int = 128            # uniform tile of the single-launch
    #   multi-scale-parallel windowed kernel (= max(block_q_levels))
    window_bytes: Optional[int] = None           # dense fmap window staged
    #   per grid step by the windowed kernel (max over tile x level pairs)
    window_bytes_compact: Optional[int] = None   # FWP-compact-native window:
    #   slot window of the compacted table + the pix2slot window slice —
    #   the VMEM the windowed kernel actually stages when fwp_mode=compact

    @property
    def fits_vmem(self) -> bool:
        return self.value_table_bytes <= self.vmem_budget_bytes

    def describe(self) -> str:
        """One-line human summary of every static decision.

        ``win=`` reports the windowed kernel's staged-VMEM accounting:
        the dense per-step window, plus (when FWP-compact is on) the
        compact-native window actually staged instead."""
        win = ""
        if self.window_bytes is not None:
            win = f", win={self.window_bytes/1024:.0f}KB"
            if self.window_bytes_compact is not None:
                win += f"(compact {self.window_bytes_compact/1024:.0f}KB)"
        return (f"MSDAPlan(backend={self.backend}, block_q={self.block_q}, "
                f"block_q_levels={self.block_q_levels}, "
                f"lanes={self.lane_layout}x{self.head_pack}, "
                f"table={self.value_table_bytes/1024:.0f}KB/"
                f"{self.vmem_budget_bytes/1024:.0f}KB{win}, "
                f"n_in={self.n_in})")


def make_plan(cfg, level_shapes: Sequence[Tuple[int, int]], *,
              backend: Optional[str] = None,
              block_q: int = 128,
              vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
              n_queries: Optional[int] = None) -> MSDAPlan:
    """Resolve the static plan.

    Backend precedence: explicit ``backend`` arg > ``cfg.backend`` >
    the legacy ``cfg.impl`` string ("pallas" -> pallas_fused, "jnp" ->
    jnp_gather). Any of them may be ``"auto"``: fused whole-table kernel
    when the staged value table fits the VMEM budget, else the windowed
    kernel when range-narrowing bounds the window, else the jnp gather.

    ``n_queries``: optional hint for auto-selection. The windowed kernel
    requires raster-ordered encoder queries (Nq == N_in); pass the query
    count for decoder-style workloads so ``auto`` never plans a backend
    whose runtime precondition is already known to fail.

    NOTE: ``auto`` gates the windowed kernel on table-vs-budget only;
    ``window_bytes`` / ``window_bytes_compact`` are accounting fields
    (see ROADMAP: consulting them in the policy awaits real-TPU VMEM
    calibration)."""
    from repro.msda import backends as backend_registry

    level_shapes = tuple((int(h), int(w)) for h, w in level_shapes)
    _, n_in = fwp_lib.level_starts(level_shapes)
    layout, pack = lane_layout(cfg.n_heads, cfg.head_dim)
    itemsize = jnp.dtype(cfg.dtype).itemsize
    lanes = cfg.head_dim if layout == "native" else _LANE_WIDTH
    table_bytes = value_rows(level_shapes) * lanes * itemsize

    requested = backend
    if requested is None:
        requested = getattr(cfg, "backend", None)
    if requested is None:
        legacy = {"jnp": "jnp_gather", "pallas": "pallas_fused"}
        requested = legacy.get(cfg.impl, cfg.impl)

    if requested == "auto":
        raster_ok = n_queries is None or n_queries == n_in
        if table_bytes <= vmem_budget_bytes:
            requested = "pallas_fused"
        elif windowed_eligible(cfg) and raster_ok:
            requested = "pallas_windowed"
        else:
            requested = "jnp_gather"

    if requested not in backend_registry.available_backends():
        raise ValueError(
            f"unknown MSDA backend {requested!r}; "
            f"available: {backend_registry.available_backends()}")
    if requested.startswith("pallas_windowed") and not windowed_eligible(cfg):
        raise ValueError(f"{requested} needs cfg.range_narrow set "
                         "(the bound IS what makes the fmap window finite)")

    block_q_levels = block_q_for_levels(level_shapes, block_q)
    tile_q = max(block_q_levels)
    window_bytes = window_bytes_compact = None
    if windowed_eligible(cfg):
        from repro.kernels.msgs_windowed import window_geometry
        geo = window_geometry(level_shapes,
                              tuple(float(r) for r in cfg.range_narrow),
                              tile_q)
        window_bytes = geo.staged_bytes(lanes, itemsize)
        if cfg.fwp_mode == "compact":
            caps = fwp_lib.level_capacities(level_shapes, cfg.fwp_capacity)
            window_bytes_compact = geo.staged_bytes(lanes, itemsize,
                                                    caps=caps)

    return MSDAPlan(cfg=cfg, level_shapes=level_shapes, backend=requested,
                    block_q=block_q, lane_layout=layout, head_pack=pack,
                    vmem_budget_bytes=vmem_budget_bytes,
                    value_table_bytes=table_bytes, n_in=n_in,
                    block_q_levels=block_q_levels, tile_q=tile_q,
                    window_bytes=window_bytes,
                    window_bytes_compact=window_bytes_compact)


@functools.lru_cache(maxsize=256)
def plan_for(cfg, level_shapes: Tuple[Tuple[int, int], ...],
             backend: Optional[str] = None,
             n_queries: Optional[int] = None) -> MSDAPlan:
    """Memoized make_plan for hot call sites (the compat shim)."""
    return make_plan(cfg, level_shapes, backend=backend, n_queries=n_queries)
