"""Cross-layer MSDA pipeline state.

The DEFA dataflow is stateful *across* encoder blocks: block k counts how
often MSGS touched each fmap pixel and block k+1 prunes its value
projection with the result (FWP, paper §3.1). The seed threaded this
through an ad-hoc ``aux["fwp_state"]`` dict; ``MSDAPipelineState`` makes
the chain explicit and gives every consumer (encoder, detector, decoder,
distributed wrapper, serving) one object to carry:

    state = MSDAPipelineState.initial()
    for block in blocks:
        out, state = msda_attention(params, plan, q, refs, x, state=state)

``block_stats`` accumulates the per-block DEFA statistics (PAP keep
fraction, FWP keep fraction, value rows) when requested. An entry is
appended for EVERY executed block — ``None`` when that block did not
collect — so ``block_stats[i]`` is always block i's entry and the indices
stay aligned with ``block_index`` even when ``collect_stats`` is toggled
mid-chain.

Under ``fwp_mode="compact"`` the carried :class:`FWPState` is also the
compact-table geometry for the next block's kernels: ``pix2slot`` (the
pixel -> slot indirection) and the raster-ordered ``keep_idx`` (slot ->
pixel), which the windowed backend searchsorts to locate per-level slot
windows of the compacted table — sampling it directly, never densifying.

The state also carries the shared :class:`~repro.msda.cache.MSDAValueCache`
when one memory is sampled by many layers (the decoder): the cache is built
once via :func:`~repro.msda.cache.build_value_cache`, attached with
:meth:`with_cache`, and every layer's
:func:`~repro.msda.attention.msda_attention_cached` call consumes it —
build-once, sample-everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.fwp import FWPState
from repro.msda.cache import MSDAValueCache


@dataclasses.dataclass(frozen=True)
class MSDAPipelineState:
    """State produced by block k, consumed by block k+1."""
    fwp: Optional[FWPState] = None       # mask/keep-list for the NEXT block
    block_index: int = 0                 # how many blocks have executed
    block_stats: Tuple[Optional[dict], ...] = ()   # per-block stats; entry
    #   i belongs to block i (None when that block didn't collect)
    cache: Optional[MSDAValueCache] = None   # shared value cache (decoder /
    #   any build-once-sample-everywhere consumer); advance() preserves it
    stream: Optional[dict] = None        # temporal-reuse accounting for the
    #   frame this state belongs to (streaming sessions only): mode
    #   ("rebuild" | "incremental"), staged/rebuild bytes, dirty-tile
    #   counts — attached by the TemporalCacheManager, preserved by
    #   advance() so every layer's consumer can read the frame's reuse story

    @classmethod
    def initial(cls) -> "MSDAPipelineState":
        """State before the first block: no mask yet, nothing counted."""
        return cls()

    def advance(self, fwp: Optional[FWPState],
                stats: Optional[dict]) -> "MSDAPipelineState":
        """State after one block: new FWP chain link, stats appended.

        Stats are appended unconditionally (``None`` when the block did not
        collect) so ``block_stats`` indices track ``block_index`` exactly."""
        return MSDAPipelineState(
            fwp=fwp, block_index=self.block_index + 1,
            block_stats=self.block_stats + (stats,),
            cache=self.cache, stream=self.stream)

    def with_cache(self, cache: Optional[MSDAValueCache]) -> "MSDAPipelineState":
        """Attach (or clear) the shared value cache, keeping the chain."""
        return dataclasses.replace(self, cache=cache)

    def with_stream(self, stream: Optional[dict]) -> "MSDAPipelineState":
        """Attach (or clear) the frame's temporal-reuse accounting."""
        return dataclasses.replace(self, stream=stream)

    def collected_stats(self) -> Tuple[dict, ...]:
        """Only the blocks that actually collected (drops the Nones)."""
        return tuple(s for s in self.block_stats if s is not None)
