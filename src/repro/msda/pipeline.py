"""Cross-layer MSDA pipeline state.

The DEFA dataflow is stateful *across* encoder blocks: block k counts how
often MSGS touched each fmap pixel and block k+1 prunes its value
projection with the result (FWP, paper §3.1). The seed threaded this
through an ad-hoc ``aux["fwp_state"]`` dict; ``MSDAPipelineState`` makes
the chain explicit and gives every consumer (encoder, detector,
distributed wrapper, serving) one object to carry:

    state = MSDAPipelineState.initial()
    for block in blocks:
        out, state = msda_attention(params, plan, q, refs, x, state=state)

``block_stats`` accumulates the per-block DEFA statistics (PAP keep
fraction, FWP keep fraction, value rows) when requested.

Under ``fwp_mode="compact"`` the carried :class:`FWPState` is also the
compact-table geometry for the next block's kernels: ``pix2slot`` (the
pixel -> slot indirection) and the raster-ordered ``keep_idx`` (slot ->
pixel), which the windowed backend searchsorts to locate per-level slot
windows of the compacted table — sampling it directly, never densifying.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.fwp import FWPState


@dataclasses.dataclass(frozen=True)
class MSDAPipelineState:
    """State produced by block k, consumed by block k+1."""
    fwp: Optional[FWPState] = None       # mask/keep-list for the NEXT block
    block_index: int = 0                 # how many blocks have executed
    block_stats: Tuple[dict, ...] = ()   # per-block stats (collect_stats)

    @classmethod
    def initial(cls) -> "MSDAPipelineState":
        """State before the first block: no mask yet, nothing counted."""
        return cls()

    def advance(self, fwp: Optional[FWPState],
                stats: Optional[dict]) -> "MSDAPipelineState":
        """State after one block: new FWP chain link, stats appended."""
        return MSDAPipelineState(
            fwp=fwp, block_index=self.block_index + 1,
            block_stats=self.block_stats + ((stats,) if stats is not None
                                            else ()))
