"""Deformable-DETR-style decoder over ONE shared MSDAValueCache.

The paper's decoder workload is exactly where feature-map reusing pays:
few learned queries (N_q ≈ 300), many layers (6), one fixed memory (the
encoder output). Rebuilding the value table per layer — project, FWP-
compact, stage — costs ``n_layers``× the staged bytes for zero new
information. This decoder builds the cache ONCE
(:func:`repro.msda.cache.build_value_cache`, inheriting the encoder
chain's final FWP compaction) and every layer samples it through
:func:`repro.msda.attention.msda_attention_cached`:

    layer l:  self-attention over the N_q queries
              deformable cross-attention against the SHARED cache
              FFN
              reference-point refinement  ref <- sigmoid(logit(ref) + Δ(h))

The per-layer cross-attention owns its sampling weights (attention
logits, offsets, output projection) but NOT a value projection — that is
the build-once seam. The launch is decode-shaped: ``make_plan(...,
n_queries=N_q, n_consumers=n_layers)`` clamps the query tiling to the
learned-query regime and keeps ``auto`` off the raster-only windowed
kernel.

With the persistent decode backend (``pallas_decode``, the ``auto``
pick when the compact table fits the staging budget) the build-once
seam extends from projection to *staging*: ``build_value_cache`` lays
the table out in the decode launch layout exactly once per memory
(``cache.staged``) and every layer's launch reuses it — one staging per
(batch, head-group) per memory, not per layer. The layers still launch
one at a time (layer l's sampling coordinates only exist after layer
l-1's self-attn/FFN), which is why the stacked single-launch variant in
kernels/msgs_decode.py is reserved for coords-precomputed workloads;
the interleaved forward ships the per-layer persistent launches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.msda.attention import msda_attention_cached
from repro.msda.cache import build_value_cache
from repro.msda.pipeline import MSDAPipelineState
from repro.msda.plan import MSDAPlan


@dataclasses.dataclass(frozen=True)
class MSDADecoderConfig:
    """Static decoder shape. The attention geometry (d_model, heads,
    levels, DEFA knobs) comes from the plan's MSDeformAttnConfig — the
    decoder samples the SAME memory the encoder produced."""
    n_layers: int = 6
    n_queries: int = 300
    d_ffn: int = 1024
    dtype: Any = jnp.float32


def _cross_attn_init(key: jax.Array, attn_cfg) -> dict:
    """Per-layer deformable cross-attention params — the sampling weights
    WITHOUT a value projection (the shared cache owns that)."""
    from repro.core.msdeform_attn import init_msdeform_attn
    p = init_msdeform_attn(key, attn_cfg)
    return {k: v for k, v in p.items() if k not in ("value_w", "value_b")}


def init_decoder(key: jax.Array, cfg: MSDADecoderConfig, attn_cfg) -> dict:
    from repro.core.msdeform_attn import init_msdeform_attn
    d = attn_cfg.d_model
    key, kq, kt, kr, kv = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(jnp.asarray(float(d)))
    shared = init_msdeform_attn(kv, attn_cfg)
    params = {
        "query_pos": (jax.random.normal(kq, (cfg.n_queries, d))
                      * scale).astype(cfg.dtype),
        "tgt_embed": (jax.random.normal(kt, (cfg.n_queries, d))
                      * scale).astype(cfg.dtype),
        "ref_head": nn.linear_init(kr, d, 2, cfg.dtype),
        # the build-once seam: ONE value projection for all layers
        "value": {k: shared[k] for k in ("value_w", "value_b")},
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        key, k1, k2, k3, k4, k5, k6, k7, k8 = jax.random.split(key, 9)
        params["layers"].append({
            "self_q": nn.linear_init(k1, d, d, cfg.dtype),
            "self_k": nn.linear_init(k2, d, d, cfg.dtype),
            "self_v": nn.linear_init(k3, d, d, cfg.dtype),
            "self_o": nn.linear_init(k4, d, d, cfg.dtype),
            "ln_sa": nn.layer_norm_init(d, cfg.dtype),
            "cross": _cross_attn_init(k5, attn_cfg),
            "ln1": nn.layer_norm_init(d, cfg.dtype),
            "ffn1": nn.linear_init(k6, d, cfg.d_ffn, cfg.dtype),
            "ffn2": nn.linear_init(k7, cfg.d_ffn, d, cfg.dtype),
            "ln2": nn.layer_norm_init(d, cfg.dtype),
            # zero-init refinement: layer 0 starts at the ref_head points
            "ref_delta": {
                "w": jnp.zeros((d, 2), cfg.dtype),
                "b": jnp.zeros((2,), cfg.dtype)},
        })
    return params


def decoder_logical_axes(cfg: MSDADecoderConfig) -> dict:
    lin = {"w": ("embed", None), "b": (None,)}
    ln = {"scale": (None,), "bias": (None,)}
    layer = {
        "self_q": lin, "self_k": lin, "self_v": lin, "self_o": lin,
        "ln_sa": ln,
        "cross": {"attn_w": ("embed", "heads", None), "attn_b": ("heads", None),
                  "offs_w": ("embed", "heads", None), "offs_b": ("heads", None),
                  "out_w": ("heads", None, "embed"), "out_b": (None,)},
        "ln1": ln, "ffn1": {"w": ("embed", "mlp"), "b": ("mlp",)},
        "ffn2": {"w": ("mlp", "embed"), "b": (None,)}, "ln2": ln,
        "ref_delta": lin,
    }
    return {
        "query_pos": (None, "embed"), "tgt_embed": (None, "embed"),
        "ref_head": lin,
        "value": {"value_w": ("embed", "heads", None), "value_b": ("heads", None)},
        "layers": [layer for _ in range(cfg.n_layers)],
    }


def _self_attention(layer: dict, h: jnp.ndarray, pos: jnp.ndarray,
                    n_heads: int) -> jnp.ndarray:
    """Standard MHA over the N_q queries (pos added to q/k, not v)."""
    b, n, d = h.shape
    dh = d // n_heads
    q = nn.linear(layer["self_q"], h + pos).reshape(b, n, n_heads, dh)
    k = nn.linear(layer["self_k"], h + pos).reshape(b, n, n_heads, dh)
    v = nn.linear(layer["self_v"], h).reshape(b, n, n_heads, dh)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, n, d)
    return nn.linear(layer["self_o"], out)


def decoder_apply(
    params: dict,
    cfg: MSDADecoderConfig,
    plan: MSDAPlan,
    memory: jnp.ndarray,                    # (B, N_in, D) encoder output
    state: Optional[MSDAPipelineState] = None,
    *,
    collect_stats: bool = False,
    cache=None,
) -> Tuple[jnp.ndarray, jnp.ndarray, MSDAPipelineState]:
    """Run the decoder stack against ONE shared value cache.

    ``state`` carries the encoder chain's final FWP link — its compaction
    decides the cache layout, so the decoder samples the same pruned
    table the last encoder block produced. ``cache`` lets a temporal
    consumer (the streaming engine) pass in a PERSISTENT, incrementally
    updated :class:`~repro.msda.cache.MSDAValueCache` instead of building
    one here — the frame-to-frame extension of the same build-once seam.
    Returns (h (B, N_q, D), refs (B, N_q, 2), decoder state). The
    returned state's ``block_stats`` has exactly one aligned entry per
    decoder layer and its ``cache`` is the shared table
    (``cache.table_bytes`` is the build-once staging cost every layer
    amortizes); a streaming caller's ``state.stream`` accounting is
    carried through."""
    b = memory.shape[0]
    attn_cfg = plan.cfg

    # ---- build ONCE: the shared, optionally FWP-compacted value table ----
    if cache is None:
        cache = build_value_cache(params["value"], plan, memory, state)
    if plan.backend == "pallas_decode":
        # the persistent decode contract: the table was staged at build
        # time, once per memory — a missing staged block would silently
        # degrade every layer to a per-launch restage
        assert cache.staged is not None, \
            "pallas_decode plan produced an unstaged cache"
    dstate = MSDAPipelineState(
        fwp=getattr(state, "fwp", None),
        stream=getattr(state, "stream", None)).with_cache(cache)

    pos = params["query_pos"][None]                       # (1, Nq, D)
    h = jnp.broadcast_to(params["tgt_embed"][None],
                         (b,) + params["tgt_embed"].shape)
    refs = jax.nn.sigmoid(nn.linear(params["ref_head"], params["query_pos"]))
    refs = jnp.broadcast_to(refs[None], (b,) + refs.shape)  # (B, Nq, 2)

    for layer in params["layers"]:
        h = nn.layer_norm(
            layer["ln_sa"],
            h + _self_attention(layer, h, pos, attn_cfg.n_heads))
        # ---- sample everywhere: cross-attention against the SHARED cache.
        # When the plan carries a query_order, the cached pass derives the
        # cache-local permutation PER LAYER from this layer's incoming
        # (pre-refinement) refs — the refinement below shifts every
        # layer's points, so no permutation survives across layers — and
        # inverts it on the output, so the ordering is invisible here.
        attn_out, dstate = msda_attention_cached(
            layer["cross"], plan, h + pos, refs, dstate.cache,
            state=dstate, collect_stats=collect_stats, update_fwp=False)
        h = nn.layer_norm(layer["ln1"], h + attn_out)
        ff = nn.linear(layer["ffn2"], jax.nn.relu(nn.linear(layer["ffn1"], h)))
        h = nn.layer_norm(layer["ln2"], h + ff)
        # ---- per-layer reference-point refinement. The INCOMING refs are
        # detached (DETR-style truncated chain) but the delta itself is
        # live: its gradient flows through the later layers' sampling
        # locations and the final box head, which is what trains the
        # zero-initialized refinement weights.
        delta = h @ layer["ref_delta"]["w"] + layer["ref_delta"]["b"]
        refs = jax.nn.sigmoid(
            nn.inverse_sigmoid(jax.lax.stop_gradient(refs)) + delta)
    return h, refs, dstate
