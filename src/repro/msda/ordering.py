"""Cache-local query ordering — QUILL-style reference-point clustering.

The windowed/decode kernels' staging economics rest on adjacent queries
in a tile sharing staged slot windows: the per-level slot ranges are
raster-ordered (``repro/core/fwp.py``), so the bytes a query tile stages
are set by the REFERENCE-POINT SPREAD of the tile, not its size. Encoder
queries arrive raster-ordered and are already local; decoder queries
arrive in arbitrary learned order, so one 128-query tile can span the
whole image and stage near-disjoint windows per level.

This module computes a permutation over queries from their reference
points, to be applied BEFORE sampling and inverted on the output:

  * ``raster`` — sort by flat pixel index on the *dominant* level (the
    largest h*w — it dominates the staged bytes). Optimal for row-window
    locality on that one level; other levels ride along (their windows
    shrink too because their coordinates are the same points rescaled).
  * ``zorder`` — sort by the Morton (Z-order) code of the quantized
    reference point. Interleaving x/y bits keeps queries 2-D-local, so
    BOTH the row span and the column spread stay bounded per tile —
    the multi-level balanced choice (every level's window shrinks by
    roughly the same factor).

Numerics are untouched: every per-query op in the MSDA pass (projections,
softmax, gather, bilinear aggregate) is row-independent, so
``invert(perm, f(permute(perm, x))) == f(x)`` holds BIT-IDENTICALLY under
the same dtype (property-tested in tests/test_msda_ordering.py). Only
locality — the measured per-tile window bytes — changes.

The knob is plan-level policy: ``MSDeformAttnConfig.query_order`` in
{"none", "raster", "zorder"}, env-overridable via
``REPRO_MSDA_QUERY_ORDER`` (same precedence shape as the table dtype:
arg > cfg field > env > default). Raster-only backends
(``pallas_windowed``) keep their queries unpermuted — their tile->window
geometry is DERIVED from raster query position, so the permutation is an
identity there and the ordering win is reported by the measured
accounting instead (:func:`tile_window_stats`).
"""
from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import fwp as fwp_lib

__all__ = [
    "QUERY_ORDERS", "resolve_query_order", "query_sort_keys",
    "query_permutation", "permute_queries", "invert_queries",
    "tile_window_stats",
]

#: The recognised ordering policies.
QUERY_ORDERS = ("none", "raster", "zorder")

#: Morton quantization grid: 2^10 cells per axis — finer than any level
#: of the DETR pyramids (<= a few hundred pixels) while keeping the
#: interleaved key in 20 bits, comfortably inside int32 (x64 is off).
_MORTON_BITS = 10


def resolve_query_order(cfg, override: Optional[str] = None) -> str:
    """Resolve the query-ordering policy for one config.

    Precedence: explicit ``override`` (the ``make_plan`` kwarg) >
    ``cfg.query_order`` > the ``REPRO_MSDA_QUERY_ORDER`` env var >
    ``"none"`` (the pre-ordering behaviour)."""
    choice = override
    if choice is None:
        choice = getattr(cfg, "query_order", None)
    if choice is None:
        choice = os.environ.get("REPRO_MSDA_QUERY_ORDER") or None
    if choice is None:
        return "none"
    if choice not in QUERY_ORDERS:
        raise ValueError(
            f"unsupported MSDA query order {choice!r}; "
            f"supported: {QUERY_ORDERS}")
    return choice


def dominant_level(level_shapes: Sequence[Tuple[int, int]]) -> int:
    """Index of the level that dominates staged bytes (largest h*w)."""
    sizes = [h * w for h, w in level_shapes]
    return int(np.argmax(sizes))


def _interleave_bits(v: jnp.ndarray) -> jnp.ndarray:
    """Spread the low ``_MORTON_BITS`` bits of ``v`` (int32, >= 0) so bit
    i lands at position 2i. Classic part1by1 magic-mask ladder; every
    intermediate stays below 2^31, so int32 math is safe with x64 off."""
    v = (v | (v << 8)) & 0x00FF00FF
    v = (v | (v << 4)) & 0x0F0F0F0F
    v = (v | (v << 2)) & 0x33333333
    v = (v | (v << 1)) & 0x55555555
    return v


def query_sort_keys(ref_points: jnp.ndarray,
                    level_shapes: Sequence[Tuple[int, int]],
                    method: str) -> jnp.ndarray:
    """Per-query sort keys from normalized reference points.

    ``ref_points``: (..., Nq, 2) with (x, y) in [0, 1]. Returns (..., Nq)
    int32 keys — raster index on the dominant level, or the Morton code
    of the 2^10-quantized point. jit-safe (pure jnp)."""
    if method == "raster":
        h, w = level_shapes[dominant_level(level_shapes)]
        px = jnp.clip((ref_points[..., 0] * w).astype(jnp.int32), 0, w - 1)
        py = jnp.clip((ref_points[..., 1] * h).astype(jnp.int32), 0, h - 1)
        return py * w + px
    if method == "zorder":
        n = 1 << _MORTON_BITS
        qx = jnp.clip((ref_points[..., 0] * n).astype(jnp.int32), 0, n - 1)
        qy = jnp.clip((ref_points[..., 1] * n).astype(jnp.int32), 0, n - 1)
        return (_interleave_bits(qy) << 1) | _interleave_bits(qx)
    raise ValueError(f"unknown query order {method!r} "
                     f"(expected one of {QUERY_ORDERS[1:]})")


def query_permutation(ref_points: jnp.ndarray,
                      level_shapes: Sequence[Tuple[int, int]],
                      method: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(perm, inv_perm) over the query axis, both (..., Nq) int32.

    ``perm[i]`` is the original index of the query placed at sorted
    position i (gather semantics: ``sorted_x = take(x, perm)``);
    ``inv_perm`` undoes it (``x == take(sorted_x, inv_perm)``). The sort
    is STABLE, so ``method="none"``-adjacent ties keep their original
    relative order and the permutation is deterministic."""
    keys = query_sort_keys(ref_points, level_shapes, method)
    perm = jnp.argsort(keys, axis=-1, stable=True).astype(jnp.int32)
    inv = jnp.argsort(perm, axis=-1, stable=True).astype(jnp.int32)
    return perm, inv


def _take_queries(arr: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """take_along_axis on the query axis (axis 1) of a (B, Nq, ...) array,
    broadcasting the (B, Nq) permutation over trailing dims."""
    idx = perm.reshape(perm.shape + (1,) * (arr.ndim - perm.ndim))
    return jnp.take_along_axis(arr, idx, axis=1)


def permute_queries(arr: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """Reorder a (B, Nq, ...) array into sorted query order."""
    return _take_queries(arr, perm)


def invert_queries(arr: jnp.ndarray, inv_perm: jnp.ndarray) -> jnp.ndarray:
    """Undo :func:`permute_queries` on a (B, Nq, ...) output."""
    return _take_queries(arr, inv_perm)


# --------------------------------------------------------------------------
# Measured per-tile window-bytes accounting (host-side, numpy)
# --------------------------------------------------------------------------

def tile_window_stats(ref_points,
                      level_shapes: Sequence[Tuple[int, int]],
                      ranges: Sequence[float],
                      tile_q: int,
                      lanes: int,
                      itemsize: int,
                      *,
                      order: str = "none",
                      capacity: Optional[float] = None) -> dict:
    """MEASURED window bytes per query tile for a concrete query set.

    The static ``window_bytes`` accounting in the plan is a worst case
    over raster tiles; this is the per-tile measurement for an ARBITRARY
    query order — the quantity ordering actually improves. For each tile
    of ``tile_q`` consecutive queries (in the given ``order``) and each
    level, the staged row window follows the windowed kernel's span
    formula (``repro/kernels/msgs_windowed.py``): rows touching
    ``ref_y*h - 0.5 ± (R + 1)`` plus the bilinear lower corner, times the
    level width. Bytes per tile sum the per-level windows (compact:
    capacity-clamped slot window + the int32 pix2slot window slice, the
    same split as ``WindowGeometry.staged_bytes``).

    ``ref_points``: (Nq, 2) or (B, Nq, 2) — batch 0 is measured.
    Returns ``{"order", "n_tiles", "max_bytes", "mean_bytes"}``."""
    refs = np.asarray(ref_points, np.float64)
    if refs.ndim == 3:
        refs = refs[0]
    nq = refs.shape[0]
    if order != "none":
        keys = np.asarray(query_sort_keys(
            jnp.asarray(refs, jnp.float32), level_shapes, order))
        refs = refs[np.argsort(keys, kind="stable")]
    caps = None
    if capacity is not None:
        caps = fwp_lib.level_capacities(level_shapes, capacity)

    n_tiles = max(1, -(-nq // tile_q))
    tile_bytes = np.zeros(n_tiles, np.int64)
    for t in range(n_tiles):
        chunk = refs[t * tile_q:(t + 1) * tile_q]
        for li, (h, w) in enumerate(level_shapes):
            r = float(ranges[li])
            y = chunk[:, 1] * h - 0.5
            ymin = float(np.min(y)) - r - 1.0
            ymax = float(np.max(y)) + r + 1.0
            r0 = max(0, int(np.floor(ymin)))
            r1 = min(h - 1, int(np.floor(ymax)) + 1)
            win_pix = (r1 - r0 + 1) * w
            if caps is None:
                tile_bytes[t] += win_pix * lanes * itemsize
            else:
                slot_win = min(win_pix, caps[li])
                tile_bytes[t] += slot_win * lanes * itemsize + win_pix * 4
    return {
        "order": order,
        "n_tiles": n_tiles,
        "max_bytes": int(tile_bytes.max()),
        "mean_bytes": float(tile_bytes.mean()),
    }
