"""MSDAValueCache — build-once, sample-everywhere compacted value tables.

DEFA's architecture level wins not only by multi-scale parallelism but by
**feature-map reusing**: the same (pruned) value table is sampled by many
attention layers, so it should be projected, FWP-compacted, and staged
*once* and then reused. The cache is that staged table plus everything a
backend needs to sample it:

  * ``v``        — the projected, head-laid-out value table
                   (B, N_rows, H, Dh); under ``fwp_mode="compact"`` the
                   table is the compacted slot buffer + zero sentinel row;
  * ``pix2slot`` — the pixel -> compact-slot indirection (None when dense);
  * ``keep_idx`` — the raster-ordered slot -> pixel map the windowed
                   kernel searchsorts for its slot windows (None when dense);
  * ``slot_windows`` — static per-level slot-window extents (compact mode);
  * ``table_bytes`` — staged-bytes accounting per (batch, head-group):
                   the VMEM/HBM cost of staging this table ONCE, the unit
                   the decoder's build-once-vs-rebuild-per-layer comparison
                   is measured in.

Consumers: every encoder block builds its own cache (its memory changes
block to block — only the FWP *compaction* is reused, via the pipeline
state), while the decoder builds ONE cache from the encoder memory and
every decoder layer samples it (``repro/msda/decoder.py``). All backends
keep the existing ``(plan, v, pts, probs)`` contract — the cache simply
carries ``v`` and its geometry between the build and the samples.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core import fwp as fwp_lib
from repro.core.quant import (maybe_fake_quant, maybe_fake_quant_with_scale,
                              quant_scale, quantize_table_rows,
                              table_quant_scale)


class MSDAValueCache(NamedTuple):
    """Projected (optionally FWP-compacted) value table + sampling geometry."""
    v: jnp.ndarray                      # (B, N_rows, H, Dh) staged table
    pix2slot: Optional[jnp.ndarray]     # (B, N_in) pixel -> slot (or None)
    keep_idx: Optional[jnp.ndarray]     # (B, cap) slot -> pixel, raster-ordered
    n_rows: int                         # static row count of ``v``
    slot_windows: Tuple[int, ...]       # static per-level slot windows
    #   (compact mode; () when dense) — what a windowed consumer may stage
    table_bytes: int                    # bytes staged per (batch, head-group)
    #   to build this table once: rows x lanes x itemsize (+ the int32
    #   pix2slot indirection in compact mode). This is the ACTUAL built
    #   table (dense when no FWP link exists yet); the static plan-side
    #   estimate that assumes compaction is ``MSDAPlan.cache_table_bytes``.
    #   Surfaced per block via the collect_stats "cache_table_bytes" entry.
    staged: Optional[object] = None     # DecodeStagedTable when the plan's
    #   backend is the persistent decode kernel: ``v`` re-laid-out ONCE
    #   per memory into the decode launch layout (kernels/msgs_decode.py);
    #   every consumer launch then reuses it — one staging per
    #   (batch, head-group) per memory, never per layer.
    scale: Optional[jnp.ndarray] = None  # (B, 1, H, Dh) f32 per-channel
    #   dequant scale when the plan stores the table as int8 codes
    #   (``plan.quantized_table``): ``v`` then holds the codes and every
    #   sampler dequantizes in-register AFTER the bilinear gather. The
    #   scale is shared across all rows of a channel, so it is frozen for
    #   the cache's lifetime — streaming row updates re-quantize against
    #   it (same grid as the surrounding table). None for float tables.


def project_values(params: dict, cfg, x_flat: jnp.ndarray,
                   fwp_state: Optional[fwp_lib.FWPState]):
    """FWP-pruned value projection V = X W^V.

    Returns (v (B, N_rows, H, Dh), pix2slot or None, n_rows)."""
    b = x_flat.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    n_in = x_flat.shape[1]
    wq = lambda w: maybe_fake_quant(w, cfg.weight_bits)
    if fwp_state is not None and cfg.fwp_mode == "compact":
        cap = fwp_state.keep_idx.shape[1]
        x_kept = jnp.take_along_axis(x_flat, fwp_state.keep_idx[..., None], axis=1)
        v = jnp.einsum("bnd,dhk->bnhk", x_kept, wq(params["value_w"])) \
            + params["value_b"]
        v = jnp.concatenate([v, jnp.zeros((b, 1, h, dh), v.dtype)], axis=1)
        pix2slot = fwp_state.pix2slot                    # (B, N_in)
        n_rows = cap + 1
    elif fwp_state is not None and cfg.fwp_mode == "mask":
        xm = x_flat * fwp_state.keep_mask[..., None].astype(x_flat.dtype)
        v = jnp.einsum("bnd,dhk->bnhk", xm, wq(params["value_w"])) \
            + params["value_b"]
        # masked pixels must contribute EXACT zero (bias would leak):
        v = v * fwp_state.keep_mask[..., None, None].astype(v.dtype)
        pix2slot = None
        n_rows = n_in
    else:
        v = jnp.einsum("bnd,dhk->bnhk", x_flat, wq(params["value_w"])) \
            + params["value_b"]
        pix2slot = None
        n_rows = n_in
    return maybe_fake_quant(v, cfg.act_bits), pix2slot, n_rows


def build_value_cache(params: dict, plan, x_flat: jnp.ndarray,
                      state=None) -> MSDAValueCache:
    """Build the shared value cache for one memory ``x_flat``.

    ``params`` needs only the value projection (``value_w``/``value_b``);
    ``state`` is the :class:`~repro.msda.pipeline.MSDAPipelineState` whose
    FWP chain link decides the compaction (None / no link => dense table).
    Called ONCE per memory; every sampler (encoder block body, all decoder
    layers) then consumes the result through
    :func:`repro.msda.attention.msda_attention_cached`."""
    # trace-time staging event on the process-wide registry: inside jit
    # this body runs once per compilation, so a flat counter after warmup
    # pins "no path is rebuilding/retracing the cache" globally —
    # complementing each engine's per-registry msda_compiles_total spy
    from repro.obs.metrics import default_registry
    default_registry().counter(
        "msda_cache_build_traces_total",
        "build_value_cache tracings/eager builds (process-wide)"
    ).inc(backend=plan.backend, table_dtype=plan.table_dtype)
    cfg = plan.cfg
    fwp_state = getattr(state, "fwp", None)
    v, pix2slot, n_rows = project_values(params, cfg, x_flat, fwp_state)
    keep_idx = fwp_state.keep_idx if pix2slot is not None else None

    scale = None
    if plan.quantized_table:
        # int8 end-to-end: the dense f32 table never exists past this
        # point — the cache stores codes + per-channel scale, and every
        # backend (gather / fused / decode / windowed) dequantizes
        # in-register after the bilinear corner gather. The sentinel row
        # is exact zero (code 0). Scale is per-channel over the rows
        # axis, so aggregation-then-dequant equals per-corner dequant.
        scale = table_quant_scale(v)
        v = quantize_table_rows(v, scale)

    table_bytes = plan.table_bytes_for_rows(
        n_rows, with_indirection=pix2slot is not None)
    slot_windows: Tuple[int, ...] = ()
    if pix2slot is not None:
        # geometry for windowed consumers of a compact cache (the raster
        # kernel derives its own via WindowGeometry; a decode-shaped
        # windowed kernel — ROADMAP — would stage these per level). The
        # bound excludes the zero sentinel row: it is addressable but
        # never part of a level's slot range.
        caps = fwp_lib.level_capacities(plan.level_shapes, cfg.fwp_capacity)
        slot_windows = tuple(min(int(c), n_rows - 1) for c in caps)

    staged = None
    if plan.backend == "pallas_decode":
        # The plan-keyed staging decision: lay the table out in the decode
        # launch layout ONCE, here, per memory — every consumer layer's
        # launch reuses the staged block (kernels/msgs_decode.py). Routed
        # through the module attribute so the staging-spy tests can count
        # stagings per memory.
        from repro.kernels import msgs_decode as msgs_decode_kernel
        staged = msgs_decode_kernel.stage_decode_table(
            v, pix2slot, head_pack=plan.decode_head_pack, scale=scale)
    return MSDAValueCache(v=v, pix2slot=pix2slot, keep_idx=keep_idx,
                          n_rows=n_rows, slot_windows=slot_windows,
                          table_bytes=table_bytes, staged=staged,
                          scale=scale)


# --------------------------------------------------------------------------
# Incremental (streaming) row updates — temporal feature-map reuse
# --------------------------------------------------------------------------

def cache_act_scale(cache: MSDAValueCache, cfg) -> Optional[jnp.ndarray]:
    """The frozen activation-quant scale of a built cache.

    ``project_values`` fake-quants the table per-tensor; the scale it
    used is recoverable from the built table (the max-magnitude element
    quantizes onto the grid's endpoint, so ``quant_scale`` of the staged
    values reproduces it up to float rounding). Streaming row updates
    re-quantize against THIS scale so partial updates stay on the same
    grid as the surrounding table (see ``fake_quant_with_scale``)."""
    if cfg.act_bits is None or cfg.act_bits <= 0:
        return None
    v = cache.v
    if cache.scale is not None:
        # int8 table: the act-quant grid lives in value space, not code
        # space — recover it from the dequantized view. The per-channel
        # amax survives quantization exactly (the amax element maps onto
        # the code grid's endpoint), so this reproduces the build scale.
        v = v.astype(cache.scale.dtype) * cache.scale
    return quant_scale(v, cfg.act_bits)


def project_cache_rows(params: dict, cfg, x_flat: jnp.ndarray,
                       pix_idx: jnp.ndarray,
                       keep_mask: Optional[jnp.ndarray] = None,
                       act_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Value-project a PIXEL-ROW subset of one memory.

    ``pix_idx`` (B, U) selects the pixels whose table rows are being
    refreshed (a changed tile's kept pixels); returns (B, U, H, Dh) rows
    computed exactly like the corresponding rows of a full
    :func:`project_values` build: same weight fake-quant, same bias,
    mask-mode zeroing via ``keep_mask``, and activation fake-quant
    against the FROZEN ``act_scale`` (partial updates must share the full
    build's quantization grid). jit-safe — every input is an array."""
    wq = lambda w: maybe_fake_quant(w, cfg.weight_bits)
    x_rows = jnp.take_along_axis(x_flat, pix_idx[..., None], axis=1)
    if keep_mask is not None:                        # fwp_mode == "mask"
        m_rows = jnp.take_along_axis(keep_mask, pix_idx, axis=1)
        x_rows = x_rows * m_rows[..., None].astype(x_rows.dtype)
    rows = jnp.einsum("bnd,dhk->bnhk", x_rows, wq(params["value_w"])) \
        + params["value_b"]
    if keep_mask is not None:
        rows = rows * m_rows[..., None, None].astype(rows.dtype)
    return maybe_fake_quant_with_scale(rows, cfg.act_bits, act_scale)


def scatter_table_rows(v: jnp.ndarray, slot_idx: jnp.ndarray,
                       rows: jnp.ndarray) -> jnp.ndarray:
    """Scatter (B, U, H, Dh) rows into the (B, N_rows, H, Dh) table.

    Dtypes must match exactly: an int8 table takes int8 CODES (quantized
    against the cache's frozen scale), never raw float rows — a silent
    cast here would scatter garbage onto the code grid."""
    if rows.dtype != v.dtype:
        raise TypeError(
            f"scatter_table_rows: rows dtype {rows.dtype} != table dtype "
            f"{v.dtype}; quantize rows against the cache's frozen scale "
            f"before scattering into an int8 table")
    bidx = jnp.arange(v.shape[0])[:, None]
    return v.at[bidx, slot_idx].set(rows)


def update_value_cache_rows(params: dict, plan, cache: MSDAValueCache,
                            x_flat: jnp.ndarray, slot_idx: jnp.ndarray,
                            act_scale: Optional[jnp.ndarray] = None,
                            keep_mask: Optional[jnp.ndarray] = None,
                            ) -> Tuple[MSDAValueCache, int]:
    """In-place (functional) tile update of a built value cache.

    Re-projects the ``slot_idx`` (B, U) table rows from the NEW memory
    ``x_flat`` and scatters them into ``cache.v`` — and, when the plan
    staged the decode layout, into ``cache.staged`` via
    ``update_staged_rows`` — leaving the keep geometry (``pix2slot`` /
    ``keep_idx`` / ``slot_windows``) untouched: a tile update never
    changes WHICH pixels hold slots, only their values (keep transitions
    rebuild instead). Returns ``(cache', staged_bytes_delta)`` where the
    delta is the per-(batch, head-group) bytes this partial restage
    actually moved — ``U`` rows under the plan's lane layout, with NO
    pix2slot restage — the unit the streaming rebuild-vs-incremental
    comparison is measured in (vs ``cache.table_bytes`` for a full
    build)."""
    cfg = plan.cfg
    u = slot_idx.shape[1]
    if cache.keep_idx is not None:                   # compact: slot -> pixel
        pix_idx = jnp.take_along_axis(cache.keep_idx, slot_idx, axis=1)
    else:                                            # dense/mask: slot == pixel
        pix_idx = slot_idx
    rows = project_cache_rows(params, cfg, x_flat, pix_idx,
                              keep_mask=keep_mask, act_scale=act_scale)
    if cache.scale is not None:
        # int8 end-to-end: re-quantize the refreshed rows against the
        # cache's FROZEN per-channel scale and scatter the codes — the
        # dense f32 table is never materialized mid-stream.
        rows = quantize_table_rows(rows, cache.scale)
    v = scatter_table_rows(cache.v, slot_idx, rows)
    staged = cache.staged
    if staged is not None:
        from repro.kernels import msgs_decode as msgs_decode_kernel
        staged = msgs_decode_kernel.update_staged_rows(staged, slot_idx, rows)
    delta_bytes = plan.table_bytes_for_rows(u, with_indirection=False)
    return cache._replace(v=v, staged=staged), delta_bytes
