"""MSDAValueCache — build-once, sample-everywhere compacted value tables.

DEFA's architecture level wins not only by multi-scale parallelism but by
**feature-map reusing**: the same (pruned) value table is sampled by many
attention layers, so it should be projected, FWP-compacted, and staged
*once* and then reused. The cache is that staged table plus everything a
backend needs to sample it:

  * ``v``        — the projected, head-laid-out value table
                   (B, N_rows, H, Dh); under ``fwp_mode="compact"`` the
                   table is the compacted slot buffer + zero sentinel row;
  * ``pix2slot`` — the pixel -> compact-slot indirection (None when dense);
  * ``keep_idx`` — the raster-ordered slot -> pixel map the windowed
                   kernel searchsorts for its slot windows (None when dense);
  * ``slot_windows`` — static per-level slot-window extents (compact mode);
  * ``table_bytes`` — staged-bytes accounting per (batch, head-group):
                   the VMEM/HBM cost of staging this table ONCE, the unit
                   the decoder's build-once-vs-rebuild-per-layer comparison
                   is measured in.

Consumers: every encoder block builds its own cache (its memory changes
block to block — only the FWP *compaction* is reused, via the pipeline
state), while the decoder builds ONE cache from the encoder memory and
every decoder layer samples it (``repro/msda/decoder.py``). All backends
keep the existing ``(plan, v, pts, probs)`` contract — the cache simply
carries ``v`` and its geometry between the build and the samples.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core import fwp as fwp_lib
from repro.core.quant import maybe_fake_quant


class MSDAValueCache(NamedTuple):
    """Projected (optionally FWP-compacted) value table + sampling geometry."""
    v: jnp.ndarray                      # (B, N_rows, H, Dh) staged table
    pix2slot: Optional[jnp.ndarray]     # (B, N_in) pixel -> slot (or None)
    keep_idx: Optional[jnp.ndarray]     # (B, cap) slot -> pixel, raster-ordered
    n_rows: int                         # static row count of ``v``
    slot_windows: Tuple[int, ...]       # static per-level slot windows
    #   (compact mode; () when dense) — what a windowed consumer may stage
    table_bytes: int                    # bytes staged per (batch, head-group)
    #   to build this table once: rows x lanes x itemsize (+ the int32
    #   pix2slot indirection in compact mode). This is the ACTUAL built
    #   table (dense when no FWP link exists yet); the static plan-side
    #   estimate that assumes compaction is ``MSDAPlan.cache_table_bytes``.
    #   Surfaced per block via the collect_stats "cache_table_bytes" entry.
    staged: Optional[object] = None     # DecodeStagedTable when the plan's
    #   backend is the persistent decode kernel: ``v`` re-laid-out ONCE
    #   per memory into the decode launch layout (kernels/msgs_decode.py);
    #   every consumer launch then reuses it — one staging per
    #   (batch, head-group) per memory, never per layer.


def project_values(params: dict, cfg, x_flat: jnp.ndarray,
                   fwp_state: Optional[fwp_lib.FWPState]):
    """FWP-pruned value projection V = X W^V.

    Returns (v (B, N_rows, H, Dh), pix2slot or None, n_rows)."""
    b = x_flat.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    n_in = x_flat.shape[1]
    wq = lambda w: maybe_fake_quant(w, cfg.weight_bits)
    if fwp_state is not None and cfg.fwp_mode == "compact":
        cap = fwp_state.keep_idx.shape[1]
        x_kept = jnp.take_along_axis(x_flat, fwp_state.keep_idx[..., None], axis=1)
        v = jnp.einsum("bnd,dhk->bnhk", x_kept, wq(params["value_w"])) \
            + params["value_b"]
        v = jnp.concatenate([v, jnp.zeros((b, 1, h, dh), v.dtype)], axis=1)
        pix2slot = fwp_state.pix2slot                    # (B, N_in)
        n_rows = cap + 1
    elif fwp_state is not None and cfg.fwp_mode == "mask":
        xm = x_flat * fwp_state.keep_mask[..., None].astype(x_flat.dtype)
        v = jnp.einsum("bnd,dhk->bnhk", xm, wq(params["value_w"])) \
            + params["value_b"]
        # masked pixels must contribute EXACT zero (bias would leak):
        v = v * fwp_state.keep_mask[..., None, None].astype(v.dtype)
        pix2slot = None
        n_rows = n_in
    else:
        v = jnp.einsum("bnd,dhk->bnhk", x_flat, wq(params["value_w"])) \
            + params["value_b"]
        pix2slot = None
        n_rows = n_in
    return maybe_fake_quant(v, cfg.act_bits), pix2slot, n_rows


def build_value_cache(params: dict, plan, x_flat: jnp.ndarray,
                      state=None) -> MSDAValueCache:
    """Build the shared value cache for one memory ``x_flat``.

    ``params`` needs only the value projection (``value_w``/``value_b``);
    ``state`` is the :class:`~repro.msda.pipeline.MSDAPipelineState` whose
    FWP chain link decides the compaction (None / no link => dense table).
    Called ONCE per memory; every sampler (encoder block body, all decoder
    layers) then consumes the result through
    :func:`repro.msda.attention.msda_attention_cached`."""
    cfg = plan.cfg
    fwp_state = getattr(state, "fwp", None)
    v, pix2slot, n_rows = project_values(params, cfg, x_flat, fwp_state)
    keep_idx = fwp_state.keep_idx if pix2slot is not None else None

    table_bytes = plan.table_bytes_for_rows(
        n_rows, with_indirection=pix2slot is not None)
    slot_windows: Tuple[int, ...] = ()
    if pix2slot is not None:
        # geometry for windowed consumers of a compact cache (the raster
        # kernel derives its own via WindowGeometry; a decode-shaped
        # windowed kernel — ROADMAP — would stage these per level). The
        # bound excludes the zero sentinel row: it is addressable but
        # never part of a level's slot range.
        caps = fwp_lib.level_capacities(plan.level_shapes, cfg.fwp_capacity)
        slot_windows = tuple(min(int(c), n_rows - 1) for c in caps)

    staged = None
    if plan.backend == "pallas_decode":
        # The plan-keyed staging decision: lay the table out in the decode
        # launch layout ONCE, here, per memory — every consumer layer's
        # launch reuses the staged block (kernels/msgs_decode.py). Routed
        # through the module attribute so the staging-spy tests can count
        # stagings per memory.
        from repro.kernels import msgs_decode as msgs_decode_kernel
        staged = msgs_decode_kernel.stage_decode_table(
            v, pix2slot, head_pack=plan.decode_head_pack)
    return MSDAValueCache(v=v, pix2slot=pix2slot, keep_idx=keep_idx,
                          n_rows=n_rows, slot_windows=slot_windows,
                          table_bytes=table_bytes, staged=staged)
