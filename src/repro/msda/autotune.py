"""plan_autotune — the measured plan table behind ``backend="auto"``.

Three planner inputs were guesses until this module: the 4 MB
``DEFAULT_WINDOW_STAGING_BUDGET`` for windowed/decode staging fit, the
assumption that the ``pallas_decode`` (query-tile x layer) sweep spares
the HBM->VMEM table refetch, and the streaming diff-vs-reprojection
crossover that sets ``StreamConfig.diff_channel_stride``/``update_frac``.
:func:`plan_autotune` replaces all three with ON-DEVICE timing:

  (a) **staging budget** — a bandwidth-knee probe: a jitted
      gather+reduce over value tables of increasing size; per-byte cost
      is flat while the working set stays resident in the fast tier and
      knees upward once it spills. The measured ceiling is the largest
      probed size still within ``KNEE_TOL`` of the best per-byte cost.
  (b) **decode sweep** — an N-layer decode-shaped cross-attention stack
      through ``pallas_decode`` (table staged once per memory) vs the
      per-layer ``pallas_fused`` restage on the same cache; the verdict
      (``decode_sweep_beneficial``) vetoes the auto policy's decode gate
      on platforms where the sweep does NOT pay.
  (c) **streaming crossover** — per-frame diff cost at channel strides
      vs the re-projection cost at update fractions, against the full
      per-frame rebuild both amortize: the chosen (stride, frac) is the
      cheapest probed diff that stays a small fraction of the rebuild,
      paired with the LARGEST update budget whose incremental frame
      still clearly undercuts rebuilding.

Winners persist in a per-platform JSON table (``results/autotune.json``,
keyed by ``jax.default_backend()`` the way ``results/benchmarks.json``
keys its sections) so measurement runs once per machine; CI and
device-less machines ride the COMMITTED table (``--no-measure``). A
corrupted/partial table falls back to the static formulas with a warning
— never a crash. The applied entry lives in :mod:`repro.msda.plan`
(``apply_tuned_plan_table``), where ``window_staging_budget()``,
``make_plan``'s auto gates, ``resolve_stream_config`` and the serve
engines consult it: ``backend="auto"`` then means "measured best".
Tuning changes WHICH backend/budget is chosen, never numerics — the
``--check`` CLI asserts tuned-vs-static bit-identity.

CLI::

    PYTHONPATH=src python -m repro.msda.autotune            # measure+persist
    PYTHONPATH=src python -m repro.msda.autotune --force    # re-tune
    PYTHONPATH=src python -m repro.msda.autotune --no-measure --check   # CI
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.msda import plan as plan_lib

SCHEMA_VERSION = 1

#: paper-style 4-level pyramid at the dry-run scale every calibration
#: measurement runs on — small enough for interpret-mode Pallas, same
#: family as the ``msda_*`` microbench rows.
CALIB_LEVELS: Tuple[Tuple[int, int], ...] = ((16, 20), (8, 10), (4, 5),
                                             (2, 3))

#: per-byte cost within this factor of the best probed size still counts
#: as "fits the fast tier" for the budget knee.
KNEE_TOL = 1.5

#: the measured budget is clamped to this sane range — a noisy probe must
#: never produce a degenerate (or absurd) ceiling.
BUDGET_CLAMP = (1 * 2**20, 64 * 2**20)

#: streaming crossover thresholds: the diff must cost at most
#: DIFF_FRAC of a full rebuild (else probe fewer channels), and an
#: incremental frame (diff + budgeted re-projection) must stay under
#: CROSSOVER_FRAC of the rebuild to justify its budget.
DIFF_FRAC = 0.25
CROSSOVER_FRAC = 0.6

#: the (32x40, d_model=256) shape the streaming crossover measures at —
#: the same geometry as the ``msda_stream_*`` microbench rows. The toy
#: CALIB_LEVELS shape is useless here: its rebuild matmul is so small
#: that fixed dispatch overheads dominate every probe and the crossover
#: degenerates to "coarsest stride, smallest budget".
STREAM_CALIB_LEVELS: Tuple[Tuple[int, int], ...] = ((32, 40), (16, 20),
                                                    (8, 10), (4, 5))
STREAM_CALIB_D_MODEL = 256

#: decode-sweep veto threshold: the sweep's real benefit is the spared
#: per-layer HBM->VMEM table refetch, which interpret-mode wall time
#: cannot observe — so the verdict only turns negative on a DECISIVE
#: measured loss (the sweep slower than per-layer restaging by more than
#: this factor), not on noise-level parity.
DECODE_VETO_TOL = 0.85


def default_table_path() -> str:
    """``results/autotune.json`` at the repo root (next to
    ``results/benchmarks.json``), overridable via the
    ``REPRO_MSDA_AUTOTUNE_TABLE`` env var."""
    env = os.environ.get("REPRO_MSDA_AUTOTUNE_TABLE")
    if env:
        return env
    here = os.path.abspath(__file__)
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(here))))
    return os.path.join(root, "results", "autotune.json")


def platform_key() -> str:
    """The table's platform key — ``jax.default_backend()`` ("cpu" |
    "gpu" | "tpu"), the same axis ``results/benchmarks.json`` rows are
    implicitly scaled along."""
    return jax.default_backend()


def _default_cfg():
    from repro.core.msdeform_attn import MSDeformAttnConfig
    return MSDeformAttnConfig(d_model=64, n_heads=4,
                              range_narrow=(6.0, 4.0, 3.0, 2.0))


# --------------------------------------------------------------------------
# Table persistence
# --------------------------------------------------------------------------

def load_table(path: Optional[str] = None) -> Optional[dict]:
    """Read the persistent plan table; a missing file returns None
    silently, a corrupted/mis-shaped one returns None WITH a warning —
    the caller falls back to the static formulas, never crashes."""
    path = path or default_table_path()
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            table = json.load(f)
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        warnings.warn(f"autotune table {path!r} is unreadable ({e}); "
                      "falling back to static plan formulas",
                      RuntimeWarning, stacklevel=2)
        return None
    if not isinstance(table, dict) \
            or table.get("schema") != SCHEMA_VERSION \
            or not isinstance(table.get("platforms"), dict):
        warnings.warn(
            f"autotune table {path!r} has an unexpected shape/schema "
            f"(want schema={SCHEMA_VERSION} with a 'platforms' dict); "
            "falling back to static plan formulas",
            RuntimeWarning, stacklevel=2)
        return None
    return table


def valid_entry(entry) -> bool:
    """Structural validation of one platform entry — a PARTIAL entry (a
    truncated write, a hand-edit gone wrong) must fail closed to the
    static formulas."""
    return (isinstance(entry, dict)
            and isinstance(entry.get("staging_budget_bytes"), int)
            and entry["staging_budget_bytes"] > 0
            and isinstance(entry.get("decode_sweep_beneficial"), bool)
            and isinstance(entry.get("stream"), dict)
            and isinstance(entry["stream"].get("diff_channel_stride"), int)
            and entry["stream"]["diff_channel_stride"] >= 1
            and isinstance(entry["stream"].get("update_frac"), (int, float))
            and 0.0 < float(entry["stream"]["update_frac"]) <= 1.0)


def save_entry(entry: dict, path: Optional[str] = None,
               platform: Optional[str] = None) -> str:
    """Merge one platform's entry into the table on disk (other
    platforms' rows survive — the committed table carries every machine
    the suite has run on, like ``results/benchmarks.json``)."""
    path = path or default_table_path()
    platform = platform or platform_key()
    table = load_table(path) or {"schema": SCHEMA_VERSION, "platforms": {}}
    table["platforms"][platform] = entry
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


# --------------------------------------------------------------------------
# Timing primitives
# --------------------------------------------------------------------------

def _time(fn, *args, iters: int = 5) -> float:
    """Median wall seconds per call (warm; block_until_ready) — the same
    discipline as benchmarks/microbench.py, fewer iters: startup
    calibration must stay cheap."""
    fn(*args)
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measure_staging_budget(sizes_mb: Sequence[int] = (1, 2, 4, 8, 16, 32),
                           iters: int = 5) -> Tuple[int, dict]:
    """(a) The staged-table VMEM/fast-tier ceiling, by bandwidth knee.

    Times a jitted sweep+gather over a (rows, 128) f32 table per probed
    size; the per-byte cost curve is flat while the table stays resident
    and knees upward at the spill point. Returns (budget_bytes, detail):
    the largest probed size within ``KNEE_TOL`` of the best per-byte
    cost, clamped to ``BUDGET_CLAMP``."""
    lanes = 128
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 4096, size=4096), jnp.int32)

    @jax.jit
    def probe(v, i):
        # one full pass over the table (the staging fetch) + a bounded
        # gather (the sampling access pattern riding on the staged copy)
        return v.sum() + jnp.take(v, i, axis=0).sum()

    per_byte = {}
    for mb in sizes_mb:
        rows = max(4096, (int(mb) * 2**20) // (lanes * 4))
        v = jnp.asarray(rng.standard_normal((rows, lanes)), jnp.float32)
        nbytes = rows * lanes * 4
        per_byte[int(mb)] = _time(probe, v, idx, iters=iters) / nbytes
    best = min(per_byte.values())
    fitting = [mb for mb in per_byte if per_byte[mb] <= KNEE_TOL * best]
    budget = max(fitting) * 2**20
    budget = int(min(max(budget, BUDGET_CLAMP[0]), BUDGET_CLAMP[1]))
    detail = {"sizes_mb": [int(m) for m in sizes_mb],
              "ns_per_byte": {str(m): per_byte[m] * 1e9 for m in per_byte},
              "knee_tol": KNEE_TOL, "budget_bytes": budget}
    return budget, detail


def measure_decode_sweep(cfg=None,
                         level_shapes: Optional[Sequence] = None,
                         n_layers: int = 3, iters: int = 3,
                         repeats: int = 3) -> Tuple[bool, float, dict]:
    """(b) Does the persistent decode sweep spare the table refetch HERE?

    Times an ``n_layers`` decode-shaped cross-attention stack sampling
    ONE built cache through ``pallas_decode`` (table staged once per
    memory, every layer's launch reuses it) vs ``pallas_fused`` (each
    layer's launch restages the whole table). The calibration stack is
    tiny enough to be scheduler-noise dominated, and noise only ever
    inflates a timing — so each backend's cost is the MIN over
    ``repeats`` interleaved timing rounds. Returns
    (beneficial, speedup, detail) with speedup = fused_t / decode_t;
    beneficial is ``speedup >= DECODE_VETO_TOL`` — only a decisive
    measured loss vetoes the sweep, since the refetch saving itself is
    invisible to interpret-mode wall time."""
    from repro import msda

    cfg = cfg or _default_cfg()
    level_shapes = tuple(tuple(s) for s in (level_shapes or CALIB_LEVELS))
    from repro.core.msdeform_attn import init_msdeform_attn
    key = jax.random.PRNGKey(11)
    params = init_msdeform_attn(key, cfg)
    nq = 64
    n_in = sum(h * w for h, w in level_shapes)
    memory = jax.random.normal(jax.random.fold_in(key, 1),
                               (1, n_in, cfg.d_model))
    q = jax.random.normal(jax.random.fold_in(key, 2), (1, nq, cfg.d_model))
    refs = jax.random.uniform(jax.random.fold_in(key, 3), (1, nq, 2),
                              minval=0.1, maxval=0.9)
    vparams = {k: params[k] for k in ("value_w", "value_b")}

    from repro.msda.backends import candidate_backends
    names = [n for n in candidate_backends(decode_shaped=True)
             if n in ("pallas_decode", "pallas_fused")]
    assert names == ["pallas_decode", "pallas_fused"], names

    fns = {}
    for name in names:
        plan = msda.make_plan(cfg, level_shapes, backend=name, n_queries=nq,
                              n_consumers=n_layers)

        def stack(p_, m_, q_, r_, plan=plan):
            cache = msda.build_value_cache(vparams, plan, m_)
            out = q_
            for _ in range(n_layers):
                o, _st = msda.msda_attention_cached(p_, plan, out, r_,
                                                    cache, update_fwp=False)
                out = out + o
            return out

        fns[name] = jax.jit(stack)
    times = {name: float("inf") for name in names}
    for _ in range(max(1, repeats)):
        for name in names:
            t = _time(fns[name], params, memory, q, refs, iters=iters)
            times[name] = min(times[name], t)
    speedup = times["pallas_fused"] / max(times["pallas_decode"], 1e-12)
    detail = {"n_layers": n_layers, "n_queries": nq,
              "level_shapes": [list(s) for s in level_shapes],
              "decode_s": times["pallas_decode"],
              "fused_s": times["pallas_fused"], "speedup": speedup,
              "repeats": max(1, repeats), "veto_tol": DECODE_VETO_TOL}
    return bool(speedup >= DECODE_VETO_TOL), float(speedup), detail


def measure_stream_crossover(d_model: int = STREAM_CALIB_D_MODEL,
                             level_shapes: Optional[Sequence] = None,
                             strides: Sequence[int] = (1, 2, 4),
                             fracs: Sequence[float] = (0.5, 0.25, 0.125),
                             tile_rows: int = 2, iters: int = 5
                             ) -> Tuple[int, float, dict]:
    """(c) The streaming diff-vs-reprojection crossover.

    Measures, on a synthetic memory at the calibration shape: the
    tile-diff cost per probed ``diff_channel_stride``, the budgeted
    re-projection cost per ``update_frac`` (a (B, U, D) projection — the
    incremental path's proportional term), and the full per-frame
    rebuild both amortize. Picks the smallest stride whose diff stays
    under ``DIFF_FRAC`` of the rebuild (exact diffing is preferred —
    larger strides only delay sub-probe changes), then the LARGEST frac
    whose incremental frame (diff + update) undercuts
    ``CROSSOVER_FRAC`` x rebuild. Returns (stride, frac, detail)."""
    from repro.stream.tiles import changed_tiles, tile_geometry

    level_shapes = tuple(tuple(s)
                         for s in (level_shapes or STREAM_CALIB_LEVELS))
    n_in = sum(h * w for h, w in level_shapes)
    geo = tile_geometry(level_shapes, tile_rows)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, n_in, d_model)), jnp.float32)
    ref = x + jnp.asarray(
        1e-3 * rng.standard_normal((1, n_in, d_model)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d_model, d_model)) / np.sqrt(d_model),
                    jnp.float32)

    diff_t = {}
    for s in strides:
        fn = jax.jit(lambda a, b, s=int(s):
                     changed_tiles(geo, a[..., ::s], b[..., ::s], 1e-5))
        diff_t[int(s)] = _time(fn, x, ref, iters=iters)

    rebuild = jax.jit(lambda a, w_: a @ w_)
    rebuild_t = _time(rebuild, x, w, iters=iters)

    update_t = {}
    for f in fracs:
        u = max(1, int(round(float(f) * n_in)))
        proj = jax.jit(lambda a, w_, u=u: a[:, :u] @ w_)
        update_t[float(f)] = _time(proj, x, w, iters=iters)

    stride = max(int(s) for s in strides)
    for s in sorted(int(s) for s in strides):
        if diff_t[s] <= DIFF_FRAC * rebuild_t:
            stride = s
            break
    frac = min(float(f) for f in fracs)
    for f in sorted((float(f) for f in fracs), reverse=True):
        if diff_t[stride] + update_t[f] <= CROSSOVER_FRAC * rebuild_t:
            frac = f
            break
    detail = {"level_shapes": [list(s) for s in level_shapes],
              "d_model": d_model, "tile_rows": tile_rows,
              "diff_s": {str(k): v for k, v in diff_t.items()},
              "update_s": {str(k): v for k, v in update_t.items()},
              "rebuild_s": rebuild_t, "diff_frac": DIFF_FRAC,
              "crossover_frac": CROSSOVER_FRAC,
              "diff_channel_stride": stride, "update_frac": frac}
    return stride, frac, detail


# --------------------------------------------------------------------------
# The autotune pass
# --------------------------------------------------------------------------

def plan_autotune(cfg=None, level_shapes: Optional[Sequence] = None, *,
                  measure: Optional[bool] = None, force: bool = False,
                  cache_path: Optional[str] = None, persist: bool = True,
                  iters: int = 5, warn_missing: bool = True
                  ) -> Optional[dict]:
    """Resolve (measure or load) the platform's plan table and APPLY it.

    The startup contract: the first run on a machine times the three
    calibration items on the actual device and persists the winners;
    every later process loads the table in microseconds. ``measure``:
    None (default) measures only when no usable entry exists; False
    never measures (CI / device-less machines — committed-table or
    static fallback); True with ``force`` re-measures over an existing
    entry. Returns the applied entry, or None on static fallback.

    After this returns, ``make_plan(..., backend="auto")``/``plan_for``
    resolve the measured budget (``describe()`` reports
    ``budget=measured``), the auto decode gate honors the measured sweep
    verdict, and ``resolve_stream_config(None)`` yields the measured
    ``diff_channel_stride``/``update_frac`` — end to end through
    ``TemporalCacheManager`` and the serve engines."""
    path = cache_path or default_table_path()
    plat = platform_key()
    entry = None
    table = load_table(path)
    if table is not None:
        entry = table.get("platforms", {}).get(plat)
        if entry is not None and not valid_entry(entry):
            warnings.warn(
                f"autotune entry for platform {plat!r} in {path!r} is "
                "partial/invalid; falling back to "
                + ("re-measurement" if measure is not False
                   else "static plan formulas"),
                RuntimeWarning, stacklevel=2)
            entry = None

    if entry is not None and not force:
        plan_lib.apply_tuned_plan_table(entry)
        return entry

    if measure is False:
        if warn_missing:
            warnings.warn(
                f"no usable autotune entry for platform {plat!r} "
                f"({path}) and measurement is disabled; static plan "
                "formulas stay in effect", RuntimeWarning, stacklevel=2)
        plan_lib.apply_tuned_plan_table(None)
        return None

    budget, budget_detail = measure_staging_budget(iters=iters)
    beneficial, speedup, decode_detail = measure_decode_sweep(
        cfg, level_shapes, iters=max(2, iters - 2))
    # the streaming crossover always measures at its own calibration
    # shape (STREAM_CALIB_LEVELS / d_model=256): the decode shape's
    # rebuild matmul is too small to expose the tradeoff
    stride, frac, stream_detail = measure_stream_crossover(iters=iters)
    entry = {
        "provenance": "measured",
        "platform": plat,
        "staging_budget_bytes": int(budget),
        "decode_sweep_beneficial": bool(beneficial),
        "decode_persistent_speedup": float(speedup),
        "stream": {"diff_channel_stride": int(stride),
                   "update_frac": float(frac)},
        "calibration": {"staging_budget": budget_detail,
                        "decode_sweep": decode_detail,
                        "stream_crossover": stream_detail},
    }
    if persist:
        try:
            save_entry(entry, path, plat)
        except OSError as e:
            warnings.warn(f"could not persist autotune table to {path!r} "
                          f"({e}); the measured entry applies to this "
                          "process only", RuntimeWarning, stacklevel=2)
    plan_lib.apply_tuned_plan_table(entry)
    return entry


_ENSURE_TRIED = False


def ensure_applied(cache_path: Optional[str] = None) -> Optional[dict]:
    """Load-only startup hook for the serve engines: apply the persisted
    per-platform entry once per process when none is applied yet. Never
    measures (startup must stay fast), never raises (a broken table must
    not take serving down) — at worst the static formulas stand."""
    global _ENSURE_TRIED
    if plan_lib.tuned_entry() is not None:
        return plan_lib.tuned_entry()
    if _ENSURE_TRIED:
        return None
    _ENSURE_TRIED = True
    try:
        return plan_autotune(measure=False, cache_path=cache_path,
                             warn_missing=False)
    except Exception:                     # noqa: BLE001 - serving shield
        return None


# --------------------------------------------------------------------------
# CLI (the CI leg: --no-measure --check)
# --------------------------------------------------------------------------

def _check(cfg, level_shapes) -> int:
    """Assert the applied table reaches the planner (budget=measured
    provenance) and that tuning never changes numerics: the auto-chosen
    backend under the tuned plan is bit-identical to the SAME backend
    chosen statically."""
    from repro import msda
    from repro.core.msdeform_attn import init_msdeform_attn

    entry = plan_lib.tuned_entry()
    if entry is None:
        print("[autotune --check] FAIL: no tuned entry applied "
              f"for platform {platform_key()!r}")
        return 2

    plan = plan_lib.plan_for(cfg, level_shapes, "auto", 64, 6)
    desc = plan.describe()
    if "budget=measured" not in desc:
        print("[autotune --check] FAIL: plan provenance is not measured: "
              + desc)
        return 2
    print(f"[autotune --check] provenance ok: {desc}")

    # tuned-vs-static bit-identity on a full planned attention pass
    key = jax.random.PRNGKey(5)
    params = init_msdeform_attn(key, cfg)
    n_in = plan.n_in
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, n_in, cfg.d_model))
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, n_in, cfg.d_model))
    from repro.core import nn
    refs = jnp.broadcast_to(
        nn.reference_points_for_levels(level_shapes)[None], (1, n_in, 2))
    tuned_plan = msda.make_plan(cfg, level_shapes, backend="auto")
    out_tuned, _ = msda.msda_attention(params, tuned_plan, q, refs, x)
    try:
        plan_lib.apply_tuned_plan_table(None)
        static_plan = msda.make_plan(cfg, level_shapes,
                                     backend=tuned_plan.backend)
        assert static_plan.budget_source == "static"
        out_static, _ = msda.msda_attention(params, static_plan, q, refs, x)
    finally:
        plan_lib.apply_tuned_plan_table(entry)
    if not np.array_equal(np.asarray(out_tuned), np.asarray(out_static)):
        print("[autotune --check] FAIL: tuned plan output differs from "
              f"static {tuned_plan.backend!r} output — tuning must change "
              "backend/budget choice, never numerics")
        return 2
    print(f"[autotune --check] bit-identity ok: auto->"
          f"{tuned_plan.backend} tuned == static "
          f"(budget {plan.staging_budget_bytes} B measured vs "
          f"{plan_lib.DEFAULT_WINDOW_STAGING_BUDGET} B static default)")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--no-measure", action="store_true",
                    help="never time the device: committed-table or "
                    "static fallback (the CI leg)")
    ap.add_argument("--force", action="store_true",
                    help="re-measure even when a table entry exists")
    ap.add_argument("--table", default=None,
                    help="plan-table path (default results/autotune.json)")
    ap.add_argument("--check", action="store_true",
                    help="assert budget=measured provenance and "
                    "tuned-vs-static bit-identity; exit non-zero on failure")
    args = ap.parse_args(argv)

    cfg = _default_cfg()
    entry = plan_autotune(cfg, CALIB_LEVELS,
                          measure=False if args.no_measure else None,
                          force=args.force, cache_path=args.table)
    if entry is None:
        print(f"[autotune] platform {platform_key()!r}: no entry applied — "
              "static plan formulas in effect")
        return 2 if args.check else 0
    src = "loaded" if not args.force and not args.no_measure else \
        ("loaded (no-measure)" if args.no_measure else "measured")
    print(f"[autotune] platform {platform_key()!r} ({src}): "
          f"staging_budget={entry['staging_budget_bytes']} B, "
          f"decode_sweep_beneficial={entry['decode_sweep_beneficial']} "
          f"(speedup {entry.get('decode_persistent_speedup', 0):.2f}x), "
          f"stream stride={entry['stream']['diff_channel_stride']} "
          f"frac={entry['stream']['update_frac']}")
    if args.check:
        return _check(cfg, CALIB_LEVELS)
    return 0


if __name__ == "__main__":
    sys.exit(main())
