"""Shared sampling-point machinery for every MSDA backend.

One place computes, for each (batch, query, head, point):

  * the PAP-surviving attention probabilities and point indices,
  * the range-narrowed, fake-quantized offsets,
  * the per-point level geometry (flat start, width, height) and the
    absolute pixel coordinates in the point's own level.

Backends then only differ in HOW they gather + bilinearly combine the
value rows (``repro/msda/backends.py``); the distributed banded path
reuses ``select_points`` and applies its own band-local geometry.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fwp as fwp_lib
from repro.core import pap as pap_lib
from repro.core.quant import maybe_fake_quant


class SamplingPoints(NamedTuple):
    """Backend-agnostic sampling geometry. All point arrays (B, Nq, H, K)."""
    x_px: jnp.ndarray        # absolute pixel x in the point's own level
    y_px: jnp.ndarray
    start: jnp.ndarray       # int32 flat start of the point's level
    wl: jnp.ndarray          # int32 level width per point
    hl: jnp.ndarray          # int32 level height per point
    lvl_of_pt: jnp.ndarray   # int32 level index per point
    pix2slot: Optional[jnp.ndarray]   # (B, N_pix) FWP-compact indirection
    keep_idx: Optional[jnp.ndarray] = None   # (B, cap) slot -> pixel map,
    #   raster-ordered per level; the windowed kernel searchsorts it to
    #   locate the compact slot window of a pixel window (no densify)


def level_meta(level_shapes: Sequence[Tuple[int, int]]):
    """Static per-level arrays: flat starts, widths, heights; total N_in."""
    starts, n_in = fwp_lib.level_starts(level_shapes)
    ws = np.asarray([w for _, w in level_shapes], np.int32)
    hs = np.asarray([h for h, _ in level_shapes], np.int32)
    return jnp.asarray(starts), jnp.asarray(ws), jnp.asarray(hs), n_in


def corner_data(x_px, y_px, wl, hl, start):
    """Per-point corner indices/weights/validity in the flat fmap.

    x_px,y_px,wl,hl,start: (...,) arrays (wl/hl/start already per-point).
    Returns idx (..., 4) int32, wgt (..., 4), valid (..., 4)."""
    x0 = jnp.floor(x_px)
    y0 = jnp.floor(y_px)
    t1 = x_px - x0
    t0 = y_px - y0
    corners = []
    for dy in (0, 1):
        for dx in (0, 1):
            cx = x0 + dx
            cy = y0 + dy
            valid = ((cx >= 0) & (cx < wl) & (cy >= 0) & (cy < hl))
            cxc = jnp.clip(cx, 0, wl - 1).astype(jnp.int32)
            cyc = jnp.clip(cy, 0, hl - 1).astype(jnp.int32)
            idx = start + cyc * wl + cxc
            w = (t1 if dx else (1 - t1)) * (t0 if dy else (1 - t0))
            corners.append((idx, w, valid))
    idx = jnp.stack([c[0] for c in corners], axis=-1)
    wgt = jnp.stack([c[1] for c in corners], axis=-1)
    valid = jnp.stack([c[2] for c in corners], axis=-1)
    return idx, wgt, valid


def flat_gather_heads(v: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """v: (B, N, H, Dh); idx: (B, Nq, H, M) -> (B, Nq, H, M, Dh)."""
    b, n, h, dh = v.shape
    _, nq, _, m = idx.shape
    vv = v.transpose(0, 2, 1, 3).reshape(b * h, n, dh)
    ii = idx.transpose(0, 2, 1, 3).reshape(b * h, nq * m)
    g = jnp.take_along_axis(vv, ii[..., None], axis=1)
    return g.reshape(b, h, nq, m, dh).transpose(0, 2, 1, 3, 4)


def select_points(params: dict, cfg, query: jnp.ndarray):
    """PAP selection + masked offset generation (pre-geometry).

    Returns (sel: PAPSelection, offs_k (B,Nq,H,K,2) range-narrowed &
    quantized, lvl_of_pt (B,Nq,H,K) int32). Shared by the planned
    execution and the distributed banded path."""
    b, nq, _ = query.shape
    h, p, lp = cfg.n_heads, cfg.n_points, cfg.n_lp
    wq = lambda w: maybe_fake_quant(w, cfg.weight_bits)

    logits = jnp.einsum("bnd,dhk->bnhk", query, wq(params["attn_w"])) \
        + params["attn_b"]
    probs = jax.nn.softmax(logits, axis=-1)
    probs = maybe_fake_quant(probs, cfg.act_bits)
    sel = pap_lib.pap_select(probs, cfg.pap_mode,
                             threshold=cfg.pap_threshold, k=cfg.pap_keep)

    offs = jnp.einsum("bnd,dhk->bnhk", query, wq(params["offs_w"])) \
        + params["offs_b"]
    offs = offs.reshape(b, nq, h, lp, 2)
    offs_k = jnp.take_along_axis(
        offs, sel.point_idx[..., None].astype(jnp.int32), axis=3)
    lvl_of_pt = (sel.point_idx // p).astype(jnp.int32)
    if cfg.range_narrow is not None:
        bounds = jnp.take(jnp.asarray(cfg.range_narrow, query.dtype), lvl_of_pt)
        offs_k = jnp.clip(offs_k, -bounds[..., None], bounds[..., None])
    offs_k = maybe_fake_quant(offs_k, cfg.act_bits)     # INT12 BI datapath input
    return sel, offs_k, lvl_of_pt


def generate_points(params: dict, cfg, query: jnp.ndarray,
                    ref_points: jnp.ndarray,
                    level_shapes: Sequence[Tuple[int, int]],
                    pix2slot: Optional[jnp.ndarray] = None,
                    keep_idx: Optional[jnp.ndarray] = None):
    """Full point generation: PAP + offsets + flat-level geometry.

    Returns (sel: PAPSelection, pts: SamplingPoints)."""
    starts, ws, hs, _ = level_meta(level_shapes)
    sel, offs_k, lvl_of_pt = select_points(params, cfg, query)
    wl = jnp.take(ws, lvl_of_pt)
    hl = jnp.take(hs, lvl_of_pt)
    st = jnp.take(starts, lvl_of_pt)
    wl_f = wl.astype(query.dtype)
    hl_f = hl.astype(query.dtype)
    x_px = ref_points[:, :, None, None, 0] * wl_f + offs_k[..., 0] - 0.5
    y_px = ref_points[:, :, None, None, 1] * hl_f + offs_k[..., 1] - 0.5
    pts = SamplingPoints(x_px=x_px, y_px=y_px, start=st, wl=wl, hl=hl,
                         lvl_of_pt=lvl_of_pt, pix2slot=pix2slot,
                         keep_idx=keep_idx)
    return sel, pts
