"""Named MSDA execution backends.

Every backend implements one uniform contract:

    backend(plan: MSDAPlan,
            v: (B, N_rows, H, Dh),          # value table (maybe FWP-compacted)
            pts: SamplingPoints,            # (B, Nq, H, K) point geometry
            probs: (B, Nq, H, K),           # PAP-surviving probabilities
            cache=None,                     # MSDAValueCache when sampling a
                                            # prebuilt shared table
            ) -> (B, Nq, H, Dh)             # per-head aggregated samples

so new kernels (sharded, quantized, batched-serving) slot in with a
``@register_backend("name")`` and zero caller changes. Selection happens
once, in ``plan.make_plan`` — never inside the hot path. ``cache`` is
how build-once artifacts (e.g. the persistent decode path's pre-staged
table) reach the kernel without widening the positional contract;
backends that don't consume it ignore it.

  * ``jnp_gather``           — XLA flat-gather oracle path (any hardware).
  * ``pallas_fused``         — whole-table-in-VMEM fused MSGS+aggregation
                               kernel (C6); head-packed 128-lane dispatch
                               when the plan packs ``head_pack`` heads per
                               group.
  * ``pallas_windowed``      — multi-scale-parallel windowed kernel
                               (C3+C5+C7): ONE launch whose grid spans
                               (B x head-group x query-tile x sampled
                               level), staging only each level's
                               range-narrowed window and accumulating
                               cross-level partials in-kernel. Samples the
                               FWP-compacted table directly through the
                               pix2slot indirection — never densifies.
                               Needs raster-ordered encoder queries
                               (Nq == N_in) and range-narrowing — no
                               decode-shaped launch.
  * ``pallas_decode``        — persistent-cache decode kernel
                               (kernels/msgs_decode.py): samples the
                               shared cache's PRE-STAGED table (laid out
                               once per memory by ``build_value_cache``),
                               grid (B x head-group x query-tile x layer)
                               with the table block indexed by
                               (batch, head-group) only. Decode-shaped
                               launches only (N_q learned queries);
                               differentiable via custom_vjp.

(``pallas_windowed_loop``, the L² launch loop kept one release as the
single-launch kernel's numeric diff target, is retired: the parity matrix
now diffs ``pallas_windowed`` against the ``jnp_gather`` oracle directly.)
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple

import jax.numpy as jnp

from repro.msda.sampling import SamplingPoints, corner_data, flat_gather_heads

BackendFn = Callable[..., jnp.ndarray]


class BackendInfo(NamedTuple):
    """Static registry metadata the planner (and benchmarks) consult:
    ``raster_only`` backends need raster-ordered encoder queries
    (Nq == N_in); ``decode_only`` backends need a decode-shaped plan
    (N_q learned queries). Neither set => any query geometry."""
    raster_only: bool = False
    decode_only: bool = False


_REGISTRY: Dict[str, BackendFn] = {}
_INFO: Dict[str, BackendInfo] = {}


def register_backend(name: str, *, raster_only: bool = False,
                     decode_only: bool = False):
    """Decorator: register fn under ``name`` in the backend registry."""
    def deco(fn: BackendFn) -> BackendFn:
        _REGISTRY[name] = fn
        _INFO[name] = BackendInfo(raster_only=raster_only,
                                  decode_only=decode_only)
        return fn
    return deco


def get_backend(name: str) -> BackendFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no MSDA backend {name!r}; "
                       f"available: {available_backends()}") from None


def backend_info(name: str) -> BackendInfo:
    """Query-geometry metadata for a registered backend (default-neutral
    for probe backends registered without explicit flags)."""
    return _INFO.get(name, BackendInfo())


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


def candidate_backends(*, decode_shaped: bool) -> List[str]:
    """Registered backends eligible for one query geometry — the
    autotuner's candidate set (and the planner's legal-choice universe):
    decode-shaped launches exclude ``raster_only`` backends, raster
    launches exclude ``decode_only`` ones."""
    out = []
    for name in available_backends():
        info = _INFO[name]
        if decode_shaped and info.raster_only:
            continue
        if not decode_shaped and info.decode_only:
            continue
        out.append(name)
    return out


# --------------------------------------------------------------------------
# jnp_gather — pure-XLA flat gather (runs anywhere, autodiff-friendly)
# --------------------------------------------------------------------------

@register_backend("jnp_gather")
def jnp_gather(plan, v: jnp.ndarray, pts: SamplingPoints,
               probs: jnp.ndarray, cache=None) -> jnp.ndarray:
    b, nq, h, k = probs.shape
    idx, wgt, valid = corner_data(pts.x_px, pts.y_px, pts.wl, pts.hl, pts.start)
    idx = idx.reshape(b, nq, h, k * 4)
    if pts.pix2slot is not None:
        # pixel -> compact-slot remap on the flat (b, nq, h, k*4) index:
        # hoisted out of the 5-D corner broadcast so the oracle path pays
        # one flat gather, not a broadcast remap plus a gather.
        bidx = jnp.arange(b).reshape(b, 1, 1, 1)
        idx = pts.pix2slot[bidx, idx]                    # pruned -> sentinel
    eff_w = wgt * valid.astype(wgt.dtype) * probs[..., None]
    g = flat_gather_heads(v, idx)
    scale = getattr(cache, "scale", None)
    if scale is not None:
        # int8 table: gather the codes, aggregate in compute dtype, and
        # dequantize ONCE after aggregation — exact because the scale is
        # shared across all rows of a channel.
        g = g.astype(probs.dtype)
    out = jnp.sum(g * eff_w.reshape(b, nq, h, k * 4)[..., None], axis=3)
    if scale is not None:
        out = out * scale.astype(out.dtype)       # (B,1,H,Dh) broadcasts
    return out


# --------------------------------------------------------------------------
# pallas_fused — whole value table staged in VMEM, optional head packing
# --------------------------------------------------------------------------

@register_backend("pallas_fused")
def pallas_fused(plan, v: jnp.ndarray, pts: SamplingPoints,
                 probs: jnp.ndarray, cache=None) -> jnp.ndarray:
    from repro.kernels import ops as kernel_ops
    h = v.shape[2]
    scale = getattr(cache, "scale", None)
    if plan.head_pack > 1 and h % plan.head_pack == 0:
        return kernel_ops.msgs_fused_packed(
            v, pts.x_px, pts.y_px, pts.start, pts.wl, pts.hl, probs,
            remap=pts.pix2slot, scale=scale, head_pack=plan.head_pack,
            block_q=plan.block_q)
    return kernel_ops.msgs_fused(
        v, pts.x_px, pts.y_px, pts.start, pts.wl, pts.hl, probs,
        remap=pts.pix2slot, scale=scale, block_q=plan.block_q)


# --------------------------------------------------------------------------
# pallas_windowed — multi-scale-parallel windowed single launch (C3+C5+C7)
# --------------------------------------------------------------------------

def _require_raster(plan, nq: int) -> None:
    assert nq == plan.n_in, (
        "windowed backends need raster-ordered encoder queries "
        f"(Nq={nq} != N_in={plan.n_in}); plan a different backend")
    assert plan.cfg.range_narrow is not None


@register_backend("pallas_windowed", raster_only=True)
def pallas_windowed(plan, v: jnp.ndarray, pts: SamplingPoints,
                    probs: jnp.ndarray, cache=None) -> jnp.ndarray:
    """One Pallas launch across all levels (multi-scale parallelism).

    The grid spans (B x head-group x query-tile x sampled-level) with the
    level axis innermost: each step stages only that level's
    range-narrowed window and the partial sums accumulate into the output
    block in-kernel, so level aggregation is fused instead of materialized
    as L HBM-sized accumulators. Under FWP-compact the window is a slot
    window of the compacted table addressed through ``pix2slot`` — the
    dense (B, N_in, H, Dh) table is never built. Off-level points ride
    along masked by the in-kernel ``lvl_of_pt == level`` test, which keeps
    PAP-topk dynamic point-to-level assignment supported."""
    from repro.core import fwp as fwp_lib
    from repro.kernels import ops as kernel_ops
    cfg = plan.cfg
    b, nq, h, k = probs.shape
    _require_raster(plan, nq)

    g = plan.head_pack if (plan.lane_layout == "pack"
                           and h % plan.head_pack == 0) else 1
    caps = None
    if pts.pix2slot is not None:
        assert pts.keep_idx is not None, (
            "FWP-compact windowed execution needs the raster-ordered "
            "keep_idx (slot -> pixel map) threaded through SamplingPoints")
        caps = fwp_lib.level_capacities(plan.level_shapes, cfg.fwp_capacity)
    scale = getattr(cache, "scale", None)
    if scale is not None:
        # windowed kernel wants the scale per head-GROUP, matching its
        # (batch, head-group) grid axes: (B,1,H,Dh) -> (B, H/g, g, Dh)
        dh = v.shape[3]
        scale = scale.reshape(b, h // g, g, dh)
    return kernel_ops.msgs_windowed_msp(
        v, pts.x_px, pts.y_px, pts.lvl_of_pt,
        probs, remap=pts.pix2slot, keep_idx=pts.keep_idx, scale=scale,
        level_shapes=plan.level_shapes, ranges=cfg.range_narrow,
        tile_q=plan.tile_q, head_pack=g, caps=caps)


# --------------------------------------------------------------------------
# pallas_decode — persistent-cache decode kernel (table staged once/memory)
# --------------------------------------------------------------------------

@register_backend("pallas_decode", decode_only=True)
def pallas_decode(plan, v: jnp.ndarray, pts: SamplingPoints,
                  probs: jnp.ndarray, cache=None) -> jnp.ndarray:
    """Decode-shaped sampling against the ONCE-staged value table.

    The decoder's ``build_value_cache`` stages the table into the decode
    launch layout exactly when the plan's backend is this one
    (``MSDAValueCache.staged``); every layer's launch then consumes the
    staged block verbatim — one staging per (batch, head-group) per
    memory, never per layer (spy-tested). A caller without a prebuilt
    cache (parity harnesses, one-shot sampling) pays a per-call staging —
    the fallback keeps the contract uniform, and the staging spy's
    positive control counts exactly those restagings."""
    from repro.kernels import ops as kernel_ops
    staged = getattr(cache, "staged", None)
    if staged is None:
        staged = kernel_ops.stage_decode_table(
            v, pts.pix2slot, head_pack=plan.decode_head_pack,
            scale=getattr(cache, "scale", None))
    return kernel_ops.msgs_decode(
        staged, pts.x_px, pts.y_px, pts.start, pts.wl, pts.hl, probs,
        block_q=plan.block_q)
