from repro.data.detection import synth_detection_batch, eval_detection_ap  # noqa: F401
from repro.data.tokens import synth_token_batch, TokenDataConfig, token_stream  # noqa: F401
