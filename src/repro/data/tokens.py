"""Deterministic synthetic token pipeline for the LM-family architectures.

Generates reproducible pseudo-text: a mixture of Zipf-distributed unigrams
and short repeated n-gram motifs so models have learnable structure (loss
decreases). Sharded iteration: each data-parallel rank draws only its own
slice (``shard_id``/``num_shards``), with deterministic keys derived from
(seed, step) — restart-safe for checkpoint/resume."""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return (p / p.sum()).astype(np.float32)


def synth_token_batch(cfg: TokenDataConfig, step: int,
                      shard_id: int = 0, num_shards: int = 1) -> dict:
    """One batch shard: {"tokens": (b_local, S+1) int32} (inputs+labels view)."""
    assert cfg.global_batch % num_shards == 0
    b_local = cfg.global_batch // num_shards
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step * 65536 + shard_id)
    k1, k2, k3 = jax.random.split(key, 3)
    probs = jnp.asarray(_zipf_probs(min(cfg.vocab_size, 4096), cfg.zipf_a))
    base = jax.random.choice(k1, probs.shape[0], (b_local, cfg.seq_len + 1), p=probs)
    # overlay repeated motifs (learnable bigram/ngram structure)
    motif_bank = jax.random.randint(
        jax.random.PRNGKey(cfg.seed + 1), (cfg.n_motifs, cfg.motif_len),
        0, min(cfg.vocab_size, 4096))
    n_insert = max(1, (cfg.seq_len + 1) // (4 * cfg.motif_len))
    pos = jax.random.randint(k2, (b_local, n_insert), 0,
                             max(1, cfg.seq_len + 1 - cfg.motif_len))
    mid = jax.random.randint(k3, (b_local, n_insert), 0, cfg.n_motifs)
    tokens = base
    cols = jnp.arange(cfg.motif_len)
    for i in range(n_insert):
        idx = pos[:, i:i + 1] + cols[None]                          # (b_local, m)
        vals = motif_bank[mid[:, i]]                                # (b_local, m)
        tokens = tokens.at[jnp.arange(b_local)[:, None], idx].set(vals)
    return {"tokens": tokens.astype(jnp.int32)}


def token_stream(cfg: TokenDataConfig, start_step: int = 0,
                 shard_id: int = 0, num_shards: int = 1) -> Iterator[dict]:
    step = start_step
    while True:
        yield synth_token_batch(cfg, step, shard_id, num_shards)
        step += 1
