"""Synthetic rectangle-detection data + AP evaluation.

Images contain 1..max_boxes axis-aligned colored rectangles; the class is
the color index. Targets are dense per-query assignments over the flattened
multi-scale pyramid (the toy analogue of Deformable-DETR's encoder-only
detection). Deterministic given the PRNG key — the 'data pipeline' for the
paper-side experiments."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_COLORS = np.asarray([
    [0.9, 0.1, 0.1], [0.1, 0.9, 0.1], [0.1, 0.1, 0.9], [0.9, 0.9, 0.1],
], np.float32)


def synth_detection_batch(key: jax.Array, batch: int, img_size: int,
                          level_shapes: Sequence[Tuple[int, int]],
                          n_classes: int = 4, max_boxes: int = 3):
    """Returns images (B,3,S,S), tgt_cls (B,N_in), tgt_box (B,N_in,4), gt dict."""
    kb, kc, kn = jax.random.split(key, 3)
    # boxes in normalized cxcywh
    c = jax.random.uniform(kb, (batch, max_boxes, 2), minval=0.2, maxval=0.8)
    wh = jax.random.uniform(jax.random.fold_in(kb, 1), (batch, max_boxes, 2),
                            minval=0.15, maxval=0.45)
    cls = jax.random.randint(kc, (batch, max_boxes), 0, n_classes)
    n_act = jax.random.randint(kn, (batch,), 1, max_boxes + 1)
    active = jnp.arange(max_boxes)[None] < n_act[:, None]           # (B, M)

    # rasterize images
    s = img_size
    ys, xs = jnp.meshgrid(jnp.linspace(0, 1, s), jnp.linspace(0, 1, s), indexing="ij")
    x0 = c[..., 0] - wh[..., 0] / 2
    x1 = c[..., 0] + wh[..., 0] / 2
    y0 = c[..., 1] - wh[..., 1] / 2
    y1 = c[..., 1] + wh[..., 1] / 2
    inside = ((xs[None, None] >= x0[..., None, None]) & (xs[None, None] <= x1[..., None, None])
              & (ys[None, None] >= y0[..., None, None]) & (ys[None, None] <= y1[..., None, None]))
    inside = inside & active[..., None, None]                       # (B,M,S,S)
    colors = jnp.asarray(_COLORS)[cls]                              # (B,M,3)
    img = jnp.einsum("bmhw,bmc->bchw", inside.astype(jnp.float32), colors)
    img = jnp.clip(img, 0.0, 1.0) + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 7), (batch, 3, s, s))

    # dense targets per pyramid query (smallest containing box wins)
    tgt_cls_all, tgt_box_all = [], []
    area = (wh[..., 0] * wh[..., 1]) + (~active) * 1e9              # inactive -> huge
    for (h, w) in level_shapes:
        qy, qx = jnp.meshgrid((jnp.arange(h) + 0.5) / h, (jnp.arange(w) + 0.5) / w,
                              indexing="ij")
        qx = qx.reshape(-1)[None, None]                             # (1,1,HW)
        qy = qy.reshape(-1)[None, None]
        inb = ((qx >= x0[..., None]) & (qx <= x1[..., None])
               & (qy >= y0[..., None]) & (qy <= y1[..., None]) & active[..., None])
        score = jnp.where(inb, area[..., None], 1e9)                # (B,M,HW)
        owner = jnp.argmin(score, axis=1)                           # (B,HW)
        has = jnp.any(inb, axis=1)                                  # (B,HW)
        oc = jnp.take_along_axis(cls, owner, axis=1)
        tgt_cls_all.append(jnp.where(has, oc, n_classes))
        boxes_cxcywh = jnp.concatenate([c, wh], axis=-1)            # (B,M,4)
        ob = jnp.take_along_axis(boxes_cxcywh, owner[..., None], axis=1)
        tgt_box_all.append(jnp.where(has[..., None], ob, 0.0))
    tgt_cls = jnp.concatenate(tgt_cls_all, axis=1)
    tgt_box = jnp.concatenate(tgt_box_all, axis=1)
    gt = {"cls": cls, "box": jnp.concatenate([c, wh], axis=-1), "active": active}
    return img, tgt_cls, tgt_box, gt


def _iou_cxcywh(a: np.ndarray, b: np.ndarray) -> float:
    ax0, ax1 = a[0] - a[2] / 2, a[0] + a[2] / 2
    ay0, ay1 = a[1] - a[3] / 2, a[1] + a[3] / 2
    bx0, bx1 = b[0] - b[2] / 2, b[0] + b[2] / 2
    by0, by1 = b[1] - b[3] / 2, b[1] + b[3] / 2
    iw = max(0.0, min(ax1, bx1) - max(ax0, bx0))
    ih = max(0.0, min(ay1, by1) - max(ay0, by0))
    inter = iw * ih
    ua = (ax1 - ax0) * (ay1 - ay0) + (bx1 - bx0) * (by1 - by0) - inter
    return inter / max(ua, 1e-9)


def eval_detection_ap(cls_logits, boxes, gt, n_classes: int = 4,
                      iou_thresh: float = 0.5, top_n: int = 50) -> float:
    """Greedy AP@IoU proxy (single operating curve, 11-pt interpolation)."""
    probs = jax.nn.softmax(cls_logits, axis=-1)
    probs = np.asarray(probs)
    boxes = np.asarray(boxes)
    records = []          # (score, is_tp)
    total_gt = 0
    for b in range(probs.shape[0]):
        fg = probs[b, :, :n_classes]
        flat = fg.reshape(-1)
        order = np.argsort(-flat)[: top_n * 4]
        gt_active = np.asarray(gt["active"][b])
        gt_box = np.asarray(gt["box"][b])
        gt_cls = np.asarray(gt["cls"][b])
        total_gt += int(gt_active.sum())
        used = np.zeros(gt_box.shape[0], bool)
        picked = 0
        for oi in order:
            if picked >= top_n:
                break
            q, c = oi // n_classes, oi % n_classes
            score = flat[oi]
            if score < 0.05:
                break
            picked += 1
            tp = False
            for m in range(gt_box.shape[0]):
                if used[m] or not gt_active[m] or gt_cls[m] != c:
                    continue
                if _iou_cxcywh(boxes[b, q], gt_box[m]) >= iou_thresh:
                    used[m] = True
                    tp = True
                    break
            records.append((score, tp))
    if not records or total_gt == 0:
        return 0.0
    records.sort(key=lambda r: -r[0])
    tps = np.cumsum([r[1] for r in records])
    fps = np.cumsum([not r[1] for r in records])
    recall = tps / total_gt
    precision = tps / np.maximum(tps + fps, 1)
    ap = 0.0
    for r in np.linspace(0, 1, 11):
        mask = recall >= r
        ap += (precision[mask].max() if mask.any() else 0.0) / 11.0
    return float(ap)
