"""Train-step builder: grad accumulation, AdamW, ZeRO-1 sharded moments.

The returned step is a plain function suitable for jax.jit with explicit
in/out shardings (launch/train.py and launch/dryrun.py provide those).
Gradient accumulation scans over microbatches so peak activation memory is
1/grad_accum of the full batch (required for grok-314b train_4k to fit a
16 GB v5e chip)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (
    AxisRules, DEFAULT_RULES, fsdp_rules_for_mesh, logical_to_spec,
    sanitize_specs_tree, specs_for_tree)
from repro.models.common import ModelConfig
from repro.models.registry import ModelAPI, get_api, rules_overrides
from repro.optim.adamw import OptConfig, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: dict
    step: jnp.ndarray


def rules_for(cfg: ModelConfig, mesh: Mesh) -> AxisRules:
    if cfg.pure_dp:
        # small-arch strategy: weights REPLICATED over the model axis (which
        # carries sequence parallelism for activations instead); ZeRO shards
        # the embed dim of weight matrices across every mesh axis.
        merged = {k: None for k in DEFAULT_RULES.rules}
        all_axes = tuple(mesh.axis_names)
        merged["embed"] = all_axes if len(all_axes) > 1 else all_axes[0]
        return AxisRules(merged)
    base = fsdp_rules_for_mesh(mesh) if cfg.use_fsdp else DEFAULT_RULES
    model_size = mesh.shape.get("model", 1)
    over = rules_overrides(cfg, model_size)
    merged = dict(base.rules)
    merged.update(over)
    if cfg.use_fsdp:
        # FSDP: additionally shard the embed dim of weight matrices over data
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        merged["embed"] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return AxisRules(merged)


def param_shardings(cfg: ModelConfig, mesh: Mesh, api: Optional[ModelAPI] = None):
    api = api or get_api(cfg)
    rules = rules_for(cfg, mesh)
    spec_tree = specs_for_tree(api.axes(cfg), rules)
    params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
    return sanitize_specs_tree(spec_tree, params_sds, mesh)


def zero_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """ZeRO-1: extend a param spec with sharding over every UNUSED mesh axis
    on the first still-unsharded, divisible dim — optimizer moments live 1/N
    per device. Falls back to progressively smaller axis subsets when
    divisibility fails (e.g. vocab=50280 shards over data but not 512)."""
    used = set()
    for s in spec:
        if s is None:
            continue
        for a in (s if isinstance(s, tuple) else (s,)):
            used.add(a)
    free = [a for a in mesh.axis_names if a not in used]
    # try largest subset first, dropping trailing axes on failure
    for cut in range(len(free), 0, -1):
        axes = free[:cut]
        nshard = int(np.prod([mesh.shape[a] for a in axes]))
        if nshard <= 1:
            continue
        new = list(spec)
        for i, s in enumerate(new):
            if s is None and shape[i] % nshard == 0 and shape[i] >= nshard:
                new[i] = tuple(axes) if len(axes) > 1 else axes[0]
                return P(*new)
    return spec


def opt_shardings(param_specs: Any, params_shape: Any, mesh: Mesh) -> dict:
    m_specs = jax.tree.map(
        lambda sp, p: zero_spec(sp, p.shape, mesh), param_specs, params_shape,
        is_leaf=lambda x: isinstance(x, P))
    return {"m": m_specs, "v": m_specs, "step": P()}


def make_train_state(key: jax.Array, cfg: ModelConfig,
                     api: Optional[ModelAPI] = None) -> TrainState:
    api = api or get_api(cfg)
    params = api.init(key, cfg)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, state_shape: TrainState):
    """PartitionSpec pytree matching a TrainState (from eval_shape)."""
    p_specs = param_shardings(cfg, mesh)
    p_specs = sanitize_specs_tree(p_specs, state_shape.params, mesh)
    o_specs = opt_shardings(p_specs, state_shape.params, mesh)
    return TrainState(params=p_specs, opt=o_specs, step=P())


def build_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                     api: Optional[ModelAPI] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves have a leading global-batch dim; with cfg.grad_accum > 1 the
    batch splits into microbatches scanned sequentially (grad accumulation)."""
    api = api or get_api(cfg)
    accum = max(1, cfg.grad_accum)

    def loss_fn(params, batch):
        return api.loss_fn(params, cfg, batch)

    def train_step(state: TrainState, batch: dict):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                (l, g) = carry
                (li, mi), gi = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                g = jax.tree.map(jnp.add, g, gi)
                return (l + li, g), mi

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (loss, grads), metrics = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)

        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, opt_cfg)
        metrics = dict(metrics) if isinstance(metrics, dict) else {"aux": metrics}
        metrics["loss"] = loss
        metrics.update(opt_metrics)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
