"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler detection, deterministic data resume.

Fleet contract implemented here (and tested in tests/test_fault_tolerance.py):
  * the loop ALWAYS starts from `latest_step(ckpt_dir)` if present — a
    crashed/preempted worker restarts bitwise-identically because the data
    pipeline derives batches from (seed, step), not from an iterator state;
  * `FailureInjector` raises at a chosen step to simulate node loss;
  * per-step wall time is tracked against a rolling median — steps slower
    than `straggler_factor` x median are logged as straggler events (on a
    real fleet this feeds the preemption/re-replication controller)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.store import (
    AsyncCheckpointer, latest_step, load_checkpoint, restore_into)
from repro.train.step import TrainState


class SimulatedNodeFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_step: Optional[int] = None
    failed: bool = False

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step \
                and not self.failed:
            self.failed = True
            raise SimulatedNodeFailure(f"injected node failure at step {step}")


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


def train_loop(
    state: TrainState,
    train_step: Callable,
    batch_fn: Callable[[int], Any],       # step -> batch (deterministic!)
    loop_cfg: TrainLoopConfig,
    ckpt_dir: Optional[str] = None,
    injector: Optional[FailureInjector] = None,
    log: Callable[[str], None] = print,
) -> tuple[TrainState, dict]:
    """Runs (resumes) training. Returns (final state, stats)."""
    start = 0
    if ckpt_dir is not None:
        last = latest_step(ckpt_dir)
        if last is not None:
            _, loaded = load_checkpoint(ckpt_dir, last)
            state = restore_into(state, loaded)
            start = last
            log(f"[loop] restored checkpoint step={last}")
    ckpt = AsyncCheckpointer(ckpt_dir, keep=loop_cfg.keep_ckpts) \
        if ckpt_dir is not None else None

    times: list[float] = []
    stats = {"straggler_events": 0, "losses": []}
    try:
        for step in range(start, loop_cfg.total_steps):
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.monotonic()
            batch = batch_fn(step)
            state, metrics = train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            times.append(dt)
            med = float(np.median(times[-32:]))
            if len(times) > 5 and dt > loop_cfg.straggler_factor * med:
                stats["straggler_events"] += 1
                log(f"[loop] STRAGGLER step={step} {dt:.3f}s vs median {med:.3f}s")
            loss = float(metrics["loss"])
            stats["losses"].append(loss)
            if step % loop_cfg.log_every == 0:
                log(f"[loop] step={step} loss={loss:.4f} ({dt:.2f}s)")
            next_step = step + 1
            if ckpt is not None and (next_step % loop_cfg.ckpt_every == 0
                                     or next_step == loop_cfg.total_steps):
                ckpt.save(next_step, state)
    finally:
        if ckpt is not None:
            ckpt.close()
    return state, stats
