from repro.train.step import (  # noqa: F401
    TrainState, build_train_step, make_train_state, param_shardings, zero_spec,
)
from repro.train.loop import train_loop, TrainLoopConfig  # noqa: F401
