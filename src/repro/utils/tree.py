"""Small pytree helpers shared across the framework."""
from __future__ import annotations

from typing import Any, Callable, Mapping

import jax
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements in all leaves."""
    return sum(int(np.prod(x.shape)) if hasattr(x, "shape") else 1
               for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """jax.tree.map_with_path but with '/'-joined string keys."""
    def _fn(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return fn(key, leaf)
    return jax.tree_util.tree_map_with_path(_fn, tree)


def flatten_dict(tree: Mapping[str, Any], sep: str = "/", prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in tree.items():
        key = f"{prefix}{sep}{k}" if prefix else str(k)
        if isinstance(v, Mapping):
            out.update(flatten_dict(v, sep=sep, prefix=key))
        else:
            out[key] = v
    return out


def unflatten_dict(flat: Mapping[str, Any], sep: str = "/") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split(sep)
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out
