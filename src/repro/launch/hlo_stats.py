"""Post-compile HLO statistics: collective-op byte accounting + roofline.

``compiled.cost_analysis()`` has FLOPs and memory bytes but NO collective
traffic; we parse the optimized (SPMD-partitioned, shard-local shapes) HLO
text and sum the bytes of every collective op. Ring-cost convention per
chip: all-gather/reduce-scatter/all-to-all/collective-permute count their
result bytes once, all-reduce counts twice (reduce + broadcast phases).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Any

import numpy as np

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(?([a-z0-9\[\],{}\s]*)\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")

_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-chip collective bytes by op kind (shard-local result shapes)."""
    by_kind: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        if "-done(" in line:         # start/done pairs: count the start only
            continue
        nbytes = _shape_bytes(shapes_str)
        by_kind[kind]["count"] += 1
        by_kind[kind]["bytes"] += int(nbytes * _FACTOR[kind])
    total = sum(v["bytes"] for v in by_kind.values())
    return {"by_kind": dict(by_kind), "total_bytes": int(total)}


def structural_bytes(mem: dict) -> int:
    """HBM-traffic estimate from the compiled buffer assignment: arguments
    are read (params/opt/cache: read+written when donated/updated), temps are
    written+read once each, outputs written. This tracks TPU behaviour far
    better than XLA's per-op 'bytes accessed' on the CPU backend, whose
    weaker fusion overcounts intermediate traffic ~20x."""
    return int(2 * mem["argument_bytes"] + mem["output_bytes"]
               + 2 * mem["temp_bytes"])


def roofline_terms(cost: dict, coll: dict, meta: dict,
                   mem: dict | None = None) -> dict:
    """Three roofline terms (seconds) from per-chip quantities."""
    flops = float(cost.get("flops", 0.0))
    if mem is not None:
        bytes_hbm = float(structural_bytes(mem))
    else:
        bytes_hbm = float(cost.get("bytes accessed", 0.0))
    bytes_coll = float(coll["total_bytes"])
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_coll = bytes_coll / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]

    # useful-FLOPs ratio: MODEL_FLOPS / HLO_FLOPs (per chip)
    n_active = meta.get("active_params", meta.get("params", 0))
    tokens = meta["global_batch"] * (meta["seq_len"] if meta["kind"] == "train"
                                     else (meta["seq_len"] if meta["kind"] == "prefill" else 1))
    factor = 6.0 if meta["kind"] == "train" else 2.0
    model_flops_global = factor * n_active * tokens
    model_flops_chip = model_flops_global / meta["n_chips"]
    useful = model_flops_chip / flops if flops else 0.0

    step_time = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "hbm_bytes_chip": bytes_hbm, "collective_bytes_chip": bytes_coll,
        "model_flops_chip": model_flops_chip, "hlo_flops_chip": flops,
        "useful_flops_ratio": useful,
        "roofline_step_s": step_time,
        "model_flops_util": (model_flops_chip / PEAK_FLOPS) / step_time
        if step_time else 0.0,
    }


def summarize(compiled, meta: dict) -> dict:
    cost = dict(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    coll = collective_stats(compiled.as_text())
    out = {
        "meta": meta,
        "cost": {k: float(cost.get(k, 0.0))
                 for k in ("flops", "bytes accessed", "transcendentals")},
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
            "peak_bytes_per_chip": int(ma.argument_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       + ma.output_size_in_bytes),
        },
        "collectives": coll,
    }
    out["roofline"] = roofline_terms(out["cost"], coll, meta, out["memory"])
    return out
