"""Multi-pod dry-run: AOT `.lower().compile()` every (arch × shape × mesh)
cell on placeholder host devices, prove the distribution config is coherent
(sharding, memory, collectives), and emit the roofline inputs.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
  python -m repro.launch.dryrun --all --detr          # include DETR family

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis / cost_analysis / collective stats; existing results are
skipped (incremental — rerun after fixes)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any other import — jax locks the device count on first init.

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config

# §Perf optimized configuration (--opt): activation-sharding constraints
# (O1/O2 via REPRO_CONSTRAIN_ACTS), save_comm remat (O6) and grad-accum
# boosts sized so train cells fit 16 GB/chip (O5).
OPT_ACCUM = {
    "olmoe-1b-7b": 4, "grok-1-314b": 8, "granite-20b": 8, "minitron-8b": 4,
    "minitron-4b": 4, "deepseek-7b": 4, "mamba2-130m": 4,
    "llava-next-34b": 8, "whisper-tiny": 2, "hymba-1.5b": 4,
}

# O2': physical q-head padding to the next TP-divisible count (output-masked,
# exact semantics) — removes the 16x attention replication for head counts
# that don't divide the model axis.
OPT_PAD_HEADS = {
    "llava-next-34b": 64,
}

# Small archs: TP-16 all-reduce cost (∝B·S·D) dwarfs their compute
# (∝B·S·D²/TP). Strategy switch: replicate weights, model axis carries
# sequence parallelism, ZeRO shards optimizer state (O7).
OPT_PURE_DP = {"minitron-4b", "mamba2-130m", "hymba-1.5b", "whisper-tiny"}


def _opt_cfg(arch: str, cfg, kind: str = "train"):
    """Kind-aware optimization: decode is weight-read bound — TP sharding of
    weights is already optimal there, and pure-DP / head padding / activation
    constraints REGRESSED decode cells (measured in §Perf). Exception: MoE
    decode keeps the explicit-EP path (olmoe decode collective 10.8→0.13 ms)."""
    import dataclasses as dc
    if kind == "decode":
        # MoE with model-axis-divisible experts: explicit EP pays off even
        # at one token (olmoe decode collective 10.8→0.13 ms); everything
        # else keeps the TP-sharded baseline (weight reads already optimal;
        # grok's 8 experts don't divide 16 -> EP can't engage).
        return cfg, (cfg.family == "moe" and cfg.n_experts % 16 == 0)
    return dc.replace(cfg, remat_policy="save_comm",
                      grad_accum=OPT_ACCUM.get(arch, cfg.grad_accum),
                      pad_heads_to=OPT_PAD_HEADS.get(arch, 0),
                      pure_dp=arch in OPT_PURE_DP), True
from repro.configs.shapes import SHAPES, shapes_for
from repro.launch.hlo_stats import summarize
from repro.launch.input_specs import build_cell
from repro.launch.mesh import make_production_mesh


def _compile(cell, mesh):
    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          out_shardings=cell.out_shardings,
                          donate_argnums=getattr(cell, "donate", ())
                          ).lower(*cell.in_sds)
        return lowered.compile()


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, verbose: bool = True) -> dict:
    """Compile one cell three ways:

      A) REAL config (blockwise attention, grad accumulation, layer scan)
         -> proves sharding coherence, gives memory_analysis (true residency).
      B/C) COST configs (dense attention so no FLOPs hide in inner loops,
         grad_accum=1, layer-scan unroll=1 and unroll=2). XLA's
         cost_analysis counts a while-loop body ONCE regardless of trip
         count, so per-layer cost = C - B and
         corrected = B + (C - B) * (n_layers - 1).
         The same two-point correction applies to the HLO-parsed collective
         bytes (per-layer collectives also sit inside the scanned body)."""
    import dataclasses as dc

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if os.environ.get("REPRO_CONSTRAIN_ACTS") == "1":
        cfg, use_policy = _opt_cfg(arch, cfg, shape.kind)
        if not use_policy:
            os.environ["REPRO_CONSTRAIN_ACTS"] = "0"
            try:
                return _run_cell_inner(arch, cfg, shape, mesh, tag, path,
                                       verbose)
            finally:
                os.environ["REPRO_CONSTRAIN_ACTS"] = "1"
    return _run_cell_inner(arch, cfg, shape, mesh, tag, path, verbose)


def _run_cell_inner(arch, cfg, shape, mesh, tag, path, verbose) -> dict:
    import dataclasses as dc

    t0 = time.perf_counter()
    cell_real = build_cell(arch, cfg, shape, mesh)
    compiled_real = _compile(cell_real, mesh)
    t_real = time.perf_counter() - t0
    result = summarize(compiled_real, cell_real.meta)
    result["raw_cost_uncorrected"] = dict(result["cost"])

    # --- two-point scan-cost correction ---------------------------------
    t0 = time.perf_counter()
    cfg1 = dc.replace(cfg, attn_impl="dense", grad_accum=1, scan_unroll=1)
    cfg2 = dc.replace(cfg, attn_impl="dense", grad_accum=1, scan_unroll=2)
    cell1 = build_cell(arch, cfg1, shape, mesh)
    cell2 = build_cell(arch, cfg2, shape, mesh)
    s1 = summarize(_compile(cell1, mesh), cell1.meta)
    s2 = summarize(_compile(cell2, mesh), cell2.meta)
    t_cost = time.perf_counter() - t0

    nl = cfg.n_layers
    corr = {}
    for k in ("flops", "bytes accessed", "transcendentals"):
        per_layer = max(0.0, s2["cost"][k] - s1["cost"][k])
        corr[k] = s1["cost"][k] + per_layer * (nl - 1)
    coll_per_layer = max(0, s2["collectives"]["total_bytes"]
                         - s1["collectives"]["total_bytes"])
    corr_coll = {"total_bytes": s1["collectives"]["total_bytes"]
                 + coll_per_layer * (nl - 1),
                 "by_kind_1l": s1["collectives"]["by_kind"],
                 "by_kind_2l": s2["collectives"]["by_kind"]}
    result["cost"] = corr
    result["collectives_corrected"] = corr_coll
    from repro.launch.hlo_stats import roofline_terms
    result["roofline"] = roofline_terms(corr, corr_coll, cell_real.meta,
                                        result["memory"])
    result["timings"] = {"real_compile_s": t_real, "cost_compiles_s": t_cost}

    if verbose:
        ma = result["memory"]
        rf = result["roofline"]
        print(f"[dryrun] {tag}: OK  peak={ma['peak_bytes_per_chip']/2**30:.2f}GiB/chip "
              f"compute={rf['t_compute_s']*1e3:.2f}ms mem={rf['t_memory_s']*1e3:.2f}ms "
              f"coll={rf['t_collective_s']*1e3:.2f}ms dom={rf['dominant']} "
              f"useful={rf['useful_flops_ratio']:.2f} "
              f"(compiles {t_real:.0f}+{t_cost:.0f}s)")
        print("  memory_analysis:", {k: v for k, v in ma.items()})
        print("  cost_analysis(corrected):", result["cost"])
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def run_detr_cell(name: str, shape_kind: str, mesh_kind: str, out_dir: str,
                  force: bool = False) -> dict:
    """DETR-family cells (the paper's own benchmark workload).

    shape_kind "banded" = the halo-exchange band-sharded serve variant."""
    from repro.launch.detr_cells import build_banded_detr_cell, build_detr_cell
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{name}__{shape_kind}__{mesh_kind}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if shape_kind == "banded":
        cell = build_banded_detr_cell(name, mesh)
    else:
        cell = build_detr_cell(name, shape_kind, mesh)
    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          out_shardings=cell.out_shardings).lower(*cell.in_sds)
        compiled = lowered.compile()
    result = summarize(compiled, cell.meta)
    result["timings"] = {"total_s": time.perf_counter() - t0}
    rf = result["roofline"]
    print(f"[dryrun] {tag}: OK dom={rf['dominant']} "
          f"coll={rf['t_collective_s']*1e3:.2f}ms mem={rf['t_memory_s']*1e3:.2f}ms")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--detr", action="store_true", help="include DETR family")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="§Perf optimized config (O1-O6)")
    args = ap.parse_args()
    if args.opt:
        os.environ["REPRO_CONSTRAIN_ACTS"] = "1"

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            fam = get_config(arch).family
            cells += [(arch, s) for s in shapes_for(fam)]
    elif args.arch:
        shapes = [args.shape] if args.shape else shapes_for(
            get_config(args.arch).family)
        cells += [(args.arch, s) for s in shapes]

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            try:
                run_cell(arch, shape, mk, args.out, force=args.force)
            except Exception as e:
                failures.append((arch, shape, mk, repr(e)))
                print(f"[dryrun] {arch}/{shape}/{mk}: FAIL {e}")
                traceback.print_exc()

    if args.detr:
        for name in ("deformable-detr", "deformable-detr-defa", "dino"):
            kinds = ("serve", "train", "banded") \
                if name == "deformable-detr-defa" else ("serve", "train")
            for kind in kinds:
                for mk in meshes:
                    try:
                        run_detr_cell(name, kind, mk, args.out, force=args.force)
                    except Exception as e:
                        failures.append((name, kind, mk, repr(e)))
                        print(f"[dryrun] {name}/{kind}/{mk}: FAIL {e}")
                        traceback.print_exc()

    print(f"\n[dryrun] done. {len(failures)} failures.")
    for f in failures:
        print("  FAIL:", f)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
