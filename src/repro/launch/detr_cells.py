"""Dry-run cells for the paper's own workload: DETR-family encoders built on
MSDeformAttn (baseline and DEFA-optimized variants).

serve: batched encoder inference (the paper's Fig. 9 comparison workload);
train: encoder fwd+bwd+AdamW with a denoising proxy objective (exercises the
same sharding/collective structure as full DETR training without hauling a
conv backbone through the dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.detr_family import CONFIGS as DETR_CONFIGS
from repro.core.encoder import (
    encoder_apply, encoder_logical_axes, init_encoder)
from repro.distributed.sharding import AxisRules, logical_to_spec, _BASE
from repro.launch.input_specs import Cell, _batch_spec, _named
from repro.optim.adamw import OptConfig, adamw_init, adamw_update
from repro.train.step import zero_spec


def _detr_rules(mesh: Mesh) -> AxisRules:
    # d_model=256/8 heads: heads (8) don't divide model=16 -> replicate heads;
    # the encoder ffn (1024) and value rows carry the model-axis sharding.
    return AxisRules({**_BASE, "heads": None})


def build_detr_cell(name: str, kind: str, mesh: Mesh,
                    batch: int | None = None,
                    query_shard: bool = False) -> Cell:
    acfg = DETR_CONFIGS[name]
    enc_cfg = acfg.encoder
    level_shapes = acfg.level_shapes
    n_in = sum(h * w for h, w in level_shapes)
    d = enc_cfg.d_model
    b = batch or (acfg.train_batch if kind == "train" else acfg.serve_batch)
    dtype = enc_cfg.dtype

    rules = _detr_rules(mesh)
    axes = encoder_logical_axes(enc_cfg)
    param_specs = jax.tree.map(
        lambda a: logical_to_spec(a, rules), axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            y is None or isinstance(y, str) for y in x))
    param_sh = _named(mesh, param_specs)
    params_sds = jax.eval_shape(
        lambda: init_encoder(jax.random.PRNGKey(0), enc_cfg))

    bspec = _batch_spec(mesh, b)
    q_ax = "model" if query_shard else None
    x_sds = jax.ShapeDtypeStruct((b, n_in, d), dtype)
    x_sh = NamedSharding(mesh, P(*bspec, q_ax, None))
    pos_sds = jax.ShapeDtypeStruct((n_in, d), dtype)
    ref_sds = jax.ShapeDtypeStruct((n_in, 2), jnp.float32)
    rep = NamedSharding(mesh, P(None, None))

    meta = {"arch": name, "shape": f"detr_{kind}_b{b}", "kind": kind,
            "seq_len": n_in, "global_batch": b, "mesh": dict(mesh.shape),
            "n_chips": mesh.size,
            "params": sum(int(jnp.prod(jnp.asarray(l.shape)))
                          for l in jax.tree.leaves(params_sds)),
            "active_params": None}
    meta["active_params"] = meta["params"]

    if kind == "serve":
        def serve_fn(params, x_flat, pos, refs):
            out, _ = encoder_apply(params, enc_cfg, x_flat, pos, refs,
                                   level_shapes)
            return out

        return Cell(name=f"{name}/serve", fn=serve_fn,
                    in_sds=(params_sds, x_sds, pos_sds, ref_sds),
                    in_shardings=(param_sh, x_sh, rep, rep),
                    out_shardings=x_sh, meta=meta)

    assert kind == "train"
    opt_cfg = OptConfig()
    opt_sds = jax.eval_shape(lambda: adamw_init(params_sds))
    m_specs = jax.tree.map(lambda sp, p: zero_spec(sp, p.shape, mesh),
                           param_specs, params_sds,
                           is_leaf=lambda x: isinstance(x, P))
    opt_sh = {"m": _named(mesh, m_specs), "v": _named(mesh, m_specs),
              "step": NamedSharding(mesh, P())}

    def train_fn(params, opt, x_flat, pos, refs):
        def loss_fn(p):
            out, _ = encoder_apply(p, enc_cfg, x_flat, pos, refs, level_shapes)
            tgt = jax.lax.stop_gradient(jnp.roll(x_flat, 1, axis=1))
            return jnp.mean(jnp.square(out - tgt).astype(jnp.float32))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return new_params, new_opt, loss

    return Cell(name=f"{name}/train", fn=train_fn,
                in_sds=(params_sds, opt_sds, x_sds, pos_sds, ref_sds),
                in_shardings=(param_sh, opt_sh, x_sh, rep, rep),
                out_shardings=(param_sh, opt_sh, None), meta=meta,
                donate=(0, 1))


def build_banded_detr_cell(name: str, mesh: Mesh,
                           batch: int | None = None) -> Cell:
    """§Perf hillclimb 3 (optimized): the DEFA encoder with band-sharded
    queries+values and range-narrowing-bounded halo exchange over the model
    axis — distribution of the paper's own workload driven by its C3/C7
    insight (bounded ranges -> bounded communication)."""
    import dataclasses as dc

    from repro.core.distributed_msdeform import (
        band_layout, msdeform_attn_banded, pad_levels_to_bands)
    from repro.core import nn as core_nn

    acfg = DETR_CONFIGS[name]
    enc_cfg = acfg.encoder
    attn_cfg = dc.replace(enc_cfg.attn, fwp_mode="off")   # banded v1: no FWP
    assert attn_cfg.range_narrow is not None
    level_shapes = acfg.level_shapes
    d = enc_cfg.d_model
    b = batch or acfg.serve_batch
    dtype = enc_cfg.dtype
    n_bands = mesh.shape["model"]
    b_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    # padded/banded geometry (static)
    rows, _ = band_layout(level_shapes, n_bands, attn_cfg.range_narrow)
    padded_shapes = tuple((rb * n_bands, w) for (h, w), rb in
                          zip(level_shapes, rows))
    n_pad = sum(hp * w for hp, w in padded_shapes)

    rules = _detr_rules(mesh)
    axes = encoder_logical_axes(enc_cfg)
    param_specs = jax.tree.map(
        lambda a: logical_to_spec(a, rules), axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            y is None or isinstance(y, str) for y in x))
    param_sh = _named(mesh, param_specs)
    params_sds = jax.eval_shape(
        lambda: init_encoder(jax.random.PRNGKey(0), enc_cfg))

    bspec = _batch_spec(mesh, b)
    x_sh = NamedSharding(mesh, P(*bspec, "model", None))
    x_sds = jax.ShapeDtypeStruct((b, n_pad, d), dtype)
    pos_sds = jax.ShapeDtypeStruct((n_pad, d), dtype)
    ref_sds = jax.ShapeDtypeStruct((b, n_pad, 2), jnp.float32)
    pos_sh = NamedSharding(mesh, P("model", None))
    ref_sh = NamedSharding(mesh, P(*bspec, "model", None))

    meta = {"arch": name + "-banded", "shape": f"detr_serve_b{b}",
            "kind": "serve", "seq_len": n_pad, "global_batch": b,
            "mesh": dict(mesh.shape), "n_chips": mesh.size,
            "params": sum(int(jnp.prod(jnp.asarray(l.shape)))
                          for l in jax.tree.leaves(params_sds))}
    meta["active_params"] = meta["params"]

    def serve_fn(params, x_flat, pos, refs):
        h = x_flat
        for blk in params["blocks"]:
            q = h + pos[None]
            attn = msdeform_attn_banded(
                blk["attn"], attn_cfg, q, refs, h, padded_shapes, mesh,
                batch_axes=b_axes if bspec != P(None) else ())
            h = core_nn.layer_norm(blk["ln1"], h + attn)
            ff = core_nn.linear(blk["ffn2"],
                                jax.nn.relu(core_nn.linear(blk["ffn1"], h)))
            h = core_nn.layer_norm(blk["ln2"], h + ff)
            h = jax.lax.with_sharding_constraint(h, x_sh)
        return h

    return Cell(name=f"{name}-banded/serve", fn=serve_fn,
                in_sds=(params_sds, x_sds, pos_sds, ref_sds),
                in_shardings=(param_sh, x_sh, pos_sh, ref_sh),
                out_shardings=x_sh, meta=meta)
