"""Training launcher.

Local mode (this container, 1 CPU device): reduced configs, real optimizer
steps, checkpoint/restart, straggler monitor — the full control plane at toy
scale. Fleet mode (TPU pods): the same entry point picks up the production
mesh; per-host data sharding comes from jax.process_index().

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
      --steps 30 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.tokens import TokenDataConfig, synth_token_batch
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.registry import get_api
from repro.optim.adamw import OptConfig
from repro.train.loop import FailureInjector, TrainLoopConfig, train_loop
from repro.train.step import (
    build_train_step, make_train_state, train_state_shardings)
from jax.sharding import NamedSharding, PartitionSpec as P


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated node failure at this step")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = get_api(cfg)
    mesh = make_production_mesh() if args.production_mesh else make_local_mesh()
    data_cfg = TokenDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch, seed=0)

    def batch_fn(step: int):
        b = synth_token_batch(data_cfg, step)
        if cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(7), step)
            b["img_embeds"] = jax.random.normal(
                key, (args.batch, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            key = jax.random.fold_in(jax.random.PRNGKey(8), step)
            b["frames"] = jax.random.normal(
                key, (args.batch, cfg.enc_seq_len, cfg.d_model), cfg.dtype)
        return b

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)
    state = make_train_state(jax.random.PRNGKey(0), cfg)
    with mesh:
        specs = train_state_shardings(cfg, mesh, jax.eval_shape(lambda: state))
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
        step_fn = jax.jit(build_train_step(cfg, opt_cfg),
                          in_shardings=(sh, None), out_shardings=(sh, None))
        injector = FailureInjector(args.fail_at) if args.fail_at else None
        loop_cfg = TrainLoopConfig(total_steps=args.steps,
                                   ckpt_every=args.ckpt_every, log_every=5)
        state, stats = train_loop(state, step_fn, batch_fn, loop_cfg,
                                  ckpt_dir=args.ckpt_dir, injector=injector)
    print(f"[train] done: final loss {stats['losses'][-1]:.4f}, "
          f"stragglers={stats['straggler_events']}")


if __name__ == "__main__":
    main()
