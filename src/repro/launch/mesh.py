"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing
jax; ordinary runs (tests, benches, examples) see the 1 real CPU device and
use `make_local_mesh`."""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run via "
            "launch/dryrun.py which forces 512 host devices")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(model_axis: int = 1):
    """Degenerate mesh over the locally available devices (tests/examples)."""
    import jax
    devices = jax.devices()
    data = len(devices) // model_axis
    return jax.sharding.Mesh(
        np.asarray(devices[:data * model_axis]).reshape(data, model_axis),
        ("data", "model"))
