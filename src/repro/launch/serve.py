"""Serving launcher: continuous-batching engine over a (smoke or full) arch.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-20b --smoke \
      --requests 12 --max-batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.registry import get_api
from repro.serve.lm import Request, ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("encdec",):
        raise SystemExit("serve launcher targets decoder-only families; "
                         "see examples/ for enc-dec usage")
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params,
                         ServeConfig(max_batch=args.max_batch,
                                     cache_len=args.cache_len))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        engine.submit(Request(rid=i,
                              prompt=rng.integers(0, cfg.vocab_size, plen),
                              max_new_tokens=args.max_new))
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {total_toks} tokens in {dt:.2f}s "
          f"({total_toks/dt:.1f} tok/s with continuous batching)")


if __name__ == "__main__":
    main()
