"""Per-(arch × shape) dry-run cell builder.

`build_cell` returns everything needed to AOT-lower one cell on a mesh:
the step function, ShapeDtypeStruct inputs (no device allocation — the
shannon/kernels pattern), and in/out shardings. Kinds:

  train    -> train_step(state, batch)            (fwd+bwd+AdamW update)
  prefill  -> prefill(params, cache, batch)       (forward + cache write)
  decode   -> decode_step(params, cache, tok, pos) (one token vs seq_len cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models.common import ModelConfig
from repro.models.registry import get_api
from repro.optim.adamw import OptConfig
from repro.train.step import (
    build_train_step, make_train_state, train_state_shardings, rules_for)
from repro.distributed.sharding import logical_to_spec


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable
    in_sds: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    meta: dict
    donate: Tuple[int, ...] = ()     # donated args (state / cache): in-place
                                     # updates, as the real launchers run them


def _batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _nshard(mesh: Mesh, axes: tuple) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _named(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_spec(mesh: Mesh, b: int) -> P:
    axes = _batch_axes(mesh)
    if b % _nshard(mesh, axes) == 0:
        return P(axes if len(axes) > 1 else axes[0])
    return P(None)


def _batch_sds(cfg: ModelConfig, b: int, seq: int, mesh: Mesh, train: bool):
    """ShapeDtypeStructs + shardings for one input batch."""
    bspec = _batch_spec(mesh, b)
    s_tok = seq + 1 if train else seq
    sds = {"tokens": jax.ShapeDtypeStruct((b, s_tok), jnp.int32)}
    sh = {"tokens": NamedSharding(mesh, bspec)}
    if cfg.family == "vlm":
        n_txt = s_tok - cfg.n_img_tokens
        sds["tokens"] = jax.ShapeDtypeStruct((b, n_txt), jnp.int32)
        sds["img_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
        sh["img_embeds"] = NamedSharding(mesh, P(*bspec, None, None))
    if cfg.family == "encdec":
        sds["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq_len, cfg.d_model), cfg.dtype)
        sh["frames"] = NamedSharding(mesh, P(*bspec, None, None))
    return sds, sh


def _cache_specs(cfg: ModelConfig, mesh: Mesh, b: int,
                 shard_len: bool) -> Callable:
    """PartitionSpec per cache leaf, keyed by leaf name."""
    bspec = _batch_spec(mesh, b)
    b_axes = bspec[0] if len(bspec) else None
    model_ok = cfg.n_kv_heads % mesh.shape.get("model", 1) == 0
    kv_ax = "model" if model_ok and mesh.shape.get("model", 1) > 1 else None
    len_ax = "data" if shard_len and "data" in mesh.axis_names else None

    def spec_for(path: str, leaf) -> P:
        name = path.split("/")[-1]
        if name in ("k", "v"):
            return P(None, b_axes, len_ax, kv_ax, None)
        if name == "kpos":
            return P(None, b_axes, len_ax)
        if name in ("mem_k", "mem_v"):
            return P(None, b_axes, None, kv_ax, None)
        if name == "ssm":
            return P(None, b_axes, None, None, None)
        if name == "conv":
            return P(None, b_axes, None, None)
        return P(*([None] * leaf.ndim))
    return spec_for


def _cache_sds_and_shardings(cfg: ModelConfig, mesh: Mesh, b: int,
                             cache_len: int, shard_len: bool):
    api = get_api(cfg)
    sds = jax.eval_shape(lambda: api.init_cache(cfg, b, cache_len))
    spec_fn = _cache_specs(cfg, mesh, b, shard_len)
    from repro.utils.tree import tree_map_with_path_str
    specs = tree_map_with_path_str(spec_fn, sds)
    return sds, _named(mesh, specs)


def _maybe_policy(fn: Callable, mesh: Mesh, cfg: ModelConfig) -> Callable:
    """O1-O4: wrap a cell fn so tracing happens under the activation-sharding
    policy. Enabled by REPRO_CONSTRAIN_ACTS=1 (the --opt dry-run flag);
    baseline runs stay propagation-only."""
    import os
    if os.environ.get("REPRO_CONSTRAIN_ACTS") != "1":
        return fn
    from repro.distributed.act_sharding import activation_policy
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    baxes = baxes if len(baxes) > 1 else baxes[0]

    def wrapped(*args, **kw):
        with activation_policy(mesh, baxes, seq_shard=cfg.pure_dp):
            return fn(*args, **kw)
    return wrapped


def build_cell(arch: str, cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               opt_cfg: Optional[OptConfig] = None) -> Cell:
    api = get_api(cfg)
    b, seq = shape.global_batch, shape.seq_len
    meta = {"arch": arch, "shape": shape.name, "kind": shape.kind,
            "seq_len": seq, "global_batch": b,
            "mesh": dict(mesh.shape), "n_chips": mesh.size,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        opt_cfg = opt_cfg or OptConfig()
        state_sds = jax.eval_shape(
            lambda: make_train_state(jax.random.PRNGKey(0), cfg))
        state_specs = train_state_shardings(cfg, mesh, state_sds)
        state_sh = _named(mesh, state_specs)
        batch_sds, batch_sh = _batch_sds(cfg, b, seq, mesh, train=True)
        fn = _maybe_policy(build_train_step(cfg, opt_cfg), mesh, cfg)
        return Cell(name=f"{arch}/{shape.name}", fn=fn,
                    in_sds=(state_sds, batch_sds),
                    in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None), meta=meta, donate=(0,))

    # serving cells share param shardings (no optimizer)
    from repro.distributed.sharding import sanitize_specs_tree
    rules = rules_for(cfg, mesh)
    param_specs = jax.tree.map(
        lambda axes: logical_to_spec(axes, rules), api.axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
    params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
    param_specs = sanitize_specs_tree(param_specs, params_sds, mesh)
    param_sh = _named(mesh, param_specs)

    if shape.kind == "prefill":
        cache_sds, cache_sh = _cache_sds_and_shardings(
            cfg, mesh, b, cache_len=seq, shard_len=False)
        batch_sds, batch_sh = _batch_sds(cfg, b, seq, mesh, train=False)

        def prefill_fn(params, cache, batch):
            return api.prefill(params, cfg, cache, batch)
        prefill_fn = _maybe_policy(prefill_fn, mesh, cfg)

        return Cell(name=f"{arch}/{shape.name}", fn=prefill_fn,
                    in_sds=(params_sds, cache_sds, batch_sds),
                    in_shardings=(param_sh, cache_sh, batch_sh),
                    out_shardings=(None, cache_sh), meta=meta, donate=(1,))

    assert shape.kind == "decode"
    shard_len = b == 1                    # SP: long-context shards the cache
    cache_sds, cache_sh = _cache_sds_and_shardings(
        cfg, mesh, b, cache_len=seq, shard_len=shard_len)
    bspec = _batch_spec(mesh, b)
    tok_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_sh = NamedSharding(mesh, bspec)

    def decode_fn(params, cache, tokens, pos):
        return api.decode_step(params, cfg, cache, tokens, pos)
    decode_fn = _maybe_policy(decode_fn, mesh, cfg)

    return Cell(name=f"{arch}/{shape.name}", fn=decode_fn,
                in_sds=(params_sds, cache_sds, tok_sds, pos_sds),
                in_shardings=(param_sh, cache_sh, tok_sh, tok_sh),
                out_shardings=(None, cache_sh), meta=meta, donate=(1,))
