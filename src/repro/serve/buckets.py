"""AOT shape buckets + admission control for the DETR serve engine.

One jitted forward per distinct image resolution would retrace (and on a
real accelerator recompile) on every new shape. Serving instead
precompiles a SMALL set of resolution buckets at startup — each bucket is
a full (resolution, level_shapes, MSDAPlan) triple derived from the
detector config — and routes every incoming image to the smallest bucket
it fits, padding up. Oversized images are rejected at admission (the
caller can split/downscale and resubmit); nothing after warmup ever
compiles (tests pin this with a compile-count spy on the engine).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

#: the detector's fixed pyramid strides (DetectorConfig.level_shapes)
STRIDES = (4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class ShapeBucket:
    """One precompiled serving shape: a square resolution, the detector
    config rebound to it (params are resolution-independent — conv
    backbone + per-pixel linears — so every bucket serves the SAME
    weights), and the bucket's memoized MSDAPlan."""
    resolution: int
    cfg: object                 # DetectorConfig with img_size == resolution
    plan: object                # MSDAPlan for this bucket's level shapes

    @property
    def level_shapes(self) -> Tuple[Tuple[int, int], ...]:
        return self.cfg.level_shapes

    @property
    def n_in(self) -> int:
        return self.plan.n_in

    def fits(self, h: int, w: int) -> bool:
        return h <= self.resolution and w <= self.resolution


def derive_buckets(cfg, resolutions, *, backend: Optional[str] = None
                   ) -> Tuple[ShapeBucket, ...]:
    """Derive the serving buckets from a detector config.

    Each resolution must divide the pyramid strides (enforced by
    :func:`repro.msda.plan.level_shapes_for_resolution`); plans resolve
    through the memoized ``plan_for`` path so repeated engines (and the
    per-bucket decoder forward) share one plan object per bucket."""
    from repro.core.detector import decoder_plan
    from repro.msda.plan import level_shapes_for_resolution, plan_for

    res = sorted({int(r) for r in resolutions})
    if not res:
        raise ValueError("at least one bucket resolution is required")
    buckets = []
    for r in res:
        shapes = level_shapes_for_resolution(r, strides=STRIDES)
        bcfg = dataclasses.replace(cfg, img_size=r)
        assert bcfg.level_shapes == shapes
        if getattr(bcfg, "decoder", None) is not None:
            plan = decoder_plan(bcfg, backend)
        else:
            plan = plan_for(bcfg.encoder.attn, shapes, backend)
        buckets.append(ShapeBucket(resolution=r, cfg=bcfg, plan=plan))
    return tuple(buckets)


class BucketRouter:
    """Route each incoming image to the smallest bucket it fits."""

    def __init__(self, buckets: Tuple[ShapeBucket, ...]):
        self.buckets = tuple(sorted(buckets, key=lambda b: b.resolution))
        if not self.buckets:
            raise ValueError("BucketRouter needs at least one bucket")

    @property
    def max_resolution(self) -> int:
        return self.buckets[-1].resolution

    def route(self, h: int, w: int) -> Optional[ShapeBucket]:
        """Smallest bucket admitting an (h, w) image; None when oversized."""
        for b in self.buckets:
            if b.fits(h, w):
                return b
        return None

    def admit(self, image) -> Tuple[Optional[ShapeBucket], Optional[str]]:
        """Admission control: (bucket, None) or (None, rejection reason)."""
        shape = tuple(getattr(image, "shape", ()))
        if len(shape) != 3 or shape[0] != 3:
            return None, f"expected a (3, H, W) image, got shape {shape}"
        _, h, w = shape
        if h < 1 or w < 1:
            return None, f"degenerate image shape {shape}"
        b = self.route(int(h), int(w))
        if b is None:
            return None, (f"image {h}x{w} exceeds the largest bucket "
                          f"({self.max_resolution}px); split or downscale "
                          "and resubmit")
        return b, None

    def table(self) -> list:
        """The bucket table (README / benchmark reporting)."""
        out = []
        for b in self.buckets:
            out.append({
                "resolution": b.resolution,
                "level_shapes": list(b.level_shapes),
                "n_in": b.n_in,
                "backend": b.plan.backend,
                "table_kb": round(b.plan.value_table_bytes / 1024, 1),
                "budget_kb": round(b.plan.staging_budget_bytes / 1024, 1),
                "budget_source": b.plan.budget_source,
            })
        return out
