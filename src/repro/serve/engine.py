"""Continuous-batching serving engine.

vLLM-style slot model adapted to JAX static shapes: a fixed decode batch of
`max_batch` slots over a ring-buffer KV/state cache. Requests are admitted
into free slots via a single-request prefill whose cache slice is scattered
into the batch cache; every engine step decodes ALL active slots one token
(inactive slots run masked). Per-slot positions ride the (B,) `pos` vector
the decode path takes natively.

This is the serving analogue the paper's "DEFA rivals GPUs" comparison maps
to: :class:`ServeEngine` serves the LM-family archs, and
:class:`DetrServeEngine` serves the paper's own workload — batched DETR
detection with the DEFA stack, where each forward builds ONE shared
:class:`~repro.msda.MSDAValueCache` from the encoder memory and every
decoder layer samples it (build-once, sample-everywhere; the driver is
examples/detr_serve.py)."""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nn
from repro.models.common import ModelConfig
from repro.models.registry import get_api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S_prompt,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    cache_len: int = 256
    greedy: bool = True
    temperature: float = 1.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, serve_cfg: ServeConfig,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.api = get_api(cfg)
        self.params = params
        self.scfg = serve_cfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        b = serve_cfg.max_batch
        self.cache = self.api.init_cache(cfg, b, serve_cfg.cache_len)
        self.pos = jnp.zeros((b,), jnp.int32)
        self.last_tok = jnp.zeros((b,), jnp.int32)
        self.active = np.zeros((b,), bool)
        self.slot_req: list[Optional[Request]] = [None] * b
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._decode = jax.jit(self._decode_impl)
        self._prefill1 = jax.jit(self._prefill1_impl)

    # --- jitted internals --------------------------------------------------
    def _prefill1_impl(self, params, cache1, tokens1):
        logits, cache1 = self.api.prefill(params, self.cfg, cache1,
                                          {"tokens": tokens1})
        return logits, cache1

    def _decode_impl(self, params, cache, tokens, pos):
        return self.api.decode_step(params, self.cfg, cache, tokens, pos)

    # --- slot management ----------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, slot: int, req: Request):
        cfg, scfg = self.cfg, self.scfg
        cache1 = self.api.init_cache(cfg, 1, scfg.cache_len)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill1(self.params, cache1, toks)
        # scatter the single-request cache into batch slot `slot`
        # (every stacked cache leaf is (n_layers, B, ...): dim 1 is batch)
        self.cache = jax.tree.map(
            lambda c, c1: c.at[:, slot].set(c1[:, 0]), self.cache, cache1)
        first = int(jnp.argmax(logits, axis=-1)[0]) if scfg.greedy \
            else self._sample(logits)[0]
        req.output.append(first)
        self.last_tok = self.last_tok.at[slot].set(first)
        self.pos = self.pos.at[slot].set(len(req.prompt))
        self.active[slot] = True
        self.slot_req[slot] = req

    def _sample(self, logits):
        self.rng, k = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(
            k, logits / self.scfg.temperature, axis=-1))

    # --- one engine step ----------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests into free slots, then decode one token for
        every active slot. Returns number of active slots."""
        for slot in range(self.scfg.max_batch):
            if not self.active[slot] and self.queue:
                self._admit(slot, self.queue.popleft())
        if not self.active.any():
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tok, self.pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32) if self.scfg.greedy \
            else jnp.asarray(self._sample(logits), jnp.int32)
        self.pos = self.pos + jnp.asarray(self.active, jnp.int32)
        self.last_tok = jnp.where(jnp.asarray(self.active), nxt, self.last_tok)
        nxt_np = np.asarray(nxt)
        for slot in range(self.scfg.max_batch):
            req = self.slot_req[slot]
            if req is None or not self.active[slot]:
                continue
            tok = int(nxt_np[slot])
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.active[slot] = False
                self.slot_req[slot] = None
        return int(self.active.sum())

    def run_until_drained(self, max_steps: int = 10000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.queue and not self.active.any():
                break
        return self.finished


# --------------------------------------------------------------------------
# DETR detection serving — the paper's workload behind the same slot model
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DetrRequest:
    rid: int
    image: np.ndarray                     # (3, S, S) float32
    # filled by the engine:
    cls_probs: Optional[np.ndarray] = None    # (Nq, C+1) softmax
    boxes: Optional[np.ndarray] = None        # (Nq, 4) cxcywh
    done: bool = False


class DetrServeEngine:
    """Micro-batching DETR detection server.

    Requests queue until ``max_batch`` images (or a flush) form one static
    batch; one jitted forward serves them all. With a decoder-head config
    the forward projects + FWP-compacts the value table ONCE into the
    shared cache and all ``n_layers`` decoder layers sample it — the
    decode plan's build-once accounting is surfaced by :meth:`describe`.
    Short batches are padded to the static shape (padded lanes are
    dropped, never returned)."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 backend: Optional[str] = None):
        from repro.core.detector import decoder_plan, detector_apply
        from repro.msda import make_plan
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.queue: deque[DetrRequest] = deque()
        self.finished: list[DetrRequest] = []
        self._fwd = jax.jit(lambda p, img: detector_apply(
            p, cfg, img, backend=backend))
        # same plan (and windowed->auto fallback) detector_apply resolves
        self._plan = decoder_plan(cfg, backend) \
            if getattr(cfg, "decoder", None) is not None \
            else make_plan(cfg.encoder.attn, cfg.level_shapes,
                           backend=backend)

    def describe(self) -> str:
        d = self._plan.describe()
        if self._plan.backend == "pallas_decode":
            # the serving-relevant consequence of the persistent decode
            # plan: every request batch stages the compact table once and
            # all decoder layers sample the staged block
            d += " [persistent decode: table staged once per memory]"
        return d

    def submit(self, req: DetrRequest):
        self.queue.append(req)

    def step(self) -> int:
        """Serve one micro-batch (padded to the static batch). Returns the
        number of requests completed this step."""
        if not self.queue:
            return 0
        batch = [self.queue.popleft()
                 for _ in range(min(self.max_batch, len(self.queue)))]
        imgs = np.stack([r.image for r in batch])
        pad = self.max_batch - len(batch)
        if pad:
            imgs = np.concatenate(
                [imgs, np.zeros((pad,) + imgs.shape[1:], imgs.dtype)])
        cls_logits, boxes, _ = self._fwd(self.params, jnp.asarray(imgs))
        probs = np.asarray(jax.nn.softmax(cls_logits, axis=-1))
        boxes = np.asarray(boxes)
        for i, req in enumerate(batch):
            req.cls_probs = probs[i]
            req.boxes = boxes[i]
            req.done = True
            self.finished.append(req)
        return len(batch)

    def run_until_drained(self, max_steps: int = 10000) -> list[DetrRequest]:
        for _ in range(max_steps):
            if not self.queue:
                break
            self.step()
        return self.finished


# --------------------------------------------------------------------------
# Streaming DETR detection — temporal value-cache reuse across video frames
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StreamSession:
    """One live video stream occupying a batch slot of the engine.

    Each entry of ``results`` carries the frame's detections plus the
    manager's frame accounting under ``"stream"`` — that dict is
    BATCH-scoped (``stream["scope"] == "batch"``): all sessions advance
    in one batched update, so its staged-bytes/dirty counts describe the
    whole step, not this session's share."""
    sid: int
    slot: int
    queue: deque = dataclasses.field(default_factory=deque)
    results: list = dataclasses.field(default_factory=list)
    frames_done: int = 0


class StreamingDetrEngine:
    """Streaming detection over persistent, incrementally updated caches.

    The temporal extension of :class:`DetrServeEngine`'s slot model: up
    to ``max_sessions`` concurrent video sessions each occupy one batch
    slot, and ONE batched :class:`~repro.stream.TemporalCacheManager`
    carries every slot's persistent ``MSDAValueCache``, diff reference,
    streaming-EMA frequency scores and hysteresis keep state. Per
    :meth:`step`, each session's next frame memory is stacked into the
    static batch (idle slots replay their diff reference, contributing
    zero dirty tiles), the manager applies ONE incremental update (or a
    full rebuild — first frame, keep transition, admission, or
    over-budget dirt), the decoder + heads run one jitted forward against
    the shared cache, and the sampled frequencies feed back into the EMA.

    Sessions submit encoder MEMORIES (N_in, D) — in a full pipeline the
    backbone+encoder run per frame upstream; the temporal reuse targets
    the value-cache build (projection + compaction + staging), which is
    what rebuilding per frame would pay per decoder stack."""

    def __init__(self, attn_cfg, decoder_cfg, params: dict,
                 level_shapes, *, max_sessions: int = 2,
                 backend: Optional[str] = None, stream_cfg=None,
                 update_fwp: bool = True):
        from repro.msda import MSDAPlan, backend_info, make_plan  # noqa: F401
        from repro.stream import (StreamConfig, TemporalCacheManager,
                                  stream_update_cap)
        self.attn_cfg = attn_cfg
        self.dec_cfg = decoder_cfg
        self.params = params
        self.max_sessions = int(max_sessions)
        self._update_fwp = bool(update_fwp) and attn_cfg.fwp_mode != "off"
        scfg = stream_cfg if stream_cfg is not None else StreamConfig()
        if backend is not None and backend != "auto" \
                and backend_info(backend).raster_only:
            backend = "auto"             # same fallback as decoder_plan
        plan = make_plan(attn_cfg, level_shapes, backend=backend,
                         n_queries=decoder_cfg.n_queries,
                         n_consumers=decoder_cfg.n_layers)
        self.plan = dataclasses.replace(
            plan, stream_update_rows=stream_update_cap(plan,
                                                       scfg.update_frac))
        self.mgr = TemporalCacheManager(
            self.plan, params["decoder"]["value"], scfg,
            batch=self.max_sessions)
        self.sessions: dict[int, StreamSession] = {}
        self._free_slots = list(range(self.max_sessions))
        self._next_sid = 0
        self._last_memory = None       # (B, N_in, D) last served batch —
        #   idle slots replay their row (zero dirty tiles by construction)
        self._slot_centroid: dict[int, np.ndarray] = {}  # slot -> mean
        #   predicted (cx, cy) of the last served frame — the session's
        #   reference-point cluster, what reorder_sessions() sorts by
        self._fwd = jax.jit(self._fwd_impl)

    def describe(self) -> str:
        r = self.mgr
        return (self.plan.describe()
                + f" [streaming: {self.max_sessions} sessions, "
                f"tile_rows={r.scfg.tile_rows}, "
                f"update<={r.update_rows}/{r.n_slots} rows/frame]")

    def capacity_estimate(self, budget_bytes: Optional[int] = None) -> dict:
        """Sessions-per-chip estimate: how many concurrent streams'
        persistent value tables fit one staging budget (default the
        REPRO_MSDA_VMEM_BUDGET window budget, 4 MB), per table dtype.
        Each session's cost is its full table (rows x lanes x itemsize,
        + the int8 scale row, + the pix2slot indirection when compact) —
        the thing a slot holds resident between frames. The f32-vs-int8
        rows are the serving story of the int8 table: ~4x more sessions
        per chip at the same budget."""
        from repro.msda import window_staging_budget
        if budget_bytes is None:
            budget_bytes = window_staging_budget()
        per_dtype = {}
        for d in ("float32", "int8"):
            p = dataclasses.replace(self.plan, table_dtype=d)
            per = p.table_bytes_for_rows(self.mgr._n_rows,
                                         with_indirection=self.mgr._compact)
            per_dtype[d] = {"bytes_per_session": per,
                            "sessions": budget_bytes // per}
        return {"budget_bytes": budget_bytes,
                "table_dtype": self.plan.table_dtype,
                "rows_per_session": self.mgr._n_rows,
                "per_dtype": per_dtype}

    # ---- session lifecycle -------------------------------------------------
    def open_session(self) -> int:
        if not self._free_slots:
            raise RuntimeError(
                f"all {self.max_sessions} streaming slots are busy")
        slot = self._free_slots.pop(0)
        sid = self._next_sid
        self._next_sid += 1
        self.sessions[sid] = StreamSession(sid=sid, slot=slot)
        # warm-start the slot's EMA/keep rows; forces a full rebuild on
        # the next step so the slot's table is built from its own frame
        self.mgr.reset_slot(slot)
        return sid

    def close_session(self, sid: int) -> StreamSession:
        sess = self.sessions.pop(sid)
        self._free_slots.append(sess.slot)
        self._slot_centroid.pop(sess.slot, None)
        return sess

    def submit_frame(self, sid: int, memory: np.ndarray) -> None:
        """Queue one frame's encoder memory (N_in, D) for session sid."""
        self.sessions[sid].queue.append(np.asarray(memory))

    # ---- jitted forward ----------------------------------------------------
    def _fwd_impl(self, params, memory, v, staged, pix2slot, keep_idx,
                  scale):
        from repro.msda.cache import MSDAValueCache
        from repro.msda.decoder import decoder_apply
        cache = MSDAValueCache(
            v=v, pix2slot=pix2slot, keep_idx=keep_idx,
            n_rows=self.mgr._n_rows, slot_windows=self.mgr._slot_windows,
            table_bytes=self.mgr._full_bytes, staged=staged, scale=scale)
        hs, refs, dstate = decoder_apply(
            params["decoder"], self.dec_cfg, self.plan, memory,
            collect_stats=self._update_fwp, cache=cache)
        cls_logits = nn.linear(params["cls_head"], hs)
        raw = nn.linear(params["box_head"], hs)
        cxy = jax.nn.sigmoid(raw[..., :2] + nn.inverse_sigmoid(refs))
        boxes = jnp.concatenate([cxy, jax.nn.sigmoid(raw[..., 2:])], axis=-1)
        freq = None
        if self._update_fwp:
            freq = sum(s["freq"] for s in dstate.collected_stats())
        return cls_logits, boxes, freq

    # ---- one engine step ---------------------------------------------------
    def step(self) -> int:
        """Ingest one pending frame per session; returns frames served."""
        pending = {s.slot: s for s in self.sessions.values() if s.queue}
        if not pending:
            return 0
        d = self.attn_cfg.d_model
        rows = []
        for slot in range(self.max_sessions):
            if slot in pending:
                rows.append(jnp.asarray(pending[slot].queue.popleft()))
            elif self._last_memory is not None:
                # idle slot: replay its last memory — zero dirty tiles,
                # zero incremental work attributed to it
                rows.append(self._last_memory[slot])
            else:
                rows.append(jnp.zeros((self.plan.n_in, d)))
        memory = jnp.stack(rows)
        self._last_memory = memory
        cache, fstats = self.mgr.step(memory)
        cls_logits, boxes, freq = self._fwd(
            self.params, memory, cache.v, cache.staged, cache.pix2slot,
            cache.keep_idx, cache.scale)
        if freq is not None:
            self.mgr.observe(freq)
        probs = np.asarray(jax.nn.softmax(cls_logits, axis=-1))
        boxes = np.asarray(boxes)
        for slot, sess in pending.items():
            sess.results.append({
                "frame": sess.frames_done,
                "cls_probs": probs[slot], "boxes": boxes[slot],
                "stream": fstats,
            })
            sess.frames_done += 1
            # the session's reference-point cluster: mean predicted box
            # center, normalized [0,1]^2 — reorder_sessions() sorts on it
            self._slot_centroid[slot] = boxes[slot][:, :2].mean(axis=0)
        return len(pending)

    # ---- cache-local session placement -------------------------------------
    def reorder_sessions(self, method: Optional[str] = None) -> dict:
        """Assign sessions whose reference points cluster to ADJACENT
        batch slots.

        The batched manager stores every per-slot array with batch as the
        leading axis, so slot adjacency IS memory adjacency: sessions
        looking at nearby image regions stage overlapping value-table
        rows, and placing them next to each other keeps those rows
        resident across the batch sweep. Sort key is the session centroid
        (mean predicted box center of its last frame) through the same
        :func:`repro.msda.ordering.query_sort_keys` the query paths use —
        ``method`` defaults to the plan's ``query_order`` (falling back
        to raster). Free slots are fixed points, so ``_free_slots`` stays
        valid; detections are per-slot state and move with their session,
        so results are unchanged. Returns {sid: slot} after the move."""
        from repro.msda import ordering
        if method is None:
            method = self.plan.query_order \
                if self.plan.query_order != "none" else "raster"
        sessions = sorted(self.sessions.values(), key=lambda s: s.sid)
        placed = [s for s in sessions if s.slot in self._slot_centroid]
        if len(placed) > 1:
            cents = jnp.asarray(
                np.stack([self._slot_centroid[s.slot] for s in placed]))
            keys = np.asarray(ordering.query_sort_keys(
                cents[None], self.plan.level_shapes, method))[0]
            order = np.argsort(keys, kind="stable")
            slots_sorted = sorted(s.slot for s in placed)
            perm = list(range(self.max_sessions))
            for i, j in enumerate(order):
                # key-sorted session i lands in the i-th occupied slot;
                # gather semantics: new slot takes the state at perm[slot]
                perm[slots_sorted[i]] = placed[int(j)].slot
            self.mgr.permute_slots(tuple(perm))
            if self._last_memory is not None:
                self._last_memory = jnp.take(
                    self._last_memory, jnp.asarray(perm), axis=0)
            old_cent = dict(self._slot_centroid)
            old_by_slot = {s.slot: s for s in placed}
            self._slot_centroid = {
                new: old_cent[old] for new, old in enumerate(perm)
                if old in old_cent}
            for new, old in enumerate(perm):
                if old in old_by_slot:
                    old_by_slot[old].slot = new
        return {s.sid: s.slot for s in self.sessions.values()}

    def run_until_drained(self, max_steps: int = 10000) -> None:
        for _ in range(max_steps):
            if self.step() == 0:
                break

    def report(self) -> dict:
        """The manager's cumulative rebuild-vs-incremental accounting."""
        return self.mgr.report()
