"""Continuous-batching serving engine.

vLLM-style slot model adapted to JAX static shapes: a fixed decode batch of
`max_batch` slots over a ring-buffer KV/state cache. Requests are admitted
into free slots via a single-request prefill whose cache slice is scattered
into the batch cache; every engine step decodes ALL active slots one token
(inactive slots run masked). Per-slot positions ride the (B,) `pos` vector
the decode path takes natively.

This is the serving analogue the paper's "DEFA rivals GPUs" comparison maps
to: :class:`ServeEngine` serves the LM-family archs, and
:class:`DetrServeEngine` serves the paper's own workload — batched DETR
detection with the DEFA stack, where each forward builds ONE shared
:class:`~repro.msda.MSDAValueCache` from the encoder memory and every
decoder layer samples it (build-once, sample-everywhere; the driver is
examples/detr_serve.py)."""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.registry import get_api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S_prompt,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    cache_len: int = 256
    greedy: bool = True
    temperature: float = 1.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, serve_cfg: ServeConfig,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.api = get_api(cfg)
        self.params = params
        self.scfg = serve_cfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        b = serve_cfg.max_batch
        self.cache = self.api.init_cache(cfg, b, serve_cfg.cache_len)
        self.pos = jnp.zeros((b,), jnp.int32)
        self.last_tok = jnp.zeros((b,), jnp.int32)
        self.active = np.zeros((b,), bool)
        self.slot_req: list[Optional[Request]] = [None] * b
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._decode = jax.jit(self._decode_impl)
        self._prefill1 = jax.jit(self._prefill1_impl)

    # --- jitted internals --------------------------------------------------
    def _prefill1_impl(self, params, cache1, tokens1):
        logits, cache1 = self.api.prefill(params, self.cfg, cache1,
                                          {"tokens": tokens1})
        return logits, cache1

    def _decode_impl(self, params, cache, tokens, pos):
        return self.api.decode_step(params, self.cfg, cache, tokens, pos)

    # --- slot management ----------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, slot: int, req: Request):
        cfg, scfg = self.cfg, self.scfg
        cache1 = self.api.init_cache(cfg, 1, scfg.cache_len)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill1(self.params, cache1, toks)
        # scatter the single-request cache into batch slot `slot`
        # (every stacked cache leaf is (n_layers, B, ...): dim 1 is batch)
        self.cache = jax.tree.map(
            lambda c, c1: c.at[:, slot].set(c1[:, 0]), self.cache, cache1)
        first = int(jnp.argmax(logits, axis=-1)[0]) if scfg.greedy \
            else self._sample(logits)[0]
        req.output.append(first)
        self.last_tok = self.last_tok.at[slot].set(first)
        self.pos = self.pos.at[slot].set(len(req.prompt))
        self.active[slot] = True
        self.slot_req[slot] = req

    def _sample(self, logits):
        self.rng, k = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(
            k, logits / self.scfg.temperature, axis=-1))

    # --- one engine step ----------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests into free slots, then decode one token for
        every active slot. Returns number of active slots."""
        for slot in range(self.scfg.max_batch):
            if not self.active[slot] and self.queue:
                self._admit(slot, self.queue.popleft())
        if not self.active.any():
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tok, self.pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32) if self.scfg.greedy \
            else jnp.asarray(self._sample(logits), jnp.int32)
        self.pos = self.pos + jnp.asarray(self.active, jnp.int32)
        self.last_tok = jnp.where(jnp.asarray(self.active), nxt, self.last_tok)
        nxt_np = np.asarray(nxt)
        for slot in range(self.scfg.max_batch):
            req = self.slot_req[slot]
            if req is None or not self.active[slot]:
                continue
            tok = int(nxt_np[slot])
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.active[slot] = False
                self.slot_req[slot] = None
        return int(self.active.sum())

    def run_until_drained(self, max_steps: int = 10000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.queue and not self.active.any():
                break
        return self.finished


# --------------------------------------------------------------------------
# DETR detection serving — the paper's workload behind the same slot model
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DetrRequest:
    rid: int
    image: np.ndarray                     # (3, S, S) float32
    # filled by the engine:
    cls_probs: Optional[np.ndarray] = None    # (Nq, C+1) softmax
    boxes: Optional[np.ndarray] = None        # (Nq, 4) cxcywh
    done: bool = False


class DetrServeEngine:
    """Micro-batching DETR detection server.

    Requests queue until ``max_batch`` images (or a flush) form one static
    batch; one jitted forward serves them all. With a decoder-head config
    the forward projects + FWP-compacts the value table ONCE into the
    shared cache and all ``n_layers`` decoder layers sample it — the
    decode plan's build-once accounting is surfaced by :meth:`describe`.
    Short batches are padded to the static shape (padded lanes are
    dropped, never returned)."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 backend: Optional[str] = None):
        from repro.core.detector import decoder_plan, detector_apply
        from repro.msda import make_plan
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.queue: deque[DetrRequest] = deque()
        self.finished: list[DetrRequest] = []
        self._fwd = jax.jit(lambda p, img: detector_apply(
            p, cfg, img, backend=backend))
        # same plan (and windowed->auto fallback) detector_apply resolves
        self._plan = decoder_plan(cfg, backend) \
            if getattr(cfg, "decoder", None) is not None \
            else make_plan(cfg.encoder.attn, cfg.level_shapes,
                           backend=backend)

    def describe(self) -> str:
        d = self._plan.describe()
        if self._plan.backend == "pallas_decode":
            # the serving-relevant consequence of the persistent decode
            # plan: every request batch stages the compact table once and
            # all decoder layers sample the staged block
            d += " [persistent decode: table staged once per memory]"
        return d

    def submit(self, req: DetrRequest):
        self.queue.append(req)

    def step(self) -> int:
        """Serve one micro-batch (padded to the static batch). Returns the
        number of requests completed this step."""
        if not self.queue:
            return 0
        batch = [self.queue.popleft()
                 for _ in range(min(self.max_batch, len(self.queue)))]
        imgs = np.stack([r.image for r in batch])
        pad = self.max_batch - len(batch)
        if pad:
            imgs = np.concatenate(
                [imgs, np.zeros((pad,) + imgs.shape[1:], imgs.dtype)])
        cls_logits, boxes, _ = self._fwd(self.params, jnp.asarray(imgs))
        probs = np.asarray(jax.nn.softmax(cls_logits, axis=-1))
        boxes = np.asarray(boxes)
        for i, req in enumerate(batch):
            req.cls_probs = probs[i]
            req.boxes = boxes[i]
            req.done = True
            self.finished.append(req)
        return len(batch)

    def run_until_drained(self, max_steps: int = 10000) -> list[DetrRequest]:
        for _ in range(max_steps):
            if not self.queue:
                break
            self.step()
        return self.finished
