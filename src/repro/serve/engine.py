"""Continuous-batching DETR serving over AOT-compiled shape buckets.

The serving analogue of the paper's "DEFA rivals GPUs" comparison, built
the way MaxText's offline-inference harness serves LLMs:

  * **AOT shape buckets** — a small set of resolution/level-shape buckets
    is derived from the detector config (``serve/buckets.py``) and each
    bucket's forward is compiled at STARTUP via
    ``jax.jit(...).lower().compile()``. Incoming images route to the
    smallest bucket they fit (padding up); oversized images are rejected
    at admission. After warmup nothing ever retraces — the engine carries
    a compile-count spy (``compile_count``) that tests assert stays flat
    under mixed load.
  * **continuous batching** — requests queue per bucket; every
    :meth:`DetrServeEngine.step` dispatches the deepest bucket's
    micro-batch. Sessions of the streaming engine join/leave batch slots
    between steps without recompiling (per-slot admission in
    ``stream/temporal.py`` — no batch-wide rebuild storm).
  * **pipelined post-processing** — top-k decode, box emission and
    per-request callbacks run on a background worker thread
    (``serve/postproc.py``): the device launches step N+1 while step N's
    outputs are still being decoded on the host.

Every forward builds ONE shared :class:`~repro.msda.MSDAValueCache` from
the encoder memory and all decoder layers sample it (build-once,
sample-everywhere). The seed-era token-decode engine lives on in
``serve/lm.py``; drivers are examples/detr_serve.py (batch + sustained
load) and examples/detr_stream.py (streaming sessions)."""
from __future__ import annotations

import dataclasses
import time
import threading
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nn
from repro.obs import Observability
from repro.serve.buckets import BucketRouter, ShapeBucket, derive_buckets
from repro.serve.postproc import (PostprocWorker, StarvationError,
                                  softmax_np, topk_detections)


@dataclasses.dataclass
class DetrRequest:
    rid: int
    image: np.ndarray                     # (3, H, W) float32, H/W <= bucket
    # filled by the engine:
    cls_probs: Optional[np.ndarray] = None    # (Nq, C+1) softmax
    boxes: Optional[np.ndarray] = None        # (Nq, 4) cxcywh
    detections: Optional[dict] = None         # top-k decode (postproc stage)
    done: bool = False
    bucket: Optional[int] = None              # resolution routed to
    error: Optional[str] = None               # admission rejection reason
    callback: Optional[Callable] = None       # invoked on completion
    t_submit: float = 0.0
    t_done: float = 0.0
    span_queue: Optional[str] = None          # open "queue" span id — the
    #   request context that carries the trace across the worker thread


class DetrServeEngine:
    """Bucketed continuous-batching DETR detection server.

    ``resolutions`` selects the AOT shape buckets (default: one bucket at
    ``cfg.img_size``). Each bucket's forward is compiled once at
    construction for the static ``(max_batch, 3, r, r)`` shape; the model
    params are resolution-independent, so every bucket serves the same
    weights. ``submit`` routes (and may reject) immediately; ``step``
    dispatches one micro-batch from the deepest bucket queue and hands
    the device outputs to the post-processing stage, which runs on a
    worker thread when ``pipeline_postproc`` is set (the default) — the
    two modes share one decode path and are bit-identical."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 backend: Optional[str] = None,
                 resolutions: Optional[tuple] = None,
                 pipeline_postproc: bool = True, topk: int = 5,
                 obs: Optional[Observability] = None):
        from repro.core.detector import detector_apply
        from repro.msda.autotune import ensure_applied
        ensure_applied()   # load-only: the committed/measured plan table,
        #   so bucket derivation below sees the tuned budgets (never
        #   raises, never times anything)
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.backend = backend
        self.topk = int(topk)
        # per-engine observability: own registry (counters are exact for
        # THIS engine) + tracer; Observability.disabled() is the zero-cost
        # uninstrumented mode the overhead benchmark compares against.
        # Everything below touches it strictly outside jit, except the
        # compile counter, whose bump runs at TRACE time by design.
        self.obs = obs if obs is not None else Observability.default()
        m = self.obs.metrics
        self._m_compiles = m.counter(
            "msda_compiles_total",
            "detector forward tracings per bucket (trace-time spy: flat "
            "after AOT warmup = zero retraces)")
        self._m_requests = m.counter(
            "serve_requests_total", "requests by bucket and outcome")
        self._m_qdepth = m.gauge(
            "serve_queue_depth", "admitted requests waiting per bucket")
        self._m_backlog = m.gauge(
            "serve_postproc_backlog", "batches queued to the postproc worker")
        self._m_latency = m.histogram(
            "serve_request_latency_seconds",
            "submit-to-callback latency per completed request")
        self._m_span = m.histogram(
            "serve_span_seconds", "per-stage latency (label span=)")
        self._m_staged = m.counter(
            "staged_bytes_total",
            "bytes staged to device per the plan's static accounting")
        if resolutions is None:
            resolutions = (cfg.img_size,)
        self.buckets = derive_buckets(cfg, resolutions, backend=backend)
        self.router = BucketRouter(self.buckets)
        self._bucket_by_res = {b.resolution: b for b in self.buckets}
        self.queues: dict[int, deque[DetrRequest]] = {
            b.resolution: deque() for b in self.buckets}
        self.finished: list[DetrRequest] = []
        self.rejected: list[DetrRequest] = []
        self._lock = threading.Lock()
        self._compiled = {}
        for b in self.buckets:
            # compile-count spy: the increment executes at TRACE time
            # only, so after the AOT warmup below it must never move
            # again — tests/test_serve.py asserts zero recompiles under
            # mixed load against this registry counter
            def fwd(p, img, _cfg=b.cfg, _res=b.resolution):
                self._m_compiles.inc(bucket=str(_res))
                return detector_apply(p, _cfg, img, backend=self.backend)
            spec = jax.ShapeDtypeStruct(
                (self.max_batch, 3, b.resolution, b.resolution), jnp.float32)
            self._compiled[b.resolution] = \
                jax.jit(fwd).lower(self.params, spec).compile()
            self.obs.tracer.event("plan", engine="DetrServeEngine",
                                  bucket=b.resolution,
                                  plan=b.plan.snapshot())
        self._post = PostprocWorker(self._complete,
                                    pipelined=pipeline_postproc,
                                    obs=self.obs)

    @property
    def compile_count(self) -> int:
        """Total detector tracings across buckets — the zero-retrace spy,
        now a view over the ``msda_compiles_total`` registry counter."""
        return int(self._m_compiles.total())

    # ---- introspection -----------------------------------------------------
    def describe(self) -> str:
        lines = []
        for b in self.buckets:
            d = b.plan.describe()
            if b.plan.backend == "pallas_decode":
                # the serving-relevant consequence of the persistent
                # decode plan: every request batch stages the compact
                # table once and all decoder layers sample the staged
                # block
                d += " [persistent decode: table staged once per memory]"
            lines.append(f"bucket {b.resolution}px: {d}")
        return "\n".join(lines)

    def bucket_table(self) -> list:
        return self.router.table()

    def pending(self) -> int:
        """Requests admitted but not yet dispatched to the device."""
        return sum(len(q) for q in self.queues.values())

    # ---- admission ---------------------------------------------------------
    def submit(self, req: DetrRequest) -> bool:
        """Route a request to its bucket queue; returns False (and records
        the reason on ``req.error``) when admission control rejects it."""
        req.t_submit = time.perf_counter()
        bucket, reason = self.router.admit(req.image)
        if bucket is None:
            req.error = reason
            self._m_requests.inc(bucket="none", outcome="rejected")
            with self._lock:
                self.rejected.append(req)
            return False
        res = bucket.resolution
        req.bucket = res
        # the "queue" span opens here and is closed by step() at dispatch;
        # its id rides on the request (the cross-thread trace context)
        req.span_queue = self.obs.tracer.start("queue", rid=req.rid,
                                               t=req.t_submit, bucket=res)
        self._m_requests.inc(bucket=str(res), outcome="admitted")
        self.queues[res].append(req)
        self._m_qdepth.set(len(self.queues[res]), bucket=str(res))
        return True

    # ---- one engine step ---------------------------------------------------
    def step(self) -> int:
        """Dispatch one micro-batch from the deepest bucket queue (padded
        to the static batch; ties pick the cheaper/smaller bucket).
        Returns the number of requests dispatched — completion happens in
        the post-processing stage."""
        res = max((r for r, q in self.queues.items() if q),
                  key=lambda r: (len(self.queues[r]), -r), default=None)
        if res is None:
            return 0
        q = self.queues[res]
        batch = [q.popleft() for _ in range(min(self.max_batch, len(q)))]
        tr = self.obs.tracer
        self._m_qdepth.set(len(q), bucket=str(res))
        for req in batch:
            if req.span_queue:
                sp = tr.end(req.span_queue)
                req.span_queue = None
                self._m_span.observe(sp.duration_s, span="queue")
        imgs = np.zeros((self.max_batch, 3, res, res), np.float32)
        for i, req in enumerate(batch):
            im = np.asarray(req.image, np.float32)
            imgs[i, :, :im.shape[1], :im.shape[2]] = im     # pad up
        # the "device" span opens at dispatch and is closed by the
        # postproc stage once the transfer completes (worker thread)
        dev_span = tr.start("device", bucket=res, n=len(batch))
        cls_logits, boxes, _aux = self._compiled[res](self.params,
                                                      jnp.asarray(imgs))
        # build-once value cache per dispatched memory (static accounting)
        self._m_staged.inc(
            self._bucket_by_res[res].plan.cache_table_bytes, mode="build")
        # hand the device arrays straight to the postproc stage: the
        # worker's np.asarray blocks on the transfer while this thread is
        # free to dispatch the next bucket's micro-batch
        self._post.submit((batch, cls_logits, boxes, dev_span))
        self._m_backlog.set(self._post.backlog)
        return len(batch)

    def _complete(self, item) -> None:
        batch, cls_logits, boxes, dev_span = item
        tr = self.obs.tracer
        probs = softmax_np(np.asarray(cls_logits))
        boxes = np.asarray(boxes)
        if dev_span:
            sp = tr.end(dev_span)    # after np.asarray: transfer included
            self._m_span.observe(sp.duration_s, span="device")
        post_span = tr.start("postproc", n=len(batch))
        for i, req in enumerate(batch):
            req.cls_probs = probs[i]
            req.boxes = boxes[i]
            req.detections = topk_detections(probs[i], boxes[i], self.topk)
            req.t_done = time.perf_counter()
            req.done = True
            if req.callback is not None:
                with tr.span("callback", rid=req.rid):
                    req.callback(req)
            self._m_latency.observe(req.t_done - req.t_submit,
                                    bucket=str(req.bucket))
            self._m_requests.inc(bucket=str(req.bucket), outcome="completed")
            with self._lock:
                self.finished.append(req)
        if post_span:
            sp = tr.end(post_span)
            self._m_span.observe(sp.duration_s, span="postproc")

    def drain(self) -> None:
        """Barrier on the post-processing stage only (no new dispatches)."""
        self._post.drain()

    def run_until_drained(self, max_steps: int = 10000
                          ) -> list[DetrRequest]:
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        self._post.drain()
        if self.pending():
            now = time.perf_counter()
            raise StarvationError({
                "engine": "DetrServeEngine", "steps": steps,
                "queued": {r: len(q) for r, q in self.queues.items() if q},
                # per-bucket age of the head (oldest) queued request,
                # from the same perf_counter timeline as the queue spans
                "oldest_age_s": {r: round(now - q[0].t_submit, 6)
                                 for r, q in self.queues.items() if q},
                "finished": len(self.finished),
                "rejected": len(self.rejected)})
        self.obs.flush_metrics()
        return self.finished

    def close(self) -> None:
        """Shut down the post-processing worker (joins its thread);
        idempotent, and ``submit``/``step`` pipelining into the worker
        raises once closed. Flushes a final metrics snapshot into the
        JSONL event log (when one is attached) and closes the sink."""
        self._post.close()
        self.obs.flush_metrics()
        self.obs.close()

    def __enter__(self) -> "DetrServeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


# --------------------------------------------------------------------------
# Streaming DETR detection — temporal value-cache reuse across video frames
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StreamSession:
    """One live video stream occupying a batch slot of the engine.

    Each entry of ``results`` carries the frame's detections plus the
    manager's frame accounting under ``"stream"`` — that dict is
    BATCH-scoped (``stream["scope"] == "batch"``): all sessions advance
    in one batched update, so its staged-bytes/dirty counts describe the
    whole step, not this session's share."""
    sid: int
    slot: int
    queue: deque = dataclasses.field(default_factory=deque)
    results: list = dataclasses.field(default_factory=list)
    frames_done: int = 0
    t_queue: deque = dataclasses.field(default_factory=deque)  # submit
    #   times (perf_counter) parallel to ``queue`` — starvation ages


class StreamingDetrEngine:
    """Streaming detection over persistent, incrementally updated caches.

    The temporal extension of :class:`DetrServeEngine`'s slot model: up
    to ``max_sessions`` concurrent video sessions each occupy one batch
    slot, and ONE batched :class:`~repro.stream.TemporalCacheManager`
    carries every slot's persistent ``MSDAValueCache``, diff reference,
    streaming-EMA frequency scores and hysteresis keep state. Per
    :meth:`step`, each session's next frame memory is stacked into the
    static batch (idle slots replay their diff reference, contributing
    zero dirty tiles), the manager applies ONE incremental update (or a
    full rebuild — first frame, keep transition, or over-budget dirt),
    the decoder + heads run one jitted forward against the shared cache,
    and the sampled frequencies feed back into the EMA.

    Sessions join and leave slots BETWEEN steps without recompiling and
    without disturbing their neighbours: admission schedules a per-slot
    build in the manager (batch-1 build scattered into the slot's rows)
    while every other session rides the ordinary incremental path — the
    continuous-batching contract of the serve tentpole.

    Sessions submit encoder MEMORIES (N_in, D) — in a full pipeline the
    backbone+encoder run per frame upstream; the temporal reuse targets
    the value-cache build (projection + compaction + staging), which is
    what rebuilding per frame would pay per decoder stack."""

    def __init__(self, attn_cfg, decoder_cfg, params: dict,
                 level_shapes, *, max_sessions: int = 2,
                 backend: Optional[str] = None, stream_cfg=None,
                 update_fwp: bool = True,
                 obs: Optional[Observability] = None):
        from repro.msda import MSDAPlan, backend_info, make_plan  # noqa: F401
        from repro.msda.autotune import ensure_applied
        from repro.stream import (TemporalCacheManager,
                                  resolve_stream_config, stream_update_cap)
        ensure_applied()   # load-only tuned plan table: budgets for the
        #   plan below, measured stream crossover for the default scfg
        self.attn_cfg = attn_cfg
        self.dec_cfg = decoder_cfg
        self.params = params
        self.max_sessions = int(max_sessions)
        self._update_fwp = bool(update_fwp) and attn_cfg.fwp_mode != "off"
        scfg = resolve_stream_config(stream_cfg)
        if backend is not None and backend != "auto" \
                and backend_info(backend).raster_only:
            backend = "auto"             # same fallback as decoder_plan
        plan = make_plan(attn_cfg, level_shapes, backend=backend,
                         n_queries=decoder_cfg.n_queries,
                         n_consumers=decoder_cfg.n_layers)
        self.plan = dataclasses.replace(
            plan, stream_update_rows=stream_update_cap(plan,
                                                       scfg.update_frac))
        # engine and manager share ONE bundle: the manager's frame/staged
        # counters and the engine's spans land in the same registry/log
        self.obs = obs if obs is not None else Observability.default()
        self._m_span = self.obs.metrics.histogram(
            "stream_span_seconds", "per-stage frame latency (label span=)")
        self._m_frame_latency = self.obs.metrics.histogram(
            "stream_frame_latency_seconds", "full step latency per frame")
        self.obs.tracer.event("plan", engine="StreamingDetrEngine",
                              plan=self.plan.snapshot())
        self.mgr = TemporalCacheManager(
            self.plan, params["decoder"]["value"], scfg,
            batch=self.max_sessions, obs=self.obs)
        self.sessions: dict[int, StreamSession] = {}
        self._free_slots = list(range(self.max_sessions))
        self._next_sid = 0
        self._last_memory = None       # (B, N_in, D) last served batch —
        #   idle slots replay their row (zero dirty tiles by construction)
        self._slot_centroid: dict[int, np.ndarray] = {}  # slot -> mean
        #   predicted (cx, cy) of the last served frame — the session's
        #   reference-point cluster, what reorder_sessions() sorts by
        self._fwd = jax.jit(self._fwd_impl)

    def describe(self) -> str:
        r = self.mgr
        return (self.plan.describe()
                + f" [streaming: {self.max_sessions} sessions, "
                f"tile_rows={r.scfg.tile_rows}, "
                f"update<={r.update_rows}/{r.n_slots} rows/frame]")

    def capacity_estimate(self, budget_bytes: Optional[int] = None) -> dict:
        """Sessions-per-chip estimate: how many concurrent streams'
        persistent value tables fit one staging budget (default the
        resolved window budget — env pin, else the autotuner's MEASURED
        ceiling when a tuned table is applied, else the 4 MB static
        formula; ``budget_source`` records which), per table dtype. Each
        session's cost is its full table (rows x lanes x itemsize, + the
        int8 scale row, + the pix2slot indirection when compact) — the
        thing a slot holds resident between frames. The f32-vs-int8 rows
        are the serving story of the int8 table: ~4x more sessions per
        chip at the same budget."""
        from repro.msda import staging_budget_source, window_staging_budget
        source = "caller"
        if budget_bytes is None:
            budget_bytes = window_staging_budget()
            source = staging_budget_source()
        per_dtype = {}
        for d in ("float32", "int8"):
            p = dataclasses.replace(self.plan, table_dtype=d)
            per = p.table_bytes_for_rows(self.mgr._n_rows,
                                         with_indirection=self.mgr._compact)
            per_dtype[d] = {"bytes_per_session": per,
                            "sessions": budget_bytes // per}
        return {"budget_bytes": budget_bytes,
                "budget_source": source,
                "table_dtype": self.plan.table_dtype,
                "rows_per_session": self.mgr._n_rows,
                "per_dtype": per_dtype}

    # ---- session lifecycle -------------------------------------------------
    def open_session(self) -> int:
        if not self._free_slots:
            raise RuntimeError(
                f"all {self.max_sessions} streaming slots are busy")
        slot = self._free_slots.pop(0)
        sid = self._next_sid
        self._next_sid += 1
        self.sessions[sid] = StreamSession(sid=sid, slot=slot)
        # warm-start the slot's EMA/keep rows and schedule a PER-SLOT
        # admission build: the next step rebuilds only this slot's table
        # rows from its own frame, other sessions ride the incremental
        # path — joining never rebuild-storms the whole batch
        self.mgr.reset_slot(slot)
        return sid

    def close_session(self, sid: int) -> StreamSession:
        sess = self.sessions.pop(sid)
        self._free_slots.append(sess.slot)
        self._slot_centroid.pop(sess.slot, None)
        return sess

    def submit_frame(self, sid: int, memory: np.ndarray) -> None:
        """Queue one frame's encoder memory (N_in, D) for session sid."""
        sess = self.sessions[sid]
        sess.queue.append(np.asarray(memory))
        sess.t_queue.append(time.perf_counter())

    # ---- jitted forward ----------------------------------------------------
    def _fwd_impl(self, params, memory, v, staged, pix2slot, keep_idx,
                  scale):
        from repro.msda.cache import MSDAValueCache
        from repro.msda.decoder import decoder_apply
        cache = MSDAValueCache(
            v=v, pix2slot=pix2slot, keep_idx=keep_idx,
            n_rows=self.mgr._n_rows, slot_windows=self.mgr._slot_windows,
            table_bytes=self.mgr._full_bytes, staged=staged, scale=scale)
        hs, refs, dstate = decoder_apply(
            params["decoder"], self.dec_cfg, self.plan, memory,
            collect_stats=self._update_fwp, cache=cache)
        cls_logits = nn.linear(params["cls_head"], hs)
        raw = nn.linear(params["box_head"], hs)
        cxy = jax.nn.sigmoid(raw[..., :2] + nn.inverse_sigmoid(refs))
        boxes = jnp.concatenate([cxy, jax.nn.sigmoid(raw[..., 2:])], axis=-1)
        freq = None
        if self._update_fwp:
            freq = sum(s["freq"] for s in dstate.collected_stats())
        return cls_logits, boxes, freq

    # ---- one engine step ---------------------------------------------------
    def step(self) -> int:
        """Ingest one pending frame per session; returns frames served."""
        pending = {s.slot: s for s in self.sessions.values() if s.queue}
        if not pending:
            return 0
        t_step0 = time.perf_counter()
        tr = self.obs.tracer
        d = self.attn_cfg.d_model
        with tr.span("frame_in", n=len(pending)) as _:
            rows = []
            for slot in range(self.max_sessions):
                if slot in pending:
                    rows.append(jnp.asarray(pending[slot].queue.popleft()))
                    pending[slot].t_queue.popleft()
                elif self._last_memory is not None:
                    # idle slot: replay its last memory — zero dirty
                    # tiles, zero incremental work attributed to it
                    rows.append(self._last_memory[slot])
                else:
                    rows.append(jnp.zeros((self.plan.n_in, d)))
            memory = jnp.stack(rows)
        self._last_memory = memory
        cache, fstats = self.mgr.step(memory)
        dec_span = tr.start("decode", n=len(pending))
        cls_logits, boxes, freq = self._fwd(
            self.params, memory, cache.v, cache.staged, cache.pix2slot,
            cache.keep_idx, cache.scale)
        if freq is not None:
            self.mgr.observe(freq)
        probs = np.asarray(jax.nn.softmax(cls_logits, axis=-1))
        boxes = np.asarray(boxes)
        if dec_span:
            sp = tr.end(dec_span)    # after np.asarray: compute included
            self._m_span.observe(sp.duration_s, span="decode")
        self._m_frame_latency.observe(time.perf_counter() - t_step0)
        for slot, sess in pending.items():
            sess.results.append({
                "frame": sess.frames_done,
                "cls_probs": probs[slot], "boxes": boxes[slot],
                "stream": fstats,
            })
            sess.frames_done += 1
            # the session's reference-point cluster: mean predicted box
            # center, normalized [0,1]^2 — reorder_sessions() sorts on it
            self._slot_centroid[slot] = boxes[slot][:, :2].mean(axis=0)
        return len(pending)

    # ---- cache-local session placement -------------------------------------
    def reorder_sessions(self, method: Optional[str] = None) -> dict:
        """Assign sessions whose reference points cluster to ADJACENT
        batch slots.

        The batched manager stores every per-slot array with batch as the
        leading axis, so slot adjacency IS memory adjacency: sessions
        looking at nearby image regions stage overlapping value-table
        rows, and placing them next to each other keeps those rows
        resident across the batch sweep. Sort key is the session centroid
        (mean predicted box center of its last frame) through the same
        :func:`repro.msda.ordering.query_sort_keys` the query paths use —
        ``method`` defaults to the plan's ``query_order`` (falling back
        to raster). Free slots are fixed points, so ``_free_slots`` stays
        valid; detections are per-slot state and move with their session,
        so results are unchanged. Returns {sid: slot} after the move."""
        from repro.msda import ordering
        if method is None:
            method = self.plan.query_order \
                if self.plan.query_order != "none" else "raster"
        sessions = sorted(self.sessions.values(), key=lambda s: s.sid)
        placed = [s for s in sessions if s.slot in self._slot_centroid]
        if len(placed) > 1:
            cents = jnp.asarray(
                np.stack([self._slot_centroid[s.slot] for s in placed]))
            keys = np.asarray(ordering.query_sort_keys(
                cents[None], self.plan.level_shapes, method))[0]
            order = np.argsort(keys, kind="stable")
            slots_sorted = sorted(s.slot for s in placed)
            perm = list(range(self.max_sessions))
            for i, j in enumerate(order):
                # key-sorted session i lands in the i-th occupied slot;
                # gather semantics: new slot takes the state at perm[slot]
                perm[slots_sorted[i]] = placed[int(j)].slot
            self.mgr.permute_slots(tuple(perm))
            if self._last_memory is not None:
                self._last_memory = jnp.take(
                    self._last_memory, jnp.asarray(perm), axis=0)
            old_cent = dict(self._slot_centroid)
            old_by_slot = {s.slot: s for s in placed}
            self._slot_centroid = {
                new: old_cent[old] for new, old in enumerate(perm)
                if old in old_cent}
            for new, old in enumerate(perm):
                if old in old_by_slot:
                    old_by_slot[old].slot = new
        return {s.sid: s.slot for s in self.sessions.values()}

    def run_until_drained(self, max_steps: int = 10000) -> None:
        steps = 0
        while any(s.queue for s in self.sessions.values()) \
                and steps < max_steps:
            if self.step() == 0:
                break
            steps += 1
        queued = {s.sid: len(s.queue)
                  for s in self.sessions.values() if s.queue}
        if queued:
            now = time.perf_counter()
            raise StarvationError({
                "engine": "StreamingDetrEngine", "steps": steps,
                "queued": queued,
                # per-session age of the oldest queued frame (same
                # perf_counter timeline the frame spans use)
                "oldest_age_s": {s.sid: round(now - s.t_queue[0], 6)
                                 for s in self.sessions.values()
                                 if s.t_queue},
                "frames_done": sum(s.frames_done
                                   for s in self.sessions.values())})
        self.obs.flush_metrics()

    def report(self) -> dict:
        """The manager's cumulative rebuild-vs-incremental accounting."""
        return self.mgr.report()
