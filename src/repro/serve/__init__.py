from repro.serve.engine import ServeEngine, Request, ServeConfig  # noqa: F401
