"""Serving subsystem: bucketed continuous batching for the DETR workload
(``serve.engine``), the pipelined post-processing stage (``serve.postproc``),
shape buckets + admission control (``serve.buckets``), and the quarantined
seed-era LM token-decode engine (``serve.lm``)."""
from repro.serve.buckets import (BucketRouter, ShapeBucket,  # noqa: F401
                                 derive_buckets)
from repro.serve.engine import (DetrRequest, DetrServeEngine,  # noqa: F401
                                StreamingDetrEngine, StreamSession)
from repro.serve.lm import Request, ServeConfig, ServeEngine  # noqa: F401
from repro.serve.postproc import (PostprocWorker,  # noqa: F401
                                  StarvationError, topk_detections)
