"""Pipelined host-side post-processing + serving-loop starvation reports.

The device can launch step N+1 while step N's outputs are still being
decoded on the host — top-k decode, box emission and per-request
callbacks are pure numpy work that would otherwise serialize with the
next dispatch. :class:`PostprocWorker` is that overlap: the engine hands
(requests, device arrays) to a queue, a daemon thread blocks on the
device transfer (``np.asarray`` releases the GIL while XLA computes) and
runs the decode, and the engine's main loop is already dispatching the
next micro-batch. ``pipelined=False`` degrades to synchronous in-line
processing through the SAME code path, so the two modes are bit-identical
on identical inputs (tests/test_serve.py pins this).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Optional

import numpy as np


class StarvationError(RuntimeError):
    """``run_until_drained`` hit its step limit with work still queued.

    The seed engines silently returned in this situation, dropping the
    queued requests on the floor; every drain loop now raises this
    instead. ``report`` carries the starvation snapshot (queue depths,
    steps executed, completions) so callers can log or re-drain. The
    engines stamp it with wall/monotonic timestamps and, when they track
    per-request submit times (span data), a per-queue ``oldest_age_s``
    map — the message calls out the most-starved request's age."""

    def __init__(self, report: dict):
        self.report = dict(report)
        # wall clock for log correlation, perf_counter for span math —
        # the same monotonic timeline the queue spans are recorded on
        self.report.setdefault("wall_time", time.time())
        self.report.setdefault("t_monotonic", time.perf_counter())
        msg = ("serving loop starved (work still queued at max_steps): "
               + ", ".join(f"{k}={v}"
                           for k, v in sorted(self.report.items())))
        ages = self.report.get("oldest_age_s") or {}
        if ages:
            worst = max(ages, key=lambda k: ages[k])
            msg += (f"; most-starved request (queue {worst}) has waited "
                    f"{ages[worst]:.3f}s")
        super().__init__(msg)


def softmax_np(x: np.ndarray) -> np.ndarray:
    """Float32 softmax over the last axis (host-side, no device round-trip)."""
    x = np.asarray(x, np.float32)
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def topk_detections(cls_probs: np.ndarray, boxes: np.ndarray,
                    k: int) -> dict:
    """Top-k box emission from one request's (Nq, C+1) probs + (Nq, 4) boxes.

    Score is each query's best FOREGROUND class probability (the last
    column is background); ties resolve to the lower query index so the
    emission is deterministic."""
    fg = cls_probs[:, :-1]
    labels = fg.argmax(axis=-1).astype(np.int32)
    scores = fg.max(axis=-1).astype(np.float32)
    k = min(int(k), scores.shape[0])
    order = np.argsort(-scores, kind="stable")[:k]
    return {"scores": scores[order], "labels": labels[order],
            "boxes": np.asarray(boxes)[order],
            "query": order.astype(np.int32)}


_STOP = object()


class PostprocWorker:
    """Background post-processing stage fed by a queue.

    ``process`` receives each submitted item; exceptions are captured and
    re-raised from :meth:`drain`/:meth:`submit` on the caller's thread (a
    crashed worker must fail the serving loop, not hang it). ``drain``
    blocks until every submitted item has been processed — the engine's
    ``run_until_drained`` barrier.

    Lifecycle: :meth:`close` (idempotent; also the context-manager exit)
    drains the queue's pending items, stops and JOINS the thread — the
    daemon thread never outlives a closed engine — and every later
    ``submit`` raises immediately instead of enqueueing into a dead
    queue."""

    def __init__(self, process: Callable, *, pipelined: bool = True,
                 name: str = "serve-postproc", obs=None):
        self._process = process
        self.pipelined = bool(pipelined)
        self._exc: Optional[BaseException] = None
        self._stopped = False
        self._q: queue.Queue = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        if obs is None:
            from repro.obs import Observability
            obs = Observability.disabled()
        self.obs = obs
        self._m_items = obs.metrics.counter(
            "serve_postproc_items_total", "batches handed to the worker")
        self._m_backlog = obs.metrics.gauge(
            "serve_postproc_backlog", "batches queued to the postproc worker")
        if self.pipelined:
            self._thread = threading.Thread(target=self._loop, name=name,
                                            daemon=True)
            self._thread.start()

    def submit(self, item) -> None:
        if self._stopped:
            raise RuntimeError(
                "PostprocWorker is closed; submit after close() would "
                "enqueue into a dead queue")
        if self._exc is not None:
            raise self._exc
        self._m_items.inc()
        if self.pipelined:
            self._q.put(item)
            self._m_backlog.set(self.backlog)
        else:
            self._process(item)

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                if self._exc is None:
                    self._process(item)
            except BaseException as e:          # noqa: BLE001 - re-raised
                self._exc = e
            finally:
                self._q.task_done()
                self._m_backlog.set(self.backlog)

    @property
    def backlog(self) -> int:
        """Items submitted but not yet fully processed."""
        return int(self._q.unfinished_tasks) if self.pipelined else 0

    def drain(self) -> None:
        """Block until every submitted item is processed; re-raise any
        worker exception on the calling thread."""
        if self.pipelined:
            self._q.join()
        if self._exc is not None:
            raise self._exc

    def close(self) -> None:
        """Stop accepting work and join the thread (idempotent). Items
        already submitted are still processed — the queue is FIFO and the
        stop sentinel goes in last — so close() is also a drain barrier
        for the pipelined path."""
        self._stopped = True
        if self._thread is not None and self._thread.is_alive():
            self._q.put(_STOP)
            self._thread.join(timeout=5.0)
        self._thread = None

    def __enter__(self) -> "PostprocWorker":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
