"""Token-decode (LM) continuous-batching engine — the seed-era slot model.

Quarantined from ``serve/engine.py`` so that module is one coherent DETR
serving subsystem: this engine serves the LM-family archs behind the same
vLLM-style slot model (fixed decode batch over ring caches, requests
admitted into free slots via a batch-1 prefill scattered into the batch
cache, every step decodes all active slots one token). Still used by
``repro.launch.serve`` and ``examples/lm_serve.py``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.registry import get_api
from repro.serve.postproc import StarvationError


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S_prompt,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    cache_len: int = 256
    greedy: bool = True
    temperature: float = 1.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, serve_cfg: ServeConfig,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.api = get_api(cfg)
        self.params = params
        self.scfg = serve_cfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        b = serve_cfg.max_batch
        self.cache = self.api.init_cache(cfg, b, serve_cfg.cache_len)
        self.pos = jnp.zeros((b,), jnp.int32)
        self.last_tok = jnp.zeros((b,), jnp.int32)
        self.active = np.zeros((b,), bool)
        self.slot_req: list[Optional[Request]] = [None] * b
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._decode = jax.jit(self._decode_impl)
        self._prefill1 = jax.jit(self._prefill1_impl)

    # --- jitted internals --------------------------------------------------
    def _prefill1_impl(self, params, cache1, tokens1):
        logits, cache1 = self.api.prefill(params, self.cfg, cache1,
                                          {"tokens": tokens1})
        return logits, cache1

    def _decode_impl(self, params, cache, tokens, pos):
        return self.api.decode_step(params, self.cfg, cache, tokens, pos)

    # --- slot management ----------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, slot: int, req: Request):
        cfg, scfg = self.cfg, self.scfg
        cache1 = self.api.init_cache(cfg, 1, scfg.cache_len)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill1(self.params, cache1, toks)
        # scatter the single-request cache into batch slot `slot`
        # (every stacked cache leaf is (n_layers, B, ...): dim 1 is batch)
        self.cache = jax.tree.map(
            lambda c, c1: c.at[:, slot].set(c1[:, 0]), self.cache, cache1)
        first = int(jnp.argmax(logits, axis=-1)[0]) if scfg.greedy \
            else self._sample(logits)[0]
        req.output.append(first)
        self.last_tok = self.last_tok.at[slot].set(first)
        self.pos = self.pos.at[slot].set(len(req.prompt))
        self.active[slot] = True
        self.slot_req[slot] = req

    def _sample(self, logits):
        self.rng, k = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(
            k, logits / self.scfg.temperature, axis=-1))

    # --- one engine step ----------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests into free slots, then decode one token for
        every active slot. Returns number of active slots."""
        for slot in range(self.scfg.max_batch):
            if not self.active[slot] and self.queue:
                self._admit(slot, self.queue.popleft())
        if not self.active.any():
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tok, self.pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32) if self.scfg.greedy \
            else jnp.asarray(self._sample(logits), jnp.int32)
        self.pos = self.pos + jnp.asarray(self.active, jnp.int32)
        self.last_tok = jnp.where(jnp.asarray(self.active), nxt, self.last_tok)
        nxt_np = np.asarray(nxt)
        for slot in range(self.scfg.max_batch):
            req = self.slot_req[slot]
            if req is None or not self.active[slot]:
                continue
            tok = int(nxt_np[slot])
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.active[slot] = False
                self.slot_req[slot] = None
        return int(self.active.sum())

    def run_until_drained(self, max_steps: int = 10000) -> list[Request]:
        for step in range(max_steps):
            self.step()
            if not self.queue and not self.active.any():
                return self.finished
        raise StarvationError({
            "engine": "ServeEngine", "steps": max_steps,
            "queued": len(self.queue), "active": int(self.active.sum()),
            "finished": len(self.finished)})
