"""End-to-end driver: train the toy deformable detector (conv backbone +
MSDeformAttn encoder + detection head) on synthetic rectangle detection,
then compare AP of the exact model vs the DEFA-pruned model.

  PYTHONPATH=src python examples/detr_train.py --steps 80
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.detr_toy import (
    eval_ap, toy_config, train_toy_detector, with_attn)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--force", action="store_true", help="retrain")
    args = ap.parse_args()

    cfg, params = train_toy_detector(steps=args.steps, force=args.force)
    ap_base = eval_ap(cfg, params)
    print(f"\nAP (exact MSDeformAttn):      {ap_base:.4f}")

    defa = with_attn(cfg, pap_mode="threshold", pap_threshold=0.02,
                     fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6,
                     range_narrow=(8.0, 6.0, 4.0, 3.0),
                     act_bits=12, weight_bits=12)
    ap_defa = eval_ap(defa, params)
    print(f"AP (DEFA: FWP+PAP+RN+INT12):  {ap_defa:.4f}  "
          f"(delta {ap_defa - ap_base:+.4f}; paper's COCO deltas sum to ~-1.4 "
          f"AP before finetuning recovery)")


if __name__ == "__main__":
    main()
