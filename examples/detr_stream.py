"""Streaming-video detection driver: temporal value-cache reuse.

N concurrent synthetic video sessions stream drifting-scene encoder
memories through the :class:`~repro.serve.engine.StreamingDetrEngine`:
each session holds a PERSISTENT, incrementally updated
``MSDAValueCache`` — per frame only the tiles the moving object dirtied
are re-projected and re-staged (scattered through the existing pix2slot
geometry), the FWP keep decision rides a streaming EMA with keep-mask
hysteresis, and the decoder + heads run one batched jitted forward
against the shared cache.

  PYTHONPATH=src python examples/detr_stream.py --frames 4 --dry-run
  PYTHONPATH=src python examples/detr_stream.py --frames 32 --sessions 2
"""
import argparse
import time

import jax
import numpy as np

from repro import msda
from repro.core import nn
from repro.core.msdeform_attn import MSDeformAttnConfig, init_msdeform_attn
from repro.serve.engine import StreamingDetrEngine
from repro.stream import StreamConfig, drifting_scene

DRY_LEVELS = ((16, 20), (8, 10), (4, 5), (2, 3))
FULL_LEVELS = ((32, 40), (16, 20), (8, 10), (4, 5))


def build_engine(args):
    levels = DRY_LEVELS if args.dry_run else FULL_LEVELS
    d = 64 if args.dry_run else 128
    attn_cfg = MSDeformAttnConfig(
        d_model=d, n_heads=4, fwp_mode="compact", fwp_k=1.0,
        fwp_capacity=0.6, range_narrow=(8.0, 6.0, 4.0, 3.0))
    dec_cfg = msda.MSDADecoderConfig(
        n_layers=3 if args.dry_run else 6,
        n_queries=32 if args.dry_run else 100,
        d_ffn=2 * d)
    key = jax.random.PRNGKey(7)
    params = {
        "decoder": msda.init_decoder(key, dec_cfg, attn_cfg),
        "cls_head": nn.linear_init(jax.random.fold_in(key, 1), d, 5),
        "box_head": nn.linear_init(jax.random.fold_in(key, 2), d, 4),
    }
    scfg = StreamConfig(tile_rows=args.tile_rows,
                        delta_threshold=args.threshold,
                        update_frac=args.update_frac,
                        diff_channel_stride=args.diff_stride)
    engine = StreamingDetrEngine(attn_cfg, dec_cfg, params, levels,
                                 max_sessions=args.sessions,
                                 backend=args.backend, stream_cfg=scfg)
    return engine, levels, d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--sessions", type=int, default=2)
    ap.add_argument("--backend", default=None,
                    choices=msda.available_backends() + ["auto"])
    ap.add_argument("--tile-rows", type=int, default=1)
    ap.add_argument("--threshold", type=float, default=1e-4)
    ap.add_argument("--update-frac", type=float, default=0.3)
    ap.add_argument("--diff-stride", type=int, default=4,
                    help="probe every s-th feature channel when diffing "
                         "tiles (1 = exact)")
    ap.add_argument("--churn", action="store_true",
                    help="mid-stream session churn: one session leaves and "
                         "a new one joins halfway — its slot is rebuilt "
                         "from its own first frame (per-slot admission) "
                         "while the others stay incremental")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes / few layers (the CI smoke path)")
    args = ap.parse_args()

    engine, levels, d = build_engine(args)
    print(f"[stream] {engine.describe()}")
    if args.dry_run:
        # sessions-per-chip at the default 4 MB staging budget: the
        # serving consequence of the int8 table (one line per dtype)
        cap = engine.capacity_estimate()
        print(f"[stream] capacity @ {cap['budget_bytes'] // 1024} KB budget "
              f"({cap['rows_per_session']} rows/session, "
              f"active dtype {cap['table_dtype']}):")
        for dt_name, row in cap["per_dtype"].items():
            print(f"[stream]   {dt_name:8s} "
                  f"{row['bytes_per_session'] / 1024:7.1f} KB/session -> "
                  f"{row['sessions']} sessions/chip")

    sids = [engine.open_session() for _ in range(args.sessions)]
    scenes = {sid: drifting_scene(100 + i, levels, d, args.frames,
                                  obj_rows=1, speed_rows=1)
              for i, sid in enumerate(sids)}
    # warm compile: first frame of every session (a rebuild frame anyway)
    for sid in sids:
        engine.submit_frame(sid, scenes[sid][0][0])
    engine.step()

    churn_at = args.frames // 2 \
        if args.churn and args.sessions > 1 and args.frames > 2 else None
    left = []
    t0 = time.perf_counter()
    for t in range(1, args.frames):
        if t == churn_at:
            old = sids.pop()
            left.append(engine.close_session(old))
            new = engine.open_session()
            sids.append(new)
            scenes[new] = drifting_scene(200 + new, levels, d, args.frames,
                                         obj_rows=1, speed_rows=1)
            print(f"[stream] churn: session {old} left after "
                  f"{left[-1].frames_done} frames, session {new} joined — "
                  "per-slot admission, neighbours stay incremental")
        for sid in sids:
            engine.submit_frame(sid, scenes[sid][t][0])
        engine.step()
        st = engine.mgr.last_stats
        print(f"frame {t}: {st['mode']:11s} "
              f"staged {st['staged_bytes']/1024:6.1f} KB "
              f"(rebuild would stage {st['rebuild_bytes']/1024:6.1f} KB), "
              f"dirty slots {st['n_dirty']}/{st['update_rows']}, "
              f"tiles {st['tiles_changed']}"
              + (f" [{st['reason']}]" if st["reason"] else "")
              + (f" [admitted slots {st['admitted_slots']}]"
                 if st.get("admitted_slots") else ""))
    dt = time.perf_counter() - t0

    r = engine.report()
    served = (args.frames - 1) * args.sessions
    print(f"\n[stream] {args.frames} frames x {args.sessions} sessions: "
          f"{served} timed frames in {dt:.2f}s = "
          f"{served/max(dt, 1e-9):.2f} frames/s (CPU)")
    print(f"[stream] staged bytes: rebuild-per-frame "
          f"{r['rebuild_bytes_total']/1024:.0f} KB vs incremental "
          f"{r['staged_bytes_total']/1024:.0f} KB = "
          f"{r['bytes_ratio']:.2f}x fewer "
          f"({r['incremental_frames']}/{r['frames']} frames incremental, "
          f"update cap {r['update_rows']}/{r['n_slots']} rows)")
    for sid in sids:
        sess = engine.close_session(sid)
        boxes = np.stack([f["boxes"] for f in sess.results])
        print(f"[stream] session {sid}: {len(sess.results)} frames, "
              f"mean box {np.mean(boxes, axis=(0, 1)).round(3)}")


if __name__ == "__main__":
    main()
