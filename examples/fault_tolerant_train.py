"""Fault-tolerant LM training demo: checkpoint/restart across an injected
node failure, with bitwise-identical convergence to an uninterrupted run.

  PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.tokens import TokenDataConfig, synth_token_batch
from repro.optim.adamw import OptConfig
from repro.train.loop import (
    FailureInjector, SimulatedNodeFailure, TrainLoopConfig, train_loop)
from repro.train.step import build_train_step, make_train_state


def main():
    cfg = get_smoke_config("deepseek-7b")
    data = TokenDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=8, seed=1)
    opt = OptConfig(lr=3e-3, warmup_steps=3, total_steps=24)
    loop_cfg = TrainLoopConfig(total_steps=24, ckpt_every=8, log_every=4)
    step_fn = jax.jit(build_train_step(cfg, opt))
    batch_fn = lambda s: synth_token_batch(data, s)

    ckpt_dir = tempfile.mkdtemp(prefix="ft_demo_")
    try:
        print("=== run A: crash injected at step 13 ===")
        state = make_train_state(jax.random.PRNGKey(0), cfg)
        try:
            train_loop(state, step_fn, batch_fn, loop_cfg, ckpt_dir=ckpt_dir,
                       injector=FailureInjector(fail_at_step=13))
        except SimulatedNodeFailure as e:
            print(f"!! {e} — node lost, restarting from checkpoint")

        print("=== run A': restart (fresh process state + checkpoint) ===")
        state2 = make_train_state(jax.random.PRNGKey(0), cfg)
        state2, stats2 = train_loop(state2, step_fn, batch_fn, loop_cfg,
                                    ckpt_dir=ckpt_dir)

        print("=== run B: uninterrupted reference ===")
        ref = make_train_state(jax.random.PRNGKey(0), cfg)
        ref, stats_ref = train_loop(ref, step_fn, batch_fn, loop_cfg,
                                    ckpt_dir=None)

        deltas = [float(np.abs(np.asarray(a, np.float32)
                               - np.asarray(b, np.float32)).max())
                  for a, b in zip(jax.tree.leaves(state2.params),
                                  jax.tree.leaves(ref.params))]
        print(f"\nmax param delta (restarted vs uninterrupted): {max(deltas):.2e}")
        assert max(deltas) < 1e-5, "restart must be deterministic!"
        print("crash -> restart -> IDENTICAL final params  [OK]")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
