"""End-to-end driver: batched DETR serving with DEFA (the paper's
deployment scenario — MSDeformAttn inference acceleration).

Streams batches of synthetic images through the conv backbone + deformable
encoder (+ optional DETR-style decoder) with the DEFA stack enabled, and
reports throughput and the realized pruning ratios per batch.

  PYTHONPATH=src python examples/detr_serve.py --batches 4 --batch 8
  PYTHONPATH=src python examples/detr_serve.py --decoder   # N_q learned
      queries cross-attend a ONE-build shared ValueCache through the
      DetrServeEngine micro-batcher (build-once, sample-everywhere)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
import time

import jax
import numpy as np

from benchmarks.detr_toy import (toy_config, train_toy_decoder_detector,
                                 train_toy_detector, with_attn)
from repro.core.detector import detector_apply
from repro.data.detection import eval_detection_ap, synth_detection_batch
from repro.msda import available_backends, make_plan
from repro.serve.engine import DetrRequest, DetrServeEngine

DEFA_KW = dict(pap_mode="topk", pap_keep=6,
               fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6,
               range_narrow=(8.0, 6.0, 4.0, 3.0),
               act_bits=12, weight_bits=12)


def serve_encoder_head(args) -> None:
    cfg, params = train_toy_detector()
    serve_cfg = with_attn(cfg, **DEFA_KW)

    plan = make_plan(serve_cfg.encoder.attn, serve_cfg.level_shapes,
                     backend=args.backend)
    print(f"[serve] {plan.describe()}")

    fwd = jax.jit(lambda p, img: detector_apply(p, serve_cfg, img,
                                                collect_stats=True,
                                                backend=args.backend))
    key = jax.random.PRNGKey(42)
    img, _, _, gt = synth_detection_batch(key, args.batch, cfg.img_size,
                                          cfg.level_shapes)
    jax.block_until_ready(fwd(params, img))          # warm compile

    total = 0
    t0 = time.perf_counter()
    aps = []
    for i in range(args.batches):
        img, _, _, gt = synth_detection_batch(
            jax.random.fold_in(key, i), args.batch, cfg.img_size,
            cfg.level_shapes)
        cls, box, aux = fwd(params, img)
        jax.block_until_ready(cls)
        total += args.batch
        aps.append(eval_detection_ap(cls, box, gt))
        keep = [float(b["pap_keep_frac"]) for b in aux["blocks"]]
        fwp = [float(b["fwp_keep_frac"]) for b in aux["blocks"][:-1]]
        print(f"batch {i}: PAP kept {np.mean(keep):.1%} of sampling points, "
              f"FWP kept {np.mean(fwp):.1%} of pixels, AP={aps[-1]:.3f}")
    dt = time.perf_counter() - t0
    print(f"\n[serve] {total} images in {dt:.2f}s = {total/dt:.2f} img/s "
          f"(CPU; TPU projection comes from the dry-run roofline), "
          f"mean AP {np.mean(aps):.3f}")


def serve_decoder_head(args) -> None:
    """Decoder-head serving through the DetrServeEngine micro-batcher:
    the value table is projected + FWP-compacted ONCE per forward and all
    decoder layers sample the shared cache."""
    cfg, params = train_toy_decoder_detector()
    serve_cfg = with_attn(cfg, **DEFA_KW)

    engine = DetrServeEngine(serve_cfg, params, max_batch=args.batch,
                             backend=args.backend)
    print(f"[serve/decoder] {engine.describe()}")

    key = jax.random.PRNGKey(42)
    rid = 0
    gts = []
    for i in range(args.batches):
        img, _, _, gt = synth_detection_batch(
            jax.random.fold_in(key, i), args.batch, cfg.img_size,
            cfg.level_shapes)
        gts.append(gt)
        for b in range(args.batch):
            engine.submit(DetrRequest(rid=rid, image=np.asarray(img[b])))
            rid += 1
    engine.step()                                    # warm compile
    t0 = time.perf_counter()
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0

    # per-batch AP from the completed requests (submit order == rid order;
    # eval_detection_ap softmaxes its logits input, so feed log(probs))
    by_rid = {r.rid: r for r in done}
    aps = []
    for i, gt in enumerate(gts):
        reqs = [by_rid[i * args.batch + b] for b in range(args.batch)]
        logp = np.log(np.clip(np.stack([r.cls_probs for r in reqs]),
                              1e-9, None))
        aps.append(eval_detection_ap(logp,
                                     np.stack([r.boxes for r in reqs]), gt))
    timed = len(done) - args.batch
    print(f"[serve/decoder] {len(done)} requests ({timed} timed) in "
          f"{dt:.2f}s = {timed/max(dt, 1e-9):.2f} img/s (CPU), "
          f"mean AP {np.mean(aps):.3f}")


def serve_sustained(args) -> None:
    """Sustained mixed-resolution load through the bucketed engine:
    AOT shape buckets + continuous batching + pipelined post-processing
    vs the single-bucket synchronous baseline (benchmarks/serve_sustained).
    ``--dry-run`` routes a few mixed requests through every bucket and
    checks the zero-recompile contract without timing anything."""
    import json

    from benchmarks.serve_sustained import report
    r = report(dry=args.dry_run, prom_path=args.obs_prom)
    print("[serve/sustained] buckets: "
          + ", ".join(f"{b['resolution']}px ({b['table_kb']}KB table)"
                      for b in r["buckets"]))
    if args.dry_run:
        print("[serve/sustained] dry run ok "
              f"({r['compiles']['sustained']} AOT compiles, 0 retraces)")
        return
    cl, ol = r["closed_loop"], r["open_loop"]
    print(f"[serve/sustained] closed loop: "
          f"{cl['sustained_us_per_request']:.0f} us/req vs "
          f"{cl['single_bucket_sync_us_per_request']:.0f} us/req "
          f"single-bucket sync = {cl['speedup']:.2f}x")
    print(f"[serve/sustained] open loop @0.9x capacity: "
          f"{ol['rps_per_chip']} req/s/chip, "
          f"P50 {ol['p50_ms']} ms / P99 {ol['p99_ms']} ms")
    print(json.dumps(r, indent=2, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    choices=available_backends() + ["auto"],
                    help="MSDA backend override (default: plan from config)")
    ap.add_argument("--decoder", action="store_true",
                    help="serve the decoder-head detector (shared "
                         "ValueCache, build-once sample-everywhere)")
    ap.add_argument("--sustained", action="store_true",
                    help="sustained mixed-resolution load: AOT buckets + "
                         "continuous batching + pipelined postproc vs the "
                         "single-bucket synchronous baseline")
    ap.add_argument("--dry-run", action="store_true",
                    help="with --sustained: route a small mixed load, "
                         "check zero recompiles, skip timing (CI smoke)")
    ap.add_argument("--obs-prom", default=None, metavar="PATH",
                    help="with --sustained: write the engine's metrics "
                         "registry in Prometheus text format to PATH "
                         "(JSONL trace export is driven by the "
                         "REPRO_OBS_JSONL env var)")
    args = ap.parse_args()
    if args.sustained:
        serve_sustained(args)
    elif args.decoder:
        serve_decoder_head(args)
    else:
        serve_encoder_head(args)


if __name__ == "__main__":
    main()
