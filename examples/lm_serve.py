"""Continuous-batching LM serving demo (vLLM-style slots over ring caches).

Serves a smoke-scale arch from the assigned pool with mixed prompt lengths;
shows requests entering/leaving slots while decode proceeds.

  PYTHONPATH=src python examples/lm_serve.py --arch granite-20b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.registry import get_api
from repro.serve.lm import Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, ServeConfig(max_batch=4, cache_len=96))

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        engine.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                         plen),
                              max_new_tokens=int(rng.integers(8, 24))))
    t0 = time.perf_counter()
    steps = 0
    while engine.queue or engine.active.any() or steps == 0:
        n_active = engine.step()
        steps += 1
        if steps % 8 == 0:
            print(f"step {steps}: {n_active} active slots, "
                  f"{len(engine.queue)} queued, {len(engine.finished)} done")
        if steps > 500:
            break
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in engine.finished)
    print(f"\n[lm-serve] {len(engine.finished)}/{args.requests} requests, "
          f"{toks} tokens, {steps} engine steps, {toks/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
