"""Quickstart: MSDeformAttn + the DEFA optimization stack in 60 lines.

Builds the paper's operator, runs the exact oracle and the DEFA-optimized
path (PAP top-k + FWP compaction + range-narrowing + INT12), validates the
fused Pallas kernel against both, and prints the measured sparsity.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.msdeform_attn import (
    MSDeformAttnConfig, init_msdeform_attn, msdeform_attn_apply,
    msdeform_attn_ref)

LEVELS = ((32, 40), (16, 20), (8, 10), (4, 5))
N_IN = sum(h * w for h, w in LEVELS)
B, NQ, D = 2, 256, 128

key = jax.random.PRNGKey(0)
cfg = MSDeformAttnConfig(d_model=D, n_heads=8)
params = init_msdeform_attn(key, cfg)
k1, k2, k3 = jax.random.split(key, 3)
query = jax.random.normal(k1, (B, NQ, D))
fmaps = jax.random.normal(k2, (B, N_IN, D))
refs = jax.random.uniform(k3, (B, NQ, 2))

# 1. exact oracle --------------------------------------------------------
out_exact = msdeform_attn_ref(params, cfg, query, refs, fmaps, LEVELS)
print(f"exact MSDeformAttn: out {out_exact.shape}")

# 2. DEFA stack (jnp execution) -----------------------------------------
defa = MSDeformAttnConfig(
    d_model=D, n_heads=8,
    pap_mode="topk", pap_keep=6,               # keep 6 of 16 points
    fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6,
    range_narrow=(16.0, 12.0, 8.0, 4.0),
    act_bits=12, weight_bits=12)
# block k produces the fmap mask for block k+1: chain two calls
_, aux = msdeform_attn_apply(params, defa, query, refs, fmaps, LEVELS,
                             collect_stats=True)
out_defa, aux2 = msdeform_attn_apply(params, defa, query, refs, fmaps, LEVELS,
                                     fwp_state=aux["fwp_state"],
                                     collect_stats=True)
err = float(jnp.mean(jnp.abs(out_defa - out_exact)))
print(f"DEFA (PAP 6/16 + FWP 60% + RN + INT12): mean |delta| = {err:.4f}")
print(f"  points kept: {float(aux2['pap_keep_frac']):.2%}  "
      f"pixels kept: {float(aux2['fwp_keep_frac']):.2%}")

# 3. fused Pallas kernel (interpret mode on CPU) -------------------------
defa_pallas = MSDeformAttnConfig(
    d_model=D, n_heads=8, pap_mode="topk", pap_keep=6,
    fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6,
    range_narrow=(16.0, 12.0, 8.0, 4.0), act_bits=12, weight_bits=12,
    impl="pallas")
out_kernel, _ = msdeform_attn_apply(params, defa_pallas, query, refs, fmaps,
                                    LEVELS, fwp_state=aux["fwp_state"])
np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_defa),
                           rtol=1e-4, atol=1e-4)
print("fused MSGS+aggregation Pallas kernel == jnp path  [OK]")
