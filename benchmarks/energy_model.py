"""Fig. 7b / Fig. 8 reproduction: byte-accounting energy model of MSGS.

Counts DRAM and SRAM traffic for the 2x2 design grid {operator fusion (C6)
off/on} x {fmap reuse (C7) off/on} by replaying the sampling-address
structure of a DETR-geometry encoder layer, then reports each feature's
saving measured the way the paper does — against the system with the OTHER
feature already enabled:

  fusion saving = 1 - E(fusion on, reuse on) / E(fusion off, reuse on)
  reuse  saving = 1 - E(fusion on, reuse on) / E(fusion on, reuse off)

Traffic components per query (reference point):
  * range fetch: without reuse every reference point DMAs its level-wise
    bounded range (2R+1)^2 from DRAM into SRAM; with reuse only the newly
    uncovered column (raster scan, paper Fig. 4) is fetched.
  * BI corner reads: 4 SRAM reads per sampling point per head.
  * sampled values: without fusion each bilinear result (H x L x P per
    query) is written to DRAM and read back for aggregation (plus SRAM
    staging); with fusion it never leaves the PE array.

Energy constants: HBM2 1.2 pJ/bit (paper [17]); SRAM read/write 0.06/0.08
pJ/bit (CACTI-class 40nm, the paper's own tool)."""
from __future__ import annotations

import numpy as np

DRAM_PJ_PER_BIT = 1.2
SRAM_R_PJ_PER_BIT = 0.06
SRAM_W_PJ_PER_BIT = 0.08
D_MODEL = 256
D_HEAD = 32
PIXEL_BYTES = D_MODEL * 1.5            # full value vector @ INT12 (all heads)
HEAD_SLICE_BYTES = D_HEAD * 1.5        # one head's slice @ INT12
BITS = 8.0


def model_energy(level_shapes=((100, 167), (50, 84), (25, 42), (13, 21)),
                 n_points: int = 4, n_heads: int = 8,
                 ranges=(16, 12, 8, 4)) -> dict:
    range_bytes_norange = 0.0
    range_bytes_reuse = 0.0
    for li, (h, w) in enumerate(level_shapes):
        r = ranges[li]
        side = 2 * r + 1
        n_ref = h * w
        # the bounded range moves the FULL pixel vector once (all heads)
        range_bytes_norange += n_ref * side * side * PIXEL_BYTES
        range_bytes_reuse += (side * side + (n_ref - 1) * side) * PIXEL_BYTES
    # every query samples n_points in EVERY level (multi-scale):
    n_queries = sum(h * w for h, w in level_shapes)
    n_levels = len(level_shapes)
    n_samples = n_queries * n_heads * n_levels * n_points
    corner_read_bytes = n_samples * 4 * HEAD_SLICE_BYTES
    sampled_bytes = n_samples * HEAD_SLICE_BYTES

    def dram(b): return b * BITS * DRAM_PJ_PER_BIT
    def sram_r(b): return b * BITS * SRAM_R_PJ_PER_BIT
    def sram_w(b): return b * BITS * SRAM_W_PJ_PER_BIT

    def energy(fusion: bool, reuse: bool):
        rng_bytes = range_bytes_reuse if reuse else range_bytes_norange
        d = dram(rng_bytes)                       # fmap DRAM fetch
        s = sram_w(rng_bytes) + sram_r(corner_read_bytes)
        if not fusion:                            # sampled-value round trip
            d += 2 * dram(sampled_bytes)
            s += sram_w(sampled_bytes) + sram_r(sampled_bytes)
        return {"dram": d, "sram": s, "total": d + s}

    e00 = energy(fusion=False, reuse=False)
    e01 = energy(fusion=False, reuse=True)
    e10 = energy(fusion=True, reuse=False)
    e11 = energy(fusion=True, reuse=True)

    return {
        "energy_grid_uJ": {"none": e00["total"] / 1e6,
                           "reuse": e01["total"] / 1e6,
                           "fusion": e10["total"] / 1e6,
                           "fusion+reuse": e11["total"] / 1e6},
        # paper-style attribution (vs the system with the other feature on)
        "dram_saving_fusion_pct": 100 * (e01["dram"] - e11["dram"]) / e01["total"],
        "sram_saving_fusion_pct": 100 * (e01["sram"] - e11["sram"]) / e01["total"],
        "dram_saving_reuse_pct": 100 * (e10["dram"] - e11["dram"]) / e10["total"],
        "sram_saving_reuse_pct": 100 * (e10["sram"] - e11["sram"]) / e10["total"],
        "paper_dram_fusion_pct": 73.3, "paper_sram_fusion_pct": 15.9,
        "paper_dram_reuse_pct": 88.2, "paper_sram_reuse_pct": 22.7,
        "total_saving_pct": 100 * (1 - e11["total"] / e00["total"]),
    }


if __name__ == "__main__":
    for k, v in model_energy().items():
        print(k, v)
