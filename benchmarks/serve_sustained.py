"""Sustained-serving benchmark: bucketed continuous batching + pipelined
post-processing vs the step-synchronous single-bucket baseline.

Workload: an open-loop, many-session synthetic load with MIXED
resolutions (3:1 small:large). The baseline serves it the only way a
single-shape engine can — every image padded up to the largest
resolution, post-processing synchronous with the device loop. The
sustained engine routes each request to the smallest AOT bucket it fits
(the small majority runs the ~4x-cheaper small forward) and decodes
outputs on a worker thread while the device runs the next micro-batch.

Two measurements:
  * **closed loop** (the CI-gated ``msda_serve_*`` micro rows): drain a
    fixed mixed workload flat-out, report us/request (median of 3).
  * **open loop** (the latency story): arrivals paced at 0.9x the
    measured closed-loop throughput; requests/sec/chip and P50/P99
    request latency (submit -> postproc done) over the run.

CPU numbers (jnp_gather backend) — structural, like every micro row:
the tracked quantity is the sustained/baseline ratio, not wall time."""
from __future__ import annotations

import time

import jax
import numpy as np

RESOLUTIONS = (32, 64)        # the serve buckets, smallest to largest
MIX = (3, 1)                  # requests per cycle at (small, large)
N_REQUESTS = 16
MAX_BATCH = 4


def _setup():
    from repro import msda
    from repro.core.detector import DetectorConfig, init_detector
    from repro.core.encoder import EncoderConfig
    from repro.core.msdeform_attn import MSDeformAttnConfig
    attn = MSDeformAttnConfig(d_model=32, n_heads=4, n_levels=4, n_points=2,
                              fwp_mode="compact", fwp_k=1.0,
                              fwp_capacity=0.6,
                              range_narrow=(8.0, 6.0, 4.0, 3.0))
    cfg = DetectorConfig(
        encoder=EncoderConfig(attn=attn, n_blocks=1, d_ffn=64),
        img_size=max(RESOLUTIONS), n_classes=4, backbone_width=8,
        decoder=msda.MSDADecoderConfig(n_layers=2, n_queries=16, d_ffn=64))
    return cfg, init_detector(jax.random.PRNGKey(0), cfg)


def _engines(cfg, params):
    from repro.serve.engine import DetrServeEngine
    sustained = DetrServeEngine(cfg, params, max_batch=MAX_BATCH,
                                backend="jnp_gather",
                                resolutions=RESOLUTIONS,
                                pipeline_postproc=True)
    baseline = DetrServeEngine(cfg, params, max_batch=MAX_BATCH,
                               backend="jnp_gather",
                               resolutions=(max(RESOLUTIONS),),
                               pipeline_postproc=False)
    return sustained, baseline


def _workload(n):
    rng = np.random.default_rng(11)
    cycle = [RESOLUTIONS[0]] * MIX[0] + [RESOLUTIONS[1]] * MIX[1]
    return [rng.standard_normal((3, r, r)).astype(np.float32)
            for r in (cycle[i % len(cycle)] for i in range(n))]


def _drain(engine, images) -> float:
    """Closed loop: submit everything, drain flat-out; seconds elapsed."""
    from repro.serve.engine import DetrRequest
    engine.finished.clear()
    t0 = time.perf_counter()
    for i, im in enumerate(images):
        assert engine.submit(DetrRequest(rid=i, image=im))
    engine.run_until_drained()
    return time.perf_counter() - t0


def _closed_loop_us(engine, images, iters: int = 3) -> float:
    _drain(engine, images)                       # warm (AOT already compiled)
    ts = [_drain(engine, images) for _ in range(iters)]
    return float(np.median(ts)) / len(images) * 1e6


def _open_loop(engine, images, rps: float) -> dict:
    """Arrivals paced at ``rps``; P50/P99 latency = submit -> postproc."""
    from repro.serve.engine import DetrRequest
    engine.finished.clear()
    reqs = [DetrRequest(rid=i, image=im) for i, im in enumerate(images)]
    interval = 1.0 / rps
    start = time.perf_counter()
    nxt = 0
    while nxt < len(reqs) or engine.pending():
        now = time.perf_counter()
        while nxt < len(reqs) and start + nxt * interval <= now:
            engine.submit(reqs[nxt])
            nxt += 1
        if engine.pending():
            engine.step()
        elif nxt < len(reqs):
            time.sleep(max(0.0, min(1e-3, start + nxt * interval - now)))
    engine.drain()
    elapsed = time.perf_counter() - start
    lat_ms = np.asarray(sorted((r.t_done - r.t_submit) * 1e3
                               for r in engine.finished))
    chips = max(1, jax.device_count())
    return {
        "offered_rps": round(rps, 2),
        "completed": len(engine.finished),
        "rps": round(len(engine.finished) / elapsed, 2),
        "rps_per_chip": round(len(engine.finished) / elapsed / chips, 2),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 2),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 2),
    }


def _obs_overhead(engine_us: float, n: int = 2000) -> dict:
    """Deterministic per-request instrumentation cost.

    A wall-clock A/B of two short engine runs is dominated by device and
    scheduler noise, so the tracked figure is the measured cost of the
    per-request instrumentation calls themselves (the counter bumps,
    gauge sets, histogram observes, and span start/ends a request incurs
    on the serve path), expressed as a fraction of the measured
    us/request."""
    from repro.obs import Observability
    obs = Observability.create()
    c = obs.metrics.counter("bench_requests_total", "bench")
    g = obs.metrics.gauge("bench_queue_depth", "bench")
    h = obs.metrics.histogram("bench_latency_seconds", "bench")
    t0 = time.perf_counter()
    for i in range(n):
        c.inc(bucket="32", outcome="completed")
        g.set(1.0, bucket="32")
        for name in ("queue", "device", "postproc"):
            obs.tracer.end(obs.tracer.start(name, rid=i))
        h.observe(1e-3, bucket="32")
        h.observe(1e-3, span="device")
    per_req_us = (time.perf_counter() - t0) / n * 1e6
    obs.close()
    return {
        "instrumentation_us_per_request": round(per_req_us, 2),
        "fraction_of_request": round(per_req_us / engine_us, 4),
    }


def _write_prom(engine, path) -> None:
    """Dump the engine's registry in Prometheus text format (CI smoke)."""
    if path:
        from repro.obs import prometheus_text
        with open(path, "w") as f:
            f.write(prometheus_text(engine.obs.metrics))


def report(dry: bool = False, log=print,
           prom_path: str | None = None) -> dict:
    cfg, params = _setup()
    sustained, baseline = _engines(cfg, params)
    n = 2 * sum(MIX) if dry else N_REQUESTS
    images = _workload(n)
    out = {
        "workload": {"n_requests": n, "resolutions": list(RESOLUTIONS),
                     "mix": f"{MIX[0]}:{MIX[1]} small:large",
                     "max_batch": MAX_BATCH},
        "buckets": sustained.bucket_table(),
        "compiles": {"sustained": sustained.compile_count,
                     "baseline": baseline.compile_count},
    }
    if dry:
        for name, eng in (("sustained", sustained), ("baseline", baseline)):
            _drain(eng, images)
            assert len(eng.finished) == n
        out["dry_run"] = True
        # the zero-recompile contract still holds on the dry pass
        assert sustained.compile_count == len(sustained.buckets)
        log(f"[serve] dry run ok: {n} mixed requests through "
            f"{len(sustained.buckets)} buckets, "
            f"{sustained.compile_count} compiles")
        _write_prom(sustained, prom_path)
        sustained.close()
        baseline.close()
        return out
    sus_us = _closed_loop_us(sustained, images)
    base_us = _closed_loop_us(baseline, images)
    assert sustained.compile_count == len(sustained.buckets), \
        "sustained load recompiled after warmup"
    rps_closed = 1e6 / sus_us
    out["closed_loop"] = {
        "sustained_us_per_request": round(sus_us, 1),
        "single_bucket_sync_us_per_request": round(base_us, 1),
        "speedup": round(base_us / sus_us, 2),
    }
    # open loop in two passes: a probe offered at the closed-loop rate
    # finds the OPEN-loop capacity (paced arrivals mean shorter batches,
    # so it sits below the closed-loop rate), then the reported run backs
    # off to 0.9x that capacity — P50/P99 of a sustainable load, not of
    # an overload queue
    probe = _open_loop(sustained, images, 0.9 * rps_closed)
    out["open_loop"] = _open_loop(sustained, images, 0.9 * probe["rps"])
    out["open_loop"]["capacity_rps"] = probe["rps"]
    # per-span latency breakdown over everything the sustained engine
    # served (closed-loop reps + both open-loop passes)
    out["spans"] = sustained.obs.tracer.span_stats()
    # instrumented-vs-uninstrumented: wall delta of a closed-loop drain
    # on an engine with the Null obs stack, plus the deterministic
    # per-request instrumentation call cost (the gated <1% figure)
    from repro.obs import Observability
    from repro.serve.engine import DetrServeEngine
    dark = DetrServeEngine(cfg, params, max_batch=MAX_BATCH,
                           backend="jnp_gather", resolutions=RESOLUTIONS,
                           pipeline_postproc=True,
                           obs=Observability.disabled())
    dark_us = _closed_loop_us(dark, images)
    dark.close()
    out["observability"] = dict(_obs_overhead(sus_us),
                                uninstrumented_us_per_request=round(dark_us, 1),
                                wall_delta_pct=round(
                                    (sus_us - dark_us) / dark_us * 100, 2))
    span_line = ", ".join(
        f"{name} P50 {st['p50_ms']}ms/P99 {st['p99_ms']}ms"
        for name, st in sorted(out["spans"].items())
        if name in ("queue", "device", "postproc", "callback"))
    log(f"[serve] spans: {span_line}")
    log(f"[serve] obs overhead: "
        f"{out['observability']['instrumentation_us_per_request']} us/req "
        f"({100 * out['observability']['fraction_of_request']:.2f}% of "
        f"request)")
    log(f"[serve] sustained {sus_us:.0f} us/req vs single-bucket sync "
        f"{base_us:.0f} us/req ({base_us / sus_us:.2f}x); open loop "
        f"{out['open_loop']['rps_per_chip']} req/s/chip, "
        f"P50 {out['open_loop']['p50_ms']} ms / "
        f"P99 {out['open_loop']['p99_ms']} ms")
    _write_prom(sustained, prom_path)
    sustained.close()
    baseline.close()
    return out


def micro_rows(log=print) -> list:
    """The CI-gated rows: us/request through each serving mode."""
    cfg, params = _setup()
    sustained, baseline = _engines(cfg, params)
    images = _workload(N_REQUESTS)
    rows = [
        ("msda_serve_sustained", _closed_loop_us(sustained, images),
         f"{len(RESOLUTIONS)} AOT buckets + pipelined postproc, "
         f"{MIX[0]}:{MIX[1]} mixed load, us/request"),
        ("msda_serve_single_bucket_sync", _closed_loop_us(baseline, images),
         f"everything padded to {max(RESOLUTIONS)}px, synchronous "
         "postproc, us/request"),
    ]
    sustained.close()
    baseline.close()
    for name, t, d in rows:
        log(f"[serve] {name}: {t:.1f} us ({d})")
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(report(), indent=2))
