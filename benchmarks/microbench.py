"""CPU micro-benchmarks: wall-time per call for the kernel paths (interpret
mode — structural, NOT TPU performance) and the toy LM substrate. These
exist to track relative regressions and to populate the us_per_call CSV;
TPU performance claims live in the roofline analysis instead."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 7) -> float:
    fn(*args)                                   # compile/warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6           # us (median: load-spike-proof)


def _msda_backend_rows() -> list[tuple[str, float, str]]:
    """Planned end-to-end MSDA block through each registered backend."""
    from repro import msda
    from repro.core import nn
    from repro.core.msdeform_attn import MSDeformAttnConfig, init_msdeform_attn

    levels = ((16, 20), (8, 10), (4, 5), (2, 3))
    n_in = sum(h * w for h, w in levels)
    cfg = MSDeformAttnConfig(d_model=64, n_heads=4,
                             range_narrow=(6.0, 4.0, 3.0, 2.0))
    key = jax.random.PRNGKey(7)
    params = init_msdeform_attn(key, cfg)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, n_in, 64))
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, n_in, 64))
    refs = jnp.broadcast_to(
        nn.reference_points_for_levels(levels)[None], (1, n_in, 2))

    rows = []
    for name in msda.available_backends():
        if msda.backend_info(name).decode_only:
            continue       # decode-shaped backends get their own rows below
        plan = msda.make_plan(cfg, levels, backend=name, block_q=64)
        fn = jax.jit(lambda p_, q_, r_, x_, plan=plan:
                     msda.msda_attention(p_, plan, q_, r_, x_)[0])
        rows.append((f"msda_{name}", _time(lambda: fn(params, q, refs, x)),
                     f"planned block, lanes={plan.lane_layout}x{plan.head_pack}"))

    # FWP-compact windowed: the single-launch kernel samples the
    # compacted table directly (no densify).
    import dataclasses
    cfg_c = dataclasses.replace(cfg, fwp_mode="compact", fwp_k=1.0,
                                fwp_capacity=0.6)
    plan_j = msda.make_plan(cfg_c, levels, backend="jnp_gather", block_q=64)
    _, state = msda.msda_attention(params, plan_j, q, refs, x)
    plan = msda.make_plan(cfg_c, levels, backend="pallas_windowed",
                          block_q=64)
    fn = jax.jit(lambda p_, q_, r_, x_, plan=plan:
                 msda.msda_attention(p_, plan, q_, r_, x_, state=state)[0])
    rows.append(("msda_pallas_windowed_fwpcompact",
                 _time(lambda: fn(params, q, refs, x)),
                 "planned block, FWP-compact table"))
    # ordering on the raster-only windowed kernel: the plan carries the
    # policy but the attention pass gates the permutation off (the kernel
    # derives per-tile windows from raster query position) — the row
    # pins the identity path's cost at parity with the row above
    plan_wo = msda.make_plan(cfg_c, levels, backend="pallas_windowed",
                             block_q=64, query_order="raster")
    fn_wo = jax.jit(lambda p_, q_, r_, x_, plan=plan_wo:
                    msda.msda_attention(p_, plan, q_, r_, x_, state=state)[0])
    rows.append(("msda_windowed_ordered",
                 _time(lambda: fn_wo(params, q, refs, x)),
                 "query_order=raster on the raster-only windowed kernel "
                 "(gated: identity path)"))
    rows.extend(_decoder_rows(cfg_c, params, levels, x, state))
    rows.extend(_stream_rows(cfg_c))
    return rows


def _stream_rows(attn_cfg):
    """Streaming temporal-reuse rows: per-frame cache maintenance on the
    drifting-scene workload — a full per-frame rebuild (project + compact
    + stage the whole table) vs the incremental tile update (diff, then
    re-project/scatter at most ``update_rows`` slots). Both run the same
    ``TemporalCacheManager.step`` host path, so the diff/decision
    overhead is IN the incremental number. Runs at d_model=256 on a
    32x40 pyramid (NOT the tiny shape the other msda rows share): the
    incremental path trades a fixed diff/top_k overhead for a
    proportional projection saving, so a toy-width table would measure
    only the overhead. Even here wall time is roughly break-even on this
    CPU — the measured win is the staged-bytes delta in the derived
    column (and it widens with scale: at the paper's 100x167 geometry
    the incremental step measures ~2x faster, but that shape's wall time
    is too noisy for the 1.5x CI gate)."""
    import dataclasses

    import jax

    from repro import msda
    from repro.core.msdeform_attn import init_msdeform_attn
    from repro.stream import StreamConfig, TemporalCacheManager, drifting_scene

    levels = ((32, 40), (16, 20), (8, 10), (4, 5))
    attn_cfg = dataclasses.replace(attn_cfg, d_model=256, n_heads=8,
                                   range_narrow=(8.0, 6.0, 4.0, 3.0))
    attn_params = init_msdeform_attn(jax.random.PRNGKey(13), attn_cfg)
    plan = msda.make_plan(attn_cfg, levels, backend="jnp_gather",
                          n_queries=64, n_consumers=6)
    vparams = {k: attn_params[k] for k in ("value_w", "value_b")}
    scfg = StreamConfig(tile_rows=1, delta_threshold=1e-4, update_frac=0.3,
                        diff_channel_stride=4)
    frames = drifting_scene(5, levels, attn_cfg.d_model, 3)

    mgr_i = TemporalCacheManager(plan, vparams, scfg, batch=1)
    mgr_i.step(frames[0])
    mgr_i.step(frames[1])
    st = mgr_i.step(frames[2])[1]
    assert st["mode"] == "incremental", st   # the row must time the
    #   incremental path, not a silent budget fallback
    mgr_r = TemporalCacheManager(plan, vparams, scfg, batch=1)
    mgr_r.step(frames[0])
    plan8 = msda.make_plan(
        dataclasses.replace(attn_cfg, table_dtype="int8"), levels,
        backend="jnp_gather", n_queries=64, n_consumers=6)
    mgr_8 = TemporalCacheManager(plan8, vparams, scfg, batch=1)
    mgr_8.step(frames[0])
    mgr_8.step(frames[1])
    st8 = mgr_8.step(frames[2])[1]
    assert st8["mode"] == "incremental", st8
    u, n = mgr_i.update_rows, mgr_i.n_slots
    ikb = mgr_i._incr_bytes / 1024
    fkb = mgr_i._full_bytes / 1024
    ikb8 = mgr_8._incr_bytes / 1024
    return [
        ("msda_stream_incremental",
         _time(lambda: mgr_i.step(frames[2])[0].v),
         f"per-frame tile update: diff + reproject<={u}/{n} slots, "
         f"{ikb:.0f}KB staged vs {fkb:.0f}KB rebuild"),
        ("msda_stream_incremental_int8",
         _time(lambda: mgr_8.step(frames[2])[0].v),
         f"same tile update, int8 codes scattered under the frozen scale "
         f"({ikb:.0f}KB -> {ikb8:.0f}KB staged per frame)"),
        ("msda_stream_rebuild",
         _time(lambda: mgr_r.step(frames[2], force_full=True)[0].v),
         f"per-frame full rebuild: project + compact + stage {fkb:.0f}KB "
         "every frame"),
    ]


def _decoder_rows(attn_cfg, attn_params, levels, memory, state):
    """Decoder micro rows: 6 layers sampling ONE shared value cache vs the
    per-layer rebuild (project + compact + stage every layer) the
    monolithic flow would pay, plus the persistent decode kernel
    (table STAGED once per memory, all layers launch against it)."""
    from repro import msda

    import dataclasses

    dcfg = msda.MSDADecoderConfig(n_layers=6, n_queries=64, d_ffn=128)
    dparams = msda.init_decoder(jax.random.PRNGKey(21), dcfg, attn_cfg)
    plan = msda.make_plan(attn_cfg, levels, backend="jnp_gather",
                          n_queries=dcfg.n_queries,
                          n_consumers=dcfg.n_layers)
    plan_p = msda.make_plan(attn_cfg, levels, backend="pallas_decode",
                            n_queries=dcfg.n_queries,
                            n_consumers=dcfg.n_layers)
    plan_p8 = msda.make_plan(
        dataclasses.replace(attn_cfg, table_dtype="int8"), levels,
        backend="pallas_decode", n_queries=dcfg.n_queries,
        n_consumers=dcfg.n_layers)
    plan_po = msda.make_plan(attn_cfg, levels, backend="pallas_decode",
                             n_queries=dcfg.n_queries,
                             n_consumers=dcfg.n_layers,
                             query_order="raster")

    def cross_stack(p_, m_, per_layer_rebuild: bool, plan=plan):
        # identical 6-layer cross-attention stack; the ONLY difference is
        # where the value cache is built (once vs inside the layer loop)
        q = jnp.broadcast_to(p_["tgt_embed"][None],
                             (m_.shape[0],) + p_["tgt_embed"].shape)
        refs = jax.nn.sigmoid(q[..., :2])
        cache = None if per_layer_rebuild \
            else msda.build_value_cache(p_["value"], plan, m_, state)
        out = q
        for layer in p_["layers"]:
            # optimization_barrier keeps XLA from CSE-merging the per-layer
            # rebuilds back into one projection (which would silently turn
            # the baseline into the cached variant)
            c = msda.build_value_cache(
                p_["value"], plan, jax.lax.optimization_barrier(m_), state) \
                if per_layer_rebuild else cache
            o, _ = msda.msda_attention_cached(
                layer["cross"], plan, out, refs, c, update_fwp=False)
            out = out + o
        return out

    cached = jax.jit(lambda p_, m_: cross_stack(p_, m_, False))
    rebuild = jax.jit(lambda p_, m_: cross_stack(p_, m_, True))
    persistent = jax.jit(lambda p_, m_: cross_stack(p_, m_, False,
                                                    plan=plan_p))
    persistent8 = jax.jit(lambda p_, m_: cross_stack(p_, m_, False,
                                                     plan=plan_p8))
    ordered = jax.jit(lambda p_, m_: cross_stack(p_, m_, False,
                                                 plan=plan_po))
    full = jax.jit(lambda p_, m_: msda.decoder_apply(
        p_, dcfg, plan, m_, state)[0])
    kb8 = plan_p8.cache_table_bytes / 1024
    kb32 = plan_p.cache_table_bytes / 1024
    return [
        ("msda_decoder6_cached",
         _time(lambda: cached(dparams, memory)),
         "6 cross-attn layers, ONE shared ValueCache (build-once)"),
        ("msda_decoder6_persistent",
         _time(lambda: persistent(dparams, memory)),
         "6 cross-attn layers, pallas_decode vs the ONCE-staged table"),
        ("msda_decode6_persistent_int8",
         _time(lambda: persistent8(dparams, memory)),
         f"same, int8 table staged+sampled in-kernel ({kb32:.0f}KB "
         f"-> {kb8:.0f}KB staged)"),
        ("msda_decode6_ordered",
         _time(lambda: ordered(dparams, memory)),
         "same persistent stack, queries raster-ordered by reference "
         "point per layer (permute + sample + invert, bit-identical)"),
        ("msda_decoder6_rebuild",
         _time(lambda: rebuild(dparams, memory)),
         "6 cross-attn layers rebuilding the value table per layer"),
        ("msda_decoder6_full",
         _time(lambda: full(dparams, memory)),
         "full decoder (self-attn+cross+ffn+refine), shared cache"),
    ] + _decode_launch_rows(attn_cfg, levels, memory, state, plan_p, dparams)


def _decode_launch_rows(attn_cfg, levels, memory, state, plan_p, dparams):
    """Stacked-vs-per-layer launch comparison on IDENTICAL precomputed
    sampling points: 6 single-layer persistent launches vs ONE stacked
    launch whose grid keeps the staged table resident across the whole
    (query-tile x layer) sweep of each (batch, head-group)."""
    from repro import msda
    from repro.kernels import ops as kernel_ops
    from repro.msda.sampling import generate_points

    n_layers = 6
    cache = msda.build_value_cache(dparams["value"], plan_p, memory, state)
    key = jax.random.PRNGKey(33)
    nq = plan_p.n_queries
    qs = jax.random.normal(key, (n_layers, memory.shape[0], nq,
                                 attn_cfg.d_model))
    refs = jax.random.uniform(jax.random.fold_in(key, 1),
                              (memory.shape[0], nq, 2),
                              minval=0.1, maxval=0.9)
    layer0 = dparams["layers"][0]["cross"]
    stack = []
    for li in range(n_layers):
        sel, pts = generate_points(layer0, attn_cfg, qs[li], refs,
                                   plan_p.level_shapes,
                                   pix2slot=cache.pix2slot,
                                   keep_idx=cache.keep_idx)
        stack.append((pts.x_px, pts.y_px, pts.start, pts.wl, pts.hl,
                      sel.probs))
    stacked = [jnp.stack([s[i] for s in stack], axis=1) for i in range(6)]

    per_layer = jax.jit(lambda c, st: sum(
        kernel_ops.msgs_decode(c.staged, *s, block_q=plan_p.block_q).sum()
        for s in st))
    one_launch = jax.jit(lambda c, sk: kernel_ops.msgs_decode_layers(
        c.staged, *sk, block_q=plan_p.block_q).sum())
    return [
        ("msda_decode6_perlayer_launches",
         _time(lambda: per_layer(cache, stack)),
         "6 single-layer persistent decode launches, shared staged table"),
        ("msda_decode6_stacked_launch",
         _time(lambda: one_launch(cache, stacked)),
         "ONE launch, layer axis innermost, table resident per (b, group)"),
    ]


def _autotune_rows() -> list[tuple[str, float, str]]:
    """Startup cost of the measured-plan path: load the persisted
    per-platform table, apply it, and resolve one auto plan against the
    measured budgets — via make_plan, NOT the memoized plan_for, so the
    row times a real resolution instead of a dict hit. This is what
    every engine construction pays after the one-off calibration run."""
    from repro import msda
    from repro.core.msdeform_attn import MSDeformAttnConfig
    from repro.msda import autotune
    from repro.msda import plan as plan_lib

    levels = ((16, 20), (8, 10), (4, 5), (2, 3))
    cfg = MSDeformAttnConfig(d_model=64, n_heads=4,
                             range_narrow=(6.0, 4.0, 3.0, 2.0))
    prev = plan_lib.tuned_entry()

    def load_apply_plan():
        entry = autotune.plan_autotune(measure=False, warn_missing=False)
        plan = msda.make_plan(cfg, levels, backend="auto", n_queries=64,
                              n_consumers=6)
        return entry, plan

    t = _time(load_apply_plan)
    _, plan = load_apply_plan()
    plan_lib.apply_tuned_plan_table(prev)     # don't leak into later rows
    return [("msda_autotune_load_plan", t,
             f"load+apply plan table, un-memoized auto plan "
             f"(budget={plan.budget_source})")]


def run(log=print) -> list[tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)
    b, nq, h, k, dh, n_pix = 1, 512, 8, 16, 32, 1000
    v = jax.random.normal(key, (b, n_pix, h, dh))
    lvl = jax.random.randint(key, (b, nq, h, k), 0, 4)
    wl = jnp.take(jnp.asarray([25, 15, 10, 5]), lvl).astype(jnp.int32)
    hl = jnp.take(jnp.asarray([20, 10, 8, 4]), lvl).astype(jnp.int32)
    st = jnp.take(jnp.asarray([0, 500, 650, 730]), lvl).astype(jnp.int32)
    x = jax.random.uniform(key, (b, nq, h, k), minval=0, maxval=20.0)
    y = jax.random.uniform(jax.random.fold_in(key, 1), (b, nq, h, k),
                           minval=0, maxval=16.0)
    p = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 2),
                                         (b, nq, h, k)), axis=-1)

    t_fused = _time(lambda: ops.msgs_fused(v, x, y, st, wl, hl, p, block_q=128))
    rows.append(("msgs_fused_pallas_interp", t_fused, "structural"))
    # head-packed dispatch: 4 heads x Dh=32 share one 128-lane group
    t_packed = _time(lambda: ops.msgs_fused_packed(
        v, x, y, st, wl, hl, p, head_pack=4, block_q=128))
    rows.append(("msgs_fused_packed4_pallas_interp", t_packed,
                 "structural; 4x32->128 lanes"))
    jref = jax.jit(ref.msgs_fused_ref)
    t_ref = _time(lambda: jref(v, x, y, st, wl, hl, p))
    rows.append(("msgs_ref_jnp", t_ref, "oracle"))
    juf = jax.jit(ref.msgs_unfused_ref)
    t_uf = _time(lambda: juf(v, x, y, st, wl, hl, p))
    rows.append(("msgs_unfused_jnp", t_uf, "materializing baseline"))

    rows.extend(_msda_backend_rows())
    rows.extend(_autotune_rows())

    xm = jax.random.normal(key, (256, 256))
    wm = jax.random.normal(jax.random.fold_in(key, 3), (256, 256))
    rows.append(("matmul_pallas_interp",
                 _time(lambda: ops.matmul(xm, wm, bm=128, bn=128, bk=128)),
                 "structural"))

    qd = jax.random.normal(key, (2, 8, 64))
    kd = jax.random.normal(jax.random.fold_in(key, 4), (2, 1024, 2, 64))
    vd = jax.random.normal(jax.random.fold_in(key, 5), (2, 1024, 2, 64))
    ok = jnp.ones((2, 1024), bool)
    rows.append(("flash_decode_pallas_interp",
                 _time(lambda: ops.flash_decode(qd, kd, vd, ok, chunk=256)),
                 "structural"))

    # sustained-serving rows (bucketed+pipelined vs single-bucket sync);
    # lazy import: serve_sustained lives beside this module and needs the
    # repo root on the path (the run.py harness always provides it)
    from benchmarks.serve_sustained import micro_rows as serve_micro
    rows.extend(serve_micro(log=lambda *_: None))

    for name, t, d in rows:
        log(f"[micro] {name}: {t:.1f} us ({d})")
    return rows
