"""Generate EXPERIMENTS.md from results/: dry-run tables, roofline tables,
baseline-vs-optimized §Perf comparison, paper-claim benchmarks.

  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline_report import load_all, markdown_table

PERF_NARRATIVE = open(os.path.join(os.path.dirname(__file__),
                                   "perf_narrative.md")).read() \
    if os.path.exists(os.path.join(os.path.dirname(__file__),
                                   "perf_narrative.md")) else ""


def _fmt_opt_compare(base_rows, opt_rows) -> str:
    base = {(r["arch"], r["shape"], r["mesh"]): r for r in base_rows}
    opt = {(r["arch"], r["shape"], r["mesh"]): r for r in opt_rows}
    hdr = ("| arch | shape | mesh | step ms (base→opt) | dominant (base→opt) "
           "| useful (base→opt) | MFU (base→opt) | peak GiB (base→opt) |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for key in sorted(set(base) & set(opt)):
        b, o = base[key], opt[key]
        speed = b["step_ms"] / o["step_ms"] if o["step_ms"] else 0
        lines.append(
            f"| {key[0]} | {key[1]} | {key[2]} "
            f"| {b['step_ms']:.1f}→{o['step_ms']:.1f} ({speed:.1f}x) "
            f"| {b['dominant']}→{o['dominant']} "
            f"| {b['useful']:.2f}→{o['useful']:.2f} "
            f"| {b['mfu']:.3f}→{o['mfu']:.3f} "
            f"| {b['peak_gib']:.1f}→{o['peak_gib']:.1f} |")
    return hdr + "\n".join(lines)


def main() -> None:
    base_rows = load_all("results/dryrun")
    opt_rows = load_all("results/dryrun_opt") \
        if os.path.isdir("results/dryrun_opt") else []
    bench = {}
    if os.path.exists("results/benchmarks.json"):
        with open("results/benchmarks.json") as f:
            bench = json.load(f)

    parts = [HEADER]

    if base_rows:
        parts.append("\n## §Dry-run\n")
        parts.append(DRYRUN_PREAMBLE)
        n_single = len([r for r in base_rows if r['mesh'] == 'single'])
        n_multi = len([r for r in base_rows if r['mesh'] == 'multi'])
        parts.append(f"\nAll cells compile on BOTH meshes: "
                     f"{n_single} single-pod (16x16=256 chips) + {n_multi} "
                     f"multi-pod (2x16x16=512 chips) compilations succeed "
                     f"(0 sharding/lowering failures). Per-cell "
                     f"memory_analysis/cost_analysis JSON: results/dryrun/.\n")
        # exemplar cell: memory analysis + collective schedule
        ex_path = "results/dryrun/deepseek-7b__train_4k__multi.json"
        if os.path.exists(ex_path):
            with open(ex_path) as f:
                ex = json.load(f)
            m = ex["memory"]
            cc = ex.get("collectives_corrected", {})
            parts.append(
                f"\nExemplar (deepseek-7b / train_4k / multi-pod): "
                f"arguments {m['argument_bytes']/2**30:.2f} GiB/chip, temps "
                f"{m['temp_bytes']/2**30:.2f} GiB/chip, HLO FLOPs "
                f"{ex['cost']['flops']:.3e}/chip; per-layer collective "
                f"schedule (1-layer compile): "
                + ", ".join(f"{k}×{v['count']} ({v['bytes']/2**30:.2f} GiB)"
                            for k, v in cc.get("by_kind_1l", {}).items())
                + ". Full schedules per cell in the JSONs.\n")

        parts.append("\n## §Roofline — baseline (single-pod, per chip)\n")
        parts.append(ROOFLINE_PREAMBLE)
        parts.append(markdown_table(base_rows, "single"))
        parts.append("\n\n### Baseline, multi-pod (2 pods / 512 chips)\n")
        parts.append(markdown_table(base_rows, "multi"))
    else:
        parts.append("\n(Dry-run/roofline sections omitted: no "
                     "results/dryrun data in this checkout — regenerate "
                     "with launch/dryrun.py on a machine with the virtual "
                     "device pool.)\n")

    if opt_rows:
        parts.append("\n\n## §Perf — optimized vs baseline\n")
        # headline summary
        base_m = {(r["arch"], r["shape"], r["mesh"]): r for r in base_rows}
        ups = []
        for r in opt_rows:
            key = (r["arch"], r["shape"], r["mesh"])
            if key in base_m and r["step_ms"] > 0:
                ups.append((base_m[key]["step_ms"] / r["step_ms"], key))
        ups.sort(reverse=True)
        if ups:
            gains = [u for u in ups if u[0] > 1.05]
            parts.append(
                f"\n**Headline**: {len(gains)}/{len(ups)} cells improve; "
                f"best: " + "; ".join(
                    f"{k[0]}/{k[1]}/{k[2]} **{s:.1f}x**"
                    for s, k in ups[:5]) + ". "
                "Paper-faithful baselines (frozen first) in results/dryrun; "
                "beyond-paper optimized runs in results/dryrun_opt.\n")
        parts.append(PERF_PREAMBLE)
        parts.append("\n### Optimized roofline (single-pod)\n")
        parts.append(markdown_table(opt_rows, "single"))
        parts.append("\n\n### Before/after (roofline step = max of 3 terms)\n")
        parts.append(_fmt_opt_compare(
            [r for r in base_rows if r["mesh"] == "single"],
            [r for r in opt_rows if r["mesh"] == "single"]))
        parts.append("\n\n### Multi-pod before/after\n")
        parts.append(_fmt_opt_compare(
            [r for r in base_rows if r["mesh"] == "multi"],
            [r for r in opt_rows if r["mesh"] == "multi"]))

    if PERF_NARRATIVE:
        parts.append("\n\n" + PERF_NARRATIVE)

    parts.append("\n\n## §Paper-claims — DEFA figure reproductions\n")
    parts.append(CLAIMS_PREAMBLE)
    if "fig7a_bank_sim" in bench:
        r = bench["fig7a_bank_sim"]
        parts.append(
            f"\n**Fig. 7a (inter- vs intra-level parallelism)** — bank "
            f"simulator: inter-level is conflict-free by construction "
            f"({r['inter_conflict_free']}); throughput ratio "
            f"**{r['throughput_ratio']:.2f}x** (paper: 3.06x). Intra-level "
            f"averages {r['intra_cycles_per_group']:.2f} cycles per "
            f"4-point group vs {r['inter_cycles_per_group']:.2f}.\n")
    if "fig7b_energy" in bench:
        e = bench["fig7b_energy"]
        parts.append(
            f"\n**Fig. 7b (fusion + fmap reuse energy)** — byte-accounting "
            f"model: operator fusion saves {e['dram_saving_fusion_pct']:.1f}% "
            f"DRAM / {e['sram_saving_fusion_pct']:.1f}% SRAM (paper: 73.3% / "
            f"15.9%); fmap reuse saves {e['dram_saving_reuse_pct']:.1f}% DRAM "
            f"/ {e['sram_saving_reuse_pct']:.1f}% SRAM (paper: 88.2% / "
            f"22.7%). Combined: {e['total_saving_pct']:.1f}% of MSGS memory "
            f"energy. The reuse numbers match; fusion attribution differs "
            f"because the paper's unfused baseline accounting (how much of "
            f"the bounded-range fetch it charges to the fusion experiment) "
            f"is not fully specified — our model charges full range fetches, "
            f"diluting the sampled-value share.\n")
    if "fig6" in bench:
        r = bench["fig6"]
        ap = r["ap"]
        red = r["reduction"]
        parts.append("\n**Fig. 6a (AP under each mechanism)** — toy synthetic "
                     "detection (COCO unavailable offline), NO finetuning "
                     "recovery step:\n\n")
        parts.append("| variant | AP | ΔAP |\n|---|---|---|\n")
        for k, v in ap.items():
            parts.append(f"| {k} | {v:.4f} | {v - ap['baseline']:+.4f} |\n")
        parts.append(
            f"\n**Fig. 6b (reductions)** — FWP prunes "
            f"**{red['fmap_pixels_pruned_pct']:.0f}%** of fmap pixels "
            f"(paper: 43%); PAP prunes "
            f"**{red['sampling_points_pruned_pct']:.0f}%** of sampling "
            f"points at threshold 0.02 (paper: 84% — our toy detector is "
            f"2 blocks / 80 steps, so attention is far less peaked than "
            f"a converged COCO model; the FWP ratio, which depends on "
            f"sampling GEOMETRY rather than training sharpness, lands on "
            f"the paper's number); MSGS compute saved "
            f"{red['msgs_compute_saved_pct']:.0f}% (paper: >50%).\n")
    if "decoder_head" in bench:
        r = bench["decoder_head"]
        reuse = bench.get("fmap_reuse_vmem", {})
        parts.append(
            f"\n**Decoder head (shared ValueCache)** — DETR-style decoder "
            f"({r['n_layers']} layers × {r['n_queries']} learned queries) "
            f"over the encoder memory, every layer sampling ONE build-once "
            f"FWP-compactable value table: toy synthetic-task AP "
            f"**{r['ap']:.3f}** (with the full DEFA stack — PAP-topk, "
            f"FWP-compact, range-narrowing, INT12 — {r['ap_defa']:.3f}; "
            f"set-matching loss — Hungarian assignment via scipy's "
            f"linear_sum_assignment when installed, greedy per-gt argmin "
            f"fallback — so not comparable to the dense per-pixel head's "
            f"AP above). ")
        if "decoder_reuse_ratio" in reuse:
            parts.append(
                f"Staged-bytes accounting for the paper-scale 6-layer "
                f"decoder: rebuild-per-layer "
                f"{reuse['decoder_rebuild_kb']:.0f} KB vs build-once "
                f"{reuse['decoder_cache_once_kb']:.0f} KB = "
                f"**{reuse['decoder_reuse_ratio']:.1f}x** reduction — by "
                f"construction (rebuild restages the identical table per "
                f"layer); the measured evidence is the "
                f"`msda_decoder6_cached` vs `msda_decoder6_rebuild` micro "
                f"wall-time rows plus the spy-tested exactly-once "
                f"projection, and the compact build "
                f"({reuse['decoder_cache_once_kb']:.0f} KB vs dense "
                f"{reuse['decoder_cache_dense_kb']:.0f} KB) is the part "
                f"that can regress (benchmarks/fmap_reuse.py).")
        if "table_dtype_ratio" in reuse:
            parts.append(
                f" The **int8 value table** (codes + one per-channel f32 "
                f"scale row, dequantized in-register after the bilinear "
                f"corner gather) shrinks the same staged build from "
                f"{reuse['table_f32_kb']:.0f} KB (f32) to "
                f"{reuse['table_int8_kb']:.0f} KB = "
                f"**{reuse['table_dtype_ratio']:.2f}x** fewer staged bytes "
                f"— measured from the same plan accounting as the FWP "
                f"compaction ratio, and multiplicative with it "
                f"(`fmap_reuse_table_dtype` row; parity within the "
                f"analytic scale/2 tolerance is tested across all four "
                f"backends).")
        if "ordering_ratio" in reuse:
            parts.append(
                f" **Cache-local query ordering** (repro/msda/ordering.py) "
                f"permutes the decode queries by reference point before "
                f"sampling and inverts the permutation on the output — "
                f"bit-identical numerics (permutation-parity tested per "
                f"backend), but each tile of {reuse['ordering_tile_q']} "
                f"queries now spans a spatially compact set of points, so "
                f"the per-tile staging window shrinks: measured on "
                f"{reuse['ordering_queries']} uniform-random decode "
                f"queries, {reuse['ordering_unordered_kb']:.0f} KB/tile "
                f"unordered vs {reuse['ordering_raster_kb']:.0f} KB "
                f"raster-ordered = **{reuse['ordering_ratio']:.2f}x** "
                f"smaller mean window (z-order: "
                f"{reuse['ordering_zorder_kb']:.0f} KB, "
                f"{reuse['ordering_zorder_ratio']:.2f}x — row-span-based "
                f"staging credits raster's row locality, not z-order's "
                f"column locality). `plan.describe()` reports the same "
                f"measured figure (`tilewin=`), and the `auto` policy can "
                f"use it for the VMEM-fit check; wall-time rows: "
                f"`msda_decode6_ordered`, `msda_windowed_ordered`.")
        micro = bench.get("micro", {})
        if "msda_decoder6_persistent" in micro \
                and "msda_decoder6_cached" in micro:
            pers = micro["msda_decoder6_persistent"]["us_per_call"]
            cach = micro["msda_decoder6_cached"]["us_per_call"]
            parts.append(
                f" The **persistent decode kernel** (`pallas_decode`, "
                f"kernels/msgs_decode.py) extends build-once from "
                f"projection to staging: the compact table is laid out in "
                f"the launch layout ONCE per memory (spy-tested once per "
                f"(batch, head-group), never per layer) and every layer's "
                f"launch reuses it — 6-layer cross-attn stack "
                f"{pers/1000:.1f} ms vs the `jnp_gather` cached baseline "
                f"{cach/1000:.1f} ms (**{cach/pers:.1f}x**, "
                f"`msda_decoder6_persistent` vs `msda_decoder6_cached`, "
                f"interpret-mode structural wall time under the CI "
                f"regression gate).")
            if "msda_decode6_stacked_launch" in micro \
                    and "msda_decode6_perlayer_launches" in micro:
                st_us = micro["msda_decode6_stacked_launch"]["us_per_call"]
                pl_us = micro["msda_decode6_perlayer_launches"]["us_per_call"]
                parts.append(
                    f" On identical precomputed points, the stacked "
                    f"single-launch variant (layer axis innermost, table "
                    f"resident per (batch, head-group)) runs 6 layers in "
                    f"{st_us/1000:.1f} ms vs {pl_us/1000:.1f} ms for 6 "
                    f"per-layer launches — interpret mode can't show the "
                    f"per-launch DMA saving, so the stacked win is "
                    f"structural (one table fetch per (b, group)), not "
                    f"wall-time.")
        parts.append("\n")
    reuse = bench.get("fmap_reuse_vmem", {})
    micro = bench.get("micro", {})
    if "stream_bytes_ratio" in reuse:
        r = reuse
        parts.append(
            f"\n**Streaming detection (temporal feature-map reuse)** — the "
            f"frame-to-frame extension of the build-once story: a "
            f"`TemporalCacheManager` (repro/stream/) diffs each video "
            f"frame's multi-scale memory at row-aligned tile granularity "
            f"and re-projects/re-stages ONLY the dirty slots of the "
            f"persistent value cache (scattered through the existing "
            f"pix2slot geometry), with FWP scores carried as a streaming "
            f"EMA under keep-mask hysteresis. On the measured "
            f"{r['stream_frames']}-frame drifting-scene benchmark: "
            f"rebuild-per-frame {r['stream_rebuild_total_kb']:.0f} KB vs "
            f"incremental {r['stream_staged_total_kb']:.0f} KB staged = "
            f"**{r['stream_bytes_ratio']:.2f}x fewer bytes** "
            f"({r['stream_incremental_frames']}/{r['stream_frames']} frames "
            f"incremental at <= {r['stream_update_rows']}/"
            f"{r['stream_slots']} rows/frame; "
            f"{r['stream_rebuild_frames']} full rebuilds incl. the warm-up "
            f"keep transitions the hysteresis then suppresses). This is a "
            f"measurement — how many tiles the moving object dirties and "
            f"how often the keep set churns decide it — not a "
            f"by-construction ratio.")
        if "msda_stream_incremental" in micro \
                and "msda_stream_rebuild" in micro:
            i_us = micro["msda_stream_incremental"]["us_per_call"]
            b_us = micro["msda_stream_rebuild"]["us_per_call"]
            parts.append(
                f" Wall time per frame (d_model=256, 32x40 pyramid, "
                f"interpret-mode structural): incremental "
                f"{i_us/1000:.1f} ms vs full rebuild {b_us/1000:.1f} ms "
                f"(`msda_stream_incremental` vs `msda_stream_rebuild`, "
                f"both under the CI regression gate); at the paper's "
                f"100x167 geometry the measured gap widens to ~2x but is "
                f"too noisy for the gate. End-to-end driver: "
                f"`examples/detr_stream.py` (N sessions, batched slots, "
                f"decoder-frequency EMA feedback).")
        parts.append("\n")
    auto_par = _autotune_paragraph(bench)
    if auto_par:
        parts.append(auto_par)
    serve = bench.get("serve_sustained", {})
    if "closed_loop" in serve:
        cl, ol = serve["closed_loop"], serve["open_loop"]
        w = serve.get("workload", {})
        buckets = ", ".join(
            f"{b['resolution']}px ({b['table_kb']} KB table)"
            for b in serve.get("buckets", []))
        parts.append(
            f"\n**Sustained serving (AOT shape buckets + continuous "
            f"batching + pipelined post-processing)** — the deployment "
            f"harness (repro/serve/): each resolution bucket's detector "
            f"forward is AOT-compiled at startup "
            f"(`jax.jit(...).lower().compile()`; buckets: {buckets}), "
            f"requests route to the smallest bucket they fit (pad up, "
            f"reject oversized), micro-batches dispatch from per-bucket "
            f"queues, and top-k decode + callbacks run on a worker thread "
            f"while the device serves the next batch. On the "
            f"{w.get('mix', 'mixed')} mixed-resolution load "
            f"(closed loop, median of 3): "
            f"{cl['sustained_us_per_request']/1000:.1f} ms/request vs "
            f"{cl['single_bucket_sync_us_per_request']/1000:.1f} ms/request "
            f"for the single-bucket synchronous baseline = "
            f"**{cl['speedup']:.2f}x sustained throughput** "
            f"(`msda_serve_sustained` vs `msda_serve_single_bucket_sync`, "
            f"both under the CI regression gate), with ZERO recompiles "
            f"after warmup (compile-count spy, tests/test_serve.py). Open "
            f"loop at 0.9x measured capacity: "
            f"{ol['rps_per_chip']} requests/s/chip, P50 {ol['p50_ms']} ms "
            f"/ P99 {ol['p99_ms']} ms request latency (submit -> "
            f"post-processing done). Driver: `examples/detr_serve.py "
            f"--sustained`.\n")
    if "spans" in serve or "observability" in serve:
        spans = serve.get("spans", {})
        span_tbl = "; ".join(
            f"`{name}` P50 {st['p50_ms']:.2f} ms / P99 {st['p99_ms']:.2f} ms "
            f"(n={st['count']})"
            for name, st in sorted(spans.items())
            if name in ("queue", "device", "postproc", "callback"))
        obs = serve.get("observability", {})
        parts.append(
            f"\n**Observability (repro/obs/)** — the same run, decomposed by "
            f"the request-tracing spans the engine emits "
            f"(`enqueue -> admit -> device_step -> postproc`): {span_tbl}. "
            f"Every engine owns a `MetricsRegistry` + `Tracer` bundle; the "
            f"zero-retrace contract is asserted against the "
            f"`msda_compiles_total` counter (bumped at trace time, flat "
            f"after warmup), and the Prometheus/JSONL exports are "
            f"CI-validated (`python -m repro.obs.validate`). Measured "
            f"instrumentation cost: "
            f"{obs.get('instrumentation_us_per_request', 0):.1f} us/request "
            f"= **{100 * obs.get('fraction_of_request', 0):.2f}%** of a "
            f"request (<1% acceptance bar; plain-dict counters outside "
            f"jit). Live view: `python -m repro.obs.dashboard --jsonl "
            f"$REPRO_OBS_JSONL --follow`.\n")
    if "fig9_table1" in bench and "baseline" in bench.get("fig9_table1", {}):
        r = bench["fig9_table1"]
        parts.append(
            f"\n**Fig. 9 / Table 1 analogue** — TPU-v5e roofline of the DETR "
            f"encoder serve cell: plain encoder "
            f"{r['baseline']['roofline_step_ms']:.2f} ms/step; naive DEFA "
            f"{r['defa']['roofline_step_ms']:.2f} ms/step (the pruning "
            f"machinery is collective-bound when only the batch axis is "
            f"used — an honest negative result the paper's ASIC never "
            f"faces)")
        if "defa_banded" in r:
            parts.append(
                f"; DEFA + band-sharded halo exchange "
                f"{r['defa_banded']['roofline_step_ms']:.2f} ms/step = "
                f"**{r.get('defa_banded_vs_baseline_speedup', 0):.2f}x over "
                f"the plain encoder** and "
                f"{r['defa']['roofline_step_ms']/r['defa_banded']['roofline_step_ms']:.1f}x "
                f"over naive DEFA ("
                f"{r['defa_banded']['imgs_per_s_per_chip']:.1f} img/s/chip)")
        parts.append(
            f". The paper's 10.1-31.9x is vs a CUDA grid-sample baseline on "
            f"GPUs — not comparable 1:1. Energy: the byte-accounting model "
            f"gives {r['energy_model']['msgs_energy_saving_pct']:.1f}% MSGS "
            f"memory-energy saving (fusion+reuse), vs the paper's "
            f"20.3-37.7x GPU energy-efficiency claim driven by the same "
            f"mechanisms.\n")

    with open("EXPERIMENTS.md", "w") as f:
        f.write("".join(parts))
    print("wrote EXPERIMENTS.md",
          f"({len(base_rows)} baseline cells, {len(opt_rows)} optimized)")


def _autotune_paragraph(bench: dict) -> str:
    """Measured-vs-static budget story from results/autotune.json: the
    per-platform calibration winners, plus the concrete plan delta the
    measured budget buys on the paper 4-level shape."""
    path = "results/autotune.json"
    if not os.path.exists(path):
        return ""
    with open(path) as f:
        table = json.load(f)
    plats = table.get("platforms", {})
    if not plats:
        return ""
    out = ["\n**Plan autotuning (measured vs static budgets)** — "
           "`repro/msda/autotune.py` replaces three static planner guesses "
           "with on-device measurements, persisted per platform in "
           "`results/autotune.json` (committed fallback for CI; "
           "`plan.describe()` reports the provenance as "
           "`budget=measured|static`):\n"]
    for plat, e in sorted(plats.items()):
        mb = e.get("staging_budget_bytes", 0) / 2**20
        stream = e.get("stream", {})
        out.append(
            f"\n- `{plat}`: staged-table budget **{mb:.0f} MB measured** "
            f"(bandwidth-knee probe) vs the 4 MB static default "
            f"({mb / 4:.0f}x); persistent decode sweep "
            f"{'KEPT' if e.get('decode_sweep_beneficial') else 'VETOED'} "
            f"(measured {e.get('decode_persistent_speedup', 0):.2f}x vs "
            f"per-layer restaging, interpret-mode); streaming crossover "
            f"`diff_channel_stride={stream.get('diff_channel_stride')}` / "
            f"`update_frac={stream.get('update_frac')}`.\n")
    delta = _paper_shape_budget_delta(plats)
    if delta:
        out.append(delta)
    micro = bench.get("micro", {})
    if "msda_autotune_load_plan" in micro:
        us = micro["msda_autotune_load_plan"]["us_per_call"]
        out.append(
            f"\nStartup cost after the one-off calibration run: loading + "
            f"applying the table and resolving an un-memoized auto plan "
            f"measures {us / 1000:.1f} ms (`msda_autotune_load_plan`, under "
            f"the CI regression gate); engines pay it once at "
            f"construction via the load-only `msda.ensure_applied()`.\n")
    return "".join(out)


def _paper_shape_budget_delta(plats: dict) -> str:
    """The measured budget's consequence on the paper 4-level pyramid —
    best-effort (the doc generator must not die on an import problem)."""
    try:
        import jax

        from repro.core.msdeform_attn import MSDeformAttnConfig
        from repro.msda import plan as plan_lib

        entry = plats.get(jax.default_backend())
        if not entry:
            return ""
        paper_levels = ((100, 167), (50, 84), (25, 42), (13, 21))
        cfg = MSDeformAttnConfig(d_model=256, n_heads=8,
                                 range_narrow=(8.0, 6.0, 4.0, 3.0))
        prev = plan_lib.tuned_entry()
        try:
            plan_lib.apply_tuned_plan_table(None)
            p_stat = plan_lib.make_plan(cfg, paper_levels, backend="auto",
                                        n_queries=300, n_consumers=6)
            plan_lib.apply_tuned_plan_table(entry)
            p_meas = plan_lib.make_plan(cfg, paper_levels, backend="auto",
                                        n_queries=300, n_consumers=6)
        finally:
            plan_lib.apply_tuned_plan_table(prev)
        staged_kb = p_meas.cache_table_bytes / 1024
        meas_mb = p_meas.staging_budget_bytes // 2**20
        stat_mb = plan_lib.DEFAULT_WINDOW_STAGING_BUDGET // 2**20
        vmem_mb = p_meas.vmem_budget_bytes / 2**20
        if p_stat.backend != p_meas.backend:
            story = (
                f"flips the auto decode plan from `{p_stat.backend}` to "
                f"`{p_meas.backend}`: the {staged_kb:.0f} KB staged decode "
                f"table clears the measured {meas_mb} MB ceiling but not "
                f"the static {stat_mb} MB guess")
        elif staged_kb * 1024 <= p_meas.staging_budget_bytes:
            # the table fits the measured staging ceiling, so the staging
            # budget is not what keeps the backend — the kernel VMEM
            # budget binds first at this shape
            story = (
                f"keeps `{p_meas.backend}`: the {staged_kb:.0f} KB staged "
                f"decode table clears the measured {meas_mb} MB staging "
                f"ceiling (it missed the static {stat_mb} MB guess), but "
                f"the {vmem_mb:.0f} MB kernel VMEM budget still binds "
                f"first at this shape")
        else:
            story = (
                f"keeps `{p_meas.backend}`: the {staged_kb:.0f} KB staged "
                f"decode table exceeds even the measured {meas_mb} MB "
                f"ceiling")
        return (
            f"\nOn the paper 4-level shape (100x167 pyramid, d_model=256, "
            f"300 decode queries, 6 layers) the measured budget {story} — "
            f"every later kernel improvement lands in production through "
            f"the same measured gate instead of waiting for a hand-raised "
            f"constant.\n")
    except Exception:                       # noqa: BLE001 - doc generator
        return ""


HEADER = """# EXPERIMENTS — DEFA on TPU

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Container is CPU-only: kernels validate in interpret mode; distribution
validates by AOT compile on 512 virtual devices; roofline terms derive from
compiled HLO (see DESIGN.md §7 and launch/hlo_stats.py for conventions,
including the two-point scan-cost correction and the structural HBM-bytes
estimate).
"""

DRYRUN_PREAMBLE = """Every (architecture × shape) cell lowers AND compiles with
explicit in/out shardings + donated state/caches on the production meshes
(`launch/dryrun.py`). `long_500k` runs for mamba2-130m and hymba-1.5b
(sub-quadratic); the eight pure full-attention archs skip it per the
assignment (DESIGN.md §5). whisper/llava frontends are ShapeDtypeStruct
stubs. 32 LM cells + DETR-family cells per mesh."""

ROOFLINE_PREAMBLE = """Terms per chip: compute = HLO_FLOPs/197e12, memory =
structural_bytes/819e9, collective = ring-weighted collective bytes/50e9.
`useful` = MODEL_FLOPS(6·N·D train, 2·N·D serve)/HLO_FLOPs; `MFU` =
useful-compute time / roofline step time. Full per-cell JSON (incl.
collective op histograms) in results/dryrun*/.
"""

PERF_PREAMBLE = """Optimized = `--opt`: O1 activation-sharding constraints,
O2 seq-parallel/padded attention for TP-indivisible heads, O3 SSD projection
split, O4' explicit shard_map expert parallelism, O5 grad-accum memory
fitting, O6 save_comm remat, O7 pure-DP strategy for small archs. The
hypothesis→measure log for each is in §Perf iterations below."""

CLAIMS_PREAMBLE = """Each paper figure/table has a benchmark
(`python -m benchmarks.run`); numbers below are from the latest run
(results/benchmarks.json)."""


if __name__ == "__main__":
    main()
