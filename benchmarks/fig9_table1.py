"""Fig. 9 / Table 1 analogue: platform comparison.

The paper measures a 40nm ASIC against RTX 2080Ti/3090Ti and three attention
ASICs. Without those platforms, we report the honest analogue: the
TPU-v5e roofline step time of the DETR encoder serve cell from the dry-run
(baseline MSDeformAttn vs DEFA-optimized), the modelled MSGS energy from
the byte-accounting model, and the derived GOPS / GOPS/W alongside the
paper's Table 1 column for DEFA. All numbers are clearly labelled
analytical (dry-run/model), not silicon measurements."""
from __future__ import annotations

import json
import os

from benchmarks.energy_model import model_energy


def _load(tag: str) -> dict | None:
    for d in ("results/dryrun", "results/dryrun_opt"):
        path = os.path.join(d, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
    return None


def run(log=print) -> dict:
    out: dict = {"paper_table1_defa": {
        "throughput_GOPS": 418, "power_mW": 99.8, "energy_eff_GOPS_W": 4187}}
    base = _load("deformable-detr__serve__single")
    defa = _load("deformable-detr-defa__serve__single")
    banded = _load("deformable-detr-defa__banded__single")
    for name, r in (("baseline", base), ("defa", defa),
                    ("defa_banded", banded)):
        if r is None:
            log(f"[fig9] {name}: dry-run result missing (run launch/dryrun "
                "--detr first)")
            continue
        rf = r["roofline"]
        step = rf["roofline_step_s"]
        b = r["meta"]["global_batch"]
        imgs_per_s_per_chip = b / step / r["meta"]["n_chips"]
        gflops_exec = rf["hlo_flops_chip"] / 1e9
        out[name] = {
            "roofline_step_ms": step * 1e3,
            "imgs_per_s_per_chip": imgs_per_s_per_chip,
            "dominant_term": rf["dominant"],
            "exec_GFLOP_per_chip": gflops_exec,
        }
        log(f"[fig9] {name}: step {step*1e3:.2f} ms, "
            f"{imgs_per_s_per_chip:.1f} img/s/chip, dom={rf['dominant']}")
    if base and defa:
        sp = out["baseline"]["roofline_step_ms"] / out["defa"]["roofline_step_ms"]
        out["defa_vs_baseline_speedup"] = sp
        log(f"[fig9] DEFA-vs-baseline roofline speedup: {sp:.2f}x "
            f"(paper's GPU speedup: 10.1-31.9x vs CUDA, different baseline)")
    if base and banded:
        sp = out["baseline"]["roofline_step_ms"] \
            / out["defa_banded"]["roofline_step_ms"]
        out["defa_banded_vs_baseline_speedup"] = sp
        log(f"[fig9] DEFA+banded-vs-baseline roofline speedup: {sp:.2f}x "
            f"(pruning + halo-exchange distribution)")
    e = model_energy()
    out["energy_model"] = {
        "msgs_energy_saving_pct": e["total_saving_pct"],
        "paper_energy_eff_ratio_vs_gpu": "20.3-37.7x",
    }
    log(f"[table1] modelled MSGS memory-energy saving: "
        f"{e['total_saving_pct']:.1f}% (fusion+reuse)")
    return out


if __name__ == "__main__":
    run()
