"""CI perf-regression gate for the per-backend micro rows.

Compares the ``micro`` section of a freshly produced benchmark JSON
(``benchmarks/run.py --only micro --json <path>``) against the committed
``results/benchmarks.json`` baseline and fails (exit 1) when any
``msda_*`` backend row is more than ``--threshold`` times slower than
its baseline. Rows without a baseline entry (new backends) are reported
but never fail; interpret-mode wall time is structural, so the default
threshold is a generous 1.5x.

Usage:
    python benchmarks/check_regression.py \
        --baseline results/benchmarks.json --current /tmp/bench.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _micro_rows(payload: dict) -> dict:
    # accept both the results file ({"micro": {...}}) and the --json
    # payload ({"results": {"micro": {...}}})
    if "micro" in payload:
        return payload["micro"]
    return payload.get("results", {}).get("micro", {})


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="results/benchmarks.json")
    ap.add_argument("--current", required=True)
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when current > threshold * baseline")
    ap.add_argument("--prefix", default="msda_",
                    help="only rows with this prefix gate the build")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = _micro_rows(json.load(f))
    with open(args.current) as f:
        cur = _micro_rows(json.load(f))

    failures = []
    for name, row in sorted(cur.items()):
        if not name.startswith(args.prefix):
            continue
        us = float(row["us_per_call"])
        if name not in base:
            print(f"[check] {name}: {us:.1f} us (no baseline — skipped)")
            continue
        ref = float(base[name]["us_per_call"])
        ratio = us / ref if ref > 0 else float("inf")
        status = "FAIL" if ratio > args.threshold else "ok"
        print(f"[check] {name}: {us:.1f} us vs baseline {ref:.1f} us "
              f"({ratio:.2f}x) {status}")
        if ratio > args.threshold:
            failures.append((name, ratio))

    if failures:
        print(f"[check] {len(failures)} backend row(s) regressed "
              f">{args.threshold}x: "
              + ", ".join(f"{n} ({r:.2f}x)" for n, r in failures))
        return 1
    print("[check] all backend rows within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
