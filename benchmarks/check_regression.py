"""CI perf-regression gate for the per-backend micro rows.

Compares the ``micro`` section of a freshly produced benchmark JSON
(``benchmarks/run.py --only micro --json <path>``) against the committed
``results/benchmarks.json`` baseline and fails (exit 1) when:

  * any gated (``msda_*``) row is more than ``--threshold`` times slower
    than its baseline (interpret-mode wall time is structural, so the
    default threshold is a generous 1.5x);
  * a gated row in the current run has NO baseline entry — a new backend
    row must be committed to ``results/benchmarks.json`` (at the
    baseline's machine-speed scale) in the same change that adds it, or
    it would ride ungated forever;
  * a gated baseline row is MISSING from the current run — a renamed or
    silently-dropped benchmark must update the baseline, not evaporate.

Usage:
    python benchmarks/check_regression.py \
        --baseline results/benchmarks.json --current /tmp/bench.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _micro_rows(payload: dict) -> dict:
    # accept both the results file ({"micro": {...}}) and the --json
    # payload ({"results": {"micro": {...}}})
    if "micro" in payload:
        return payload["micro"]
    return payload.get("results", {}).get("micro", {})


class EmptyIntersectionError(ValueError):
    """Baseline and current run share NO gated rows — a machine-speed
    scale factor computed over nothing is meaningless (the old code path
    would divide by nothing or silently scale by 1.0). The message
    prints both row sets so the mismatch is diagnosable from CI logs."""

    def __init__(self, base_rows, cur_rows, prefix: str):
        self.base_rows = sorted(base_rows)
        self.cur_rows = sorted(cur_rows)
        self.prefix = prefix
        super().__init__(
            f"no shared {prefix!r} rows between baseline and current run; "
            f"cannot derive a machine-speed scale factor.\n"
            f"  baseline rows: {self.base_rows or '(none)'}\n"
            f"  current rows:  {self.cur_rows or '(none)'}")


def shared_row_scale(base: dict, cur: dict, prefix: str = "msda_") -> float:
    """Median baseline/current per-call ratio over the shared gated rows.

    The factor that maps THIS machine's timings onto the committed
    baseline's machine speed — how a new benchmark row gets committed at
    baseline scale (``--print-scale``). Raises
    :class:`EmptyIntersectionError` when the intersection is empty
    instead of guessing."""
    def med(xs):
        xs = sorted(xs)
        n = len(xs)
        return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])

    base_rows = {n for n in base if n.startswith(prefix)}
    cur_rows = {n for n in cur if n.startswith(prefix)}
    ratios = [float(base[n]["us_per_call"]) / float(cur[n]["us_per_call"])
              for n in sorted(base_rows & cur_rows)
              if float(cur[n]["us_per_call"]) > 0]
    if not ratios:
        raise EmptyIntersectionError(base_rows, cur_rows, prefix)
    return float(med(ratios))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="results/benchmarks.json")
    ap.add_argument("--current", required=True)
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when current > threshold * baseline")
    ap.add_argument("--prefix", default="msda_",
                    help="only rows with this prefix gate the build")
    ap.add_argument("--print-scale", action="store_true",
                    help="also print the median shared-row baseline/current "
                         "scale factor (for committing new rows at the "
                         "baseline's machine speed)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = _micro_rows(json.load(f))
    with open(args.current) as f:
        cur = _micro_rows(json.load(f))

    if args.print_scale:
        scale = shared_row_scale(base, cur, args.prefix)
        print(f"[check] shared-row scale factor (baseline/current median): "
              f"{scale:.4f}")

    failures = []
    missing_baseline = []
    for name, row in sorted(cur.items()):
        if not name.startswith(args.prefix):
            continue
        us = float(row["us_per_call"])
        if name not in base:
            print(f"[check] {name}: {us:.1f} us — NO baseline entry "
                  f"(gated rows must be committed to {args.baseline})")
            missing_baseline.append(name)
            continue
        ref = float(base[name]["us_per_call"])
        ratio = us / ref if ref > 0 else float("inf")
        status = "FAIL" if ratio > args.threshold else "ok"
        print(f"[check] {name}: {us:.1f} us vs baseline {ref:.1f} us "
              f"({ratio:.2f}x) {status}")
        if ratio > args.threshold:
            failures.append((name, ratio))

    missing_current = sorted(
        n for n in base if n.startswith(args.prefix) and n not in cur)

    ok = True
    if failures:
        print(f"[check] {len(failures)} backend row(s) regressed "
              f">{args.threshold}x: "
              + ", ".join(f"{n} ({r:.2f}x)" for n, r in failures))
        ok = False
    if missing_baseline:
        print(f"[check] {len(missing_baseline)} gated row(s) missing from "
              f"the committed baseline ({args.baseline}): "
              + ", ".join(missing_baseline)
              + " — add them (scaled to the baseline's machine speed) in "
              "the change that introduces them")
        ok = False
    if missing_current:
        print(f"[check] {len(missing_current)} gated baseline row(s) "
              f"missing from the current run: "
              + ", ".join(missing_current)
              + " — a dropped/renamed benchmark must update the baseline, "
              "not silently pass")
        ok = False
    if not ok:
        return 1
    print("[check] all backend rows present and within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
