"""C7 quantification: VMEM working-set reduction of the windowed MSGS kernel.

The windowed kernel (kernels/msgs_windowed.py) holds `tile_rows + 2R + 2`
rows per level instead of the whole level — this benchmark reports the
per-level VMEM bytes for the DETR geometry at the paper's bounded ranges,
plus the DRAM-fetch ratio with Pallas's pipelined window reuse (consecutive
tiles share `window - tile` rows)."""
from __future__ import annotations

import numpy as np

LEVELS = ((100, 167), (50, 84), (25, 42), (13, 21))
RANGES = (16, 12, 8, 4)
D_HEAD = 32
BYTES = 2          # bf16


def report(block_q: int = 512) -> dict:
    rows = []
    tot_full, tot_win = 0, 0
    for (h, w), r in zip(LEVELS, RANGES):
        tile_rows = int(np.ceil(block_q / w)) + 1
        window_rows = min(h, tile_rows + 2 * r + 2)
        full = h * w * D_HEAD * BYTES
        win = window_rows * w * D_HEAD * BYTES
        # fetch traffic: without reuse each tile refetches its window; the
        # pipeline reuses the overlap, fetching only `tile_rows` new rows
        n_tiles = int(np.ceil(h * w / block_q))
        fetch_norere = n_tiles * window_rows * w
        fetch_reuse = window_rows * w + (n_tiles - 1) * tile_rows * w
        rows.append({
            "level": f"{h}x{w}", "range": r,
            "vmem_full_kb": full / 1024, "vmem_window_kb": win / 1024,
            "vmem_ratio": full / win,
            "fetch_reuse_saving_pct": 100 * (1 - fetch_reuse / fetch_norere),
        })
        tot_full += full
        tot_win += win
    out = {"levels": rows, "total_vmem_full_kb": tot_full / 1024,
           "total_vmem_window_kb": tot_win / 1024,
           "total_ratio": tot_full / tot_win}
    out.update(_msp_staged(block_q))
    return out


def _msp_staged(block_q: int, capacity: float = 0.6) -> dict:
    """What the single-launch multi-scale-parallel kernel ACTUALLY stages
    per grid step (all L level windows co-resident), dense vs the
    FWP-compact slot windows — computed from the kernel's real static
    window geometry, not the analytic model above."""
    from repro.core.fwp import level_capacities
    from repro.kernels.msgs_windowed import window_geometry
    geo = window_geometry(LEVELS, tuple(float(r) for r in RANGES), block_q)
    caps = level_capacities(LEVELS, capacity)
    dense = geo.staged_bytes(D_HEAD, BYTES)
    compact = geo.staged_bytes(D_HEAD, BYTES, caps=caps)
    return {"msp_staged_dense_kb": dense / 1024,
            "msp_staged_compact_kb": compact / 1024,
            "msp_compact_ratio": dense / compact}


if __name__ == "__main__":
    r = report()
    for row in r["levels"]:
        print(row)
    print(f"total VMEM: {r['total_vmem_full_kb']:.0f} KB -> "
          f"{r['total_vmem_window_kb']:.0f} KB ({r['total_ratio']:.1f}x)")
    print(f"msp staged/step: dense {r['msp_staged_dense_kb']:.0f} KB -> "
          f"compact {r['msp_staged_compact_kb']:.0f} KB "
          f"({r['msp_compact_ratio']:.2f}x)")
