"""C7 quantification: fmap-reuse memory accounting, two granularities.

  * windowed kernel (kernels/msgs_windowed.py): holds `tile_rows + 2R + 2`
    rows per level instead of the whole level — per-level VMEM bytes for
    the DETR geometry at the paper's bounded ranges, plus the DRAM-fetch
    ratio with Pallas's pipelined window reuse (consecutive tiles share
    `window - tile` rows);
  * decoder ValueCache (repro/msda/cache.py): a 6-layer decoder sampling
    ONE build-once shared value table vs. re-projecting + re-staging it
    per layer — the paper's fine-grained layer-fusion / feature-map
    reusing claim at the architecture level."""
from __future__ import annotations

import numpy as np

LEVELS = ((100, 167), (50, 84), (25, 42), (13, 21))
RANGES = (16, 12, 8, 4)
D_HEAD = 32
BYTES = 2          # bf16
N_DEC_LAYERS = 6
N_QUERIES = 300


def report(block_q: int = 512) -> dict:
    rows = []
    tot_full, tot_win = 0, 0
    for (h, w), r in zip(LEVELS, RANGES):
        tile_rows = int(np.ceil(block_q / w)) + 1
        window_rows = min(h, tile_rows + 2 * r + 2)
        full = h * w * D_HEAD * BYTES
        win = window_rows * w * D_HEAD * BYTES
        # fetch traffic: without reuse each tile refetches its window; the
        # pipeline reuses the overlap, fetching only `tile_rows` new rows
        n_tiles = int(np.ceil(h * w / block_q))
        fetch_norere = n_tiles * window_rows * w
        fetch_reuse = window_rows * w + (n_tiles - 1) * tile_rows * w
        rows.append({
            "level": f"{h}x{w}", "range": r,
            "vmem_full_kb": full / 1024, "vmem_window_kb": win / 1024,
            "vmem_ratio": full / win,
            "fetch_reuse_saving_pct": 100 * (1 - fetch_reuse / fetch_norere),
        })
        tot_full += full
        tot_win += win
    out = {"levels": rows, "total_vmem_full_kb": tot_full / 1024,
           "total_vmem_window_kb": tot_win / 1024,
           "total_ratio": tot_full / tot_win}
    out.update(_msp_staged(block_q))
    out.update(_decoder_staged())
    out.update(_table_dtype_staged())
    out.update(_ordering_staged())
    out.update(_stream_staged())
    return out


def _msp_staged(block_q: int, capacity: float = 0.6) -> dict:
    """What the single-launch multi-scale-parallel kernel ACTUALLY stages
    per grid step (all L level windows co-resident), dense vs the
    FWP-compact slot windows — computed from the kernel's real static
    window geometry, not the analytic model above."""
    from repro.core.fwp import level_capacities
    from repro.kernels.msgs_windowed import window_geometry
    geo = window_geometry(LEVELS, tuple(float(r) for r in RANGES), block_q)
    caps = level_capacities(LEVELS, capacity)
    dense = geo.staged_bytes(D_HEAD, BYTES)
    compact = geo.staged_bytes(D_HEAD, BYTES, caps=caps)
    return {"msp_staged_dense_kb": dense / 1024,
            "msp_staged_compact_kb": compact / 1024,
            "msp_compact_ratio": dense / compact}


def _decoder_staged(n_layers: int = N_DEC_LAYERS,
                    capacity: float = 0.6) -> dict:
    """Build-once vs rebuild-per-layer staged bytes for the decoder.

    Uses the REAL decode-shaped plan accounting
    (``MSDAPlan.cache_table_bytes``): the FWP-compacted slot table + the
    int32 pix2slot indirection, staged once by ``build_value_cache`` and
    then sampled by all ``n_layers`` decoder layers — vs. the per-layer
    rebuild every layer of the seed's monolithic project-then-sample flow
    would pay.

    HONESTY NOTE: the reduction ratio is ``n_layers`` BY CONSTRUCTION
    (rebuild restages the identical table each layer) — it is accounting,
    not a measurement, and can only change if the layer count does. What
    CAN vary, and is reported alongside, is the per-build footprint
    (compact vs dense — tracks capacity/compaction regressions). The
    MEASURED evidence that build-once wins wall-clock is the
    ``msda_decoder6_cached`` vs ``msda_decoder6_rebuild`` micro rows, and
    the exactly-once projection guarantee is spy-tested
    (tests/test_msda_decoder.py)."""
    import dataclasses

    import jax.numpy as jnp

    from repro.core.msdeform_attn import MSDeformAttnConfig
    from repro.msda import make_plan

    cfg = MSDeformAttnConfig(
        d_model=256, n_heads=8, fwp_mode="compact", fwp_capacity=capacity,
        range_narrow=tuple(float(r) for r in RANGES), dtype=jnp.bfloat16)
    plan = make_plan(cfg, LEVELS, backend="jnp_gather",
                     n_queries=N_QUERIES, n_consumers=n_layers)
    once = plan.cache_table_bytes
    rebuild = n_layers * once
    # dense (no-FWP) reference for scale
    plan_d = make_plan(dataclasses.replace(cfg, fwp_mode="off"), LEVELS,
                       backend="jnp_gather", n_queries=N_QUERIES,
                       n_consumers=n_layers)
    return {"decoder_layers": n_layers,
            "decoder_cache_once_kb": once / 1024,
            "decoder_rebuild_kb": rebuild / 1024,
            "decoder_reuse_ratio": rebuild / once,
            "decoder_cache_dense_kb": plan_d.cache_table_bytes / 1024,
            "decoder_plan": plan.describe()}


def _table_dtype_staged(capacity: float = 0.6) -> dict:
    """f32 vs int8 value-table staged bytes — the table-DTYPE analogue of
    the FWP compaction ratio above, from the REAL plan accounting
    (``MSDAPlan.table_bytes_for_rows`` reads the itemsize from the plan's
    resolved table dtype; int8 adds one per-channel f32 scale row). Two
    ratios: the pure table (what every staging/scatter actually moves)
    and the full cache including the dtype-independent int32 pix2slot
    indirection (what a decode session holds resident)."""
    import dataclasses

    from repro.core.msdeform_attn import MSDeformAttnConfig
    from repro.msda import make_plan

    cfg = MSDeformAttnConfig(
        d_model=256, n_heads=8, fwp_mode="compact", fwp_capacity=capacity,
        range_narrow=tuple(float(r) for r in RANGES))
    plans = {d: make_plan(dataclasses.replace(cfg, table_dtype=d), LEVELS,
                          backend="jnp_gather", n_queries=N_QUERIES,
                          n_consumers=N_DEC_LAYERS)
             for d in ("float32", "int8")}
    from repro.core.fwp import level_capacities
    plan_rows = sum(level_capacities(LEVELS, capacity)) + 1  # + sentinel
    tbl = {d: p.table_bytes_for_rows(plan_rows, with_indirection=False)
           for d, p in plans.items()}
    full = {d: p.cache_table_bytes for d, p in plans.items()}
    return {"table_f32_kb": tbl["float32"] / 1024,
            "table_int8_kb": tbl["int8"] / 1024,
            "table_dtype_ratio": tbl["float32"] / tbl["int8"],
            "cache_f32_kb": full["float32"] / 1024,
            "cache_int8_kb": full["int8"] / 1024,
            "cache_dtype_ratio": full["float32"] / full["int8"]}


def _ordering_staged(capacity: float = 0.6,
                     n_queries: int = N_QUERIES) -> dict:
    """MEASURED per-tile staged-window bytes under cache-local query
    ordering (repro/msda/ordering.py) on the paper 4-level shape.

    Decode queries arrive in learned-query order — spatially arbitrary —
    so each tile of ``tile_q`` queries spans reference points scattered
    over the whole image and its per-level staging window degenerates
    toward the full level. Sorting the queries by reference point
    (raster order over the dominant level) makes each tile's points
    spatially compact, shrinking the row-span window every tile stages.
    The measurement is the plan's own ``with_measured_tile_window``
    accounting (dense window, the staging worst case the plan's
    VMEM-fit check uses) over ``N_QUERIES`` uniform-random decode
    queries; zorder is reported alongside — it trades the row span this
    full-row staging model pays for against column locality it does not
    credit, which is why raster wins here (see README)."""
    import dataclasses

    import jax

    from repro.core.msdeform_attn import MSDeformAttnConfig
    from repro.msda import make_plan

    cfg = MSDeformAttnConfig(
        d_model=256, n_heads=8, fwp_mode="compact", fwp_capacity=capacity,
        range_narrow=tuple(float(r) for r in RANGES))
    plan = make_plan(cfg, LEVELS, backend="jnp_gather",
                     n_queries=n_queries, n_consumers=N_DEC_LAYERS)
    refs = jax.random.uniform(jax.random.PRNGKey(29), (1, n_queries, 2))
    pm = plan.with_measured_tile_window(refs)        # order fallback: raster
    un_max, un_mean, r_max, r_mean = pm.measured_tilewin
    pz = dataclasses.replace(plan, query_order="zorder") \
        .with_measured_tile_window(refs)
    _, _, z_max, z_mean = pz.measured_tilewin
    return {"ordering_tile_q": plan.tile_q,
            "ordering_queries": n_queries,
            "ordering_unordered_kb": un_mean / 1024,
            "ordering_raster_kb": r_mean / 1024,
            "ordering_ratio": un_mean / max(r_mean, 1),
            "ordering_max_ratio": un_max / max(r_max, 1),
            "ordering_zorder_kb": z_mean / 1024,
            "ordering_zorder_ratio": un_mean / max(z_mean, 1),
            "ordering_plan": pm.describe()}


def _stream_staged(n_frames: int = 32, capacity: float = 0.6) -> dict:
    """MEASURED frame-level reuse: the drifting-scene stream through the
    real :class:`~repro.stream.TemporalCacheManager`.

    Unlike the decoder section's by-construction layer ratio, this one is
    a measurement: a synthetic scene (static background + a 1-row object
    marching down every level) is diffed at tile granularity and only the
    dirty slots are re-projected — how many tiles a moving object
    actually dirties, and how often the keep-mask hysteresis forces a
    full rebuild, decide the ratio. The EMA is fed a synthetic sampling
    frequency (per-pixel feature magnitude — no decoder in the loop
    here; the end-to-end feedback path runs in examples/detr_stream.py),
    so keep transitions are exercised too. Wall-time evidence is the
    ``msda_stream_rebuild`` vs ``msda_stream_incremental`` micro rows."""
    import jax.numpy as jnp

    from repro.core.msdeform_attn import MSDeformAttnConfig
    from repro.core.msdeform_attn import init_msdeform_attn
    import jax
    from repro.msda import make_plan
    from repro.stream import StreamConfig, TemporalCacheManager, drifting_scene

    levels = ((16, 20), (8, 10), (4, 5), (2, 3))
    d = 64
    cfg = MSDeformAttnConfig(d_model=d, n_heads=4, fwp_mode="compact",
                             fwp_capacity=capacity,
                             range_narrow=(8.0, 6.0, 4.0, 3.0))
    plan = make_plan(cfg, levels, backend="jnp_gather", n_queries=32,
                     n_consumers=N_DEC_LAYERS)
    params = init_msdeform_attn(jax.random.PRNGKey(11), cfg)
    mgr = TemporalCacheManager(
        plan, {k: params[k] for k in ("value_w", "value_b")},
        StreamConfig(tile_rows=1, delta_threshold=1e-4, update_frac=0.3),
        batch=1)
    for x in drifting_scene(17, levels, d, n_frames):
        mgr.step(x)
        mgr.observe(jnp.linalg.norm(jnp.asarray(x), axis=-1))
    r = mgr.report()
    return {"stream_frames": r["frames"],
            "stream_rebuild_frames": r["rebuild_frames"],
            "stream_incremental_frames": r["incremental_frames"],
            "stream_update_rows": r["update_rows"],
            "stream_slots": r["n_slots"],
            "stream_rebuild_kb_frame": r["full_bytes_per_frame"] / 1024,
            "stream_incremental_kb_frame":
                r["incremental_bytes_per_frame"] / 1024,
            "stream_staged_total_kb": r["staged_bytes_total"] / 1024,
            "stream_rebuild_total_kb": r["rebuild_bytes_total"] / 1024,
            "stream_bytes_ratio": r["bytes_ratio"]}


if __name__ == "__main__":
    r = report()
    for row in r["levels"]:
        print(row)
    print(f"total VMEM: {r['total_vmem_full_kb']:.0f} KB -> "
          f"{r['total_vmem_window_kb']:.0f} KB ({r['total_ratio']:.1f}x)")
    print(f"msp staged/step: dense {r['msp_staged_dense_kb']:.0f} KB -> "
          f"compact {r['msp_staged_compact_kb']:.0f} KB "
          f"({r['msp_compact_ratio']:.2f}x)")
    print(f"decoder ({r['decoder_layers']} layers): rebuild-per-layer "
          f"{r['decoder_rebuild_kb']:.0f} KB -> build-once "
          f"{r['decoder_cache_once_kb']:.0f} KB "
          f"({r['decoder_reuse_ratio']:.1f}x by construction; compact "
          f"build {r['decoder_cache_once_kb']:.0f} KB vs dense "
          f"{r['decoder_cache_dense_kb']:.0f} KB is the measurable part; "
          f"wall-time: msda_decoder6_* micro rows)")
    print(f"  {r['decoder_plan']}")
    print(f"table dtype: f32 {r['table_f32_kb']:.0f} KB -> int8 "
          f"{r['table_int8_kb']:.0f} KB staged per build "
          f"({r['table_dtype_ratio']:.2f}x; with pix2slot indirection "
          f"{r['cache_f32_kb']:.0f} KB -> {r['cache_int8_kb']:.0f} KB, "
          f"{r['cache_dtype_ratio']:.2f}x)")
    print(f"query ordering ({r['ordering_queries']} decode queries, "
          f"tile_q={r['ordering_tile_q']}, MEASURED): window/tile "
          f"{r['ordering_unordered_kb']:.0f} KB unordered -> "
          f"{r['ordering_raster_kb']:.0f} KB raster "
          f"({r['ordering_ratio']:.2f}x mean, "
          f"{r['ordering_max_ratio']:.2f}x max; zorder "
          f"{r['ordering_zorder_kb']:.0f} KB, "
          f"{r['ordering_zorder_ratio']:.2f}x)")
    print(f"  {r['ordering_plan']}")
    print(f"stream ({r['stream_frames']} drifting-scene frames, MEASURED): "
          f"rebuild-per-frame {r['stream_rebuild_total_kb']:.0f} KB -> "
          f"incremental {r['stream_staged_total_kb']:.0f} KB "
          f"({r['stream_bytes_ratio']:.2f}x; "
          f"{r['stream_incremental_frames']}/{r['stream_frames']} frames "
          f"incremental at <= {r['stream_update_rows']}/{r['stream_slots']} "
          f"rows, {r['stream_rebuild_frames']} rebuilds incl. keep "
          f"transitions)")
