"""Benchmark harness — one entry per paper table/figure.

  fig6a/fig6b  accuracy + pruning ratios   (trained toy detector)
  fig7a        bank-conflict simulator     (inter- vs intra-level parallel)
  fig7b/fig8   MSGS memory-energy model    (fusion + fmap reuse)
  fig9/table1  platform comparison analogue (roofline from dry-run)
  micro        kernel wall-time micro-benches (CPU interpret, structural)

Prints ``name,us_per_call,derived`` CSV rows at the end."""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig6,fig7a,fig7b,fig9,fmap_reuse,"
                         "micro,decoder,serve")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write machine-readable rows "
                         "[{name, us_per_call, derived}, ...] to PATH "
                         "(for BENCH_*.json perf tracking)")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    rows: list[tuple[str, float, str]] = []
    results: dict = {}

    def want(name: str) -> bool:
        return only is None or name in only

    if want("fig7a"):
        from benchmarks.bank_sim import simulate
        t0 = time.perf_counter()
        r = simulate()
        dt = (time.perf_counter() - t0) * 1e6
        results["fig7a_bank_sim"] = r
        rows.append(("fig7a_inter_vs_intra_throughput", dt,
                     f"ratio={r['throughput_ratio']:.2f}x "
                     f"(paper 3.06x), conflict_free={r['inter_conflict_free']}"))
        print(f"[fig7a] inter/intra throughput ratio "
              f"{r['throughput_ratio']:.2f}x (paper: 3.06x); "
              f"inter-level conflict-free: {r['inter_conflict_free']}")

    if want("fig7b"):
        from benchmarks.energy_model import model_energy
        t0 = time.perf_counter()
        e = model_energy()
        dt = (time.perf_counter() - t0) * 1e6
        results["fig7b_energy"] = e
        rows.append(("fig7b_energy_model", dt,
                     f"dram_fusion={e['dram_saving_fusion_pct']:.1f}% "
                     f"dram_reuse={e['dram_saving_reuse_pct']:.1f}%"))
        print(f"[fig7b] fusion: DRAM -{e['dram_saving_fusion_pct']:.1f}% "
              f"(paper 73.3%), SRAM -{e['sram_saving_fusion_pct']:.1f}% "
              f"(paper 15.9%)")
        print(f"[fig7b] reuse:  DRAM -{e['dram_saving_reuse_pct']:.1f}% "
              f"(paper 88.2%), SRAM -{e['sram_saving_reuse_pct']:.1f}% "
              f"(paper 22.7%)")

    if want("fig6"):
        from benchmarks.fig6_pruning import run as fig6_run
        t0 = time.perf_counter()
        r = fig6_run()
        dt = (time.perf_counter() - t0) * 1e6
        results["fig6"] = r
        ap_b = r["ap"]["baseline"]
        rows.append(("fig6a_ap_baseline", dt, f"AP={ap_b:.3f}"))
        for name, ap_v in r["ap"].items():
            if name != "baseline":
                rows.append((f"fig6a_ap_{name}", 0.0,
                             f"dAP={ap_v-ap_b:+.4f}"))
        red = r["reduction"]
        rows.append(("fig6b_reductions", 0.0,
                     f"pixels={red['fmap_pixels_pruned_pct']:.0f}% "
                     f"points={red['sampling_points_pruned_pct']:.0f}% "
                     f"compute={red['msgs_compute_saved_pct']:.0f}%"))

    if want("fig9"):
        from benchmarks.fig9_table1 import run as fig9_run
        t0 = time.perf_counter()
        r = fig9_run()
        dt = (time.perf_counter() - t0) * 1e6
        results["fig9_table1"] = r
        if "defa_vs_baseline_speedup" in r:
            rows.append(("fig9_defa_speedup", dt,
                         f"{r['defa_vs_baseline_speedup']:.2f}x roofline"))

    if want("fmap_reuse"):
        from benchmarks.fmap_reuse import report as reuse_report
        t0 = time.perf_counter()
        r = reuse_report()
        dt = (time.perf_counter() - t0) * 1e6
        results["fmap_reuse_vmem"] = r
        rows.append(("fmap_reuse_vmem_ratio", dt,
                     f"window kernel VMEM {r['total_vmem_full_kb']:.0f}KB->"
                     f"{r['total_vmem_window_kb']:.0f}KB "
                     f"({r['total_ratio']:.1f}x smaller working set)"))
        rows.append(("fmap_reuse_decoder_cache", 0.0,
                     f"{r['decoder_layers']}-layer decoder staged bytes "
                     f"{r['decoder_rebuild_kb']:.0f}KB rebuild-per-layer -> "
                     f"{r['decoder_cache_once_kb']:.0f}KB build-once "
                     f"({r['decoder_reuse_ratio']:.1f}x)"))
        rows.append(("fmap_reuse_table_dtype", 0.0,
                     f"value table f32 {r['table_f32_kb']:.0f}KB -> int8 "
                     f"{r['table_int8_kb']:.0f}KB per build "
                     f"({r['table_dtype_ratio']:.2f}x staged-bytes)"))
        rows.append(("fmap_reuse_stream", 0.0,
                     f"{r['stream_frames']}-frame drifting scene staged "
                     f"bytes {r['stream_rebuild_total_kb']:.0f}KB "
                     f"rebuild-per-frame -> "
                     f"{r['stream_staged_total_kb']:.0f}KB incremental "
                     f"({r['stream_bytes_ratio']:.2f}x measured, "
                     f"{r['stream_rebuild_frames']} rebuild frames)"))
        print(f"[fmap-reuse] windowed kernel working set: "
              f"{r['total_vmem_full_kb']:.0f} KB -> "
              f"{r['total_vmem_window_kb']:.0f} KB ({r['total_ratio']:.1f}x)")
        print(f"[fmap-reuse] decoder ValueCache ({r['decoder_layers']} "
              f"layers): {r['decoder_rebuild_kb']:.0f} KB rebuild -> "
              f"{r['decoder_cache_once_kb']:.0f} KB build-once "
              f"({r['decoder_reuse_ratio']:.1f}x)")
        print(f"[fmap-reuse] table dtype: f32 {r['table_f32_kb']:.0f} KB -> "
              f"int8 {r['table_int8_kb']:.0f} KB per build "
              f"({r['table_dtype_ratio']:.2f}x staged-bytes)")
        print(f"[fmap-reuse] streaming ({r['stream_frames']} frames, "
              f"measured): {r['stream_rebuild_total_kb']:.0f} KB "
              f"rebuild-per-frame -> {r['stream_staged_total_kb']:.0f} KB "
              f"incremental ({r['stream_bytes_ratio']:.2f}x)")

    if want("decoder"):
        from benchmarks.detr_toy import (eval_ap, train_toy_decoder_detector,
                                         with_attn)
        t0 = time.perf_counter()
        dcfg, dparams = train_toy_decoder_detector()
        ap_dec = eval_ap(dcfg, dparams)
        dt = (time.perf_counter() - t0) * 1e6
        defa_cfg = with_attn(dcfg, pap_mode="topk", pap_keep=6,
                             fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6,
                             range_narrow=(8.0, 6.0, 4.0, 3.0),
                             act_bits=12, weight_bits=12)
        ap_defa = eval_ap(defa_cfg, dparams)
        results["decoder_head"] = {
            "ap": ap_dec, "ap_defa": ap_defa,
            "n_layers": dcfg.decoder.n_layers,
            "n_queries": dcfg.decoder.n_queries,
        }
        rows.append(("decoder_head_ap", dt,
                     f"AP={ap_dec:.3f} (DEFA stack {ap_defa:.3f}), "
                     f"{dcfg.decoder.n_layers} layers x "
                     f"{dcfg.decoder.n_queries} queries, shared ValueCache"))
        print(f"[decoder] toy synthetic-task AP with the decoder head: "
              f"{ap_dec:.3f} (with the full DEFA stack: {ap_defa:.3f})")

    if want("serve"):
        from benchmarks.serve_sustained import report as serve_report
        t0 = time.perf_counter()
        r = serve_report()
        dt = (time.perf_counter() - t0) * 1e6
        results["serve_sustained"] = r
        cl, ol = r["closed_loop"], r["open_loop"]
        rows.append(("serve_sustained_speedup", dt,
                     f"{cl['speedup']:.2f}x vs single-bucket sync; "
                     f"{ol['rps_per_chip']} req/s/chip, "
                     f"P50 {ol['p50_ms']}ms P99 {ol['p99_ms']}ms"))

    if want("micro"):
        from benchmarks.microbench import run as micro_run
        micro_rows = micro_run()
        rows.extend(micro_rows)
        # per-backend micro rows keyed by name: the CI regression gate
        # (benchmarks/check_regression.py) diffs these against the
        # committed results/benchmarks.json baseline
        results["micro"] = {n: {"us_per_call": round(us, 1), "derived": d}
                            for n, us, d in micro_rows}

    os.makedirs("results", exist_ok=True)
    # merge: a partial run (--only micro) must not clobber the other
    # figures' entries in the committed baseline
    baseline: dict = {}
    if os.path.exists("results/benchmarks.json"):
        try:
            with open("results/benchmarks.json") as f:
                baseline = json.load(f)
        except (OSError, ValueError):
            baseline = {}
    baseline.update(results)
    with open("results/benchmarks.json", "w") as f:
        json.dump(baseline, f, indent=1, default=str)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        payload = {
            "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                     for n, us, d in rows],
            "results": results,
        }
        os.makedirs(os.path.dirname(os.path.abspath(args.json)), exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print(f"[run] wrote {len(payload['rows'])} rows to {args.json}")


if __name__ == "__main__":
    main()
