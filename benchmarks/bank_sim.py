"""Fig. 7a reproduction: cycle-accurate bank-conflict model of MSGS.

TPU VMEM has no software-visible banks, so the paper's inter-level-parallel
claim (3.06x MSGS throughput over intra-level) cannot be measured on-chip;
we reproduce it STRUCTURALLY with an address-replay simulator of the DEFA
memory system: 16 single-port SRAM banks, 4 bilinear samples (16 pixel
reads) issued per cycle.

  * intra-level (Fig. 5a): 4 sampling points from the SAME level; pixels of
    the level interleave across all 16 banks by flat address. Reads to the
    same bank in one group serialize -> stall cycles.
  * inter-level (Fig. 5b, DEFA): 4 points from 4 DIFFERENT levels; each
    level owns 4 banks and the 2x2 "Neighbor Window" maps a bilinear quad's
    corners to the 4 distinct banks: bank = 4*level + (y&1)*2 + (x&1).
    The four corners of any bilinear sample are {x0,x0+1}x{y0,y0+1} — one
    even/odd pair each way — so the quad ALWAYS hits 4 distinct banks and
    the level separation makes the 4 quads disjoint: zero conflicts by
    construction.
"""
from __future__ import annotations

import numpy as np

N_BANKS = 16
POINTS_PER_CYCLE = 4


def _sample_points(rng, n_queries: int, level_shapes, n_points: int,
                   concentration: float = 2.0):
    """Synthesize sampling coordinates: reference points uniform over the
    image, offsets Laplace-ish concentrated near the reference (trained
    MSDeformAttn offsets are small — concentration mimics that)."""
    pts = []
    for li, (h, w) in enumerate(level_shapes):
        ref = rng.uniform(0, 1, (n_queries, 2))
        off = rng.laplace(0, concentration, (n_queries, n_points, 2))
        x = np.clip(ref[:, None, 0] * w + off[..., 0], 0, w - 1.001)
        y = np.clip(ref[:, None, 1] * h + off[..., 1], 0, h - 1.001)
        pts.append(np.stack([x, y], -1))                   # (Q, P, 2)
    return pts                                             # list per level


def _corners(x, y):
    x0 = np.floor(x).astype(np.int64)
    y0 = np.floor(y).astype(np.int64)
    return [(x0, y0), (x0 + 1, y0), (x0, y0 + 1), (x0 + 1, y0 + 1)]


def simulate(n_queries: int = 512, level_shapes=((100, 167), (50, 84),
                                                 (25, 42), (13, 21)),
             n_points: int = 4, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    pts = _sample_points(rng, n_queries, level_shapes, n_points)

    # ---- intra-level: 4 points of one level per cycle-group ---------------
    intra_cycles = 0
    intra_groups = 0
    for li, (h, w) in enumerate(level_shapes):
        p = pts[li].reshape(-1, 2)                         # (Q*P, 2)
        for g in range(0, len(p) - POINTS_PER_CYCLE + 1, POINTS_PER_CYCLE):
            banks = []
            for x, y in zip(p[g:g + 4, 0], p[g:g + 4, 1]):
                for cx, cy in _corners(np.asarray(x), np.asarray(y)):
                    cx = int(np.clip(cx, 0, w - 1))
                    cy = int(np.clip(cy, 0, h - 1))
                    banks.append((cy * w + cx) % N_BANKS)
            counts = np.bincount(banks, minlength=N_BANKS)
            intra_cycles += int(counts.max())              # serialized conflicts
            intra_groups += 1

    # ---- inter-level (DEFA): one point from each of 4 levels per cycle ----
    inter_cycles = 0
    inter_groups = 0
    n_groups = min(p.reshape(-1, 2).shape[0] for p in
                   [pts[li].reshape(-1, 2) for li in range(4)])
    flat = [pts[li].reshape(-1, 2) for li in range(4)]
    for g in range(n_groups):
        banks = []
        for li, (h, w) in enumerate(level_shapes):
            x, y = flat[li][g]
            for cx, cy in _corners(np.asarray(x), np.asarray(y)):
                cx = int(np.clip(cx, 0, w - 1))
                cy = int(np.clip(cy, 0, h - 1))
                banks.append(4 * li + (cy & 1) * 2 + (cx & 1))
        counts = np.bincount(banks, minlength=N_BANKS)
        inter_cycles += int(counts.max())
        inter_groups += 1

    intra_tp = intra_groups * POINTS_PER_CYCLE / max(intra_cycles, 1)
    inter_tp = inter_groups * POINTS_PER_CYCLE / max(inter_cycles, 1)
    return {
        "intra_cycles_per_group": intra_cycles / max(intra_groups, 1),
        "inter_cycles_per_group": inter_cycles / max(inter_groups, 1),
        "throughput_ratio": inter_tp / intra_tp,
        "inter_conflict_free": inter_cycles == inter_groups,
        "paper_claim": 3.06,
    }


if __name__ == "__main__":
    r = simulate()
    print(r)
